//! Property tests: Theorem-1 invariants of the transformation on random
//! graphs.
//!
//! The in-repo `prop` harness (no proptest in the vendored crate set)
//! drives random layered DAGs and random stencil problems through both
//! halo modes and re-verifies every invariant from scratch — the checker
//! itself recomputes availability rather than trusting the derivation.

use imp_latency::graph::TaskKind;
use imp_latency::prop::{check, random_dag, random_stencil, DagParams};
use imp_latency::sim::ExecPlan;
use imp_latency::stencil::heat1d_graph;
use imp_latency::transform::{
    check_schedule, communication_avoiding, superstep_graphs, ScheduleStats, TransformOptions,
};

const MODES: [TransformOptions; 2] =
    [TransformOptions::multilevel(), TransformOptions::level0()];

#[test]
fn random_dags_satisfy_theorem_1() {
    check(120, |rng| {
        let g = random_dag(rng, &DagParams::default());
        for opts in MODES {
            let s = communication_avoiding(&g, opts);
            check_schedule(&g, &s).map_err(|v| format!("{opts:?}: {v}"))?;
        }
        Ok(())
    });
}

#[test]
fn random_dags_coverage_and_redundancy() {
    check(80, |rng| {
        let g = random_dag(rng, &DagParams::default());
        let s = communication_avoiding(&g, TransformOptions::default());
        let st = ScheduleStats::compute(&g, &s);
        // Theorem 1's final remark: the union over-covers L_p.
        if st.executed_tasks < st.graph_tasks {
            return Err(format!(
                "under-covering: executed {} < graph {}",
                st.executed_tasks, st.graph_tasks
            ));
        }
        // Redundancy never exceeds p× the graph (every proc computing
        // everything is the worst case).
        let p = g.num_procs() as usize;
        if st.executed_tasks > st.graph_tasks * p {
            return Err("impossible redundancy".into());
        }
        Ok(())
    });
}

#[test]
fn random_dags_multilevel_never_more_redundant_than_level0() {
    check(60, |rng| {
        let g = random_dag(rng, &DagParams::default());
        let multi = communication_avoiding(&g, MODES[0]);
        let lvl0 = communication_avoiding(&g, MODES[1]);
        if multi.total_computed() > lvl0.total_computed() {
            return Err(format!(
                "multilevel {} > level0 {}",
                multi.total_computed(),
                lvl0.total_computed()
            ));
        }
        Ok(())
    });
}

#[test]
fn random_stencils_satisfy_theorem_1() {
    check(60, |rng| {
        let (n, m, p, r) = random_stencil(rng);
        let g = imp_latency::stencil::heat1d_program(n, m, p, r).unroll();
        for opts in MODES {
            let s = communication_avoiding(&g, opts);
            check_schedule(&g, &s).map_err(|v| format!("n={n} m={m} p={p} r={r}: {v}"))?;
        }
        Ok(())
    });
}

#[test]
fn random_stencil_l1_sets_are_pred_closed() {
    // The key lemma behind Theorem 1: preds(L1) ⊆ L0 ∪ L1 — phase 1 can
    // run with zero synchronization.
    check(40, |rng| {
        let (n, m, p, r) = random_stencil(rng);
        let g = imp_latency::stencil::heat1d_program(n, m, p, r).unroll();
        let s = communication_avoiding(&g, TransformOptions::default());
        for ps in &s.per_proc {
            let avail: std::collections::HashSet<u32> =
                ps.l0.iter().chain(ps.l1.iter()).copied().collect();
            for &t in &ps.l1 {
                for &pr in g.preds(imp_latency::graph::TaskId(t)) {
                    if !avail.contains(&pr) {
                        return Err(format!("{}: pred t{pr} of L1 task t{t} escapes L0∪L1", ps.proc));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn random_blocking_supersteps_well_formed() {
    check(50, |rng| {
        let (n, m, p, _) = random_stencil(rng);
        let g = heat1d_graph(n, m.max(2), p);
        let b = 1 + (rng.below(m.max(2) as u64) as u32);
        for ss in superstep_graphs(&g, b).map_err(|e| e)? {
            ss.validate_against(&g).map_err(|e| format!("b={b}: {e}"))?;
            let s = communication_avoiding(&ss.graph, TransformOptions::default());
            check_schedule(&ss.graph, &s).map_err(|v| format!("b={b}: {v}"))?;
        }
        Ok(())
    });
}

#[test]
fn sends_are_never_empty_or_duplicated() {
    check(60, |rng| {
        let g = random_dag(rng, &DagParams::default());
        let s = communication_avoiding(&g, TransformOptions::default());
        for ps in &s.per_proc {
            for m in &ps.send {
                if m.tasks.is_empty() {
                    return Err(format!("{}: empty message to {}", ps.proc, m.peer));
                }
                let mut d = m.tasks.clone();
                d.dedup();
                if d.len() != m.tasks.len() {
                    return Err(format!("{}: duplicate values to {}", ps.proc, m.peer));
                }
                if m.peer == ps.proc {
                    return Err(format!("{}: self-send", ps.proc));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn received_values_are_actually_needed() {
    // No gratuitous traffic: every received value is a predecessor of
    // something the receiver computes (or a task it owns).
    check(60, |rng| {
        let g = random_dag(rng, &DagParams::default());
        let s = communication_avoiding(&g, TransformOptions::default());
        for ps in &s.per_proc {
            let mut needed: std::collections::HashSet<u32> = std::collections::HashSet::new();
            for &t in ps.l3.iter().chain(ps.l4.iter()) {
                for &pr in g.preds(imp_latency::graph::TaskId(t)) {
                    needed.insert(pr);
                }
            }
            for t in g.tasks() {
                if g.owner(t) == ps.proc {
                    needed.insert(t.0);
                }
            }
            for m in &ps.recv {
                for &t in &m.tasks {
                    if !needed.contains(&t) {
                        return Err(format!("{}: receives unneeded t{t}", ps.proc));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn plans_from_random_graphs_are_consistent() {
    check(40, |rng| {
        let g = random_dag(rng, &DagParams::default());
        let naive = ExecPlan::naive(&g);
        // Naive executes exactly the graph's compute tasks.
        if naive.executed_tasks() != g.num_compute_tasks() {
            return Err("naive plan task count".into());
        }
        // CA plans (b = whole depth) execute at least as many.
        let depth = g.num_levels().saturating_sub(1).max(1);
        let ca = ExecPlan::ca(&g, depth, TransformOptions::default()).map_err(|e| e)?;
        if ca.executed_tasks() < g.num_compute_tasks() {
            return Err("ca plan under-executes".into());
        }
        Ok(())
    });
}

#[test]
fn input_only_tasks_never_execute() {
    check(30, |rng| {
        let g = random_dag(rng, &DagParams::default());
        let s = communication_avoiding(&g, TransformOptions::default());
        for ps in &s.per_proc {
            for set in [&ps.l1, &ps.l2, &ps.l3, &ps.l4] {
                for &t in set.iter() {
                    if g.kind(imp_latency::graph::TaskId(t)) == TaskKind::Input {
                        return Err(format!("input t{t} scheduled for compute"));
                    }
                }
            }
        }
        Ok(())
    });
}
