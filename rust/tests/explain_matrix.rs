//! The causal-profiling matrix: the blame decomposition's exactness
//! claims must hold on every workload the crate ships, every strategy,
//! every wire model, and several processor counts — not just the smoke
//! preset.
//!
//! For each cell the test pins four contracts:
//!
//! 1. **Bit-exact sums** — `Blame::verify`: the plan-level terms and
//!    every per-proc decomposition sum back to the observed makespan
//!    to the last bit, and the observed critical path tiles
//!    `[0, makespan]` with no gap or overlap.
//! 2. **Soundness** — the observed makespan never undercuts the
//!    analytic critical-path bound, and equals it bit-for-bit on
//!    exact wires ([`CrossCheck`]).
//! 3. **Non-interference** — a provenance-recording run returns the
//!    same makespan, bit-for-bit, as the plain compiled engine on the
//!    same effective machine and wire.
//! 4. **Category sanity** — at α = 0 nothing can be blamed on
//!    latency: the exposed-latency term is exactly zero.

use imp_latency::explain::{explain_input, BlameSummary, PlanDiff};
use imp_latency::pipeline::{
    ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy, Workload,
};
use imp_latency::sim::{simulate_compiled, EngineScratch, Machine, NetworkKind};
use imp_latency::stencil::CsrMatrix;

/// Drive one workload through strategies × procs × α × wires.
fn exercise<W: Workload + Clone>(workload: W, procs_list: &[u32]) {
    let mut scratch = EngineScratch::new();
    for &procs in procs_list {
        for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
            let mut p = Pipeline::new(workload.clone()).procs(procs).strategy(strategy);
            if strategy == Strategy::Ca {
                p = p.block(2);
            }
            let name = workload.name();
            let ctx = format!("{name} p={procs} {strategy:?}");
            let t = p.transform().unwrap_or_else(|e| panic!("{ctx}: {e}"));
            let input = t.sweep_input();
            for alpha in [0.0, 50.0] {
                let base = Machine::new(procs, 2, alpha, 0.5, 1.0);
                for kind in NetworkKind::all_default() {
                    let ctx = format!("{ctx}/{}/α={alpha}", kind.label());
                    let e = explain_input(&input, &base, kind, &mut scratch)
                        .unwrap_or_else(|err| panic!("{ctx}: {err}"));

                    // 1. Bit-exact sums and path tiling.
                    e.blame.verify().unwrap_or_else(|err| panic!("{ctx}: {err}"));

                    // 2. Observed ≥ bound, bit-equal on exact wires.
                    assert!(
                        e.cross.ok(),
                        "{ctx}: observed {} vs bound {} (exact wire: {})",
                        e.cross.observed,
                        e.cross.bound,
                        e.cross.exact_wire
                    );

                    // 3. Provenance never feeds back into the timing:
                    // the plain engine on the same effective machine
                    // reproduces the observed makespan bit-for-bit.
                    let mach = Machine::new(
                        input.plan.per_proc.len() as u32,
                        base.threads,
                        base.alpha,
                        base.beta * input.words_per_value as f64,
                        base.gamma,
                    );
                    let mut net = kind.build_for(&mach, input.layout.as_ref());
                    let plain =
                        simulate_compiled(&input.compiled, &mach, net.as_mut(), &mut scratch, false)
                            .unwrap_or_else(|err| panic!("{ctx}: {err:?}"));
                    assert_eq!(
                        plain.total_time.to_bits(),
                        e.blame.makespan.to_bits(),
                        "{ctx}: observed run drifted from the plain engine"
                    );

                    // 4. No α, no latency blame.
                    if alpha == 0.0 && matches!(kind, NetworkKind::AlphaBeta) {
                        assert_eq!(
                            e.blame.plan.exposed_latency(),
                            0.0,
                            "{ctx}: latency blamed on an α=0 wire"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn heat1d_explain_matrix() {
    exercise(Heat1d::new(48, 6), &[2, 4]);
}

#[test]
fn heat2d_explain_matrix() {
    exercise(Heat2d { h: 8, w: 8, steps: 4 }, &[2, 4]);
}

#[test]
fn moore2d_explain_matrix() {
    exercise(Moore2d { h: 8, w: 8, steps: 4 }, &[2, 4]);
}

#[test]
fn spmv_explain_matrix() {
    exercise(Spmv { matrix: CsrMatrix::laplace2d(6, 6), steps: 4 }, &[2, 4]);
}

#[test]
fn cg_explain_matrix() {
    exercise(ConjugateGradient { unknowns: 24, iters: 2 }, &[2, 3]);
}

/// The paper's §3 claim as an end-to-end assertion: in the
/// latency-dominated regime the CA transform strictly reduces the
/// exposed latency on the heat1d *observed* critical path, and the
/// differential explanation reports the move.
#[test]
fn ca_moves_latency_off_the_observed_critical_path() {
    let mut scratch = EngineScratch::new();
    let base = Machine::new(4, 2, 500.0, 0.1, 1.0);
    let mk = |strategy: Strategy, block: Option<u32>| {
        let mut p = Pipeline::new(Heat1d::new(256, 16)).procs(4).strategy(strategy);
        if let Some(b) = block {
            p = p.block(b);
        }
        p.transform().expect("transforms").sweep_input()
    };
    let naive = explain_input(&mk(Strategy::Naive, None), &base, NetworkKind::AlphaBeta, &mut scratch)
        .expect("naive explains");
    let ca = explain_input(&mk(Strategy::Ca, Some(8)), &base, NetworkKind::AlphaBeta, &mut scratch)
        .expect("ca explains");
    let d = PlanDiff::between(
        BlameSummary::from_blame("naive", &naive.blame),
        BlameSummary::from_blame("ca(b=8)", &ca.blame),
    );
    assert!(
        d.latency_moved_off_path() > 0.0,
        "CA must strictly reduce exposed latency at α=500: naive {} vs ca {}",
        naive.blame.plan.exposed_latency(),
        ca.blame.plan.exposed_latency()
    );
    assert!(d.speedup() > 1.0, "CA must beat naive at α=500: {}", d.summary());
    assert!(d.summary().contains("ca(b=8) vs naive"), "{}", d.summary());
}
