//! Integration matrix for the `tune` subsystem: the autotuner must
//! never lose to the baselines it searched over, must reproduce the
//! §2.1 oracle where the closed form is valid, must beat it where the
//! wire breaks the closed form's assumptions, and must serve repeat
//! problems from the cache without touching the engine.

use imp_latency::cost::CostModel;
use imp_latency::pipeline::{
    ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy, Workload,
};
use imp_latency::sim::{Machine, NetworkKind};
use imp_latency::stencil::CsrMatrix;
use imp_latency::transform::select_b;
use imp_latency::tune::{Candidate, Tuner, TuningSpace};

/// Tune `w` on every wire model at `procs` processors and assert the
/// engine-scored winner is never slower (beyond the 1% plateau
/// tolerance) than either the naive baseline or the §2.1 closed-form
/// pick, both evaluated by the same engine.
fn assert_tuned_dominates<W: Workload + Clone>(w: W, procs: u32) {
    let mach = Machine::new(procs, 4, 50.0, 0.1, 1.0);
    for kind in NetworkKind::all_default() {
        let mut tuner = Tuner::exhaustive();
        let base = Pipeline::new(w.clone()).procs(procs).machine(mach).network(kind);
        let tuned = base.clone().autotune(&mut tuner).unwrap_or_else(|e| {
            panic!("{}@{} p={procs}: {e}", w.name(), kind.label())
        });
        let report = tuned.tune_report().unwrap();
        let tag = format!("{}@{} p={procs}", w.name(), kind.label());

        // Never slower than naive (which the tuner itself scored).
        assert!(
            report.makespan <= report.naive_makespan * 1.01 + 1e-9,
            "{tag}: tuned {} vs naive {}",
            report.makespan,
            report.naive_makespan
        );

        // Never slower than the closed-form fixed-b pick, re-scored by
        // the engine under the same machine + wire.
        let depth = tuned.graph.num_levels().saturating_sub(1).max(1);
        if let Some(b) = TuningSpace::closed_form_seed(&mach, depth) {
            if let Ok(fixed) = base.clone().block(b).transform() {
                let fixed_time = fixed.simulate_configured().unwrap().time.value();
                assert!(
                    report.makespan <= fixed_time * 1.01 + 1e-9,
                    "{tag}: tuned {} vs closed-form b={b} {}",
                    report.makespan,
                    fixed_time
                );
            }
        }
        assert!(report.engine_runs > 0, "{tag}");
    }
}

#[test]
fn tuner_never_slower_than_naive_or_closed_form_heat1d() {
    for procs in [2u32, 4] {
        assert_tuned_dominates(Heat1d::new(48, 6), procs);
    }
}

#[test]
fn tuner_never_slower_than_naive_or_closed_form_heat2d() {
    for procs in [2u32, 4] {
        assert_tuned_dominates(Heat2d { h: 8, w: 8, steps: 4 }, procs);
    }
}

#[test]
fn tuner_never_slower_than_naive_or_closed_form_moore2d() {
    for procs in [2u32, 4] {
        assert_tuned_dominates(Moore2d { h: 8, w: 8, steps: 4 }, procs);
    }
}

#[test]
fn tuner_never_slower_than_naive_or_closed_form_spmv() {
    for procs in [2u32, 4] {
        assert_tuned_dominates(Spmv { matrix: CsrMatrix::laplace2d(4, 4), steps: 3 }, procs);
    }
}

#[test]
fn tuner_never_slower_than_naive_or_closed_form_cg() {
    for procs in [2u32, 4] {
        assert_tuned_dominates(ConjugateGradient { unknowns: 12, iters: 2 }, procs);
    }
}

/// Acceptance: on the ideal α/β wire — where the paper's analysis is
/// exact — the engine-backed tuner lands on the same block factor as
/// the §2.1 `select_b` oracle.  Latency dominates by two orders of
/// magnitude, so both pickers see an unambiguous optimum at the
/// whole-depth superstep.
#[test]
fn alphabeta_autotune_reproduces_select_b() {
    let (n, m, p) = (1024u64, 32u32, 8u32);
    let mach = Machine::new(p, 16, 10_000.0, 0.1, 1.0);
    let oracle = select_b(n, m, &mach, &[1, 2, 4, 8, 16, 32]).unwrap();
    assert_eq!(oracle.chosen_b, 32, "{oracle:?}");

    let mut tuner = Tuner::exhaustive();
    let tuned = Pipeline::new(Heat1d::new(n, m))
        .procs(p)
        .machine(mach)
        .autotune(&mut tuner)
        .unwrap();
    let chosen = tuned.tune_report().unwrap().chosen;
    assert_eq!(chosen.strategy, Strategy::Ca, "{chosen:?}");
    assert_eq!(chosen.block, Some(oracle.chosen_b), "{chosen:?} vs {oracle:?}");
    assert_eq!(tuned.block(), Some(oracle.chosen_b));
}

/// Acceptance: under NIC contention with ample per-level compute the
/// closed form (which can model neither the contention nor the overlap)
/// still prescribes CA at b = sqrt(α/γ_eff) = 8, but the engine sees
/// that the per-level overlap already hides the entire message cost —
/// redundant CA work can only lose.  The tuner must pick a different
/// configuration than the closed form, and not pay for it.
#[test]
fn contended_network_tuner_diverges_from_closed_form() {
    let (n, m, p) = (1024u64, 32u32, 4u32);
    let mach = Machine::new(p, 1, 64.0, 0.1, 1.0);
    let model = CostModel::from_machine(n, m, &mach);
    let model_b = model.optimal_b(32);
    assert_eq!(model_b, 8, "test premise: closed form picks 8");

    let mut tuner = Tuner::exhaustive();
    let base = Pipeline::new(Heat1d::new(n, m))
        .procs(p)
        .machine(mach)
        .network(NetworkKind::Contended);
    let tuned = base.clone().autotune(&mut tuner).unwrap();
    let report = tuned.tune_report().unwrap();
    let chosen = report.chosen;

    // The closed-form candidate was in the searched space…
    assert!(
        report.evaluated.iter().any(|(c, _)| *c == Candidate::ca(model_b, p)),
        "space must contain the closed-form pick: {:?}",
        report.evaluated
    );
    // …and lost: the tuner demonstrably picks a different config.
    assert_ne!(chosen, Candidate::ca(model_b, p), "{report:?}");
    // Not by accident but on merit — never slower than the closed form
    // under this wire.
    let fixed_time = base
        .block(model_b)
        .transform()
        .unwrap()
        .simulate_configured()
        .unwrap()
        .time
        .value();
    assert!(
        report.makespan <= fixed_time * 1.01 + 1e-9,
        "tuned {} vs closed-form {}",
        report.makespan,
        fixed_time
    );
}

/// Acceptance: a second `autotune()` with the same key is served from
/// the cache — hit counted, zero engine runs — including across tuner
/// instances through the persistent JSON store.
#[test]
fn cache_serves_repeat_autotune_without_engine_runs() {
    let mach = Machine::high_latency(2, 4);
    let path = std::env::temp_dir().join(format!(
        "imp_latency_tune_matrix_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let first_chosen;
    {
        let mut tuner = Tuner::exhaustive().with_cache_path(&path);
        let t = Pipeline::new(Heat1d::new(96, 8))
            .procs(2)
            .machine(mach)
            .autotune(&mut tuner)
            .unwrap();
        let r = t.tune_report().unwrap();
        assert!(!r.cache_hit && r.engine_runs > 0);
        assert_eq!((tuner.cache.hits(), tuner.cache.misses()), (0, 1));
        first_chosen = r.chosen;

        // Same tuner, same problem: hit, no engine runs.
        let again = Pipeline::new(Heat1d::new(96, 8))
            .procs(2)
            .machine(mach)
            .autotune(&mut tuner)
            .unwrap();
        let r2 = again.tune_report().unwrap();
        assert!(r2.cache_hit);
        assert_eq!(r2.engine_runs, 0);
        assert_eq!(r2.chosen, first_chosen);
        assert_eq!((tuner.cache.hits(), tuner.cache.misses()), (1, 1));
    }

    // Fresh tuner, same backing file: still a hit, still no engine.
    let mut tuner = Tuner::exhaustive().with_cache_path(&path);
    assert_eq!(tuner.cache.len(), 1);
    let t = Pipeline::new(Heat1d::new(96, 8))
        .procs(2)
        .machine(mach)
        .autotune(&mut tuner)
        .unwrap();
    let r = t.tune_report().unwrap();
    assert!(r.cache_hit);
    assert_eq!(r.engine_runs, 0);
    assert_eq!(r.chosen, first_chosen);
    // A different problem still misses (key includes the signature).
    let other = Pipeline::new(Heat1d::new(128, 8))
        .procs(2)
        .machine(mach)
        .autotune(&mut tuner)
        .unwrap();
    assert!(!other.tune_report().unwrap().cache_hit);
    let _ = std::fs::remove_file(&path);
}
