//! Integration matrix for the `serve` daemon: the serving claims must
//! hold end to end — warm cache hits cost zero engine work, concurrent
//! duplicates collapse onto exactly one search (in-process, across
//! threads, and across processes), compatible simulate requests share
//! one coalesced grid, overload is shed explicitly, corrupt cache
//! shards degrade to a miss for that shard alone, and the scripted
//! smoke mix proves the daemon gets faster as the cache warms.

use std::io::Cursor;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};

use imp_latency::config::Config;
use imp_latency::pipeline::{Heat1d, Pipeline};
use imp_latency::serve::protocol::parse_flat_object;
use imp_latency::serve::{
    run_smoke, CacheOutcome, Payload, Request, RequestError, Response, ServeConfig, Server,
};
use imp_latency::sim::{compile_count, Machine, NetworkKind};
use imp_latency::tune::{search_from_tag, tune_pipeline, TuneReport, Tuner, TuningCache};

/// Per-test scratch directory (unique per test name + process).
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imp_serve_matrix_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn server_with(cache_dir: Option<PathBuf>, workers: usize, max_in_flight: usize) -> Server {
    Server::new(ServeConfig {
        workers,
        max_in_flight,
        reserve: 0,
        budget: None,
        cache_dir,
        slots: 4,
        search: "exhaustive".to_string(),
    })
}

/// A small tune request; `n`/`h`/`w` cover heat1d and heat2d alike.
fn tune_line(id: &str, workload: &str, alpha: f64) -> String {
    format!(
        "{{\"id\": \"{id}\", \"op\": \"tune\", \"workload\": \"{workload}\", \"n\": 96, \
         \"m\": 6, \"h\": 8, \"w\": 8, \"p\": 2, \"threads\": 4, \"alpha\": {alpha}, \
         \"beta\": 0.1, \"gamma\": 1.0}}"
    )
}

fn sim_line(id: &str, strategy: &str, alpha: f64) -> String {
    format!(
        "{{\"id\": \"{id}\", \"op\": \"simulate\", \"workload\": \"heat1d\", \"n\": 96, \
         \"m\": 6, \"p\": 2, \"threads\": 4, \"alpha\": {alpha}, \"beta\": 0.1, \
         \"gamma\": 1.0, \"strategy\": \"{strategy}\"}}"
    )
}

fn wave(server: &Server, lines: &[String]) -> Vec<Response> {
    server.run_wave(lines.iter().map(|l| Request::parse(l)).collect())
}

fn tune_outcome(r: &Response) -> (CacheOutcome, usize) {
    match &r.result {
        Ok(Payload::Tune { cache, engine_runs, .. }) => (*cache, *engine_runs),
        other => panic!("expected a tune payload for {:?}, got {other:?}", r.id),
    }
}

#[test]
fn cold_tune_searches_then_warm_hits_are_engine_free() {
    let dir = tmp("cold_warm");
    let server = server_with(Some(dir.clone()), 1, 16);

    let cold = wave(&server, &[tune_line("cold", "heat1d", 500.0)]);
    let (outcome, runs) = tune_outcome(&cold[0]);
    assert_eq!(outcome, CacheOutcome::Miss);
    assert!(runs > 0, "a cold tune must run the engine");

    // Single-request waves run inline on this thread, so the
    // thread-local compile counter proves the warm path never touches
    // the engine — no simulations, not even a plan lowering.
    let compiles_before = compile_count();
    let warm = wave(&server, &[tune_line("warm", "heat1d", 500.0)]);
    let (outcome, runs) = tune_outcome(&warm[0]);
    assert_eq!(outcome, CacheOutcome::Hit);
    assert_eq!(runs, 0);
    assert_eq!(compile_count(), compiles_before, "warm hit compiled a plan");
    assert_eq!(server.stats().searches.load(Ordering::Relaxed), 1);
    assert_eq!(server.stats().warm_hits.load(Ordering::Relaxed), 1);

    // The verdict survives the process: a fresh server on the same
    // shard directory answers from disk.
    let reborn = server_with(Some(dir.clone()), 1, 16);
    let warm = wave(&reborn, &[tune_line("reborn", "heat1d", 500.0)]);
    let (outcome, runs) = tune_outcome(&warm[0]);
    assert_eq!(outcome, CacheOutcome::Hit);
    assert_eq!(runs, 0);
    assert_eq!(reborn.stats().searches.load(Ordering::Relaxed), 0);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_duplicates_cost_exactly_one_search() {
    let server = server_with(None, 4, 16);
    let lines: Vec<String> =
        (0..6).map(|i| tune_line(&format!("dup{i}"), "heat1d", 333.0)).collect();
    let responses = wave(&server, &lines);
    assert_eq!(responses.len(), 6);

    let mut searched = 0;
    let mut free = 0;
    for r in &responses {
        let (outcome, runs) = tune_outcome(r);
        match outcome {
            CacheOutcome::Miss => {
                searched += 1;
                assert!(runs > 0, "{:?}: the miss is the one that searched", r.id);
            }
            CacheOutcome::Hit | CacheOutcome::Deduped => {
                free += 1;
                assert_eq!(runs, 0, "{:?}: followers must not re-run the engine", r.id);
            }
        }
    }
    assert_eq!(searched, 1, "exactly one request leads the search");
    assert_eq!(free, 5);
    assert_eq!(
        server.stats().searches.load(Ordering::Relaxed),
        1,
        "N identical concurrent requests must collapse onto one engine search"
    );
}

/// The tuning problem both sides of the thread/process tests share.
fn probe_pipeline() -> Pipeline<Heat1d> {
    Pipeline::new(Heat1d::new(96, 6))
        .procs(2)
        .machine(Machine::new(2, 4, 200.0, 0.1, 1.0))
        .network(NetworkKind::AlphaBeta)
}

fn probe_tune(dir: &Path) -> TuneReport {
    let mut tuner = Tuner::new(
        search_from_tag("exhaustive").expect("exhaustive search exists"),
        TuningCache::sharded_unloaded(dir),
    );
    tune_pipeline(&probe_pipeline(), &mut tuner).expect("heat1d tunes").report
}

#[test]
fn two_threads_on_one_shard_dir_make_one_search_and_one_hit() {
    let dir = tmp("two_threads");
    let reports: Vec<TuneReport> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2).map(|_| s.spawn(|| probe_tune(&dir))).collect();
        handles.into_iter().map(|h| h.join().expect("no panic")).collect()
    });
    // The shard file lock serialises the two: the loser re-reads the
    // shard under the lock and adopts the winner's verdict.
    assert_eq!(reports.iter().filter(|r| !r.cache_hit).count(), 1, "one search");
    assert_eq!(reports.iter().filter(|r| r.cache_hit).count(), 1, "one hit");
    assert_eq!(reports.iter().filter(|r| r.engine_runs > 0).count(), 1);
    assert_eq!(reports[0].chosen.label(), reports[1].chosen.label());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Child half of the cross-process test: only active when the parent
/// sets `SERVE_MATRIX_CHILD_DIR`; tunes the shared problem against the
/// parent's shard directory and prints a machine-readable verdict.
#[test]
fn child_process_probe() {
    let dir = match std::env::var("SERVE_MATRIX_CHILD_DIR") {
        Ok(dir) => PathBuf::from(dir),
        Err(_) => return,
    };
    let report = probe_tune(&dir);
    println!("CHILD cache_hit={} engine_runs={}", report.cache_hit, report.engine_runs);
}

#[test]
fn two_processes_on_one_shard_dir_make_one_search_and_one_hit() {
    let dir = tmp("two_procs");
    let parent = probe_tune(&dir);
    assert!(!parent.cache_hit && parent.engine_runs > 0, "parent runs the search");

    let exe = std::env::current_exe().expect("test binary path");
    let output = std::process::Command::new(exe)
        .args(["child_process_probe", "--exact", "--nocapture"])
        .env("SERVE_MATRIX_CHILD_DIR", &dir)
        .output()
        .expect("child test process runs");
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(output.status.success(), "child failed:\n{stdout}");
    assert!(
        stdout.contains("CHILD cache_hit=true engine_runs=0"),
        "child must be served from the parent's shard files:\n{stdout}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_shard_is_a_miss_for_that_shard_alone() {
    let dir = tmp("corrupt");
    {
        let server = server_with(Some(dir.clone()), 1, 16);
        let responses = wave(
            &server,
            &[tune_line("a", "heat1d", 250.0), tune_line("b", "heat2d", 250.0)],
        );
        for r in &responses {
            assert_eq!(tune_outcome(r).0, CacheOutcome::Miss);
        }
        server.flush().expect("flush shard files");
    }

    // Distinct workload signatures persist as distinct shard files.
    let mut shards: Vec<PathBuf> = std::fs::read_dir(&dir)
        .expect("shard dir exists")
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false))
        .collect();
    shards.sort();
    assert!(shards.len() >= 2, "expected one shard per workload signature, got {shards:?}");
    std::fs::write(&shards[0], "{ \"version\": garbage, truncated").expect("corrupt one shard");

    // One workload lost its shard (miss → fresh search); the sibling
    // shard still hits.  Neither request errors.
    let server = server_with(Some(dir.clone()), 1, 16);
    let responses = wave(
        &server,
        &[tune_line("a2", "heat1d", 250.0), tune_line("b2", "heat2d", 250.0)],
    );
    let outcomes = [tune_outcome(&responses[0]).0, tune_outcome(&responses[1]).0];
    assert!(
        outcomes.contains(&CacheOutcome::Hit) && outcomes.contains(&CacheOutcome::Miss),
        "expected one hit and one miss, got {outcomes:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compatible_simulations_coalesce_into_one_grid() {
    let server = server_with(None, 2, 16);
    let responses = wave(
        &server,
        &[
            sim_line("s1", "naive", 500.0),
            sim_line("s2", "overlap", 500.0),
            sim_line("s3", "naive", 9.0), // different machine → its own grid
        ],
    );
    for (id, want_batch) in [("s1", 2), ("s2", 2), ("s3", 1)] {
        let r = responses.iter().find(|r| r.id == id).expect(id);
        match &r.result {
            Ok(Payload::Simulate { batch, makespan, .. }) => {
                assert_eq!(*batch, want_batch, "{id}");
                assert!(*makespan > 0.0, "{id}");
            }
            other => panic!("{id}: expected simulate payload, got {other:?}"),
        }
    }
    assert_eq!(server.stats().batches.load(Ordering::Relaxed), 2);
    assert_eq!(server.stats().batch_cells.load(Ordering::Relaxed), 3);
}

#[test]
fn overload_is_shed_and_reported_in_cache_stats() {
    // Limit 0 deterministically admits nothing.
    let server = server_with(None, 1, 0);
    let responses = wave(&server, &[tune_line("over", "heat1d", 123.0)]);
    match &responses[0].result {
        Err(RequestError::Overloaded(_)) => {}
        other => panic!("expected overloaded, got {other:?}"),
    }
    assert!(responses[0].to_json().contains("\"status\": \"overloaded\""));

    let stats = wave(&server, &[String::from("{\"id\": \"st\", \"op\": \"cache-stats\"}")]);
    match &stats[0].result {
        Ok(Payload::CacheStats { shed, in_flight, .. }) => {
            assert_eq!(*shed, 1);
            assert_eq!(*in_flight, 0, "the shed permit must not leak");
        }
        other => panic!("expected cache-stats payload, got {other:?}"),
    }
}

#[test]
fn serve_reader_answers_blank_line_waves_and_honours_stop() {
    let server = server_with(None, 2, 16);
    let stop = AtomicBool::new(false);
    let script = "{\"id\": \"a\", \"op\": \"cache-stats\"}\n\n\
                  {\"id\": \"b\", \"op\": \"cache-stats\"}\n";
    let mut out: Vec<u8> = Vec::new();
    let n = server.serve_reader(Cursor::new(script), &mut out, &stop).expect("reader runs");
    assert_eq!(n, 2);
    let text = String::from_utf8(out).expect("utf8 output");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2);
    for line in &lines {
        // Every response line is parseable by our own wire parser.
        let fields = parse_flat_object(line).expect("valid response line");
        assert!(fields.iter().any(|(k, v)| k == "status" && v == "ok"), "{line}");
    }

    // A raised stop flag ends the session before answering anything.
    stop.store(true, Ordering::SeqCst);
    let mut out: Vec<u8> = Vec::new();
    let n = server
        .serve_reader(Cursor::new("{\"id\": \"x\", \"op\": \"cache-stats\"}\n"), &mut out, &stop)
        .expect("reader stops");
    assert_eq!(n, 0);
    assert!(out.is_empty());
}

#[test]
fn smoke_mix_warms_up_dedupes_and_batches() {
    let dir = tmp("smoke");
    let mut cfg = Config::new();
    cfg.set("workloads", "heat1d");
    cfg.set("networks", "alphabeta");
    cfg.set("n", 96);
    cfg.set("m", 6);
    cfg.set("p", 2);
    cfg.set("threads", 4);
    cfg.set("cache", dir.display().to_string());
    let stop = AtomicBool::new(false);
    let outcome = run_smoke(&cfg, &stop).expect("smoke runs");
    assert!(!outcome.interrupted);

    let cold = outcome.cold.expect("cold phase ran");
    let warm = outcome.warm.expect("warm phase ran");
    assert!(cold.engine_runs > 0, "cold wave must pay for its searches");
    assert_eq!(warm.engine_runs, 0, "warm wave must be engine-free");
    assert!(warm.rps > cold.rps, "warm {} must beat cold {} req/s", warm.rps, cold.rps);
    assert!(outcome.dedupe_hits >= 1, "duplicate burst must dedupe");
    assert_eq!(outcome.dedupe_searches, 1, "duplicate burst must share one search");
    assert!(outcome.batch_grids >= 1);
    assert!(outcome.batch_cells >= outcome.batch_grids);
    for key in ["\"serve\"", "\"cold\"", "\"warm\"", "\"dedupe\"", "\"batch\"", "\"latency_ms\""] {
        assert!(outcome.json.contains(key), "BENCH document is missing {key}: {}", outcome.json);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
