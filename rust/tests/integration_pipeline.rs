//! Integration tests across modules: transform → plan → simulator →
//! cost-model consistency, and the full PJRT path when artifacts exist.

use imp_latency::cost::CostModel;
use imp_latency::runtime::Registry;
use imp_latency::sim::{
    ca_time_for, ca_time_sequential_for, naive_time_1d, simulate, ExecPlan, Machine,
};
use imp_latency::stencil::{heat1d_graph, heat2d_graph, spmv_program, CsrMatrix};
use imp_latency::transform::{
    check_schedule, communication_avoiding_default, ScheduleStats, TransformOptions,
};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = Registry::default_dir();
    dir.join("manifest.txt").exists().then_some(dir)
}

// ---------------------------------------------------------------------------
// Simulator ↔ analytic ↔ cost-model coherence
// ---------------------------------------------------------------------------

#[test]
fn discrete_and_analytic_agree_across_configs() {
    for (n, m, p, b, threads, alpha) in [
        (256u64, 8u32, 4u32, 4u32, 2u32, 50.0),
        (512, 12, 8, 3, 8, 500.0),
        (1024, 8, 2, 8, 1, 10.0),
    ] {
        let g = heat1d_graph(n, m, p);
        let mach = Machine::new(p, threads, alpha, 0.2, 1.0);
        let opts = TransformOptions::default();
        let discrete = simulate(&g, &ExecPlan::ca(&g, b, opts).unwrap(), &mach, false).total_time;
        let analytic = ca_time_for(&g, b, opts, &mach);
        let rel = (discrete - analytic).abs() / discrete;
        assert!(rel < 0.3, "n={n} m={m} p={p} b={b}: discrete {discrete} analytic {analytic}");
    }
}

#[test]
fn cost_model_brackets_sequential_simulation() {
    // T(b) should track the sequential-phase CA evaluation within a
    // small constant factor across b (same α and per-thread γ).
    let (n, m, p, threads) = (4096u64, 32u32, 8u32, 8u32);
    let g = heat1d_graph(n, m, p);
    let mach = Machine::new(p, threads, 200.0, 0.1, 1.0);
    let model = CostModel::from_machine(n, m, &mach);
    for b in [1u32, 2, 4, 8, 16] {
        let sim = if b == 1 {
            naive_time_1d(n, m, &mach)
        } else {
            ca_time_sequential_for(&g, b, TransformOptions::default(), &mach)
        };
        let t = model.cost(b);
        let ratio = sim / t;
        assert!(
            (0.5..2.0).contains(&ratio),
            "b={b}: sim {sim:.1} vs model {t:.1} (ratio {ratio:.2})"
        );
    }
}

#[test]
fn blocking_strictly_helps_at_high_latency_end_to_end() {
    let g = heat1d_graph(2048, 16, 8);
    let mach = Machine::new(8, 16, 1000.0, 0.1, 1.0);
    let naive = simulate(&g, &ExecPlan::naive(&g), &mach, false).total_time;
    let overlap = simulate(&g, &ExecPlan::overlap(&g), &mach, false).total_time;
    let ca =
        simulate(&g, &ExecPlan::ca(&g, 16, TransformOptions::default()).unwrap(), &mach, false)
            .total_time;
    assert!(overlap <= naive);
    assert!(ca < overlap / 2.0, "ca {ca} overlap {overlap} naive {naive}");
}

// ---------------------------------------------------------------------------
// Transform on non-stencil substrates
// ---------------------------------------------------------------------------

#[test]
fn spmv_chain_transform_well_formed_and_blockable() {
    let a = CsrMatrix::laplace2d(8, 8); // irregular 5-point pattern, n=64
    let g = spmv_program(&a, 6, 4).unroll();
    let s = communication_avoiding_default(&g);
    check_schedule(&g, &s).unwrap();
    let st = ScheduleStats::compute(&g, &s);
    assert!(st.messages < st.naive_messages);
    // And through the plan/simulator:
    let mach = Machine::new(4, 4, 300.0, 0.1, 1.0);
    let naive = simulate(&g, &ExecPlan::naive(&g), &mach, false).total_time;
    let ca = simulate(&g, &ExecPlan::ca(&g, 3, TransformOptions::default()).unwrap(), &mach, false)
        .total_time;
    assert!(ca < naive, "ca {ca} naive {naive}");
}

#[test]
fn heat2d_graph_transform_well_formed() {
    let g = heat2d_graph(12, 12, 4, 2, 2);
    let s = communication_avoiding_default(&g);
    check_schedule(&g, &s).unwrap();
    // Diagonal dependencies must appear for b ≥ 2: some processor's
    // closure includes tasks owned by its diagonal neighbour.
    let st = ScheduleStats::compute(&g, &s);
    assert!(st.redundant_tasks > 0 || st.words > 0);
}

// ---------------------------------------------------------------------------
// Full PJRT path (skipped without artifacts)
// ---------------------------------------------------------------------------

#[test]
fn pjrt_artifacts_match_simulated_message_counts() {
    let Some(dir) = artifacts() else { return };
    use imp_latency::coordinator::heat1d::{run, Heat1dConfig};
    let (workers, steps, b) = (4u32, 16u32, 4u32);
    let cfg = Heat1dConfig {
        n_per_worker: 256,
        workers,
        b,
        steps,
        nu: 0.1,
        artifacts_dir: dir,
    };
    let init: Vec<f32> = (0..cfg.total_points()).map(|i| (i as f32 * 0.01).sin()).collect();
    let (_, stats) = run(&cfg, &init).unwrap();
    // (workers − 1) internal boundaries × 2 messages × (steps / b).
    let expected = (workers as u64 - 1) * 2 * (steps / b) as u64;
    assert_eq!(stats.messages, expected);
}

#[test]
fn pjrt_blocked_kernel_equals_unblocked_composition() {
    let Some(dir) = artifacts() else { return };
    use imp_latency::runtime::{Runtime, Value};
    let rt = Runtime::new(&dir).unwrap();
    let b = 8usize;
    let x: Vec<f32> = (0..256 + 2 * b).map(|i| (i as f32 * 0.1).cos()).collect();
    let fused = rt
        .execute_f32_1("heat1d_n256_b8", &[Value::F32(x.clone()), Value::scalar(0.2)])
        .unwrap();
    // Compose eight b=1 calls on progressively shrinking tiles computed
    // in Rust (slice off one halo point each side per step).
    let mut cur = x;
    for _ in 0..b {
        let next: Vec<f32> = cur
            .windows(3)
            .map(|w| w[1] + 0.2 * (w[0] - 2.0 * w[1] + w[2]))
            .collect();
        cur = next;
    }
    assert_eq!(cur.len(), 256);
    for (a, w) in fused.iter().zip(&cur) {
        assert!((a - w).abs() < 1e-4, "{a} vs {w}");
    }
}

#[test]
fn pjrt_radius2_artifact_ghost_width_matches_transform() {
    // The radius-2 kernel needs a 2b-deep ghost — exactly what the
    // transformation derives for Signature::stencil_radius(2).
    let Some(dir) = artifacts() else { return };
    use imp_latency::runtime::{Runtime, Value};
    use imp_latency::transform::communication_avoiding;

    let b = 2u32;
    let g = imp_latency::stencil::heat1d_program(512, b, 2, 2).unroll();
    let s = communication_avoiding(&g, TransformOptions::level0());
    let ghost: usize = s.per_proc[0].recv.iter().map(|m| m.tasks.len()).sum();
    assert_eq!(ghost, 2 * b as usize, "transform-derived ghost width");

    // And the artifact consumes exactly n + 2·(2b) points.
    let rt = Runtime::new(&dir).unwrap();
    let spec = rt.registry().get("heat1d_r2_n256_b2").unwrap();
    assert_eq!(spec.inputs[0].dims, vec![256 + 4 * 2]);
    let x = vec![1.0f32; 256 + 8];
    let out = rt
        .execute_f32_1("heat1d_r2_n256_b2", &[Value::F32(x), Value::scalar(0.1)])
        .unwrap();
    // Constant field is a fixed point of the 4th-order update.
    assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-5));
}

#[test]
fn pjrt_cg_and_heat_share_runtime() {
    // One worker using several artifact kinds through one Runtime —
    // executable caching across dispatch types.
    let Some(dir) = artifacts() else { return };
    use imp_latency::runtime::{Runtime, Value};
    let rt = Runtime::new(&dir).unwrap();
    let v = vec![1.0f32; 2048];
    rt.execute("dot_partial_n2048", &[Value::F32(v.clone()), Value::F32(v.clone())]).unwrap();
    rt.execute("axpy_n2048", &[Value::scalar(2.0), Value::F32(v.clone()), Value::F32(v)])
        .unwrap();
    let m = rt.metrics();
    assert_eq!(m.compiles, 2);
    assert_eq!(m.executions, 2);
}
