//! Integration matrix for the telemetry layer: the observability
//! claims must hold end to end — installing the global recorder turns
//! on engine counters, tuner search timelines, and serve request
//! lifecycles all at once and merges them into one Chrome trace; an
//! injected recorder isolates a server from its siblings and from the
//! global gate; the log-bucketed histograms order their percentiles
//! and render a well-formed Prometheus exposition; and draining spans
//! empties the buffer.
//!
//! This binary is its own process, so exercising the global
//! `telemetry` gate here cannot race the library's unit tests.  Within
//! the binary, only the first test touches the global recorder; every
//! other test uses private `Recorder`s (injected or free-standing),
//! which stay correct no matter what the global gate is doing on a
//! sibling test thread.

use std::sync::Arc;

use imp_latency::pipeline::{Heat1d, Pipeline};
use imp_latency::serve::{Payload, Request, Response, ServeConfig, Server};
use imp_latency::sim::{simulate_compiled, EngineScratch, Machine, NetworkKind};
use imp_latency::telemetry::{self, Recorder};
use imp_latency::trace::chrome_trace_with_telemetry;
use imp_latency::tune::Tuner;

fn memory_server(workers: usize) -> Server {
    Server::new(ServeConfig {
        workers,
        max_in_flight: 16,
        reserve: 0,
        budget: None,
        cache_dir: None,
        slots: 4,
        search: "exhaustive".to_string(),
    })
}

/// A small tune request (distinct `alpha`s keep per-test caches cold).
fn tune_line(id: &str, alpha: f64) -> String {
    format!(
        "{{\"id\": \"{id}\", \"op\": \"tune\", \"workload\": \"heat1d\", \"n\": 96, \
         \"m\": 6, \"p\": 2, \"threads\": 4, \"alpha\": {alpha}, \"beta\": 0.1, \
         \"gamma\": 1.0}}"
    )
}

fn wave(server: &Server, lines: &[String]) -> Vec<Response> {
    server.run_wave(lines.iter().map(|l| Request::parse(l)).collect())
}

/// The whole stack through the one global gate: engine counters, the
/// pipeline transform timer, a tuner search timeline, and a serve
/// request lifecycle all land in the same installed recorder, merge
/// into one Chrome trace, and disappear again when the gate closes.
/// (The only test in this binary that touches the global recorder.)
#[test]
fn global_recorder_traces_engine_tuner_and_serve_end_to_end() {
    let rec = Arc::new(Recorder::new());
    telemetry::install(Arc::clone(&rec));

    // Engine + pipeline: a compiled simulation behind the enabled gate.
    let input = Pipeline::new(Heat1d::new(256, 8))
        .procs(4)
        .block(4)
        .transform()
        .expect("Theorem 1")
        .sweep_input();
    let mach = Machine::new(4, 4, 50.0, 1.0, 1.0);
    let mut scratch = EngineScratch::new();
    let mut net = NetworkKind::AlphaBeta.build_for(&mach, input.layout.as_ref());
    let sim = simulate_compiled(&input.compiled, &mach, net.as_mut(), &mut scratch, true)
        .expect("pipeline plans are deadlock-free");
    assert!(!sim.spans.is_empty(), "record_spans=true must yield Gantt spans");
    assert!(rec.counter("engine.runs").get() >= 1);
    assert!(rec.counter("engine.events").get() > 0);
    assert!(rec.counter("pipeline.transforms").get() >= 1);
    assert!(rec.registry.find_histogram("pipeline.transform_ms").is_some());

    // Tuner: a direct autotune records its search span + counters.
    let mut tuner = Tuner::exhaustive();
    Pipeline::new(Heat1d::new(96, 6))
        .procs(2)
        .machine(Machine::new(2, 4, 50.0, 1.0, 1.0))
        .network(NetworkKind::AlphaBeta)
        .autotune(&mut tuner)
        .expect("tunable");
    assert!(rec.counter("tune.searches").get() >= 1);

    // Serve: a server with no injected recorder falls back to the
    // installed global; the metrics op reads the same aggregates.
    let server = memory_server(2);
    let responses = wave(&server, &[tune_line("t1", 60.0)]);
    assert!(responses[0].result.is_ok(), "{responses:?}");
    let metrics = wave(&server, &[r#"{"id": "m", "op": "metrics"}"#.to_string()]);
    match &metrics[0].result {
        Ok(Payload::Metrics { enabled, requests, .. }) => {
            assert!(*enabled, "the global recorder must be visible to the metrics op");
            assert!(*requests >= 1);
        }
        other => panic!("expected a metrics payload, got {other:?}"),
    }

    // Export: all three instrumented layers share one trace.
    let spans = rec.drain_spans();
    let lifecycle = spans
        .iter()
        .find(|s| s.track == "serve" && s.name == "request:tune:t1")
        .expect("serve lifecycle span");
    assert!(
        spans.iter().any(|s| s.track == "serve.phase" && s.tid == lifecycle.tid),
        "lifecycle must carry phase marks on its lane"
    );
    assert!(
        spans.iter().any(|s| s.track == "tune" && s.name.starts_with("search:heat1d:")),
        "tuner search timeline missing: {spans:?}"
    );
    let chrome = chrome_trace_with_telemetry(&sim.spans, &spans);
    assert!(chrome.contains("request:tune:t1"));
    assert!(chrome.contains("search:heat1d:"));
    assert!(chrome.contains("\"cat\": \"sim\""));
    let prom = rec.registry.prometheus();
    for needle in [
        "engine_runs",
        "tune_search_ms",
        "serve_request_latency_ms",
        "quantile=\"0.99\"",
    ] {
        assert!(prom.contains(needle), "missing {needle} in:\n{prom}");
    }

    telemetry::set_enabled(false);
    assert!(telemetry::recorder().is_none(), "a closed gate hides the recorder");
    assert!(telemetry::with(|r| r.now_us()).is_none());
}

/// An injected recorder beats the global fallback and keeps sibling
/// servers' aggregates fully separate.
#[test]
fn injected_recorders_isolate_sibling_servers() {
    let rec1 = Arc::new(Recorder::new());
    let rec2 = Arc::new(Recorder::new());
    let s1 = memory_server(1).with_recorder(Arc::clone(&rec1));
    let s2 = memory_server(1).with_recorder(Arc::clone(&rec2));

    let r = wave(&s1, &[tune_line("iso", 80.0)]);
    assert!(r[0].result.is_ok(), "{r:?}");
    assert_eq!(rec1.counter("serve.requests").get(), 1);
    assert!(rec1.span_count() > 0, "the request must leave lifecycle + phase spans");
    assert_eq!(rec2.counter("serve.requests").get(), 0);
    assert_eq!(rec2.span_count(), 0);

    // The sibling's metrics op reads its own (still empty) recorder —
    // the registry snapshot is taken before the op's own lifecycle is
    // recorded, so a fresh server reports zero requests.
    match &wave(&s2, &[r#"{"id": "m", "op": "metrics"}"#.to_string()])[0].result {
        Ok(Payload::Metrics { enabled, requests, spans, .. }) => {
            assert!(*enabled);
            assert_eq!(*requests, 0);
            assert_eq!(*spans, 0);
        }
        other => panic!("expected a metrics payload, got {other:?}"),
    }
}

/// Log-bucketed histograms: ordered percentiles, exact count/sum, and
/// a Prometheus exposition with sanitized names, typed sections, and
/// summary quantiles.
#[test]
fn histogram_percentiles_order_and_prometheus_renders() {
    let rec = Recorder::new();
    let h = rec.histogram("serve.request_latency_ms");
    for v in 1..=100 {
        h.record(f64::from(v));
    }
    let (p50, p90, p99) = (h.percentile(0.50), h.percentile(0.90), h.percentile(0.99));
    assert!(p50 <= p90 && p90 <= p99, "percentiles out of order: {p50} {p90} {p99}");
    // Log buckets trade ~9% resolution for O(1) memory; the median of
    // 1..=100 must still land near 50.
    assert!((40.0..=60.0).contains(&p50), "p50 {p50} too far from the true median");
    assert_eq!(h.count(), 100);
    assert!((h.sum() - 5050.0).abs() < 1e-9, "sum {} drifted", h.sum());

    rec.counter("engine.runs").add(3);
    rec.gauge("engine.heap_depth_high_water").set_max(7);
    let prom = rec.registry.prometheus();
    for needle in [
        "# TYPE engine_runs counter",
        "engine_runs 3",
        "# TYPE engine_heap_depth_high_water gauge",
        "engine_heap_depth_high_water 7",
        "# TYPE serve_request_latency_ms summary",
        "quantile=\"0.5\"",
        "quantile=\"0.9\"",
        "quantile=\"0.99\"",
        "serve_request_latency_ms_count 100",
    ] {
        assert!(prom.contains(needle), "missing {needle} in:\n{prom}");
    }
}

/// Merged export from a private recorder: simulator Gantt spans and
/// serve telemetry share one well-formed Chrome trace, and draining
/// leaves the span buffer empty.
#[test]
fn chrome_export_merges_sim_and_serve_spans_and_drain_empties() {
    let input = Pipeline::new(Heat1d::new(128, 8))
        .procs(4)
        .block(4)
        .transform()
        .expect("Theorem 1")
        .sweep_input();
    let mach = Machine::new(4, 4, 50.0, 1.0, 1.0);
    let mut scratch = EngineScratch::new();
    let mut net = NetworkKind::AlphaBeta.build_for(&mach, input.layout.as_ref());
    let sim = simulate_compiled(&input.compiled, &mach, net.as_mut(), &mut scratch, true)
        .expect("pipeline plans are deadlock-free");
    assert!(!sim.spans.is_empty());

    let rec = Arc::new(Recorder::new());
    let server = memory_server(1).with_recorder(Arc::clone(&rec));
    let r = wave(&server, &[tune_line("m1", 120.0)]);
    assert!(r[0].result.is_ok(), "{r:?}");
    let telem = rec.drain_spans();
    assert!(telem.iter().any(|s| s.track == "serve.phase"));
    assert_eq!(rec.span_count(), 0, "drain must empty the buffer");
    assert_eq!(rec.dropped_spans(), 0);

    let chrome = chrome_trace_with_telemetry(&sim.spans, &telem);
    assert!(chrome.starts_with("[\n") && chrome.ends_with("]\n"));
    assert!(chrome.contains("request:tune:m1"));
    // One complete ("X") event per span, exactly one comma between
    // consecutive events: the array is machine-loadable.
    let events = sim.spans.len() + telem.len();
    assert_eq!(chrome.matches("\"ph\": \"X\"").count(), events);
    assert_eq!(chrome.matches("},").count(), events - 1);
}
