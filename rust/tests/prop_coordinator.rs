//! Property tests: the real threaded coordinator reproduces sequential
//! semantics for every plan on random graphs.
//!
//! Each case spawns one OS thread per processor and real channels; task
//! values are exact u64 mixes, so any routing, phase-ordering, message-
//! pairing or state-management bug produces a hard divergence.

use imp_latency::prop::{check, random_dag, random_stencil, DagParams};
use imp_latency::sim::ExecPlan;
use imp_latency::transform::TransformOptions;
use std::sync::Arc;

#[test]
fn naive_plans_execute_correctly_on_random_dags() {
    check(40, |rng| {
        let g = Arc::new(random_dag(rng, &DagParams::default()));
        let plan = ExecPlan::naive(&g);
        imp_latency::coordinator::run_and_verify(&g, &plan).map(|_| ())
    });
}

#[test]
fn overlap_plans_execute_correctly_on_random_dags() {
    check(40, |rng| {
        let g = Arc::new(random_dag(rng, &DagParams::default()));
        let plan = ExecPlan::overlap(&g);
        imp_latency::coordinator::run_and_verify(&g, &plan).map(|_| ())
    });
}

#[test]
fn ca_plans_execute_correctly_on_random_dags() {
    check(40, |rng| {
        let g = Arc::new(random_dag(rng, &DagParams::default()));
        let depth = g.num_levels().saturating_sub(1).max(1);
        let b = 1 + (rng.below(depth as u64) as u32);
        for opts in [TransformOptions::multilevel(), TransformOptions::level0()] {
            let plan = ExecPlan::ca(&g, b, opts)?;
            imp_latency::coordinator::run_and_verify(&g, &plan)
                .map_err(|e| format!("b={b} {opts:?}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn ca_plans_execute_correctly_on_random_stencils() {
    check(30, |rng| {
        let (n, m, p, r) = random_stencil(rng);
        let g = Arc::new(imp_latency::stencil::heat1d_program(n, m, p, r).unroll());
        let b = 1 + (rng.below(m as u64) as u32);
        let plan = ExecPlan::ca(&g, b, TransformOptions::default())?;
        let res = imp_latency::coordinator::run_and_verify(&g, &plan)
            .map_err(|e| format!("n={n} m={m} p={p} r={r} b={b}: {e}"))?;
        // Message conservation: the run sends exactly what the plan says.
        if res.messages as usize != plan.messages() {
            return Err(format!("messages {} != plan {}", res.messages, plan.messages()));
        }
        Ok(())
    });
}

#[test]
fn every_task_owner_obtains_its_value_exactly_once_per_worker() {
    // Execution counts: the CA plan executes each task at most once per
    // worker (no double compute within one processor's phases).
    check(30, |rng| {
        let g = Arc::new(random_dag(rng, &DagParams::default()));
        let plan = ExecPlan::ca(&g, 2, TransformOptions::default())?;
        for (p, pp) in plan.per_proc.iter().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for ph in &pp.phases {
                if let imp_latency::sim::Phase::Compute(ts) = ph {
                    for &t in ts {
                        if !seen.insert(t) {
                            return Err(format!("p{p} computes t{t} twice"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}
