//! Seed-determinism matrix for the chaos layer: fault injection is a
//! *reproducible* experiment, not noise.  For every workload × strategy
//! × wire-model cell, an everything-on fault scenario must
//!
//! - replay bit-identically on the compiled engine (fresh wire, fresh
//!   scratch — same makespan, message count, and word count),
//! - agree bit-for-bit with the interpreting engine under the same
//!   seed (the perturbed costs are baked into the compiled plan by
//!   [`perturb_input`]; the interpreter re-draws them per task — both
//!   must see the identical numbers),
//! - leave the traffic untouched (faults perturb *time*; the message
//!   and word counts of the clean run are invariant), and
//! - draw *different* delays under different seeds (otherwise the
//!   ensemble percentiles in `chaos` would be N copies of one run).
//!
//! The matrix spans all five workloads (heat1d, heat2d, moore2d, spmv,
//! cg), the full strategy family of [`strategy_sweep_inputs`] (naive,
//! overlap, ca(b=2)), and all four wire models.

use std::sync::Arc;

use imp_latency::chaos::{perturb_input, FaultConfig, JitterWire, WireFault};
use imp_latency::pipeline::{
    strategy_sweep_inputs, ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Workload,
};
use imp_latency::sim::sweep::SweepInput;
use imp_latency::sim::{simulate_compiled, try_simulate, EngineScratch, Machine, NetworkKind};
use imp_latency::stencil::CsrMatrix;

const PROCS: u32 = 4;

/// The four wire models at their default sweep-axis parameters.
fn wires() -> [NetworkKind; 4] {
    [
        NetworkKind::AlphaBeta,
        NetworkKind::LogGp { overhead: 1.0, gap: 2.0 },
        NetworkKind::Hierarchical { node_size: 2, intra_factor: 0.1 },
        NetworkKind::Contended,
    ]
}

/// An everything-on scenario: static heterogeneity, per-task jitter,
/// heavy stragglers, and a fat-tailed wire — every draw stream active.
fn fault(seed: u64) -> FaultConfig {
    FaultConfig {
        seed,
        hetero: 0.15,
        jitter: 0.1,
        straggler_rate: 0.2,
        straggler_factor: 4.0,
        wire: WireFault::Pareto { scale: 1.0, shape: 1.5 },
    }
}

/// The machine a sweep cell would build for `input` (β scaled by the
/// input's words-per-value) — identical construction on every run is
/// part of what makes the bits reproducible.
fn machine_for(input: &SweepInput) -> Machine {
    Machine::new(PROCS, 2, 8.0, 0.1 * input.words_per_value as f64, 1.0)
}

/// Simulate a perturbed input once on the compiled engine with a fresh
/// jittered wire and fresh scratch.
fn compiled_run(
    input: &SweepInput,
    kind: NetworkKind,
    mach: &Machine,
    ctx: &str,
) -> (f64, usize, usize) {
    let fc = input.fault.clone().unwrap_or_default();
    let mut scratch = EngineScratch::new();
    let mut net = JitterWire::wrap(kind.build_for(mach, input.layout.as_ref()), &fc);
    let r = simulate_compiled(&input.compiled, mach, net.as_mut(), &mut scratch, false)
        .unwrap_or_else(|e| panic!("{ctx}: compiled run failed: {e}"));
    (r.total_time, r.messages, r.words)
}

/// Run one perturbed cell three ways — compiled, compiled replay, and
/// interpreted — and assert all three are bit-identical.  Returns the
/// agreed (makespan, messages, words).
fn run_all_engines(
    input: &SweepInput,
    kind: NetworkKind,
    mach: &Machine,
    ctx: &str,
) -> (f64, usize, usize) {
    let (mk1, msgs1, words1) = compiled_run(input, kind, mach, ctx);
    let (mk2, msgs2, words2) = compiled_run(input, kind, mach, ctx);
    assert_eq!(
        mk1.to_bits(),
        mk2.to_bits(),
        "{ctx}: compiled replay diverged: {mk1} vs {mk2}"
    );
    assert_eq!((msgs1, words1), (msgs2, words2), "{ctx}: compiled replay traffic diverged");

    let fc = input.fault.clone().unwrap_or_default();
    let mut net = JitterWire::wrap(kind.build_for(mach, input.layout.as_ref()), &fc);
    let i = try_simulate(&input.graph, &input.plan, mach, net.as_mut(), input.cost.as_ref(), false)
        .unwrap_or_else(|e| panic!("{ctx}: interpreted run failed: {e}"));
    assert_eq!(
        mk1.to_bits(),
        i.total_time.to_bits(),
        "{ctx}: engines disagree under the same seed: compiled {mk1} vs interpreted {}",
        i.total_time
    );
    assert_eq!(
        (msgs1, words1),
        (i.messages, i.words),
        "{ctx}: engines disagree on traffic under the same seed"
    );
    (mk1, msgs1, words1)
}

/// Drive one workload through the strategy family × all four wires.
fn exercise<W: Workload + Clone>(workload: W) {
    let name = workload.name();
    let base = Pipeline::new(workload).procs(PROCS);
    let inputs = strategy_sweep_inputs(&base, &[2]).unwrap_or_else(|e| panic!("{name}: {e}"));
    assert_eq!(inputs.len(), 3, "{name}: expected naive, overlap, ca(b=2)");

    for input in &inputs {
        let mach = machine_for(input);
        let perturbed = perturb_input(input, &fault(42));
        for kind in wires() {
            let ctx = format!("{}/{}/{}", input.workload, input.strategy, kind.label());

            // Clean reference: same plan, unperturbed costs, bare wire.
            let mut scratch = EngineScratch::new();
            let mut net = kind.build_for(&mach, input.layout.as_ref());
            let clean = simulate_compiled(&input.compiled, &mach, net.as_mut(), &mut scratch, false)
                .unwrap_or_else(|e| panic!("{ctx}: clean run failed: {e}"));

            let (mk, msgs, words) = run_all_engines(&perturbed, kind, &mach, &ctx);
            assert!(mk.is_finite() && mk > 0.0, "{ctx}: degenerate perturbed makespan {mk}");
            assert_eq!(
                (msgs, words),
                (clean.messages, clean.words),
                "{ctx}: faults must perturb time, not traffic"
            );
            // Every perturbation is slowdown-only and the program order
            // is fixed, so on the uncontended wire the perturbed run
            // can never beat the clean one.  (Contended serializes
            // sends by arrival, where delaying one message can reorder
            // the NIC queue — monotonicity is only claimed here for
            // the plain α-β wire.)
            if matches!(kind, NetworkKind::AlphaBeta) {
                assert!(
                    mk >= clean.total_time - 1e-9,
                    "{ctx}: slowdown-only faults sped the run up: {mk} < {}",
                    clean.total_time
                );
            }
        }
    }
}

#[test]
fn heat1d_chaos_matrix() {
    exercise(Heat1d::new(64, 4));
}

#[test]
fn heat2d_chaos_matrix() {
    exercise(Heat2d { h: 8, w: 8, steps: 3 });
}

#[test]
fn moore2d_chaos_matrix() {
    exercise(Moore2d { h: 8, w: 8, steps: 3 });
}

#[test]
fn spmv_chaos_matrix() {
    exercise(Spmv { matrix: CsrMatrix::laplace2d(6, 6), steps: 3 });
}

#[test]
fn cg_chaos_matrix() {
    exercise(ConjugateGradient { unknowns: 24, iters: 2 });
}

/// Different root seeds must draw different perturbations — across
/// three seeds the perturbed makespans cannot all collapse to one
/// value, and the compute factors separate per proc and per task.
#[test]
fn different_seeds_draw_distinct_perturbations() {
    let base = Pipeline::new(Heat1d::new(64, 4)).procs(PROCS);
    let inputs = strategy_sweep_inputs(&base, &[2]).expect("heat1d family");
    let overlap = &inputs[1];
    let mach = machine_for(overlap);

    let mut seen = std::collections::BTreeSet::new();
    for seed in [1u64, 2, 3] {
        let perturbed = perturb_input(overlap, &fault(seed));
        let ctx = format!("heat1d/overlap/alphabeta seed={seed}");
        let (mk, _, _) = run_all_engines(&perturbed, NetworkKind::AlphaBeta, &mach, &ctx);
        seen.insert(mk.to_bits());
    }
    assert!(seen.len() >= 2, "three seeds produced one makespan: {seen:?}");

    // The draw streams separate entities: distinct procs and distinct
    // tasks get distinct factors, and every factor only ever slows.
    let fc = fault(7);
    let (a, b, c) = (fc.compute_factor(0, 0), fc.compute_factor(1, 0), fc.compute_factor(0, 1));
    for (label, f) in [("p0/t0", a), ("p1/t0", b), ("p0/t1", c)] {
        assert!(f >= 1.0, "{label}: compute factor {f} < 1 would mean speed-up");
    }
    assert!(a != b, "distinct procs drew the same heterogeneity factor {a}");
    assert!(a != c, "distinct tasks drew the same jitter factor {a}");
}

/// The perturbed input shares graph and plan with its clean template —
/// [`perturb_input`] recompiles costs, it does not rebuild structure.
#[test]
fn perturb_input_shares_structure_and_tags_the_fault() {
    let base = Pipeline::new(Heat1d::new(64, 4)).procs(PROCS);
    let inputs = strategy_sweep_inputs(&base, &[2]).expect("heat1d family");
    let clean = &inputs[0];
    let perturbed = perturb_input(clean, &fault(9));
    assert!(Arc::ptr_eq(&clean.graph, &perturbed.graph), "graph must be shared, not rebuilt");
    assert!(Arc::ptr_eq(&clean.plan, &perturbed.plan), "plan must be shared, not rebuilt");
    assert!(clean.fault.is_none(), "templates stay clean");
    assert_eq!(
        perturbed.fault.as_ref().map(|f| f.seed),
        Some(9),
        "the fault scenario must ride on the input"
    );
    assert!(
        !Arc::ptr_eq(&clean.compiled, &perturbed.compiled),
        "perturbed costs must be recompiled, not aliased"
    );
}
