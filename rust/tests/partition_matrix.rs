//! Integration matrix for the `partition` subsystem — the ISSUE's
//! acceptance criteria plus partition-invariant property tests:
//!
//! * on heat2d at ≥ 9 procs under the Hierarchical wire, a 2-D ProcGrid
//!   partition beats the 1-D strip outright, and `autotune()` with the
//!   grid axis enabled picks a non-strip shape;
//! * on a banded+random SpMV matrix, the edge-cut partitioner moves
//!   fewer words than the row-block baseline — asserted both via
//!   `PartitionQuality` and the engine's message accounting;
//! * every partitioner produces a covering, disjoint, balance-bounded
//!   partition whose edge-cut words equal what the simulator actually
//!   sends;
//! * the layout-aware tuning space clamps its block axis to the tile
//!   geometry, and the transformation stays verified across it.

use imp_latency::partition::{
    banded_random, grid_axis, to_distribution, PartitionQuality, Partitioner, Partitioning,
    ProcGrid,
};
use imp_latency::pipeline::{Heat2d, Pipeline, Spmv, Strategy};
use imp_latency::sim::{Machine, NetworkKind};
use imp_latency::stencil::CsrMatrix;
use imp_latency::transform::HaloMode;
use imp_latency::tune::{Tuner, TuningSpace};

fn hier() -> NetworkKind {
    NetworkKind::Hierarchical { node_size: 3, intra_factor: 0.1 }
}

/// Nine processors, four threads each; β = 2γ so the words a layout
/// moves dominate the wire time.
fn machine9() -> Machine {
    Machine::new(9, 4, 40.0, 2.0, 1.0)
}

#[test]
fn grid_beats_strip_on_heat2d_under_hier() {
    let (h, w, m) = (18u64, 18u64, 6u32);
    let base = Pipeline::new(Heat2d { h, w, steps: m })
        .procs(9)
        .machine(machine9())
        .network(hier())
        .naive();
    let strip = base
        .clone()
        .partitioning(Partitioning::Grid(ProcGrid::Strip))
        .transform()
        .unwrap()
        .simulate_configured()
        .unwrap();
    let grid = base
        .partitioning(Partitioning::Grid(ProcGrid::Grid { px: 3, py: 3 }))
        .transform()
        .unwrap()
        .simulate_configured()
        .unwrap();
    // 6x6 tiles send 4 six-value edges instead of 2 eighteen-value rows,
    // and the grid-aware node map keeps one tile row per node: strictly
    // lower makespan, strictly fewer words.
    assert!(
        grid.time.value() < strip.time.value(),
        "grid {} vs strip {}",
        grid.time.value(),
        strip.time.value()
    );
    assert!(grid.words < strip.words, "grid {} vs strip {}", grid.words, strip.words);
}

#[test]
fn autotune_with_grid_axis_selects_a_non_strip_shape() {
    let space = TuningSpace {
        strategies: vec![Strategy::Naive, Strategy::Overlap],
        halos: vec![HaloMode::MultiLevel],
        blocks: Vec::new(),
        procs: vec![9],
        layouts: grid_axis(9), // strip, 1x9, 3x3
    };
    let mut tuner = Tuner::exhaustive().with_space(space);
    let t = Pipeline::new(Heat2d { h: 18, w: 18, steps: 6 })
        .procs(9)
        .machine(machine9())
        .network(hier())
        .autotune(&mut tuner)
        .unwrap();
    let report = t.tune_report().unwrap().clone();
    assert!(report.engine_runs > 0);
    let chosen = report.chosen;
    assert!(
        matches!(
            chosen.layout,
            Some(Partitioning::Grid(ProcGrid::Grid { px, py })) if px > 1 && py > 1
        ),
        "tuner must pick a genuine 2-D shape: {chosen:?}"
    );
    assert_eq!(t.partitioning(), chosen.layout.unwrap());
    // The verdict survives the cache, layout included.
    let again = Pipeline::new(Heat2d { h: 18, w: 18, steps: 6 })
        .procs(9)
        .machine(machine9())
        .network(hier())
        .autotune(&mut tuner)
        .unwrap();
    let r2 = again.tune_report().unwrap();
    assert!(r2.cache_hit);
    assert_eq!(r2.chosen, chosen);
    assert_eq!(again.partitioning(), chosen.layout.unwrap());
}

#[test]
fn edge_cut_partitioner_moves_fewer_words_than_row_block() {
    let a = banded_random(6, 24, 8);
    let p = 4u32;
    let steps = 3u32;
    let qb = PartitionQuality::evaluate(&a, &Partitioner::RowBlock.assign(&a, p), p);
    let qr = PartitionQuality::evaluate(&a, &Partitioner::RcbRefined.assign(&a, p), p);
    assert!(
        qr.edge_cut_words < qb.edge_cut_words,
        "rcb+refine {} vs rowblock {}",
        qr.edge_cut_words,
        qb.edge_cut_words
    );

    // The engine's message accounting agrees with the static metric:
    // a naive m-step plan sends exactly m × edge_cut_words words and
    // m × message_pairs messages.
    let mach = Machine::new(p, 4, 40.0, 1.0, 1.0);
    for (part, q) in [(Partitioner::RowBlock, &qb), (Partitioner::RcbRefined, &qr)] {
        let r = Pipeline::new(Spmv { matrix: a.clone(), steps })
            .procs(p)
            .machine(mach)
            .naive()
            .partitioning(Partitioning::Graph(part))
            .transform()
            .unwrap()
            .simulate_configured()
            .unwrap();
        assert_eq!(r.words, steps as usize * q.edge_cut_words, "{}", part.key());
        assert_eq!(r.messages, steps as usize * q.message_pairs, "{}", part.key());
    }
}

#[test]
fn partitions_cover_disjointly_within_balance_bounds() {
    let matrices = vec![
        CsrMatrix::laplace1d(17),
        CsrMatrix::laplace2d(5, 7),
        banded_random(4, 16, 6),
    ];
    for a in &matrices {
        for parts in [2u32, 3, 4] {
            for part in Partitioner::all() {
                let assign = part.assign(a, parts);
                let tag = format!("{} n={} parts={parts}", part.key(), a.n);
                assert_eq!(assign.len(), a.n, "{tag}");
                assert!(assign.iter().all(|&q| q < parts), "{tag}");
                // to_distribution re-validates cover + disjointness (the
                // IMP layer rejects overlaps and holes outright).
                let dist = to_distribution(&assign, parts);
                for v in 0..a.n as u64 {
                    assert_eq!(dist.owner_of(v).0, assign[v as usize], "{tag}: index {v}");
                }
                let q = PartitionQuality::evaluate(a, &assign, parts);
                assert!(q.imbalance >= 1.0 - 1e-9, "{tag}: {q:?}");
                assert!(q.imbalance <= 1.35, "{tag}: {q:?}");
                assert!(q.max_neighbors < parts as usize, "{tag}: {q:?}");
                assert!(q.edge_cut_words <= q.edge_cut_nnz, "{tag}: {q:?}");
            }
        }
    }
}

#[test]
fn blocking_respects_tile_geometry_on_2d_grids() {
    let grid = ProcGrid::Grid { px: 2, py: 2 };
    // 12x8 over 2x2: tiles 6x4, so a superstep halo fits until b = 4.
    let bound = grid.tile_bound(4, 12, 8).unwrap();
    assert_eq!(bound, 4);
    // The layout-aware tuning space clamps its block axis to the bound.
    let mach = Machine::high_latency(4, 4);
    let space = TuningSpace::for_problem(4, 8, &mach)
        .with_layouts(vec![Partitioning::Grid(grid)])
        .clamp_blocks(bound);
    assert!(space.blocks.iter().all(|&b| b <= bound), "{:?}", space.blocks);
    assert!(space.blocks.contains(&bound));
    // And the transformation stays Theorem-1-checked and value-verified
    // through the bound — and beyond it (wider halos reach past the
    // adjacent tile; the multi-level halo handles that, it just stops
    // being the §2.1 single-neighbour regime the space searches).
    for b in [2u32, bound, bound * 2] {
        let r = Pipeline::new(Heat2d { h: 12, w: 8, steps: 8 })
            .procs(4)
            .partitioning(Partitioning::Grid(grid))
            .block(b)
            .transform()
            .unwrap_or_else(|e| panic!("b={b}: {e}"))
            .execute()
            .unwrap_or_else(|e| panic!("b={b}: {e}"));
        assert!(r.verification.is_verified(), "b={b}");
    }
}

#[test]
fn block_cyclic_and_partitioned_workloads_execute_verified() {
    // Block-cyclic heat2d: tiles dealt round-robin still route every
    // value correctly through the real threaded coordinator.
    let cyclic = ProcGrid::BlockCyclic { px: 2, py: 2, th: 3, tw: 3 };
    let r = Pipeline::new(Heat2d { h: 12, w: 12, steps: 3 })
        .procs(4)
        .partitioning(Partitioning::Grid(cyclic))
        .block(3)
        .transform()
        .unwrap()
        .execute()
        .unwrap();
    assert!(r.verification.is_verified());

    // An rcb+refine-partitioned SpMV executes verified too.
    let a = banded_random(4, 12, 4);
    let r = Pipeline::new(Spmv { matrix: a, steps: 3 })
        .procs(4)
        .partitioning(Partitioning::Graph(Partitioner::RcbRefined))
        .block(3)
        .transform()
        .unwrap()
        .execute()
        .unwrap();
    assert!(r.verification.is_verified());
    assert!(r.messages > 0);
}
