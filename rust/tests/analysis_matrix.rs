//! The static-analysis mutation matrix: the verifier must agree with
//! the engine on every workload the crate ships, and no corrupted plan
//! may pass as clean.
//!
//! For every workload × {naive, overlap, CA} × processor count the test
//! first pins the *healthy* contract — pipeline-built plans analyze
//! clean, their static deadlock verdict matches `try_simulate`, and the
//! analytic critical path equals the simulated makespan on the
//! stateless α-β wire (and still at α=0) while lower-bounding every
//! other wire.  It then corrupts each plan four ways — drop a `Recv`,
//! re-aim a `Recv` at the wrong peer, hoist a dependent `Compute` above
//! its inputs, inflate a `Send`'s word count — and asserts that static
//! analysis never calls the corrupted plan clean and that its deadlock
//! verdict (including the stuck frontier) still matches the engine's.

use std::sync::Arc;

use imp_latency::analysis::{analyze, critical_path, deadlock_check, verify, DeadlockVerdict};
use imp_latency::graph::{ProcId, TaskGraph};
use imp_latency::pipeline::{
    ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy, Workload,
};
use imp_latency::sim::{
    try_simulate, ExecPlan, Machine, NetworkKind, Phase, SimError, UniformCost,
};
use imp_latency::stencil::CsrMatrix;

/// Drop the first `Recv` phase anywhere in the plan.
fn drop_a_recv(plan: &ExecPlan) -> Option<ExecPlan> {
    let mut m = plan.clone();
    for pp in &mut m.per_proc {
        if let Some(i) = pp.phases.iter().position(|ph| matches!(ph, Phase::Recv { .. })) {
            pp.phases.remove(i);
            m.label = format!("{}+drop-recv", plan.label);
            return Some(m);
        }
    }
    None
}

/// Re-aim the first `Recv` at a peer that never feeds it (needs ≥ 3
/// procs so the new peer is neither the old one nor the receiver).
fn swap_a_peer(plan: &ExecPlan) -> Option<ExecPlan> {
    let nprocs = plan.per_proc.len() as u32;
    if nprocs < 3 {
        return None;
    }
    let mut m = plan.clone();
    for (p, pp) in m.per_proc.iter_mut().enumerate() {
        for ph in &mut pp.phases {
            if let Phase::Recv { from, .. } = ph {
                let mut other = (from.0 + 1) % nprocs;
                if other == p as u32 {
                    other = (other + 1) % nprocs;
                }
                if other != from.0 && other != p as u32 {
                    *from = ProcId(other);
                    m.label = format!("{}+swap-peer", plan.label);
                    return Some(m);
                }
            }
        }
    }
    None
}

/// Hoist a processor's last `Compute` phase to the front, ahead of the
/// phases that produce or receive its inputs.
fn hoist_last_compute(plan: &ExecPlan) -> Option<ExecPlan> {
    let mut m = plan.clone();
    for pp in &mut m.per_proc {
        let computes: Vec<usize> = pp
            .phases
            .iter()
            .enumerate()
            .filter(|(_, ph)| matches!(ph, Phase::Compute(_)))
            .map(|(i, _)| i)
            .collect();
        if computes.len() >= 2 {
            let ph = pp.phases.remove(*computes.last().unwrap());
            pp.phases.insert(0, ph);
            m.label = format!("{}+hoist-compute", plan.label);
            return Some(m);
        }
    }
    None
}

/// Inflate the first non-empty `Send`'s word count by duplicating one
/// of its (already available) values.
fn inflate_a_send(plan: &ExecPlan) -> Option<ExecPlan> {
    let mut m = plan.clone();
    for pp in &mut m.per_proc {
        for ph in &mut pp.phases {
            if let Phase::Send { tasks, .. } = ph {
                if let Some(&t0) = tasks.first() {
                    tasks.push(t0);
                    m.label = format!("{}+inflate-send", plan.label);
                    return Some(m);
                }
            }
        }
    }
    None
}

/// The pinning check: the static deadlock verdict — including the stuck
/// frontier — must equal `try_simulate`'s dynamic one.
fn assert_verdicts_agree(g: &TaskGraph, plan: &ExecPlan, mach: &Machine, ctx: &str) {
    let mut net = NetworkKind::AlphaBeta.build(mach);
    let dynamic = try_simulate(g, plan, mach, net.as_mut(), &UniformCost, false);
    match (deadlock_check(plan), dynamic) {
        (DeadlockVerdict::Free, Ok(_)) => {}
        (DeadlockVerdict::Stuck(s), Err(SimError::Deadlock { stuck })) => {
            assert_eq!(s, stuck, "{ctx}: stuck frontiers differ");
        }
        (stat, dynam) => panic!("{ctx}: static {stat:?} vs dynamic {:?}", dynam.map(|_| ())),
    }
}

/// One healthy plan: clean analysis, matching verdicts, and a sound —
/// on the α-β wire exact — critical-path bound.
fn assert_healthy(g: &TaskGraph, plan: &ExecPlan, procs: u32, ctx: &str) {
    let report = analyze(g, plan);
    assert!(report.is_clean(), "{ctx}: {}", report.summary());
    assert!(report.deadlock_free(), "{ctx}");
    assert!(verify(g, plan).is_ok(), "{ctx}");

    for alpha in [50.0, 0.0] {
        let mach = Machine::new(procs, 2, alpha, 0.5, 1.0);
        assert_verdicts_agree(g, plan, &mach, ctx);
        for kind in [NetworkKind::AlphaBeta, NetworkKind::LogGp, NetworkKind::Contended] {
            let mut net = kind.build(&mach);
            let r = try_simulate(g, plan, &mach, net.as_mut(), &UniformCost, false)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            let cp = critical_path(g, plan, &mach, net.as_ref(), &UniformCost)
                .unwrap_or_else(|e| panic!("{ctx}: {e}"));
            assert!(
                cp.makespan <= r.total_time + 1e-9,
                "{ctx}/{}/α={alpha}: lb {} > sim {}",
                kind.label(),
                cp.makespan,
                r.total_time
            );
            if cp.exact_wire {
                assert_eq!(
                    cp.makespan,
                    r.total_time,
                    "{ctx}/{}/α={alpha}: stateless bound must be exact",
                    kind.label()
                );
            }
        }
    }
}

/// One corrupted plan: never clean, verdicts still pinned to the engine.
fn assert_corrupted(g: &TaskGraph, mutated: &ExecPlan, procs: u32) {
    let ctx = &mutated.label;
    let report = analyze(g, mutated);
    assert!(
        !report.is_clean(),
        "{ctx}: corrupted plan passed static analysis as clean"
    );
    let mach = Machine::new(procs, 2, 50.0, 0.5, 1.0);
    assert_verdicts_agree(g, mutated, &mach, ctx);
    // The report and the deadlock verdict must tell the same story.
    assert_eq!(report.deadlock_free(), deadlock_check(mutated).is_free(), "{ctx}");
}

/// Drive one workload through strategies × procs × mutations.
fn exercise<W: Workload + Clone>(workload: W, procs_list: &[u32]) {
    for &procs in procs_list {
        for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
            let mut p = Pipeline::new(workload.clone()).procs(procs).strategy(strategy);
            if strategy == Strategy::Ca {
                p = p.block(2);
            }
            let name = workload.name();
            let ctx = format!("{name} p={procs} {strategy:?}");
            let t = p.transform().unwrap_or_else(|e| panic!("{ctx}: {e}"));
            let (g, plan) = (Arc::clone(&t.graph), Arc::clone(&t.plan));
            assert_healthy(&g, &plan, procs, &ctx);

            let mutations = [
                drop_a_recv(&plan),
                swap_a_peer(&plan),
                hoist_last_compute(&plan),
                inflate_a_send(&plan),
            ];
            let mut applied = 0;
            for mutated in mutations.into_iter().flatten() {
                assert_corrupted(&g, &mutated, procs);
                applied += 1;
            }
            // Every plan in the matrix communicates and computes, so at
            // least the recv-drop, compute-hoist and send-inflate
            // mutations must have applied.
            assert!(applied >= 3, "{ctx}: only {applied} mutations applied");
        }
    }
}

#[test]
fn heat1d_analysis_matrix() {
    exercise(Heat1d::new(48, 6), &[2, 3, 4]);
}

#[test]
fn heat2d_analysis_matrix() {
    exercise(Heat2d { h: 8, w: 8, steps: 4 }, &[2, 4]);
}

#[test]
fn moore2d_analysis_matrix() {
    exercise(Moore2d { h: 8, w: 8, steps: 4 }, &[2, 4]);
}

#[test]
fn spmv_analysis_matrix() {
    exercise(Spmv { matrix: CsrMatrix::laplace2d(6, 6), steps: 4 }, &[2, 4]);
}

#[test]
fn cg_analysis_matrix() {
    exercise(ConjugateGradient { unknowns: 24, iters: 2 }, &[2, 3]);
}

#[test]
fn word_inflation_is_a_warning_not_a_false_deadlock() {
    // The inflated-send mutation misroutes payload but cannot block the
    // engine; the analyzer must classify it below Fatal so `verify`
    // still passes while `analyze` reports it.
    let t = Pipeline::new(Heat1d::new(32, 4)).procs(4).block(2).transform().unwrap();
    let mutated = inflate_a_send(&t.plan).expect("CA plans send");
    let report = analyze(&t.graph, &mutated);
    assert!(!report.is_clean());
    assert!(report.is_safe(), "{}", report.summary());
    assert!(report.deadlock_free());
    assert!(report.warning_count() > 0);
    assert!(verify(&t.graph, &mutated).is_ok());
}

#[test]
fn dropped_recv_is_caught_statically_before_the_engine_would_misroute() {
    // Dropping a receive never deadlocks the engine (sends don't block),
    // which is exactly why the static census must catch it instead.
    let t = Pipeline::new(Heat1d::new(32, 4)).procs(4).strategy(Strategy::Naive)
        .transform()
        .unwrap();
    let mutated = drop_a_recv(&t.plan).expect("naive plans receive");
    let mach = Machine::new(4, 2, 50.0, 0.5, 1.0);
    let mut net = NetworkKind::AlphaBeta.build(&mach);
    assert!(try_simulate(&t.graph, &mutated, &mach, net.as_mut(), &UniformCost, false).is_ok());
    let report = analyze(&t.graph, &mutated);
    assert!(!report.is_clean(), "{}", report.summary());
}
