//! The workload × strategy × procs matrix: every `Workload` the crate
//! ships runs end to end through the `Pipeline` builder under naive,
//! overlap, and communication-avoiding plans at 2–4 processors.
//!
//! For each cell the test asserts `run_and_verify`-style correctness
//! (every owner-held value equals the sequential reference — `execute()`
//! errors otherwise) plus `check_schedule` well-formedness of the
//! whole-graph §3 schedule (CA plans additionally get the per-superstep
//! Theorem-1 check inside `transform()` itself).

use imp_latency::pipeline::{
    ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy, Workload,
};
use imp_latency::sim::Machine;
use imp_latency::stencil::CsrMatrix;
use imp_latency::transform::check_schedule;

/// Drive one workload through the full matrix.
fn exercise<W: Workload + Clone>(workload: W, blocks: &[u32]) {
    for procs in [2u32, 4] {
        for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
            // Naive/overlap take no block factor; CA runs whole-depth
            // (None) plus every requested b.
            let bs: Vec<Option<u32>> = match strategy {
                Strategy::Ca => {
                    std::iter::once(None).chain(blocks.iter().map(|&b| Some(b))).collect()
                }
                _ => vec![None],
            };
            for b in bs {
                let mut p = Pipeline::new(workload.clone()).procs(procs).strategy(strategy);
                if let Some(b) = b {
                    p = p.block(b);
                }
                let name = workload.name();
                let ctx = format!("{name} p={procs} {strategy:?} b={b:?}");
                let t = p.transform().unwrap_or_else(|e| panic!("{ctx}: {e}"));

                // Well-formedness of the whole-graph schedule.
                if let Some(s) = t.full_schedule() {
                    check_schedule(&t.graph, &s)
                        .unwrap_or_else(|v| panic!("{ctx}: Theorem 1 violated: {v}"));
                }

                // Real execution, verified against the reference.
                let real = t.execute().unwrap_or_else(|e| panic!("{ctx}: {e}"));
                assert!(real.verification.is_verified(), "{ctx}");
                assert!(
                    real.executed_tasks >= t.stats().tasks,
                    "{ctx}: under-executes the graph"
                );

                // And the simulator accepts the same plan.
                let sim = t.simulate(&Machine::new(procs, 4, 50.0, 0.1, 1.0));
                assert!(sim.time.value().is_finite() && sim.time.value() > 0.0, "{ctx}");
                assert_eq!(sim.messages, real.messages, "{ctx}: sim/real traffic disagree");
            }
        }
    }
}

#[test]
fn heat1d_matrix() {
    exercise(Heat1d::new(48, 6), &[2, 3]);
}

#[test]
fn heat1d_radius2_matrix() {
    exercise(Heat1d { n: 40, steps: 4, radius: 2 }, &[2]);
}

#[test]
fn heat2d_matrix() {
    exercise(Heat2d { h: 8, w: 8, steps: 4 }, &[2]);
}

#[test]
fn moore2d_matrix() {
    exercise(Moore2d { h: 8, w: 8, steps: 4 }, &[2]);
}

#[test]
fn spmv_matrix() {
    exercise(Spmv { matrix: CsrMatrix::laplace2d(6, 6), steps: 4 }, &[2]);
}

#[test]
fn cg_matrix() {
    exercise(ConjugateGradient { unknowns: 24, iters: 2 }, &[2, 3]);
}

#[test]
fn moore2d_needs_diagonal_traffic_at_b1() {
    // The new workload's signature makes corners *direct* dependencies:
    // even the naive per-level exchange moves diagonal values, which the
    // five-point heat2d does not at matching geometry.
    let nine = Pipeline::new(Moore2d { h: 8, w: 8, steps: 2 }).procs(4).block(1);
    let five = Pipeline::new(Heat2d { h: 8, w: 8, steps: 2 }).procs(4).block(1);
    let rn = nine.transform().unwrap().execute().unwrap();
    let rf = five.transform().unwrap().execute().unwrap();
    assert!(
        rn.words > rf.words,
        "nine-point should move more ghost data: {} vs {}",
        rn.words,
        rf.words
    );
}

#[test]
fn blocking_cuts_messages_for_every_workload() {
    // The (M/b)·α effect must hold across the whole zoo (CG excepted:
    // its AllToAll levels force traffic regardless of blocking).
    fn msgs<W: Workload + Clone>(w: W, b: u32) -> usize {
        Pipeline::new(w).procs(4).block(b).transform().unwrap().execute().unwrap().messages
    }
    assert!(msgs(Heat1d::new(64, 4), 4) < msgs(Heat1d::new(64, 4), 1));
    assert!(msgs(Heat2d { h: 8, w: 8, steps: 4 }, 4) < msgs(Heat2d { h: 8, w: 8, steps: 4 }, 1));
    assert!(
        msgs(Moore2d { h: 8, w: 8, steps: 4 }, 4) < msgs(Moore2d { h: 8, w: 8, steps: 4 }, 1)
    );
    let a = CsrMatrix::laplace2d(6, 6);
    assert!(
        msgs(Spmv { matrix: a.clone(), steps: 4 }, 4) < msgs(Spmv { matrix: a, steps: 4 }, 1)
    );
}
