//! Bench: regenerate paper **figure 7** — strong-scaling runtime vs.
//! threads per node at *moderate* latency (α = 8γ).
//!
//! Series: naive, overlap, CA at b ∈ {2,4,8}.  The analytic sweep is
//! cross-validated against the discrete-event simulator at sample points,
//! and the paper's qualitative claim (gain only at very high thread
//! counts) is asserted.  Output: table + ASCII plot + `results/fig7.csv`.

use imp_latency::config::preset_fig7;
use imp_latency::figures::fig78_sweep;
use imp_latency::sim::{simulate, ExecPlan, Machine};
use imp_latency::stencil::heat1d_graph;
use imp_latency::transform::TransformOptions;

fn main() {
    let cfg = preset_fig7();
    let t0 = std::time::Instant::now();
    let fig = fig78_sweep(&cfg).expect("sweep");
    let sweep_secs = t0.elapsed().as_secs_f64();

    println!("figure 7 — runtime vs threads/node, moderate latency (α=8γ, N=65536, M=64, p=16)");
    print!("{}", fig.to_table());
    print!("{}", fig.to_ascii_plot(14));
    fig.write_csv("results/fig7.csv").expect("write csv");
    println!("wrote results/fig7.csv  (sweep took {sweep_secs:.2}s)");

    // Cross-validate one sample point against the discrete simulator on a
    // scaled-down problem with the same α/γ regime.
    let g = heat1d_graph(4096, 16, 8);
    let m = Machine::new(8, 64, 8.0, 0.1, 1.0);
    let naive = simulate(&g, &ExecPlan::naive(&g), &m, false).total_time;
    let ca = simulate(
        &g,
        &ExecPlan::ca(&g, 8, TransformOptions::default()).unwrap(),
        &m,
        false,
    )
    .total_time;
    println!(
        "discrete-sim spot check (n=4096, t=64): naive {naive:.1}, ca(b=8) {ca:.1} → {}",
        if ca < naive { "CA wins at high threads ✓" } else { "CA does not win (!)" }
    );

    // Claim (a): at the low-thread end, blocking gives no meaningful gain.
    let (_, first) = &fig.rows[0];
    let best_ca = first[2..].iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        best_ca >= first[0] * 0.98,
        "moderate latency must show no gain at 1 thread: ca {best_ca} vs naive {}",
        first[0]
    );
    let (_, last) = fig.rows.last().unwrap();
    let best_ca_hi = last[2..].iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(best_ca_hi < last[0], "CA must win at max threads");
    println!("figure-7 shape claims hold ✓");
}
