//! Bench: L3 performance — transformation and simulator throughput.
//!
//! The "communication avoiding compiler" must scale to real task graphs:
//! this bench times graph construction, the §3 transformation, the
//! Theorem-1 checker and the discrete-event simulator on 1-D stencil
//! graphs from 10⁴ to ~4·10⁶ tasks, reporting tasks/second.
//!
//! Perf targets (DESIGN.md §7): transform ≥ 1M tasks/s, simulator ≥ 1M
//! task-events/s.  Output: `results/transform_scalability.csv`.

use imp_latency::sim::{simulate, ExecPlan, Machine};
use imp_latency::stencil::heat1d_graph;
use imp_latency::transform::{check_schedule, communication_avoiding_default};
use imp_latency::util::{Csv, Timer};

fn main() {
    println!("transform / simulator throughput (1-D stencil graphs, p=16)");
    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "tasks", "edges", "build(s)", "xform(s)", "Mtasks/s", "check(s)", "sim Mev/s"
    );
    let mut csv = Csv::new(&[
        "tasks",
        "build_s",
        "transform_s",
        "transform_mtasks_per_s",
        "check_s",
        "sim_mevents_per_s",
    ]);
    let p = 16u32;
    let mut last_rate = 0.0;
    for (n, m) in [(1u64 << 10, 16u32), (1 << 13, 32), (1 << 15, 32), (1 << 17, 32)] {
        let tb = Timer::start();
        let g = heat1d_graph(n, m, p);
        let build = tb.elapsed_s();

        let tx = Timer::start();
        let s = communication_avoiding_default(&g);
        let xform = tx.elapsed_s();

        let tc = Timer::start();
        check_schedule(&g, &s).expect("well-formed");
        let check = tc.elapsed_s();

        // Simulator throughput on the naive plan (one event per task/level).
        let plan = ExecPlan::naive(&g);
        let mach = Machine::new(p, 8, 100.0, 0.1, 1.0);
        let ts = Timer::start();
        let r = simulate(&g, &plan, &mach, false);
        let sim = ts.elapsed_s();
        let sim_rate = plan.executed_tasks() as f64 / sim / 1e6;

        let rate = g.len() as f64 / xform / 1e6;
        last_rate = rate;
        println!(
            "{:>10} {:>10} {:>12.3} {:>12.3} {:>12.2} {:>12.3} {:>12.2}",
            g.len(),
            g.num_edges(),
            build,
            xform,
            rate,
            check,
            sim_rate
        );
        csv.rowf(&[g.len() as f64, build, xform, rate, check, sim_rate]);
        let _ = r;
    }
    csv.write_file("results/transform_scalability.csv").expect("write csv");
    println!("wrote results/transform_scalability.csv");
    println!(
        "largest-graph transform rate: {last_rate:.2} Mtasks/s (target ≥ 1.0) {}",
        if last_rate >= 1.0 { "✓" } else { "✗ BELOW TARGET" }
    );
}
