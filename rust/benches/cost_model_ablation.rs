//! Bench: validate the paper's **§2.1 cost analysis** against the
//! simulator.
//!
//! `T(b) = (M/b)α + Mβ + (MN/p + Mb)γ` describes the *non-overlapped*
//! blocked execution (figure 1 without figure 2's overlap), so the model
//! is validated against the sequential-phase evaluator; the overlapped
//! evaluator is reported alongside to show what overlap buys on top
//! (its optimum is flatter — once α hides behind L², growing b only adds
//! redundant work).
//!
//! Sweeps the latency/compute ratio α/γ and per point compares the cost
//! model's discrete optimum, its architectural prediction `b* = sqrt(α·t/γ)`,
//! and the simulator's measured optimum.  Also verifies §2.1's claim that
//! the optimum is independent of `N` and `p`.
//! Output: `results/cost_model.csv`.

use imp_latency::cost::CostModel;
use imp_latency::sim::{ca_time_for, ca_time_sequential_for, naive_time_1d, Machine};
use imp_latency::stencil::heat1d_graph;
use imp_latency::transform::TransformOptions;
use imp_latency::util::Csv;

const BGRID: [u32; 7] = [1, 2, 4, 8, 16, 32, 64];

fn best_b(n: u64, m: u32, mach: &Machine, overlap: bool) -> (u32, f64) {
    let g = heat1d_graph(n, m, mach.nprocs);
    let mut best = (1u32, naive_time_1d(n, m, mach));
    for &b in &BGRID[1..] {
        if m % b != 0 || 2 * b as u64 >= n / mach.nprocs as u64 {
            continue;
        }
        let t = if overlap {
            ca_time_for(&g, b, TransformOptions::default(), mach)
        } else {
            ca_time_sequential_for(&g, b, TransformOptions::default(), mach)
        };
        if t < best.1 {
            best = (b, t);
        }
    }
    best
}

fn grid_pos(b: u32) -> usize {
    BGRID.iter().position(|&x| x >= b).unwrap_or(BGRID.len() - 1)
}

fn main() {
    println!("§2.1 cost-model ablation: optimal block factor vs latency ratio");
    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "alpha", "model b*", "seq-sim b*", "ovl-sim b*", "sqrt(at/g)", "seq speedup", "ovl speedup"
    );
    let (n, m, p, threads) = (8192u64, 64u32, 8u32, 16u32);
    let mut csv = Csv::new(&[
        "alpha",
        "model_b",
        "seq_sim_b",
        "overlap_sim_b",
        "continuous_b",
        "seq_speedup",
        "overlap_speedup",
    ]);
    for &alpha in &[2.0, 8.0, 32.0, 128.0, 512.0, 2048.0] {
        let mach = Machine::new(p, threads, alpha, 0.1, 1.0);
        let model = CostModel::from_machine(n, m, &mach);
        let mb = model.optimal_b(64);
        let (sb, st) = best_b(n, m, &mach, false);
        let (ob, ot) = best_b(n, m, &mach, true);
        let naive = naive_time_1d(n, m, &mach);
        let cont = model.optimal_b_continuous();
        println!(
            "{alpha:>10.0} {mb:>10} {sb:>12} {ob:>12} {cont:>12.1} {:>12.2} {:>12.2}",
            naive / st,
            naive / ot
        );
        csv.rowf(&[alpha, mb as f64, sb as f64, ob as f64, cont, naive / st, naive / ot]);
        // The model's optimum must land within one b-grid step of the
        // sequential simulator's.
        assert!(
            grid_pos(mb).abs_diff(grid_pos(sb)) <= 1,
            "model b*={mb} vs sequential-sim b*={sb} at alpha={alpha}"
        );
    }
    csv.write_file("results/cost_model.csv").expect("write csv");
    println!("wrote results/cost_model.csv");
    println!("model optimum tracks the (non-overlapped) simulator within one grid step ✓");

    // Claim: optimal b independent of N and p (architecture-only).
    let alpha = 128.0;
    let mut optima = Vec::new();
    for (n, p) in [(4096u64, 4u32), (8192, 8), (32768, 16)] {
        let mach = Machine::new(p, threads, alpha, 0.1, 1.0);
        optima.push(best_b(n, m, &mach, false).0);
    }
    println!("sequential-sim b* across (N,p) at alpha=128: {optima:?}");
    let spread = optima.iter().max().unwrap() / optima.iter().min().unwrap();
    assert!(spread <= 2, "optimal b should be (nearly) problem-independent: {optima:?}");
    println!("optimal b is problem-independent (within one grid step) ✓");
}
