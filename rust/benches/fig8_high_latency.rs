//! Bench: regenerate paper **figure 8** — strong-scaling runtime vs.
//! threads per node at *high* latency (α = 500γ), plus the joint
//! figure-7/8 claims check (crossover moves left, gain grows).
//!
//! Output: table + ASCII plot + `results/fig8.csv`.

use imp_latency::config::{preset_fig7, preset_fig8};
use imp_latency::figures::{check_fig78_claims, fig78_sweep};
use imp_latency::sim::{simulate, ExecPlan, Machine};
use imp_latency::stencil::heat1d_graph;
use imp_latency::transform::TransformOptions;

fn main() {
    let fig = fig78_sweep(&preset_fig8()).expect("sweep");
    println!("figure 8 — runtime vs threads/node, high latency (α=500γ, N=65536, M=64, p=16)");
    print!("{}", fig.to_table());
    print!("{}", fig.to_ascii_plot(14));
    fig.write_csv("results/fig8.csv").expect("write csv");
    println!("wrote results/fig8.csv");

    // Discrete-sim cross-check at a moderate thread count: blocking must
    // already win (the paper's figure-8 observation).
    let g = heat1d_graph(4096, 16, 8);
    let m = Machine::new(8, 8, 500.0, 0.1, 1.0);
    let naive = simulate(&g, &ExecPlan::naive(&g), &m, false).total_time;
    let ca = simulate(
        &g,
        &ExecPlan::ca(&g, 8, TransformOptions::default()).unwrap(),
        &m,
        false,
    )
    .total_time;
    println!("discrete-sim spot check (t=8): naive {naive:.1}, ca(b=8) {ca:.1}");
    assert!(ca < naive, "high latency: CA must win at moderate thread counts");

    // The joint claims of §4 across both figures.
    let f7 = fig78_sweep(&preset_fig7()).expect("sweep");
    match check_fig78_claims(&f7, &fig) {
        Ok(v) => println!("{v} ✓"),
        Err(e) => panic!("figure-7/8 claims FAILED: {e}"),
    }
}
