//! Bench: the request-path hot loop — PJRT dispatch latency and
//! end-to-end distributed throughput.
//!
//! Measures (a) single-artifact execute latency per blocked-kernel
//! variant (the per-superstep dispatch cost the coordinator pays), and
//! (b) whole-system points·steps/second of the real distributed heat
//! run per block factor — the end-to-end counterpart of figures 7/8 on
//! this host.  Output: `results/runtime_hotpath.csv`.

use imp_latency::coordinator::heat1d::{run, Heat1dConfig};
use imp_latency::runtime::{Registry, Runtime, Value};
use imp_latency::util::{Csv, Timer};

fn main() {
    let dir = Registry::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts not built; run `make artifacts` first");
        std::process::exit(2);
    }

    // ---- (a) dispatch latency per artifact --------------------------------
    let rt = Runtime::new(&dir).expect("runtime");
    println!("PJRT dispatch latency (n=2048 tile, 100 reps after warmup):");
    println!("{:>14} {:>12} {:>14} {:>16}", "artifact", "µs/call", "steps/call", "points·steps/s");
    let mut csv = Csv::new(&["artifact", "us_per_call", "points_steps_per_s"]);
    for b in [1u32, 2, 4, 8] {
        let name = format!("heat1d_n2048_b{b}");
        let tile = vec![0.5f32; 2048 + 2 * b as usize];
        let nu = Value::scalar(0.2);
        rt.execute_f32_1(&name, &[Value::F32(tile.clone()), nu.clone()]).unwrap(); // warm
        let reps = 100;
        let t = Timer::start();
        for _ in 0..reps {
            rt.execute_f32_1(&name, &[Value::F32(tile.clone()), nu.clone()]).unwrap();
        }
        let us = t.elapsed_us() / reps as f64;
        let rate = 2048.0 * b as f64 / (us * 1e-6);
        println!("{name:>14} {us:>12.1} {b:>14} {rate:>16.3e}");
        csv.rowf(&[b as f64, us, rate]);
    }

    // ---- (b) end-to-end distributed throughput ----------------------------
    println!("\nend-to-end distributed heat (N=16384, M=256, 8 workers):");
    println!(
        "{:>4} {:>10} {:>10} {:>10} {:>10} {:>8} {:>16}",
        "b", "wall(s)", "steady(s)", "exch(s)", "comp(s)", "msgs", "points·steps/s"
    );
    let mut e2e = Csv::new(&[
        "b",
        "wall_s",
        "steady_s",
        "exchange_s",
        "compute_s",
        "messages",
        "steady_rate",
    ]);
    let n = 2048 * 8;
    let init: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.003).sin()).collect();
    for b in [1u32, 2, 4, 8] {
        let cfg = Heat1dConfig {
            n_per_worker: 2048,
            workers: 8,
            b,
            steps: 256,
            nu: 0.2,
            artifacts_dir: dir.clone(),
        };
        let (_, stats) = run(&cfg, &init).expect("run");
        // Steady-state rate: exclude the pay-once PJRT setup, which a
        // long-running service amortizes.
        let rate = n as f64 * 256.0 / stats.steady_secs();
        println!(
            "{b:>4} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>8} {:>16.3e}",
            stats.wall_secs,
            stats.steady_secs(),
            stats.exchange_secs,
            stats.compute_secs,
            stats.messages,
            rate
        );
        e2e.rowf(&[
            b as f64,
            stats.wall_secs,
            stats.steady_secs(),
            stats.exchange_secs,
            stats.compute_secs,
            stats.messages as f64,
            rate,
        ]);
    }
    csv.write_file("results/runtime_dispatch.csv").expect("csv");
    e2e.write_file("results/runtime_hotpath.csv").expect("csv");
    println!("\nwrote results/runtime_dispatch.csv, results/runtime_hotpath.csv");
}
