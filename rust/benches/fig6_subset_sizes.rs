//! Bench: regenerate paper **figures 1–6** — the structural figures.
//!
//! For a 1-D heat-equation processor this prints the k₁/k₂/k₃ grid
//! (figure 6), checks the subset sizes against the closed-form trapezoid
//! geometry, and tabulates the figure-1 vs. figure-3 trade (level-0 halo
//! vs. multi-level halo: redundancy vs. message volume) over block
//! factors — the ablation DESIGN.md calls out.

use imp_latency::figures;
use imp_latency::stencil::heat1d_graph;
use imp_latency::transform::{
    communication_avoiding, ScheduleStats, TransformOptions,
};
use imp_latency::util::Csv;

fn main() {
    // ---- Figure 6 proper -------------------------------------------------
    let (text, d) = figures::fig6(64, 6, 4).expect("figure-6 configuration is valid");
    print!("{text}");

    // Closed-form check: for a middle processor with n_p points and depth
    // b, L4 = Σ_{s=1..b} max(0, n_p − 2s).
    let (n_p, b) = (16i64, 6i64);
    let l4: i64 = (1..=b).map(|s| (n_p - 2 * s).max(0)).sum();
    assert_eq!((d.k1 + d.k2) as i64, l4, "trapezoid size");
    println!("closed-form trapezoid check: k1+k2 = Σ max(0, n_p − 2s) = {l4} ✓\n");

    // ---- Figures 1/3 ablation: halo mode trade over b ---------------------
    println!("figure 1 vs figure 3 — redundancy/communication trade per block factor");
    println!(
        "{:>4} {:>14} {:>14} {:>12} {:>12} {:>10} {:>10}",
        "b", "redund(lvl0)", "redund(multi)", "words(lvl0)", "words(multi)", "msgs", "msgs(naive)"
    );
    let mut csv = Csv::new(&[
        "b",
        "redundant_level0",
        "redundant_multilevel",
        "words_level0",
        "words_multilevel",
        "messages",
        "naive_messages",
    ]);
    for b in [2u32, 4, 8, 16] {
        let g = heat1d_graph(256, b, 4);
        let s0 = communication_avoiding(&g, TransformOptions::level0());
        let sm = communication_avoiding(&g, TransformOptions::default());
        let st0 = ScheduleStats::compute(&g, &s0);
        let stm = ScheduleStats::compute(&g, &sm);
        assert!(stm.redundant_tasks <= st0.redundant_tasks);
        assert_eq!(st0.messages, stm.messages, "same message count, different payload");
        println!(
            "{b:>4} {:>14} {:>14} {:>12} {:>12} {:>10} {:>10}",
            st0.redundant_tasks,
            stm.redundant_tasks,
            st0.words,
            stm.words,
            stm.messages,
            stm.naive_messages
        );
        csv.rowf(&[
            b as f64,
            st0.redundant_tasks as f64,
            stm.redundant_tasks as f64,
            st0.words as f64,
            stm.words as f64,
            stm.messages as f64,
            stm.naive_messages as f64,
        ]);
    }
    csv.write_file("results/fig6_subsets.csv").expect("write csv");
    println!("\nwrote results/fig6_subsets.csv");

    // Redundancy per superstep grows ~ b² (paper §2.1's b²/2 per side).
    let quad = |b: u32| {
        let g = heat1d_graph(256, b, 4);
        let s = communication_avoiding(&g, TransformOptions::level0());
        ScheduleStats::compute(&g, &s).redundant_tasks as f64
    };
    let (r4, r8) = (quad(4), quad(8));
    let growth = r8 / r4;
    println!("redundancy growth from b=4 to b=8: {growth:.2}x (quadratic trend ⇒ ≈4x) ✓");
    assert!(growth > 3.0 && growth < 5.0, "{growth}");
}
