//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension` (a native library baked into the
//! AOT build image, not into this repository), so an offline checkout
//! could not compile against it.  This stub reproduces exactly the API
//! surface `imp_latency::runtime` uses; every entry point that would
//! touch PJRT returns an "unavailable" error instead.
//!
//! That degradation is safe by construction: the coordinator only reaches
//! PJRT through [`imp_latency::runtime::Runtime`], whose constructor
//! first loads `artifacts/manifest.txt` — absent whenever the stub is in
//! use — so all artifact-backed tests and examples skip gracefully long
//! before any of these stubs run.  Swapping the real crate back in is a
//! one-line change in the root `Cargo.toml`.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT backend unavailable: built against the offline `xla` stub \
     (run on the AOT image with the real xla_extension crate for real execution)";

/// Error type of every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types a [`Literal`] can carry (the subset the repo uses).
pub trait NativeType: sealed::Sealed + Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// A PJRT client (the real one is `Rc`-based and thread-local).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("unavailable"));
    }
}
