//! Vendored offline stand-in for the `anyhow` crate.
//!
//! The build must work with no network access, so the repository vendors
//! the small subset of `anyhow` it actually uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the [`anyhow!`]/[`bail!`] macros.
//! Semantics match the real crate where the repo depends on them:
//!
//! * `Error` is constructible from any `std::error::Error + Send + Sync`
//!   via `?` (and from messages via the macros);
//! * `.context(..)` / `.with_context(..)` wrap an error with an outer
//!   message, preserved as a chain;
//! * `Display` renders the chain outermost-first, `": "`-joined, so CLI
//!   error lines carry the full story.
//!
//! Intentionally *not* implemented: downcasting, backtraces, `ensure!`.

use std::fmt;

/// A chainable, type-erased error (message chain, outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M>(msg: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message of the chain.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion legal.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("base failure {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "base failure 42");
    }

    #[test]
    fn context_chains() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: base failure 42");
        assert_eq!(e.root_message(), "outer");
    }

    #[test]
    fn with_context_lazy() {
        let e = fails().with_context(|| format!("worker {}", 3)).unwrap_err();
        assert!(e.to_string().starts_with("worker 3: "));
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn anyhow_single_expr() {
        let msg = String::from("plain");
        assert_eq!(anyhow!(msg).to_string(), "plain");
    }
}
