//! Execution traces: ASCII Gantt charts, Chrome-trace export, and CSV
//! series for the figures.
//!
//! ## Module map (record → aggregate → export)
//!
//! This module is the *export* end of the observability story.  The
//! simulator records [`BusySpan`]s as it runs; [`crate::telemetry`]
//! records [`crate::telemetry::SpanRecord`]s (serve request lifecycles
//! and phases, tuner search/eval timelines, engine samples) and
//! aggregates scalars in its registry.  Here they fan out to renderers:
//!
//! | item | input | output |
//! |------|-------|--------|
//! | [`gantt_ascii`] | sim spans | terminal Gantt chart |
//! | [`chrome_trace_json`] | sim spans | Chrome/Perfetto JSON |
//! | [`chrome_trace_with_telemetry`] | sim + telemetry spans | one combined Chrome/Perfetto JSON |
//! | [`chrome_trace_with_flows`] | sim spans + [`MessageFlow`]s | Chrome/Perfetto JSON with critical-path flow arrows |
//! | [`summary_line`] | a `SimResult` | one-line summary |
//! | [`FigureSeries`] | figure data | CSV / ASCII table / ASCII plot |
//! | [`compare_bench_files`] | `BENCH_*.json` vs `BENCH_baseline/` | per-metric drift report |
//!
//! (Prometheus text exposition lives with the registry itself:
//! `telemetry::Registry::prometheus`.)

mod chrome;
mod compare;

pub use chrome::{
    chrome_trace_json, chrome_trace_with_flows, chrome_trace_with_telemetry, write_chrome_trace,
    write_chrome_trace_with_flows, write_chrome_trace_with_telemetry, MessageFlow,
};
pub use compare::{compare_bench_files, compare_documents, numeric_leaves};

use crate::sim::{BusySpan, SimResult};
use crate::util::Csv;

/// Render per-processor thread activity as an ASCII Gantt chart.
///
/// Each row is one (proc, thread); time is quantized into `width` columns;
/// `#` marks compute, `.` marks waiting in a receive, space is idle.
///
/// Degenerate inputs (no spans, a zero/negative/NaN `total_time`, or a
/// zero-column `width`) all render the empty placeholder rather than
/// panicking or emitting a `NaN` header.
pub fn gantt_ascii(spans: &[BusySpan], total_time: f64, width: usize) -> String {
    if spans.is_empty() || width == 0 || total_time.is_nan() || total_time <= 0.0 {
        return String::from("(no spans recorded)\n");
    }
    let mut keys: Vec<(u32, u32)> = spans.iter().map(|s| (s.proc, s.thread)).collect();
    keys.sort_unstable();
    keys.dedup();
    let scale = width as f64 / total_time;
    let mut out = String::new();
    out.push_str(&format!("time 0 .. {total_time:.1} ({width} cols)\n"));
    for (p, t) in keys {
        let mut row = vec![b' '; width];
        for s in spans.iter().filter(|s| s.proc == p && s.thread == t) {
            let a = ((s.start * scale) as usize).min(width - 1);
            let b = ((s.end * scale).ceil() as usize).clamp(a + 1, width);
            let ch = if s.what == "wait" { b'.' } else { b'#' };
            for c in &mut row[a..b] {
                // compute wins over wait in shared cells
                if *c != b'#' {
                    *c = ch;
                }
            }
        }
        out.push_str(&format!("p{p:<2}t{t:<2} |{}|\n", String::from_utf8(row).unwrap()));
    }
    out
}

/// Summarize a [`SimResult`] in one line (used by the CLI and examples).
pub fn summary_line(label: &str, r: &SimResult) -> String {
    format!(
        "{label:<12} time {:>12.1}   msgs {:>6}   words {:>8}   max wait {:>10.1}",
        r.total_time,
        r.messages,
        r.words,
        r.proc_wait.iter().copied().fold(0.0, f64::max),
    )
}

/// A figure series: one x column and one y column per labelled strategy.
pub struct FigureSeries {
    pub xlabel: String,
    pub labels: Vec<String>,
    pub rows: Vec<(f64, Vec<f64>)>,
}

impl FigureSeries {
    pub fn new(xlabel: &str, labels: &[&str]) -> Self {
        FigureSeries {
            xlabel: xlabel.to_string(),
            labels: labels.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.labels.len());
        self.rows.push((x, ys));
    }

    /// Render as CSV (header = xlabel + series labels).
    pub fn to_csv(&self) -> String {
        let mut header: Vec<&str> = vec![self.xlabel.as_str()];
        header.extend(self.labels.iter().map(|s| s.as_str()));
        let mut csv = Csv::new(&header);
        for (x, ys) in &self.rows {
            let mut row = vec![*x];
            row.extend(ys.iter().copied());
            csv.rowf(&row);
        }
        csv.finish()
    }

    /// Render as an ASCII table (fixed-width columns, for terminal output).
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:>10}", self.xlabel));
        for l in &self.labels {
            out.push_str(&format!("{l:>14}"));
        }
        out.push('\n');
        for (x, ys) in &self.rows {
            out.push_str(&format!("{x:>10.0}"));
            for y in ys {
                out.push_str(&format!("{y:>14.1}"));
            }
            out.push('\n');
        }
        out
    }

    /// Crude ASCII line plot (log-y), one glyph per series.
    pub fn to_ascii_plot(&self, height: usize) -> String {
        if self.rows.is_empty() {
            return String::new();
        }
        let glyphs = ['*', 'o', '+', 'x', '@', '%', '&', '~'];
        let all: Vec<f64> =
            self.rows.iter().flat_map(|(_, ys)| ys.iter().copied()).filter(|y| *y > 0.0).collect();
        let (lo, hi) = all
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &y| (lo.min(y), hi.max(y)));
        let (llo, lhi) = (lo.ln(), hi.ln().max(lo.ln() + 1e-9));
        let cols = self.rows.len();
        let mut grid = vec![vec![' '; cols]; height];
        for (ci, (_, ys)) in self.rows.iter().enumerate() {
            for (si, &y) in ys.iter().enumerate() {
                if y <= 0.0 {
                    continue;
                }
                let fr = (y.ln() - llo) / (lhi - llo);
                let r = ((1.0 - fr) * (height - 1) as f64).round() as usize;
                grid[r][ci] = glyphs[si % glyphs.len()];
            }
        }
        let mut out = String::new();
        out.push_str(&format!("log-scale runtime: {:.1} (top) .. {:.1} (bottom)\n", hi, lo));
        for row in grid {
            out.push('|');
            out.extend(row);
            out.push('\n');
        }
        out.push('+');
        out.extend(std::iter::repeat_n('-', cols));
        out.push('\n');
        let legend: Vec<String> = self
            .labels
            .iter()
            .enumerate()
            .map(|(i, l)| format!("{} {}", glyphs[i % glyphs.len()], l))
            .collect();
        out.push_str(&format!("x: {} | {}\n", self.xlabel, legend.join("  ")));
        out
    }

    /// Write the CSV to `path`.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(p: u32, t: u32, a: f64, b: f64, what: &'static str) -> BusySpan {
        BusySpan { proc: p, thread: t, start: a, end: b, what }
    }

    #[test]
    fn gantt_renders_rows() {
        let spans =
            vec![span(0, 0, 0.0, 5.0, "compute"), span(1, 0, 5.0, 10.0, "wait")];
        let g = gantt_ascii(&spans, 10.0, 20);
        assert!(g.contains("p0 t0") || g.contains("p0"));
        assert!(g.contains('#'));
        assert!(g.contains('.'));
    }

    #[test]
    fn gantt_empty() {
        assert!(gantt_ascii(&[], 0.0, 10).contains("no spans"));
    }

    #[test]
    fn gantt_degenerate_inputs_render_the_placeholder() {
        let spans = vec![span(0, 0, 0.0, 5.0, "compute")];
        // width == 0 used to underflow at `.min(width - 1)`.
        assert_eq!(gantt_ascii(&spans, 10.0, 0), "(no spans recorded)\n");
        // NaN total_time used to sail past the `<= 0.0` guard and
        // render a NaN header with an all-idle chart.
        assert_eq!(gantt_ascii(&spans, f64::NAN, 20), "(no spans recorded)\n");
        assert_eq!(gantt_ascii(&spans, -3.0, 20), "(no spans recorded)\n");
    }

    #[test]
    fn series_csv_roundtrip() {
        let mut f = FigureSeries::new("threads", &["naive", "ca"]);
        f.push(1.0, vec![100.0, 80.0]);
        f.push(2.0, vec![60.0, 30.0]);
        let csv = f.to_csv();
        assert!(csv.starts_with("threads,naive,ca\n"));
        assert!(csv.contains("2,60,30"));
    }

    #[test]
    fn series_table_and_plot() {
        let mut f = FigureSeries::new("threads", &["naive"]);
        f.push(1.0, vec![100.0]);
        f.push(2.0, vec![10.0]);
        assert!(f.to_table().contains("naive"));
        let plot = f.to_ascii_plot(5);
        assert!(plot.contains('*'));
    }
}
