//! Chrome-trace (about://tracing, Perfetto) export of simulation spans.
//!
//! Hand-rolled JSON (no serde in the vendored crate set): each busy span
//! becomes a complete ("X") event; processors map to pids, threads to
//! tids; waits are colourable by name.

use crate::sim::BusySpan;

/// Render spans as a Chrome trace JSON array (`traceEvents` format).
/// Times are interpreted as microseconds (the format's unit).
pub fn chrome_trace_json(spans: &[BusySpan]) -> String {
    let mut out = String::from("[\n");
    for (i, s) in spans.iter().enumerate() {
        let dur = (s.end - s.start).max(0.0);
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"pid\": {}, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}{}\n",
            s.what,
            s.proc,
            s.thread,
            s.start,
            dur,
            if i + 1 == spans.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    out
}

/// Write the Chrome trace to a file.
pub fn write_chrome_trace(spans: &[BusySpan], path: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, chrome_trace_json(spans))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(p: u32, t: u32, a: f64, b: f64, what: &'static str) -> BusySpan {
        BusySpan { proc: p, thread: t, start: a, end: b, what }
    }

    #[test]
    fn json_shape() {
        let spans = vec![span(0, 0, 0.0, 5.0, "compute"), span(1, 2, 5.0, 9.0, "wait")];
        let j = chrome_trace_json(&spans);
        assert!(j.starts_with("[\n"));
        assert!(j.contains("\"ph\": \"X\""));
        assert!(j.contains("\"pid\": 1"));
        assert!(j.contains("\"tid\": 2"));
        assert!(j.contains("\"dur\": 4.000"));
        // valid-ish JSON: balanced brackets, one comma between two events.
        assert_eq!(j.matches('{').count(), 2);
        assert_eq!(j.matches("},").count(), 1);
    }

    #[test]
    fn empty_trace() {
        assert_eq!(chrome_trace_json(&[]), "[\n]\n");
    }

    #[test]
    fn from_real_simulation() {
        use crate::sim::{simulate, ExecPlan, Machine};
        use crate::stencil::heat1d_graph;
        let g = heat1d_graph(32, 4, 2);
        let r = simulate(&g, &ExecPlan::naive(&g), &Machine::new(2, 2, 10.0, 0.1, 1.0), true);
        let j = chrome_trace_json(&r.spans);
        assert!(j.matches('{').count() >= g.num_compute_tasks());
    }
}
