//! Chrome-trace (about://tracing, Perfetto) export of simulation spans
//! and telemetry spans.
//!
//! Hand-rolled JSON (no serde in the vendored crate set): each busy span
//! becomes a complete ("X") event; processors map to pids, threads to
//! tids; waits are colourable by name.  Telemetry spans
//! ([`crate::telemetry::SpanRecord`]) ride the same file on reserved
//! pids per track — serve request lifecycles, serve phases, tuner
//! search timelines, and engine samples land next to the simulated
//! processor rows, so one Perfetto load shows the whole stack.

use crate::sim::BusySpan;
use crate::telemetry::SpanRecord;

/// JSON-escape a span name: `"` and `\` are escaped, common whitespace
/// escapes are used for \n/\t/\r, and remaining control characters
/// become `\u00XX`.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn push_event(
    out: &mut String,
    name: &str,
    cat: &str,
    pid: u64,
    tid: u64,
    ts: f64,
    dur: f64,
    last: bool,
) {
    out.push_str(&format!(
        "  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"pid\": {}, \"tid\": {}, \
         \"ts\": {:.3}, \"dur\": {:.3}}}{}\n",
        esc(name),
        esc(cat),
        pid,
        tid,
        ts,
        dur,
        if last { "" } else { "," }
    ));
}

/// Render spans as a Chrome trace JSON array (`traceEvents` format).
/// Times are interpreted as microseconds (the format's unit).
pub fn chrome_trace_json(spans: &[BusySpan]) -> String {
    let mut out = String::from("[\n");
    for (i, s) in spans.iter().enumerate() {
        let dur = (s.end - s.start).max(0.0);
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"pid\": {}, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}{}\n",
            esc(s.what),
            s.proc,
            s.thread,
            s.start,
            dur,
            if i + 1 == spans.len() { "" } else { "," }
        ));
    }
    out.push_str("]\n");
    out
}

/// The reserved pid a telemetry track renders under (simulated procs
/// own the low pids).
fn track_pid(track: &str) -> u64 {
    match track {
        "serve" | "serve.phase" => 1001,
        "tune" => 1002,
        "engine" => 1003,
        _ => 1004,
    }
}

/// Render simulator spans and telemetry spans into one Chrome trace.
///
/// Sim spans keep their proc/thread pid/tid mapping; telemetry spans
/// land on reserved pids per track (serve → 1001, tune → 1002, engine →
/// 1003, other → 1004) with the span's own lane (request id, search id)
/// as tid and the track name as the event category.
pub fn chrome_trace_with_telemetry(spans: &[BusySpan], telem: &[SpanRecord]) -> String {
    let total = spans.len() + telem.len();
    let mut out = String::from("[\n");
    let mut emitted = 0usize;
    for s in spans {
        emitted += 1;
        push_event(
            &mut out,
            s.what,
            "sim",
            u64::from(s.proc),
            u64::from(s.thread),
            s.start,
            (s.end - s.start).max(0.0),
            emitted == total,
        );
    }
    for t in telem {
        emitted += 1;
        push_event(
            &mut out,
            &t.name,
            t.track,
            track_pid(t.track),
            t.tid,
            t.start_us,
            t.dur_us,
            emitted == total,
        );
    }
    out.push_str("]\n");
    out
}

/// One message flight to draw as a Perfetto flow arrow: a paired
/// `ph:"s"` (start, at the sender's post) / `ph:"f"` (finish, at the
/// receiver's arrival) event sharing one flow `id`.  The explain layer
/// emits one per message on the observed critical path, so Perfetto
/// draws the causal chain across processor rows.
#[derive(Debug, Clone, Copy)]
pub struct MessageFlow {
    /// Flow id — unique per arrow (the explain path uses message slots).
    pub id: u64,
    /// Sending processor (arrow tail pid).
    pub from_proc: u32,
    /// Post time on the sender (µs).
    pub post: f64,
    /// Receiving processor (arrow head pid).
    pub to_proc: u32,
    /// Delivery time at the receiver (µs).
    pub arrival: f64,
}

fn push_flow(out: &mut String, f: &MessageFlow, last: bool) {
    // `bp:"e"` binds the finish to the enclosing slice, the form both
    // chrome://tracing and Perfetto accept for legacy flow events.
    out.push_str(&format!(
        "  {{\"name\": \"msg\", \"cat\": \"crit\", \"ph\": \"s\", \"id\": {}, \"pid\": {}, \
         \"tid\": 0, \"ts\": {:.3}}},\n",
        f.id, f.from_proc, f.post
    ));
    out.push_str(&format!(
        "  {{\"name\": \"msg\", \"cat\": \"crit\", \"ph\": \"f\", \"bp\": \"e\", \"id\": {}, \
         \"pid\": {}, \"tid\": 0, \"ts\": {:.3}}}{}\n",
        f.id,
        f.to_proc,
        f.arrival,
        if last { "" } else { "," }
    ));
}

/// Render simulator spans plus Perfetto flow arrows for the messages on
/// the observed critical path.  Span events come first (so every flow
/// endpoint has a slice to bind to), then one `s`/`f` pair per flow.
pub fn chrome_trace_with_flows(spans: &[BusySpan], flows: &[MessageFlow]) -> String {
    let mut out = String::from("[\n");
    for (i, s) in spans.iter().enumerate() {
        push_event(
            &mut out,
            s.what,
            "sim",
            u64::from(s.proc),
            u64::from(s.thread),
            s.start,
            (s.end - s.start).max(0.0),
            flows.is_empty() && i + 1 == spans.len(),
        );
    }
    for (i, f) in flows.iter().enumerate() {
        push_flow(&mut out, f, i + 1 == flows.len());
    }
    out.push_str("]\n");
    out
}

/// Write a spans + critical-path-flows Chrome trace to a file.
pub fn write_chrome_trace_with_flows(
    spans: &[BusySpan],
    flows: &[MessageFlow],
    path: &str,
) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, chrome_trace_with_flows(spans, flows))
}

/// Write the Chrome trace to a file.
pub fn write_chrome_trace(spans: &[BusySpan], path: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, chrome_trace_json(spans))
}

/// Write a combined sim + telemetry Chrome trace to a file.
pub fn write_chrome_trace_with_telemetry(
    spans: &[BusySpan],
    telem: &[SpanRecord],
    path: &str,
) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, chrome_trace_with_telemetry(spans, telem))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(p: u32, t: u32, a: f64, b: f64, what: &'static str) -> BusySpan {
        BusySpan { proc: p, thread: t, start: a, end: b, what }
    }

    #[test]
    fn json_shape() {
        let spans = vec![span(0, 0, 0.0, 5.0, "compute"), span(1, 2, 5.0, 9.0, "wait")];
        let j = chrome_trace_json(&spans);
        assert!(j.starts_with("[\n"));
        assert!(j.contains("\"ph\": \"X\""));
        assert!(j.contains("\"pid\": 1"));
        assert!(j.contains("\"tid\": 2"));
        assert!(j.contains("\"dur\": 4.000"));
        // valid-ish JSON: balanced brackets, one comma between two events.
        assert_eq!(j.matches('{').count(), 2);
        assert_eq!(j.matches("},").count(), 1);
    }

    #[test]
    fn empty_trace() {
        assert_eq!(chrome_trace_json(&[]), "[\n]\n");
    }

    #[test]
    fn names_are_json_escaped() {
        // A name with a quote, a backslash, a newline, and a control
        // char used to emit invalid JSON; now every byte is escaped.
        let spans = vec![span(0, 0, 0.0, 1.0, "say \"hi\" \\ twice\n\u{1}")];
        let j = chrome_trace_json(&spans);
        assert!(j.contains("say \\\"hi\\\" \\\\ twice\\n\\u0001"));
        // The name field closes exactly where it should: quote count is
        // balanced (6 structural quotes per event * fields + escaped ones
        // don't terminate strings).
        let unescaped_quotes =
            j.as_bytes().windows(2).filter(|w| w[1] == b'"' && w[0] != b'\\').count();
        assert_eq!(unescaped_quotes % 2, 0, "unbalanced quotes in {j}");
        assert!(!j.contains('\u{1}'), "raw control byte leaked into JSON");
    }

    #[test]
    fn flow_events_are_well_formed() {
        let spans = vec![span(0, 0, 0.0, 5.0, "compute"), span(1, 0, 7.0, 9.0, "compute")];
        let flows = vec![
            MessageFlow { id: 42, from_proc: 0, post: 5.0, to_proc: 1, arrival: 7.0 },
            MessageFlow { id: 43, from_proc: 1, post: 9.0, to_proc: 0, arrival: 11.5 },
        ];
        let j = chrome_trace_with_flows(&spans, &flows);
        // Every flow is one "s"/"f" pair sharing an id; the finish
        // carries the enclosing-slice binding point.
        assert_eq!(j.matches("\"ph\": \"s\"").count(), 2);
        assert_eq!(j.matches("\"ph\": \"f\"").count(), 2);
        assert_eq!(j.matches("\"bp\": \"e\"").count(), 2);
        assert_eq!(j.matches("\"id\": 42").count(), 2);
        assert_eq!(j.matches("\"id\": 43").count(), 2);
        // The start sits on the sender's row, the finish on the receiver's.
        assert!(j.contains("\"ph\": \"s\", \"id\": 42, \"pid\": 0, \"tid\": 0, \"ts\": 5.000"));
        assert!(j.contains(
            "\"ph\": \"f\", \"bp\": \"e\", \"id\": 42, \"pid\": 1, \"tid\": 0, \"ts\": 7.000"
        ));
        // Balanced JSON: 2 span + 4 flow events, comma-separated.
        assert_eq!(j.matches('{').count(), 6);
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches("},").count(), 5);
        assert!(j.ends_with("]\n"));
        // No flows degrades to the plain span trace shape.
        let plain = chrome_trace_with_flows(&spans, &[]);
        assert_eq!(plain.matches('{').count(), 2);
        assert_eq!(plain.matches("},").count(), 1);
        // No spans still emits a closed array of flow pairs.
        let only_flows = chrome_trace_with_flows(&[], &flows[..1]);
        assert_eq!(only_flows.matches('{').count(), 2);
        assert!(only_flows.ends_with("]\n"));
    }

    #[test]
    fn telemetry_spans_share_the_trace() {
        let sim = vec![span(0, 0, 0.0, 5.0, "compute")];
        let telem = vec![
            SpanRecord {
                track: "serve",
                name: "request:tune:1".into(),
                tid: 1,
                start_us: 0.0,
                dur_us: 100.0,
            },
            SpanRecord {
                track: "tune",
                name: "search:heat1d:exhaustive".into(),
                tid: 0,
                start_us: 5.0,
                dur_us: 80.0,
            },
        ];
        let j = chrome_trace_with_telemetry(&sim, &telem);
        assert!(j.contains("\"name\": \"compute\", \"cat\": \"sim\""));
        assert!(j.contains("\"name\": \"request:tune:1\", \"cat\": \"serve\", \"ph\": \"X\", \"pid\": 1001, \"tid\": 1"));
        assert!(j.contains("\"name\": \"search:heat1d:exhaustive\", \"cat\": \"tune\", \"ph\": \"X\", \"pid\": 1002"));
        // 3 events, 2 commas, closed array.
        assert_eq!(j.matches('{').count(), 3);
        assert_eq!(j.matches("},").count(), 2);
        assert!(j.ends_with("]\n"));
    }

    #[test]
    fn combined_trace_of_nothing_is_the_empty_array() {
        assert_eq!(chrome_trace_with_telemetry(&[], &[]), "[\n]\n");
    }

    #[test]
    fn from_real_simulation() {
        use crate::sim::{simulate, ExecPlan, Machine};
        use crate::stencil::heat1d_graph;
        let g = heat1d_graph(32, 4, 2);
        let r = simulate(&g, &ExecPlan::naive(&g), &Machine::new(2, 2, 10.0, 0.1, 1.0), true);
        let j = chrome_trace_json(&r.spans);
        assert!(j.matches('{').count() >= g.num_compute_tasks());
    }
}
