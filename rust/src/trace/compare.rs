//! Baseline comparison for the `BENCH_*.json` smoke artifacts.
//!
//! Every smoke emits a JSON document of gate results and throughput
//! numbers; CI uploads them but nothing watches how they *drift* across
//! pushes.  This module diffs a freshly emitted artifact against a
//! committed snapshot in `BENCH_baseline/`, metric by metric, without a
//! JSON dependency: a scanner collects every `"key": <number>` leaf in
//! document order (repeated keys — per-cell rows — get `#N` suffixes so
//! nothing collides), and the comparer reports the largest relative
//! deltas.  Advisory by design: the hard gates live inside each smoke;
//! this surfaces the slow regressions those gates are too coarse to
//! catch.

use std::collections::BTreeMap;

/// Every `"key": <number>` pair in `json`, in document order.  The
/// N-th repeat of a key is renamed `key#N` (N ≥ 1), so per-cell rows
/// that share field names stay distinct and positionally comparable.
/// Strings, booleans, and malformed numbers are skipped.
pub fn numeric_leaves(json: &str) -> Vec<(String, f64)> {
    let bytes = json.as_bytes();
    let mut out = Vec::new();
    let mut counts: BTreeMap<&str, usize> = BTreeMap::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] != b'"' {
            i += 1;
            continue;
        }
        let start = i + 1;
        let mut j = start;
        while j < bytes.len() && bytes[j] != b'"' {
            j += 1;
        }
        if j >= bytes.len() {
            break; // unterminated string: nothing more to scan
        }
        let token = &json[start..j];
        let mut k = j + 1;
        while k < bytes.len() && bytes[k].is_ascii_whitespace() {
            k += 1;
        }
        if k < bytes.len() && bytes[k] == b':' {
            k += 1;
            while k < bytes.len() && bytes[k].is_ascii_whitespace() {
                k += 1;
            }
            let mut e = k;
            while e < bytes.len()
                && (bytes[e].is_ascii_digit()
                    || matches!(bytes[e], b'-' | b'+' | b'.' | b'e' | b'E'))
            {
                e += 1;
            }
            if e > k {
                if let Ok(v) = json[k..e].parse::<f64>() {
                    let n = counts.entry(token).or_insert(0);
                    let name =
                        if *n == 0 { token.to_string() } else { format!("{token}#{n}") };
                    *n += 1;
                    out.push((name, v));
                }
            }
            // Continue from the value: a string value is re-scanned as
            // a candidate key and rejected (no ':' follows it).
            i = k;
            continue;
        }
        i = j + 1;
    }
    out
}

/// Diff two versions of one artifact.  Reports how many metrics were
/// comparable, how many exist on only one side (a structure change),
/// and the largest relative deltas above a 1% noise floor — at most 8,
/// biggest first.
pub fn compare_documents(name: &str, baseline: &str, current: &str) -> String {
    let base: BTreeMap<String, f64> = numeric_leaves(baseline).into_iter().collect();
    let cur: BTreeMap<String, f64> = numeric_leaves(current).into_iter().collect();
    // key, baseline value, current value, relative delta
    let mut deltas: Vec<(&String, f64, f64, f64)> = Vec::new();
    for (k, bv) in &base {
        if let Some(cv) = cur.get(k) {
            deltas.push((k, *bv, *cv, (cv - bv) / bv.abs().max(1e-12)));
        }
    }
    let compared = deltas.len();
    let only_base = base.len() - compared;
    let only_cur = cur.len() - compared;
    deltas.sort_by(|x, y| y.3.abs().total_cmp(&x.3.abs()));
    let mut s = format!("{name}: {compared} metrics compared");
    if only_base + only_cur > 0 {
        s.push_str(&format!(
            " ({only_base} baseline-only, {only_cur} current-only — structure changed)"
        ));
    }
    let shown: Vec<_> = deltas.iter().take(8).filter(|d| d.3.abs() >= 0.01).collect();
    if shown.is_empty() {
        s.push_str(", all within 1% of baseline\n");
    } else {
        s.push('\n');
        for (k, bv, cv, rel) in shown {
            s.push_str(&format!("  {k}: {bv} -> {cv} ({:+.1}%)\n", 100.0 * rel));
        }
    }
    s
}

/// Compare each named artifact in the working directory against its
/// snapshot under `baseline_dir`.  Every outcome — including a missing
/// baseline — is a report line, never an error: this surface must stay
/// safe to run unconditionally in CI.
pub fn compare_bench_files(baseline_dir: &str, names: &[&str]) -> String {
    let dir = std::path::Path::new(baseline_dir);
    if !dir.is_dir() {
        return format!(
            "bench-compare: no baseline directory {baseline_dir:?} — run the smokes, then \
             `make bench-baseline` to commit a snapshot\n"
        );
    }
    let mut out = String::new();
    for name in names {
        match (std::fs::read_to_string(name), std::fs::read_to_string(dir.join(name))) {
            (Err(_), _) => {
                out.push_str(&format!("{name}: no current artifact (run the smoke first)\n"));
            }
            (_, Err(_)) => out.push_str(&format!("{name}: no committed baseline\n")),
            (Ok(current), Ok(baseline)) => {
                out.push_str(&compare_documents(name, &baseline, &current));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaves_skip_strings_and_booleans_and_index_repeats() {
        let json = r#"{
          "tag": "smoke", "ok": true, "makespan": 1250.5,
          "cells": [
            {"workload": "heat1d", "makespan": 100.0, "exact": true},
            {"workload": "heat2d", "makespan": -2.5e1}
          ],
          "overhead_ratio": 0.993
        }"#;
        let leaves = numeric_leaves(json);
        assert_eq!(
            leaves,
            vec![
                ("makespan".to_string(), 1250.5),
                ("makespan#1".to_string(), 100.0),
                ("makespan#2".to_string(), -25.0),
                ("overhead_ratio".to_string(), 0.993),
            ]
        );
    }

    #[test]
    fn document_diff_reports_drift_above_the_noise_floor() {
        let baseline = r#"{"events_per_sec": 1000.0, "makespan": 50.0, "spans": 12}"#;
        let current = r#"{"events_per_sec": 900.0, "makespan": 50.2, "spans": 12}"#;
        let s = compare_documents("BENCH_x.json", baseline, current);
        assert!(s.starts_with("BENCH_x.json: 3 metrics compared"), "{s}");
        assert!(s.contains("events_per_sec: 1000 -> 900 (-10.0%)"), "{s}");
        // makespan moved 0.4% — under the floor — and spans are equal.
        assert!(!s.contains("makespan"), "{s}");
        assert!(!s.contains("spans"), "{s}");
        let same = compare_documents("BENCH_x.json", baseline, baseline);
        assert!(same.contains("all within 1% of baseline"), "{same}");
    }

    #[test]
    fn structure_changes_and_missing_baselines_are_reported_not_fatal() {
        let s = compare_documents("b.json", r#"{"a": 1, "b": 2}"#, r#"{"a": 1, "c": 3}"#);
        assert!(s.contains("1 metrics compared (1 baseline-only, 1 current-only"), "{s}");
        let missing = compare_bench_files("definitely/not/a/dir", &["BENCH_x.json"]);
        assert!(missing.contains("no baseline directory"), "{missing}");
    }
}
