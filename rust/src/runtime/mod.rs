//! The PJRT runtime: loads AOT-compiled HLO-text artifacts and executes
//! them from the coordinator's hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path compute engine.  Interchange is HLO *text* — the
//! image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos, while the text parser reassigns ids (see
//! `/opt/xla-example/README.md` and `python/compile/aot.py`).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so a [`Runtime`] is
//! thread-local: the coordinator creates one per worker thread.
//! Executables are compiled lazily on first use and cached.

mod registry;

pub use registry::{ArtifactSpec, DType, Registry, TensorSpec};

use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;

/// A tensor value crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Value {
    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(_) => DType::I32,
        }
    }

    /// Unwrap as f32 data.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => bail!("expected f32 value, got i32"),
        }
    }

    /// Scalar f32 helper (shape `[1]`).
    pub fn scalar(x: f32) -> Value {
        Value::F32(vec![x])
    }

    /// Scalar i32 helper (shape `[1]`).
    pub fn scalar_i32(x: i32) -> Value {
        Value::I32(vec![x])
    }
}

/// Cumulative execution metrics (per runtime instance).
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeMetrics {
    pub executions: u64,
    pub compiles: u64,
    /// Seconds spent inside PJRT execute calls.
    pub execute_secs: f64,
    /// Seconds spent compiling.
    pub compile_secs: f64,
}

/// Thread-local artifact executor.
pub struct Runtime {
    registry: Registry,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    metrics: RefCell<RuntimeMetrics>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over the artifact directory.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let registry = Registry::load(&dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            registry,
            client,
            cache: RefCell::new(HashMap::new()),
            metrics: RefCell::new(RuntimeMetrics::default()),
        })
    }

    /// Create a runtime over the default artifact directory
    /// (`$IMP_ARTIFACTS` or `./artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(Registry::default_dir())
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> RuntimeMetrics {
        *self.metrics.borrow()
    }

    /// Ensure `name` is compiled (idempotent).  Returns compile time in
    /// seconds when a compile actually happened.
    pub fn warm(&self, name: &str) -> Result<Option<f64>> {
        if self.cache.borrow().contains_key(name) {
            return Ok(None);
        }
        let path = self.registry.hlo_path(name);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(name.to_string(), exe);
        let mut m = self.metrics.borrow_mut();
        m.compiles += 1;
        m.compile_secs += dt;
        Ok(Some(dt))
    }

    /// Execute artifact `name` with `inputs`; validates shapes against the
    /// manifest and returns the output tuple as [`Value`]s.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.registry.get(name).map_err(|e| anyhow!(e))?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        for (i, (v, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if v.len() != s.elems() || v.dtype() != s.dtype {
                bail!(
                    "{name}: input {i} mismatch: got {:?}[{}], manifest says {}",
                    v.dtype(),
                    v.len(),
                    s
                );
            }
        }
        self.warm(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(v, s)| -> Result<xla::Literal> {
                let lit = match v {
                    Value::F32(data) => xla::Literal::vec1(data),
                    Value::I32(data) => xla::Literal::vec1(data),
                };
                // vec1 is rank-1; reshape to the manifest rank when needed.
                if s.dims.len() == 1 {
                    Ok(lit)
                } else {
                    Ok(lit.reshape(&s.dims_i64())?)
                }
            })
            .collect::<Result<_>>()?;

        let t0 = std::time::Instant::now();
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("warmed above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let root = result[0][0].to_literal_sync()?;
        {
            let mut m = self.metrics.borrow_mut();
            m.executions += 1;
            m.execute_secs += t0.elapsed().as_secs_f64();
        }

        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: expected {} outputs, got {}", spec.outputs.len(), parts.len());
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| -> Result<Value> {
                match s.dtype {
                    DType::F32 => Ok(Value::F32(lit.to_vec::<f32>()?)),
                    DType::I32 => Ok(Value::I32(lit.to_vec::<i32>()?)),
                }
            })
            .collect()
    }

    /// Convenience: execute with f32 slices, single-output artifacts.
    pub fn execute_f32_1(&self, name: &str, inputs: &[Value]) -> Result<Vec<f32>> {
        let mut out = self.execute(name, inputs)?;
        if out.len() != 1 {
            bail!("{name}: expected single output, got {}", out.len());
        }
        out.pop().unwrap().into_f32()
    }
}

#[cfg(test)]
mod tests {
    //! PJRT round-trip tests.  These need `artifacts/` built (`make
    //! artifacts`); they are skipped gracefully when it is missing so
    //! `cargo test` works on a fresh checkout, and the integration suite
    //! + examples cover the full path in CI (`make test`).
    use super::*;

    fn runtime() -> Option<Runtime> {
        match Runtime::from_default_dir() {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!("skipping PJRT test: {e}");
                None
            }
        }
    }

    fn ref_heat1d(x: &[f32], nu: f32, b: usize) -> Vec<f32> {
        let mut cur = x.to_vec();
        for _ in 0..b {
            cur = cur
                .windows(3)
                .map(|w| w[1] + nu * (w[0] - 2.0 * w[1] + w[2]))
                .collect();
        }
        cur
    }

    #[test]
    fn heat1d_artifact_matches_reference() {
        let Some(rt) = runtime() else { return };
        let b = 4usize;
        let n = 256usize;
        let x: Vec<f32> = (0..n + 2 * b).map(|i| ((i * 37) % 17) as f32 * 0.1 - 0.8).collect();
        let out = rt
            .execute_f32_1(
                "heat1d_n256_b4",
                &[Value::F32(x.clone()), Value::scalar(0.2)],
            )
            .unwrap();
        let want = ref_heat1d(&x, 0.2, b);
        assert_eq!(out.len(), n);
        for (a, w) in out.iter().zip(&want) {
            assert!((a - w).abs() < 1e-4, "{a} vs {w}");
        }
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(rt) = runtime() else { return };
        let err = rt
            .execute("heat1d_n256_b4", &[Value::F32(vec![0.0; 3]), Value::scalar(0.2)])
            .unwrap_err();
        assert!(format!("{err}").contains("mismatch"), "{err}");
    }

    #[test]
    fn unknown_artifact_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[]).is_err());
    }

    #[test]
    fn multi_output_artifact() {
        let Some(rt) = runtime() else { return };
        let n = 2048usize;
        let x = vec![0.0f32; n];
        let r: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.1).collect();
        let p = r.clone();
        let ap = vec![0.5f32; n];
        let alpha = 0.25f32;
        let out = rt
            .execute(
                "cg_xr_update_n2048",
                &[
                    Value::F32(x),
                    Value::F32(r.clone()),
                    Value::F32(p.clone()),
                    Value::F32(ap.clone()),
                    Value::scalar(alpha),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 3);
        let xn = out[0].as_f32().unwrap();
        let rn = out[1].as_f32().unwrap();
        let rr = out[2].as_f32().unwrap();
        for i in 0..n {
            assert!((xn[i] - alpha * p[i]).abs() < 1e-5);
            assert!((rn[i] - (r[i] - alpha * ap[i])).abs() < 1e-5);
        }
        let want_rr: f32 = rn.iter().map(|v| v * v).sum();
        assert!((rr[0] - want_rr).abs() / want_rr.max(1e-6) < 1e-3);
    }

    #[test]
    fn dynamic_step_count_artifact() {
        let Some(rt) = runtime() else { return };
        let n = 2048usize;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32).sin()).collect();
        let once = rt
            .execute_f32_1(
                "heat1d_full_n2048",
                &[Value::F32(x.clone()), Value::scalar(0.2), Value::scalar_i32(2)],
            )
            .unwrap();
        let twice = {
            let mid = rt
                .execute_f32_1(
                    "heat1d_full_n2048",
                    &[Value::F32(x), Value::scalar(0.2), Value::scalar_i32(1)],
                )
                .unwrap();
            rt.execute_f32_1(
                "heat1d_full_n2048",
                &[Value::F32(mid), Value::scalar(0.2), Value::scalar_i32(1)],
            )
            .unwrap()
        };
        for (a, b) in once.iter().zip(&twice) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn metrics_accumulate() {
        let Some(rt) = runtime() else { return };
        let x = vec![0.0f32; 258];
        rt.execute_f32_1("heat1d_n256_b1", &[Value::F32(x.clone()), Value::scalar(0.1)]).unwrap();
        rt.execute_f32_1("heat1d_n256_b1", &[Value::F32(x), Value::scalar(0.1)]).unwrap();
        let m = rt.metrics();
        assert_eq!(m.executions, 2);
        assert_eq!(m.compiles, 1); // cached after first call
        assert!(m.execute_secs > 0.0);
    }
}
