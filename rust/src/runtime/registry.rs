//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`, one line per
//! AOT-compiled module:
//!
//! ```text
//! heat1d_n2048_b8: f32[2064], f32[1] -> f32[2048]
//! heat2d_h64w64_b2: f32[68x68], f32[1] -> f32[64x64]
//! ```
//!
//! This module parses that contract; it is the single source of truth for
//! the shapes the Rust side feeds PJRT, so parsing is strict and fully
//! unit-tested (no PJRT needed).

use std::collections::HashMap;

/// Element types used by the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(format!("unsupported dtype {other:?}")),
        }
    }
}

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse `f32[68x68]` / `i32[1]` / `f32[]` (scalar).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        let open = s.find('[').ok_or_else(|| format!("missing '[' in {s:?}"))?;
        if !s.ends_with(']') {
            return Err(format!("missing ']' in {s:?}"));
        }
        let dtype = DType::parse(&s[..open])?;
        let body = &s[open + 1..s.len() - 1];
        let dims = if body.is_empty() {
            Vec::new()
        } else {
            body.split('x')
                .map(|d| d.parse::<usize>().map_err(|e| format!("bad dim {d:?}: {e}")))
                .collect::<Result<Vec<_>, _>>()?
        };
        Ok(TensorSpec { dtype, dims })
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Dims as i64 (what `Literal::reshape` wants).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }
}

impl std::fmt::Display for TensorSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let d = match self.dtype {
            DType::F32 => "f32",
            DType::I32 => "i32",
        };
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", d, dims.join("x"))
    }
}

/// One artifact's interface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Parse one manifest line.
    pub fn parse_line(line: &str) -> Result<Self, String> {
        let (name, rest) =
            line.split_once(':').ok_or_else(|| format!("missing ':' in {line:?}"))?;
        let (ins, outs) =
            rest.split_once("->").ok_or_else(|| format!("missing '->' in {line:?}"))?;
        let parse_list = |s: &str| -> Result<Vec<TensorSpec>, String> {
            s.split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(TensorSpec::parse)
                .collect()
        };
        Ok(ArtifactSpec {
            name: name.trim().to_string(),
            inputs: parse_list(ins)?,
            outputs: parse_list(outs)?,
        })
    }
}

/// The parsed manifest: artifact name → spec.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub specs: HashMap<String, ArtifactSpec>,
    pub dir: std::path::PathBuf,
}

impl Registry {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (directory recorded for later `.hlo.txt` loads).
    pub fn parse(text: &str, dir: std::path::PathBuf) -> Result<Self, String> {
        let mut specs = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let spec = ArtifactSpec::parse_line(line)?;
            if specs.insert(spec.name.clone(), spec.clone()).is_some() {
                return Err(format!("duplicate artifact {:?}", spec.name));
            }
        }
        Ok(Registry { specs, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.specs.get(name).ok_or_else(|| {
            format!("artifact {name:?} not in manifest ({} entries)", self.specs.len())
        })
    }

    /// Path of an artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> std::path::PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Default artifact directory: `$IMP_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> std::path::PathBuf {
        std::env::var_os("IMP_ARTIFACTS")
            .map(Into::into)
            .unwrap_or_else(|| "artifacts".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tensor_specs() {
        assert_eq!(
            TensorSpec::parse("f32[2064]").unwrap(),
            TensorSpec { dtype: DType::F32, dims: vec![2064] }
        );
        assert_eq!(
            TensorSpec::parse("f32[68x68]").unwrap(),
            TensorSpec { dtype: DType::F32, dims: vec![68, 68] }
        );
        assert_eq!(TensorSpec::parse("i32[1]").unwrap().dtype, DType::I32);
        assert_eq!(TensorSpec::parse("f32[]").unwrap().elems(), 1);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TensorSpec::parse("f32").is_err());
        assert!(TensorSpec::parse("f16[2]").is_err());
        assert!(TensorSpec::parse("f32[2y3]").is_err());
    }

    #[test]
    fn parse_manifest_line() {
        let s = ArtifactSpec::parse_line(
            "cg_xr_update_n2048: f32[2048], f32[2048], f32[2048], f32[2048], f32[1] -> f32[2048], f32[2048], f32[1]",
        )
        .unwrap();
        assert_eq!(s.name, "cg_xr_update_n2048");
        assert_eq!(s.inputs.len(), 5);
        assert_eq!(s.outputs.len(), 3);
        assert_eq!(s.outputs[2].elems(), 1);
    }

    #[test]
    fn parse_registry_text() {
        let text = "a: f32[4] -> f32[2]\n\n# comment\nb: f32[2x3], i32[1] -> f32[1]\n";
        let r = Registry::parse(text, "artifacts".into()).unwrap();
        assert_eq!(r.specs.len(), 2);
        assert_eq!(r.get("b").unwrap().inputs[0].dims, vec![2, 3]);
        assert!(r.get("missing").is_err());
        assert!(r.hlo_path("a").ends_with("a.hlo.txt"));
    }

    #[test]
    fn duplicate_rejected() {
        let text = "a: f32[4] -> f32[2]\na: f32[4] -> f32[2]\n";
        assert!(Registry::parse(text, ".".into()).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        if let Ok(r) = Registry::load(Registry::default_dir()) {
            assert!(r.specs.len() >= 19, "{}", r.specs.len());
            let h = r.get("heat1d_n2048_b8").unwrap();
            assert_eq!(h.inputs[0].dims, vec![2064]);
            assert_eq!(h.outputs[0].dims, vec![2048]);
        }
    }

    #[test]
    fn display_roundtrip() {
        let t = TensorSpec::parse("f32[68x68]").unwrap();
        assert_eq!(t.to_string(), "f32[68x68]");
    }
}
