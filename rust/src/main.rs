//! `imp-latency` — the command-line launcher.
//!
//! Subcommands (arguments are `key=value` pairs, see `--help`):
//!
//! * `figure <f1..f8|all>` — regenerate a paper figure (CSV + ASCII);
//! * `pipeline` — any workload end to end through the [`Pipeline`] API:
//!   transform, simulate, and verified real execution in one go;
//! * `transform` — run the §3 transformation, print subsets + Theorem-1 verdict;
//! * `simulate` — compare naive/overlap/CA on the discrete-event simulator;
//! * `cost` — the §2.1 cost model table and optimal block factor;
//! * `run-heat1d` / `run-heat2d` — real distributed runs (PJRT compute);
//! * `run-cg` — distributed CG, classic vs. pipelined;
//! * `analyze` — static plan verification and analytic critical-path
//!   bounds checked against the engine, plus a pruned-vs-full tuner
//!   audit (CI gate: `make analyze-smoke` → `BENCH_analyze.json`);
//! * `serve` — long-running tuning/simulation daemon: JSON request
//!   streams over stdin batches or TCP/Unix sockets, cache-first with
//!   in-flight dedupe, batching, and admission control;
//! * `trace` — telemetry overhead + fidelity study: times the compiled
//!   engine with the gate off, merges an instrumented sim + serve +
//!   tune pass into one Chrome trace (CI gate: `make trace-smoke` →
//!   `BENCH_trace.json`);
//! * `explain` — causal profiling: observed critical paths, bit-exact
//!   makespan blame decompositions, naive→overlap→CA differential
//!   explanations, and the provenance-gate overhead bound (CI gate:
//!   `make explain-smoke` → `BENCH_explain.json`);
//! * `bench-compare` — diff the freshly emitted `BENCH_*.json` smoke
//!   artifacts against the committed `BENCH_baseline/` snapshots
//!   (advisory — never fails the build);
//! * `dot` — Graphviz export of a (small) transformed graph.
//!
//! Every subcommand lives in the [`COMMANDS`] table; `--help` documents
//! each entry (a test keeps the two in sync).

use imp_latency::analysis;
use imp_latency::chaos::{self, EnsembleConfig, FaultConfig, WireFault};
use imp_latency::config::{
    parse_list, preset_analyze, preset_analyze_smoke, preset_bench, preset_bench_smoke,
    preset_chaos, preset_chaos_smoke, preset_end_to_end, preset_explain, preset_explain_smoke,
    preset_fig10, preset_fig7, preset_fig8, preset_fig9, preset_partition,
    preset_partition_smoke, preset_serve, preset_serve_smoke, preset_sweep, preset_sweep_smoke,
    preset_trace, preset_trace_smoke, preset_tune, preset_tune_smoke, Config,
};
use imp_latency::coordinator::{heat1d, heat2d};
use imp_latency::cost::CostModel;
use imp_latency::explain::{self, BlameSummary, PlanDiff};
use imp_latency::figures;
use imp_latency::krylov::distributed::{self as dcg, CgConfig};
use imp_latency::partition::{self, Partitioner, Partitioning, PartitionQuality, ProcGrid};
use imp_latency::pipeline::{
    dispatch_workload, ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy,
    Workload, WorkloadVisitor,
};
use imp_latency::runtime::Registry;
use imp_latency::serve::{self, signals, Request, ServeConfig, Server};
use imp_latency::sim::{
    simulate_compiled, simulate_observed, sweep, try_simulate, CompiledPlan, EngineScratch,
    Machine, NetworkKind, ProvenanceBuffer, UniformCost,
};
use imp_latency::stencil::CsrMatrix;
use imp_latency::telemetry::{self, Recorder};
use imp_latency::trace::{
    chrome_trace_with_flows, chrome_trace_with_telemetry, gantt_ascii, summary_line,
};
use imp_latency::transform::{check_schedule, HaloMode, ScheduleStats, TransformOptions};
use imp_latency::tune::{self, SearchStrategy as _, Tuner, TuningCache};
use std::sync::Arc;

const HELP: &str = "\
imp-latency — Task Graph Transformations for Latency Tolerance (Eijkhout 2018)

USAGE: imp-latency <command> [key=value ...]

COMMANDS
  figure <f1..f10|all> [out=results/ engine=analytic|sim network=alphabeta]
             regenerate paper figures (f7/f8 optionally on the event engine;
             f9 is the tuned-vs-fixed-b study across the four wire models;
             f10 is partition quality vs makespan per wire model)
  pipeline   [workload=heat1d|heat2d|moore2d|spmv|cg n=4096 m=16 p=4 b=4
              strategy=ca|naive|overlap halo=multi|level0 h=32 w=32
              threads=8 alpha=500 beta=0.1 gamma=1]
             one workload end to end: transform + simulate + verified real run
  transform  [n=64 m=8 p=4 halo=multi] subsets + Theorem-1 check + stats
  simulate   [n=4096 m=32 p=8 threads=8 alpha=500 beta=0.1 gamma=1 blocks=2,4,8
              network=alphabeta|loggp|hier|contended]
  sweep      [--smoke workloads=heat1d,heat2d,cg networks=alphabeta,loggp,hier,contended
              alphas=1,2,4,8,16,64,256,500 threads=1,4,16,64 blocks=2,4,8 p=4
              n=4096 m=16 h=32 w=32 cg_n=256 iters=3 beta=0.1 gamma=1 jobs=0
              out=results/sweep.json csv=]
             parallel (α × threads × block × network) grid on the event engine;
             --smoke runs the reduced fig-7/8 preset and defaults out=BENCH_sim.json
  bench      [--smoke repeat=20 workloads=... networks=... alphas=... threads=...
              out=results/bench.json]
             engine micro-benchmark: every cell of the sweep-smoke grid simulated
             repeat× on the compiled engine (CompiledPlan + reusable scratch) and
             on the interpreting engine, cross-checked bit-for-bit; reports
             events/sec, sims/sec, compile-vs-simulate split, and the
             compiled-vs-interpreted speedup; --smoke emits BENCH_engine.json and
             fails on any divergence
  cost       [n=65536 m=128 p=16 alpha=300 beta=0.2 gamma=1 max_b=64]
  run-heat1d [n_per_worker=2048 workers=8 b=8 steps=256 nu=0.2]
  run-heat2d [px=2 py=2 b=2 steps=16 nu=0.15]
  run-cg     [workers=2 tol=1e-5 max_iters=2000 pipelined=0]
  powers     [n=4096 workers=4 s=8]    CA matrix-powers kernel vs baseline
  autotune   [n=65536 m=64 p=16 threads=16 alpha=500 beta=0.1 gamma=1]
             the §2.1 closed-form-vs-analytic-simulator comparison (heat1d, α/β wire)
  tune       [--smoke workloads=heat1d,heat2d,spmv networks=alphabeta,loggp,hier,contended
              search=exhaustive|golden|coord n=4096 m=32 p=4 h=32 w=32 threads=8
              alpha=500 beta=0.1 gamma=1 repeat=1 cache=results/tune_cache.json
              out=results/tune.json]
             engine-in-the-loop autotuner: any workload × any wire model, scored by
             the event engine, persisted in a JSON tuning cache; --smoke runs the CI
             preset twice (cache demo) and emits BENCH_tune.json
  partition  [--smoke h=30 w=30 m=8 p=9 threads=4 alpha=40 beta=1 gamma=1
              grids=strip,1x9,3x3 partitioners=rowblock,rcb,rcb+refine
              networks=alphabeta,loggp,hier,contended spmv_h=8 spmv_w=32 chords=16
              out=results/partition.json]
             data-layout study: heat2d under each processor-grid shape and a
             banded+random SpMV under each graph partitioner, simulated per wire;
             every cell pairs makespan with the layout's PartitionQuality (edge-cut
             words, imbalance, max neighbors); --smoke emits BENCH_partition.json
  analyze    [--smoke workloads=heat1d,heat2d,cg tune_workloads=heat1d,heat2d
              networks=alphabeta,loggp,hier,contended alphas=0,8,64,500
              threads=1,8,64 blocks=2,4,8 p=4 n=2048 m=16 h=16 w=16 cg_n=64
              iters=2 beta=0.1 gamma=1 repeat=50 tune_alpha=500 tune_threads=8
              out=results/analyze.json]
             static plan verifier + critical-path analyzer: proves every
             pipeline-built plan channel-safe, hazard-free and deadlock-free
             without running the engine, checks the analytic makespan lower
             bound against the simulated makespan on every grid cell (bit-exact
             on stateless wires and at α=0), and audits lower-bound tuner
             pruning against un-pruned tuning (identical winner required);
             --smoke emits BENCH_analyze.json and fails on any violated gate
  chaos      [--smoke workloads=heat1d,heat2d networks=alphabeta,hier blocks=4,8
              rates=0.05,0.1,0.25 seeds=64 p=4 n=2048 m=16 h=24 w=24 threads=4
              alpha=8 beta=0.1 gamma=1 seed=1 hetero=0.1 jitter=0.1
              straggler_factor=8 wire=exp:2 gate_rate=0.2 out=results/chaos.json]
             deterministic fault injection: every workload × strategy × wire ×
             straggler-rate group runs an N-seed perturbed ensemble against its
             clean baseline (per-proc speed heterogeneity, seeded compute
             jitter, probabilistic stragglers, per-message wire-latency jitter —
             every draw a pure function of the seed) and reports p50/p95/p99
             makespan plus the perturbed/clean degradation ratio; gates:
             compiled ≡ interpreted bit-for-bit per seed, blame sums bit-exact
             on perturbed runs, the clean analytic lower bound is never
             undercut, and at rates ≥ gate_rate the transforms' p99 degradation
             must not exceed naive's; --smoke emits BENCH_chaos.json
  serve      [--smoke requests=-|FILE listen=tcp:HOST:PORT|unix:PATH
              cache=results/serve_cache slots=8 workers=4 max_in_flight=64
              reserve=0 budget=0 search=exhaustive telemetry=0 metrics=0
              out=BENCH_serve.json]
             long-running tuning/simulation daemon: newline-delimited JSON
             requests (ops tune|simulate|analyze|explain|cache-stats|metrics|
             drain) from a stdin/file batch or a TCP/Unix socket; warm cache
             hits cost zero engine runs, identical in-flight requests dedupe
             onto one search, compatible simulate requests coalesce into shared
             sweep grids, excess load is shed with an explicit overloaded
             response (priority=low|normal|high per request, reserve=N holds
             slots back from low), per-request deadline_ms budgets answer
             "deadline" with zero engine runs once expired, and the drain op
             closes admission, waits out in-flight searches and flushes shards;
             SIGINT/SIGTERM flush cache shards; telemetry=1 gives every request
             a phase-tiled lifecycle span (the metrics op reports the
             percentiles), metrics=N dumps the Prometheus exposition to stderr
             every N waves; --smoke drives the scripted cold → warm →
             duplicate-burst → batch mix and emits BENCH_serve.json
  trace      [--smoke n=4096 m=16 p=4 threads=8 alpha=500 beta=0.1 gamma=1
              network=alphabeta repeat=60 trials=3
              chrome=results/trace_chrome.json out=results/trace.json]
             telemetry overhead + fidelity study: times the compiled engine with
             the gate off, runs an instrumented sim + serve + tune pass, merges
             every span into one Perfetto-loadable Chrome trace, then re-times
             the engine with the gate off again; gates: disabled-gate throughput
             within 3% of baseline, and every serve request's phase breakdown
             sums to its measured latency; --smoke emits BENCH_trace.json
  explain    [--smoke workloads=heat1d,heat2d,cg networks=alphabeta,loggp,hier,contended
              n=4096 m=16 h=16 w=16 cg_n=64 iters=2 p=4 threads=8 alpha=500
              beta=0.1 gamma=1 b=8 repeat=60 trials=3
              chrome=results/explain_chrome.json out=results/explain.json]
             causal profiling: every workload × naive/overlap/CA × wire cell runs
             the provenance-recording engine and is decomposed into bit-exact
             compute / exposed-latency / bandwidth / idle blame terms, checked
             against the analytic critical-path bound; plans are diffed (which α
             terms the transforms moved off the observed critical path), a tuned
             winner carries its differential explanation, the observed critical
             path is exported as a Chrome trace with flow arrows, and the dormant
             provenance gate must keep the engine within 3% of baseline; --smoke
             emits BENCH_explain.json and fails on any violated gate
  bench-compare [dir=BENCH_baseline files=BENCH_explain.json,...]
             diff current BENCH_*.json artifacts against the committed baseline
             snapshots, metric by metric (advisory: exits 0 even on drift;
             run `make bench-baseline` to refresh the snapshots)
  dot        [n=16 m=3 p=2]            Graphviz of the transformed graph

Artifacts are searched in $IMP_ARTIFACTS or ./artifacts (run `make artifacts`).
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

type Handler = fn(&[&str]) -> Result<(), String>;

/// Every registered subcommand, in `--help` order.  `run` dispatches
/// from this table; a test asserts the help text documents each entry.
const COMMANDS: &[(&str, Handler)] = &[
    ("figure", cmd_figure),
    ("pipeline", cmd_pipeline),
    ("transform", cmd_transform),
    ("simulate", cmd_simulate),
    ("sweep", cmd_sweep),
    ("bench", cmd_bench),
    ("cost", cmd_cost),
    ("run-heat1d", cmd_run_heat1d),
    ("run-heat2d", cmd_run_heat2d),
    ("run-cg", cmd_run_cg),
    ("powers", cmd_powers),
    ("autotune", cmd_autotune),
    ("tune", cmd_tune),
    ("partition", cmd_partition),
    ("analyze", cmd_analyze),
    ("chaos", cmd_chaos),
    ("serve", cmd_serve),
    ("trace", cmd_trace),
    ("explain", cmd_explain),
    ("bench-compare", cmd_bench_compare),
    ("dot", cmd_dot),
];

fn run(args: &[String]) -> Result<(), String> {
    let cmd = match args.first() {
        Some(cmd) => cmd.as_str(),
        None => {
            print!("{HELP}");
            return Ok(());
        }
    };
    if matches!(cmd, "help" | "--help" | "-h") {
        print!("{HELP}");
        return Ok(());
    }
    let rest: Vec<&str> = args[1..].iter().map(String::as_str).collect();
    match COMMANDS.iter().find(|(name, _)| *name == cmd) {
        Some((_, handler)) => handler(&rest),
        None => Err(format!("unknown command {cmd:?}; try --help")),
    }
}

fn config_from(defaults: Config, args: &[&str]) -> (Config, Vec<String>) {
    let mut cfg = defaults;
    let rest = cfg.apply_overrides(args);
    (cfg, rest.into_iter().map(str::to_string).collect())
}

fn cmd_figure(args: &[&str]) -> Result<(), String> {
    let which = args.first().copied().unwrap_or("all");
    let (cfg, _) = config_from(Config::new(), &args[args.len().min(1)..]);
    let out_dir = cfg.get_or("out", "results".to_string());
    let all = which == "all";
    let mut did = false;

    if all || which == "f1" {
        print!("{}", figures::fig1(48, 4, 4)?);
        did = true;
    }
    if all || which == "f2" {
        print!("{}", figures::fig2(64, 4, 4)?);
        did = true;
    }
    if all || which == "f3" {
        print!("{}", figures::fig3(48, 4, 4)?);
        did = true;
    }
    if all || which == "f4" {
        print!("{}", figures::fig4(48, 4, 4)?);
        did = true;
    }
    if all || which == "f5" {
        print!("{}", figures::fig5(32, 3, 4)?);
        did = true;
    }
    if all || which == "f6" {
        let (text, _) = figures::fig6(64, 6, 4)?;
        print!("{text}");
        did = true;
    }
    if all || which == "f7" || which == "f8" {
        // `engine=analytic` (default) evaluates the closed-form model;
        // `engine=sim` runs the event-driven engine via the sweep
        // machinery, under any `network=` wire model.
        let engine = cfg.get_or("engine", "analytic".to_string());
        let (f7, f8, suffix) = match engine.as_str() {
            "analytic" => (
                figures::fig78_sweep(&preset_fig7())?,
                figures::fig78_sweep(&preset_fig8())?,
                "",
            ),
            "sim" => {
                let kind =
                    NetworkKind::parse(&cfg.get_or("network", "alphabeta".to_string()))?;
                (
                    figures::fig78_sweep_sim(&preset_fig7(), kind)?,
                    figures::fig78_sweep_sim(&preset_fig8(), kind)?,
                    "_sim",
                )
            }
            other => return Err(format!("engine must be analytic|sim, got {other:?}")),
        };
        if all || which == "f7" {
            println!("Figure 7 — runtime vs threads/node, moderate latency (α=8γ)");
            print!("{}", f7.to_table());
            print!("{}", f7.to_ascii_plot(12));
            f7.write_csv(&format!("{out_dir}/fig7{suffix}.csv")).map_err(|e| e.to_string())?;
            println!("wrote {out_dir}/fig7{suffix}.csv");
        }
        if all || which == "f8" {
            println!("Figure 8 — runtime vs threads/node, high latency (α=500γ)");
            print!("{}", f8.to_table());
            print!("{}", f8.to_ascii_plot(12));
            f8.write_csv(&format!("{out_dir}/fig8{suffix}.csv")).map_err(|e| e.to_string())?;
            println!("wrote {out_dir}/fig8{suffix}.csv");
        }
        match figures::check_fig78_claims(&f7, &f8) {
            Ok(verdict) => println!("{verdict}"),
            // The analytic claims are the paper's; under alternative wire
            // models they are informative, not a hard gate.
            Err(e) if suffix == "_sim" => println!("claims check (sim engine): {e}"),
            Err(e) => return Err(e),
        }
        did = true;
    }
    if all || which == "f9" {
        // Beyond the paper: the engine-backed tuner vs. the §2.1 fixed
        // closed-form b, across the four wire models.
        let (cfg9, _) = config_from(preset_fig9(), &args[args.len().min(1)..]);
        let fig = figures::fig9_tuned(&cfg9)?;
        println!("Figure 9 — tuned vs fixed-b vs naive makespan per wire model");
        println!("  x = network index: 0 alphabeta, 1 loggp, 2 hier, 3 contended");
        print!("{}", fig.to_table());
        fig.write_csv(&format!("{out_dir}/fig9.csv")).map_err(|e| e.to_string())?;
        println!("wrote {out_dir}/fig9.csv");
        println!("{}", figures::check_fig9_claims(&fig)?);
        did = true;
    }
    if all || which == "f10" {
        // Beyond the paper: the partition subsystem's quality-vs-makespan
        // study — rowblock/rcb/rcb+refine on the banded+random SpMV
        // matrix, x = the partition's edge cut in words.
        let (cfg10, _) = config_from(preset_fig10(), &args[args.len().min(1)..]);
        let fig = figures::fig10_partition(&cfg10)?;
        println!("Figure 10 — SpMV partition quality (edge-cut words) vs makespan per wire");
        println!("  rows = rowblock, rcb, rcb+refine on the banded+random matrix");
        print!("{}", fig.to_table());
        fig.write_csv(&format!("{out_dir}/fig10.csv")).map_err(|e| e.to_string())?;
        println!("wrote {out_dir}/fig10.csv");
        println!("{}", figures::check_fig10_claims(&fig)?);
        did = true;
    }
    if !did {
        return Err(format!("unknown figure {which:?} (f1..f10 or all)"));
    }
    Ok(())
}

fn parse_halo(cfg: &Config) -> Result<HaloMode, String> {
    match cfg.get_or("halo", "multi".to_string()).as_str() {
        "multi" => Ok(HaloMode::MultiLevel),
        "level0" => Ok(HaloMode::Level0Only),
        other => Err(format!("halo must be multi|level0, got {other:?}")),
    }
}

fn cmd_transform(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("n", 64);
    defaults.set("m", 8);
    defaults.set("p", 4);
    defaults.set("halo", "multi");
    let (cfg, _) = config_from(defaults, args);
    let (n, m, p) = (cfg.require("n")?, cfg.require("m")?, cfg.require("p")?);
    let halo = parse_halo(&cfg)?;
    let t = Pipeline::new(Heat1d { n, steps: m, radius: 1 })
        .procs(p)
        .halo(halo)
        .skip_check() // checked explicitly below, with a printed verdict
        .transform()
        .map_err(|e| e.to_string())?;
    // Time exactly one whole-graph §3 derivation, so the printed
    // Mtasks/s figure stays comparable across versions.
    let t0 = std::time::Instant::now();
    let s = t.full_schedule().expect("CA strategy");
    let dt = t0.elapsed().as_secs_f64();
    let g = &t.graph;
    println!(
        "graph: {} tasks, {} edges, {} levels, {} procs  (transformed in {:.1} ms, {:.2} Mtasks/s)",
        g.len(),
        g.num_edges(),
        g.num_levels(),
        g.num_procs(),
        dt * 1e3,
        g.len() as f64 / dt / 1e6
    );
    match check_schedule(&g, &s) {
        Ok(()) => println!("Theorem 1: schedule is well-formed ✓"),
        Err(v) => println!("Theorem 1 VIOLATED: {v}"),
    }
    print!("{}", ScheduleStats::compute(&g, &s).report());
    for ps in &s.per_proc {
        println!(
            "  {}: |L0|={} |L1|={} |L2|={} |L3|={}  send {:?}  recv {:?}",
            ps.proc,
            ps.l0.len(),
            ps.l1.len(),
            ps.l2.len(),
            ps.l3.len(),
            ps.send.iter().map(|m| (m.peer.0, m.tasks.len())).collect::<Vec<_>>(),
            ps.recv.iter().map(|m| (m.peer.0, m.tasks.len())).collect::<Vec<_>>(),
        );
    }
    Ok(())
}

fn cmd_simulate(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("n", 4096);
    defaults.set("m", 32);
    defaults.set("p", 8);
    defaults.set("threads", 8);
    defaults.set("alpha", 500.0);
    defaults.set("beta", 0.1);
    defaults.set("gamma", 1.0);
    defaults.set("blocks", "2,4,8");
    defaults.set("gantt", 0);
    defaults.set("network", "alphabeta");
    let (cfg, _) = config_from(defaults, args);
    let (n, m, p): (u64, u32, u32) = (cfg.require("n")?, cfg.require("m")?, cfg.require("p")?);
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    let kind = NetworkKind::parse(&cfg.get_or("network", "alphabeta".to_string()))?;
    let blocks: Vec<u32> = parse_list(&cfg.get_or("blocks", "2,4,8".to_string()))?;
    let want_gantt = cfg.get_or("gantt", 0) != 0;

    println!(
        "1-D heat, n={n} m={m} p={p} threads={} α={} β={} γ={} wire={}",
        mach.threads,
        mach.alpha,
        mach.beta,
        mach.gamma,
        kind.label()
    );
    let base = Pipeline::new(Heat1d { n, steps: m, radius: 1 }).procs(p);
    let mut runs = vec![
        base.clone().naive().transform().map_err(|e| e.to_string())?,
        base.clone().overlap().transform().map_err(|e| e.to_string())?,
    ];
    for &b in &blocks {
        runs.push(base.clone().block(b).transform().map_err(|e| e.to_string())?);
    }
    for t in &runs {
        let mut net = kind.build(&mach);
        let r = try_simulate(&t.graph, &t.plan, &mach, net.as_mut(), &UniformCost, want_gantt)
            .map_err(|e| e.to_string())?;
        println!("{}", summary_line(&t.plan.label, &r));
        if want_gantt {
            print!("{}", gantt_ascii(&r.spans, r.total_time, 100));
        }
    }
    Ok(())
}

/// Build the sweep inputs for one workload name: naive + overlap + one CA
/// plan per block factor, all sharing the workload's graph.
fn sweep_inputs_for(
    name: &str,
    cfg: &Config,
    blocks: &[u32],
) -> Result<Vec<sweep::SweepInput>, String> {
    struct V<'a> {
        cfg: &'a Config,
        blocks: &'a [u32],
    }
    impl WorkloadVisitor for V<'_> {
        type Out = Result<Vec<sweep::SweepInput>, String>;
        fn visit<W: Workload + Clone>(&mut self, w: W) -> Self::Out {
            let p: u32 = self.cfg.require("p")?;
            imp_latency::pipeline::strategy_sweep_inputs(&Pipeline::new(w).procs(p), self.blocks)
                .map_err(|e| e.to_string())
        }
    }
    dispatch_workload(name, cfg, &mut V { cfg, blocks })?
}

/// Comma-separated `workloads=` names from the config.
fn workloads_from(cfg: &Config) -> Result<Vec<String>, String> {
    Ok(cfg
        .require::<String>("workloads")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect())
}

/// Comma-separated `networks=` tags from the config, parsed into kinds.
fn networks_from(cfg: &Config) -> Result<Vec<NetworkKind>, String> {
    let mut networks = Vec::new();
    for tag in cfg.require::<String>("networks")?.split(',') {
        let tag = tag.trim();
        if !tag.is_empty() {
            networks.push(NetworkKind::parse(tag)?);
        }
    }
    Ok(networks)
}

/// Write a report JSON to `out` (creating parent directories) and log it
/// — the shared tail of every `BENCH_*.json`-emitting subcommand.
fn write_json_report(out: &str, json: &str) -> Result<(), String> {
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
    }
    std::fs::write(out, json).map_err(|e| e.to_string())?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_sweep(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    // `--smoke` is the CI perf tracker: the fig-7 (α=8) and fig-8 (α=500)
    // regimes on problems small enough to run on every push.
    let defaults = if smoke { preset_sweep_smoke() } else { preset_sweep() };
    let (cfg, _) = config_from(defaults, args);

    let workloads = workloads_from(&cfg)?;
    let networks = networks_from(&cfg)?;
    let alphas: Vec<f64> = parse_list(&cfg.require::<String>("alphas")?)?;
    let threads: Vec<u32> = parse_list(&cfg.require::<String>("threads")?)?;
    let blocks: Vec<u32> = parse_list(&cfg.require::<String>("blocks")?)?;

    let mut inputs = Vec::new();
    for wl in &workloads {
        inputs.extend(sweep_inputs_for(wl, &cfg, &blocks)?);
    }
    let grid = sweep::SweepGrid {
        inputs,
        networks,
        alphas,
        threads,
        beta: cfg.require("beta")?,
        gamma: cfg.require("gamma")?,
        jobs: cfg.get_or("jobs", 0),
    };
    println!(
        "sweep: {} plans × {} networks × {} α values × {} thread counts = {} cells",
        grid.inputs.len(),
        grid.networks.len(),
        grid.alphas.len(),
        grid.threads.len(),
        grid.num_cells()
    );
    signals::install();
    let t0 = std::time::Instant::now();
    // Stop-aware: SIGINT/SIGTERM drains the workers and still flushes
    // whatever cells finished, so a long sweep is never lost to Ctrl-C.
    let outcome = sweep::run_with_stop(&grid, signals::flag())?;
    let interrupted = match &outcome {
        sweep::SweepRun::Complete(_) => None,
        sweep::SweepRun::Interrupted { completed, total, .. } => Some((*completed, *total)),
    };
    let cells = outcome.cells();
    let wall = t0.elapsed().as_secs_f64();
    let max_u = cells.iter().map(|c| c.utilization).fold(0.0, f64::max);
    let sim_secs: f64 = cells.iter().map(|c| c.sim_wall_secs).sum();
    println!(
        "{} cells in {wall:.2}s wall ({sim_secs:.2}s simulator time, max utilization {max_u:.3})",
        cells.len()
    );

    let out = cfg.get_or("out", "results/sweep.json".to_string());
    let tag = if interrupted.is_some() {
        "partial"
    } else if smoke {
        "smoke"
    } else {
        "sweep"
    };
    let json = sweep::to_json(tag, &cells);
    write_json_report(&out, &json)?;
    if let Some(csv_path) = cfg.get("csv") {
        if !csv_path.is_empty() {
            std::fs::write(csv_path, sweep::to_csv(&cells)).map_err(|e| e.to_string())?;
            println!("wrote {csv_path}");
        }
    }
    match interrupted {
        Some((completed, total)) => Err(format!(
            "sweep interrupted after {completed} of {total} cells; partial {out} written"
        )),
        None => Ok(()),
    }
}

/// One benchmarked grid cell: both engines run `repeat` identical
/// simulations, cross-checked bit-for-bit before timing is reported.
struct BenchCell {
    workload: String,
    strategy: String,
    network: &'static str,
    alpha: f64,
    threads: u32,
    makespan: f64,
    /// Heap events one simulation processes (compiled engine count).
    events: u64,
    interpreted_secs: f64,
    compiled_secs: f64,
}

fn bench_to_json(tag: &str, repeat: usize, cells: &[BenchCell], compile_secs: f64) -> String {
    let interp: f64 = cells.iter().map(|c| c.interpreted_secs).sum();
    let compiled: f64 = cells.iter().map(|c| c.compiled_secs).sum();
    let sims = (cells.len() * repeat) as f64;
    let events: u64 = cells.iter().map(|c| c.events * repeat as u64).sum();
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"bench\": {tag:?},\n"));
    s.push_str(&format!("  \"repeat\": {repeat},\n"));
    s.push_str(&format!("  \"cells\": {},\n", cells.len()));
    s.push_str(&format!("  \"sims_per_sec_compiled\": {},\n", sims / compiled.max(1e-12)));
    s.push_str(&format!("  \"sims_per_sec_interpreted\": {},\n", sims / interp.max(1e-12)));
    s.push_str(&format!("  \"speedup\": {},\n", interp / compiled.max(1e-12)));
    s.push_str(&format!(
        "  \"events_per_sec\": {},\n",
        events as f64 / compiled.max(1e-12)
    ));
    s.push_str(&format!("  \"compile_secs\": {compile_secs},\n"));
    s.push_str(&format!("  \"simulate_secs\": {compiled},\n"));
    s.push_str(&format!(
        "  \"compile_fraction\": {},\n",
        compile_secs / (compile_secs + compiled).max(1e-12)
    ));
    s.push_str("  \"regimes\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": {:?}, \"strategy\": {:?}, \"network\": {:?}, \
             \"alpha\": {}, \"threads\": {}, \"makespan\": {}, \"events\": {}, \
             \"interpreted_secs\": {}, \"compiled_secs\": {}, \"speedup\": {}}}{}",
            c.workload,
            c.strategy,
            c.network,
            c.alpha,
            c.threads,
            c.makespan,
            c.events,
            c.interpreted_secs,
            c.compiled_secs,
            c.interpreted_secs / c.compiled_secs.max(1e-12),
            if i + 1 == cells.len() { "" } else { "," }
        ));
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}

/// The engine micro-benchmark behind `BENCH_engine.json`: the sweep-smoke
/// grid (fig-7/8 regimes × the four wire models), every cell simulated
/// `repeat` times by the compiled engine (one `CompiledPlan` per input,
/// one reused `EngineScratch`) and by the interpreting engine, with the
/// two results compared bit-for-bit — any divergence fails the run (and
/// therefore `make bench-smoke` / CI).
fn cmd_bench(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_bench_smoke() } else { preset_bench() };
    let (cfg, _) = config_from(defaults, args);
    let repeat: usize = cfg.get_or("repeat", 5).max(1);

    let workloads = workloads_from(&cfg)?;
    let networks = networks_from(&cfg)?;
    let alphas: Vec<f64> = parse_list(&cfg.require::<String>("alphas")?)?;
    let threads: Vec<u32> = parse_list(&cfg.require::<String>("threads")?)?;
    let blocks: Vec<u32> = parse_list(&cfg.require::<String>("blocks")?)?;
    let beta: f64 = cfg.require("beta")?;
    let gamma: f64 = cfg.require("gamma")?;

    let mut inputs = Vec::new();
    for wl in &workloads {
        inputs.extend(sweep_inputs_for(wl, &cfg, &blocks)?);
    }

    // Compile-vs-simulate split: time a fresh lowering of every input
    // (each input already carries one, built by `sweep_input`; this
    // measures what that one-time cost was).
    let mut channels = 0usize;
    let t0 = std::time::Instant::now();
    for input in &inputs {
        let cp = CompiledPlan::compile(&input.graph, &input.plan, input.cost.as_ref());
        channels += cp.num_channels();
    }
    let compile_secs = t0.elapsed().as_secs_f64();

    let mut scratch = EngineScratch::new();
    let mut cells: Vec<BenchCell> = Vec::new();
    for input in &inputs {
        let procs = input.plan.per_proc.len() as u32;
        for kind in &networks {
            for &alpha in &alphas {
                for &t in &threads {
                    let mach = Machine::new(
                        procs,
                        t,
                        alpha,
                        beta * input.words_per_value as f64,
                        gamma,
                    );
                    let tag = format!(
                        "{}/{}/{}/α={alpha}/t={t}",
                        input.workload,
                        input.strategy,
                        kind.label()
                    );
                    let t0 = std::time::Instant::now();
                    let mut interp = None;
                    for _ in 0..repeat {
                        let mut net = kind.build_for(&mach, input.layout.as_ref());
                        interp = Some(
                            try_simulate(
                                &input.graph,
                                &input.plan,
                                &mach,
                                net.as_mut(),
                                input.cost.as_ref(),
                                false,
                            )
                            .map_err(|e| format!("{tag}: {e}"))?,
                        );
                    }
                    let interpreted_secs = t0.elapsed().as_secs_f64();
                    let t0 = std::time::Instant::now();
                    let mut compiled = None;
                    for _ in 0..repeat {
                        let mut net = kind.build_for(&mach, input.layout.as_ref());
                        compiled = Some(
                            simulate_compiled(
                                &input.compiled,
                                &mach,
                                net.as_mut(),
                                &mut scratch,
                                false,
                            )
                            .map_err(|e| format!("{tag}: {e}"))?,
                        );
                    }
                    let compiled_secs = t0.elapsed().as_secs_f64();
                    let (ri, rc) = (interp.unwrap(), compiled.unwrap());
                    // The hard gate: the compiled engine must be
                    // bit-for-bit the interpreting engine on every cell —
                    // including the busy/wait accounting that only shows
                    // up in utilization figures.
                    if rc.total_time != ri.total_time
                        || rc.messages != ri.messages
                        || rc.words != ri.words
                        || rc.proc_finish != ri.proc_finish
                        || rc.proc_busy != ri.proc_busy
                        || rc.proc_wait != ri.proc_wait
                    {
                        return Err(format!(
                            "compiled/interpreted divergence on {tag}: \
                             makespan {} vs {}, {} vs {} msgs, {} vs {} words",
                            rc.total_time,
                            ri.total_time,
                            rc.messages,
                            ri.messages,
                            rc.words,
                            ri.words
                        ));
                    }
                    cells.push(BenchCell {
                        workload: input.workload.to_string(),
                        strategy: input.strategy.to_string(),
                        network: kind.label(),
                        alpha,
                        threads: t,
                        makespan: rc.total_time,
                        events: scratch.events(),
                        interpreted_secs,
                        compiled_secs,
                    });
                }
            }
        }
    }

    let interp: f64 = cells.iter().map(|c| c.interpreted_secs).sum();
    let compiled: f64 = cells.iter().map(|c| c.compiled_secs).sum();
    let sims = cells.len() * repeat;
    println!(
        "bench: {} plans ({channels} channels) × {} cells × {repeat} sims, all \
         compiled≡interpreted",
        inputs.len(),
        cells.len()
    );
    println!(
        "  compiled    {:>10.0} sims/s  ({compiled:.3}s total, compile split {compile_secs:.3}s)",
        sims as f64 / compiled.max(1e-12),
    );
    println!(
        "  interpreted {:>10.0} sims/s  ({interp:.3}s total)",
        sims as f64 / interp.max(1e-12)
    );
    println!("  speedup     {:>10.2}x", interp / compiled.max(1e-12));

    let out = cfg.get_or("out", "results/bench.json".to_string());
    let json = bench_to_json(if smoke { "smoke" } else { "bench" }, repeat, &cells, compile_secs);
    write_json_report(&out, &json)
}

fn cmd_pipeline(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("workload", "heat1d");
    defaults.set("m", 16);
    defaults.set("p", 4);
    defaults.set("h", 32);
    defaults.set("w", 32);
    defaults.set("strategy", "ca");
    defaults.set("halo", "multi");
    defaults.set("threads", 8);
    defaults.set("alpha", 500.0);
    defaults.set("beta", 0.1);
    defaults.set("gamma", 1.0);
    let (cfg, _) = config_from(defaults, args);
    let m: u32 = cfg.require("m")?;
    let h: u64 = cfg.require("h")?;
    let w: u64 = cfg.require("w")?;
    match cfg.get_or("workload", "heat1d".to_string()).as_str() {
        "heat1d" => run_pipeline(
            Heat1d { n: cfg.get_or("n", 4096), steps: m, radius: cfg.get_or("r", 1) },
            &cfg,
        ),
        "heat2d" => run_pipeline(Heat2d { h, w, steps: m }, &cfg),
        "moore2d" => run_pipeline(Moore2d { h, w, steps: m }, &cfg),
        "spmv" => run_pipeline(
            Spmv { matrix: CsrMatrix::laplace2d(h as usize, w as usize), steps: m },
            &cfg,
        ),
        // The AllToAll dot levels make CG graphs O(n²) in edges — keep
        // the default system small.
        "cg" => run_pipeline(
            ConjugateGradient { unknowns: cfg.get_or("n", 256), iters: cfg.get_or("iters", 4) },
            &cfg,
        ),
        other => Err(format!("unknown workload {other:?} (heat1d|heat2d|moore2d|spmv|cg)")),
    }
}

/// Shared driver: transform `workload` per the config, then simulate and
/// execute it, printing the uniform reports.
fn run_pipeline<W: Workload>(workload: W, cfg: &Config) -> Result<(), String> {
    let p: u32 = cfg.require("p")?;
    let strategy = match cfg.get_or("strategy", "ca".to_string()).as_str() {
        "ca" => Strategy::Ca,
        "naive" => Strategy::Naive,
        "overlap" => Strategy::Overlap,
        other => return Err(format!("strategy must be ca|naive|overlap, got {other:?}")),
    };
    let mut pipeline = Pipeline::new(workload)
        .procs(p)
        .strategy(strategy)
        .options(TransformOptions::default().with_halo(parse_halo(cfg)?));
    if let Some(b) = cfg.get("b") {
        pipeline = pipeline.block(b.parse().map_err(|_| format!("bad block factor {b:?}"))?);
    }
    let t0 = std::time::Instant::now();
    let t = pipeline.transform().map_err(|e| e.to_string())?;
    let st = t.stats();
    println!(
        "transformed in {:.1} ms: {} tasks / {} edges / {} levels on {} procs → \
         {} executions ({:.3}x), {} msgs / {} words",
        t0.elapsed().as_secs_f64() * 1e3,
        st.tasks,
        st.edges,
        st.levels,
        st.procs,
        st.executed_tasks,
        st.redundancy_factor,
        st.messages,
        st.words
    );
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    println!("  {}", t.simulate(&mach).summary());
    let report = t.execute().map_err(|e| e.to_string())?;
    println!("  {}", report.summary());
    Ok(())
}

fn cmd_cost(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("n", 65536);
    defaults.set("m", 128);
    defaults.set("p", 16);
    defaults.set("alpha", 300.0);
    defaults.set("beta", 0.2);
    defaults.set("gamma", 1.0);
    defaults.set("max_b", 64);
    let (cfg, _) = config_from(defaults, args);
    let c = CostModel::new(
        cfg.require("n")?,
        cfg.require("m")?,
        cfg.require("p")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    let max_b: u32 = cfg.require("max_b")?;
    println!("T(b) = (M/b)α + Mβ + (MN/p + Mb)γ   with α={} β={} γ={}", c.alpha, c.beta, c.gamma);
    println!("{:>6} {:>16} {:>16} {:>10}", "b", "T(b)", "overhead", "speedup");
    let mut b = 1u32;
    while b <= max_b {
        println!(
            "{b:>6} {:>16.1} {:>16.1} {:>10.4}",
            c.cost(b),
            c.overhead(b),
            c.speedup(b)
        );
        b *= 2;
    }
    println!(
        "optimal b: continuous sqrt(α/γ) = {:.2}, discrete argmin = {} (independent of N, M, p)",
        c.optimal_b_continuous(),
        c.optimal_b(max_b)
    );
    Ok(())
}

fn artifact_dir() -> std::path::PathBuf {
    Registry::default_dir()
}

fn cmd_run_heat1d(args: &[&str]) -> Result<(), String> {
    let (cfg, _) = config_from(preset_end_to_end(), args);
    let c = heat1d::Heat1dConfig {
        n_per_worker: cfg.require("n_per_worker")?,
        workers: cfg.require("workers")?,
        b: cfg.get_or("b", 8),
        steps: cfg.require("steps")?,
        nu: cfg.require("nu")?,
        artifacts_dir: artifact_dir(),
    };
    let n = c.total_points();
    let init: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.003).sin() * 0.5).collect();
    let (field, stats) = heat1d::run(&c, &init).map_err(|e| e.to_string())?;
    println!(
        "heat1d: N={} workers={} b={} steps={} → wall {:.3}s (exchange {:.3}s, compute {:.3}s), {} msgs / {} words",
        n, c.workers, c.b, c.steps, stats.wall_secs, stats.exchange_secs, stats.compute_secs,
        stats.messages, stats.words
    );
    let reference = heat1d::reference(&artifact_dir(), &init, c.nu, c.steps)
        .map_err(|e| e.to_string())?;
    println!("rel-l2 vs sequential reference: {:.3e}", heat1d::rel_l2(&field, &reference));
    Ok(())
}

fn cmd_run_heat2d(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("px", 2);
    defaults.set("py", 2);
    defaults.set("b", 2);
    defaults.set("steps", 16);
    defaults.set("nu", 0.15);
    let (cfg, _) = config_from(defaults, args);
    let c = heat2d::Heat2dConfig {
        tile_h: 64,
        tile_w: 64,
        px: cfg.require("px")?,
        py: cfg.require("py")?,
        b: cfg.require("b")?,
        steps: cfg.require("steps")?,
        nu: cfg.require("nu")?,
        artifacts_dir: artifact_dir(),
    };
    let (h, w) = (c.grid_h(), c.grid_w());
    let init: Vec<f32> = (0..h * w)
        .map(|k| ((k / w) as f32 * 0.37).sin() + ((k % w) as f32 * 0.23).cos())
        .collect();
    let (field, stats) = heat2d::run(&c, &init).map_err(|e| e.to_string())?;
    let reference = heat2d::reference_periodic(&init, h, w, c.nu, c.steps);
    println!(
        "heat2d: {}x{} grid, {}x{} workers, b={} steps={} → wall {:.3}s, {} msgs, rel-l2 {:.3e}",
        h,
        w,
        c.px,
        c.py,
        c.b,
        c.steps,
        stats.wall_secs,
        stats.messages,
        heat1d::rel_l2(&field, &reference)
    );
    Ok(())
}

fn cmd_run_cg(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("workers", 2);
    defaults.set("tol", 1e-5);
    defaults.set("max_iters", 2000);
    defaults.set("pipelined", 0);
    let (cfg, _) = config_from(defaults, args);
    let c = CgConfig {
        workers: cfg.require("workers")?,
        tol: cfg.require("tol")?,
        max_iters: cfg.require("max_iters")?,
        pipelined: cfg.get_or("pipelined", 0) != 0,
        artifacts_dir: artifact_dir(),
    };
    let n = dcg::SHARD * c.workers as usize;
    let rhs: Vec<f32> = (0..n).map(|i| ((i * 13 + 5) % 29) as f32 / 29.0 - 0.5).collect();
    let (_, stats) = dcg::solve(&c, &rhs).map_err(|e| e.to_string())?;
    println!(
        "cg({}): N={} workers={} → {} iters, residual {:.3e}, wall {:.3}s (compute {:.3}s, reduce-wait {:.3}s), {} msgs",
        if c.pipelined { "pipelined" } else { "classic" },
        n,
        c.workers,
        stats.iterations,
        stats.final_residual,
        stats.wall_secs,
        stats.compute_secs,
        stats.reduce_wait_secs,
        stats.messages
    );
    Ok(())
}

fn cmd_powers(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("n", 4096);
    defaults.set("workers", 4);
    defaults.set("s", 8);
    let (cfg, _) = config_from(defaults, args);
    let n: usize = cfg.require("n")?;
    let workers: u32 = cfg.require("workers")?;
    let s: u32 = cfg.require("s")?;
    let v: Vec<f32> = (0..n).map(|i| ((i * 17 + 3) % 23) as f32 / 23.0 - 0.5).collect();
    let blocked =
        imp_latency::krylov::powers::matrix_powers(&v, workers, s, true).map_err(|e| e.to_string())?;
    let baseline =
        imp_latency::krylov::powers::matrix_powers(&v, workers, s, false).map_err(|e| e.to_string())?;
    println!(
        "matrix powers [Ap..A^{s}p], N={n}, {workers} workers:\n  \
         blocked : {} msgs / {} words / {:.4}s\n  \
         baseline: {} msgs / {} words / {:.4}s\n  \
         message reduction {}x (one s-wide exchange instead of s exchanges)",
        blocked.messages,
        blocked.words,
        blocked.wall_secs,
        baseline.messages,
        baseline.words,
        baseline.wall_secs,
        baseline.messages / blocked.messages.max(1)
    );
    // Verify agreement.
    let mut worst = 0.0f32;
    for (a, b) in blocked.powers.iter().flatten().zip(baseline.powers.iter().flatten()) {
        worst = worst.max((a - b).abs());
    }
    println!("  max |blocked − baseline| = {worst:.3e}");
    Ok(())
}

fn cmd_autotune(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("n", 65536);
    defaults.set("m", 64);
    defaults.set("p", 16);
    defaults.set("threads", 16);
    defaults.set("alpha", 500.0);
    defaults.set("beta", 0.1);
    defaults.set("gamma", 1.0);
    let (cfg, _) = config_from(defaults, args);
    let mach = Machine::new(
        cfg.require("p")?,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    let r = imp_latency::transform::select_b(
        cfg.require("n")?,
        cfg.require("m")?,
        &mach,
        &[1, 2, 4, 8, 16, 32, 64],
    )
    .map_err(|e| e.to_string())?;
    println!(
        "autotune: grid {:?}\n  §2.1 model b* = {} (continuous {:.1})\n  simulator b* = {}\n  \
         chosen b = {}  (predicted {:.1}, naive {:.1}, speedup {:.2}x)",
        r.grid,
        r.model_b,
        r.continuous_b,
        r.sim_b,
        r.chosen_b,
        r.predicted_time,
        r.naive_time,
        r.predicted_speedup()
    );
    Ok(())
}

/// Autotune one named workload under every configured wire model,
/// `repeat` times each (repeats demonstrate the tuning cache: the
/// second pass is served without engine runs).
fn tune_rows_for(
    name: &str,
    cfg: &Config,
    tuner: &mut Tuner,
) -> Result<Vec<tune::TuneRow>, String> {
    struct V<'a, 'b> {
        cfg: &'a Config,
        tuner: &'b mut Tuner,
    }
    impl WorkloadVisitor for V<'_, '_> {
        type Out = Result<Vec<tune::TuneRow>, String>;
        fn visit<W: Workload + Clone>(&mut self, w: W) -> Self::Out {
            let cfg = self.cfg;
            let p: u32 = cfg.require("p")?;
            let mach = Machine::new(
                p,
                cfg.require("threads")?,
                cfg.require("alpha")?,
                cfg.require("beta")?,
                cfg.require("gamma")?,
            );
            let repeat: u32 = cfg.get_or("repeat", 1);
            let mut rows = Vec::new();
            for tag in cfg.require::<String>("networks")?.split(',') {
                let tag = tag.trim();
                if tag.is_empty() {
                    continue;
                }
                let kind = NetworkKind::parse(tag)?;
                for _ in 0..repeat.max(1) {
                    // Shutdown boundary: every finished row is already in
                    // `rows` and every cache entry is already on disk.
                    if signals::shutdown_requested() {
                        return Ok(rows);
                    }
                    let t = Pipeline::new(w.clone())
                        .procs(p)
                        .machine(mach)
                        .network(kind)
                        .autotune(self.tuner)
                        .map_err(|e| e.to_string())?;
                    let r = t.tune_report().expect("autotune attaches a report");
                    println!("{}", r.summary());
                    rows.push(tune::TuneRow::from_report(r));
                }
            }
            Ok(rows)
        }
    }
    dispatch_workload(name, cfg, &mut V { cfg, tuner })?
}

fn cmd_tune(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_tune_smoke() } else { preset_tune() };
    let (cfg, _) = config_from(defaults, args);

    let search = tune::search_from_tag(&cfg.get_or("search", "exhaustive".to_string()))?;
    // A `.json` path keeps the legacy single-file cache; any other
    // non-empty path is a shard directory (per-signature files + locks),
    // which is what the serve daemon shares with the CLI.
    let cache = match cfg.get("cache") {
        Some(path) if path.ends_with(".json") => TuningCache::with_path(path),
        Some(path) if !path.is_empty() => TuningCache::sharded(path),
        _ => TuningCache::new(),
    };
    let preloaded = cache.len();
    let mut tuner = Tuner::new(search, cache);

    let workloads = workloads_from(&cfg)?;
    println!(
        "tune: {} workloads × networks [{}], search={} ({} cached entries loaded)",
        workloads.len(),
        cfg.get_or("networks", String::new()),
        tuner.search.label(),
        preloaded
    );
    signals::install();
    let t0 = std::time::Instant::now();
    let compiles0 = imp_latency::sim::compile_count();
    let mut rows: Vec<tune::TuneRow> = Vec::new();
    for wl in &workloads {
        if signals::shutdown_requested() {
            break;
        }
        rows.extend(tune_rows_for(wl, &cfg, &mut tuner)?);
    }
    let interrupted = signals::shutdown_requested();
    let engine_runs: usize = rows.iter().map(|r| r.engine_runs).sum();
    let compiles = imp_latency::sim::compile_count() - compiles0;
    println!(
        "{} tunings ({engine_runs} engine runs, {compiles} plan compilations) in {:.2}s; \
         cache {} hits / {} misses (hit rate {:.2})",
        rows.len(),
        t0.elapsed().as_secs_f64(),
        tuner.cache.hits(),
        tuner.cache.misses(),
        tuner.cache.hit_rate()
    );

    let out = cfg.get_or("out", "results/tune.json".to_string());
    let tag = if interrupted {
        "partial"
    } else if smoke {
        "smoke"
    } else {
        "tune"
    };
    let json = tune::rows_to_json(tag, &rows, tuner.cache.hits(), tuner.cache.misses());
    write_json_report(&out, &json)?;
    if interrupted {
        // Cache entries persist as each tuning completes; the partial
        // report is flushed above — exit nonzero so callers notice.
        tuner.cache.save().map_err(|e| e.to_string())?;
        return Err(format!("tune interrupted after {} rows; partial {out} written", rows.len()));
    }
    Ok(())
}

/// One layout's `BENCH_partition.json` cells: transform once, then fan
/// the single shared plan across every wire model through the sweep
/// worker pool — the same one-build-many-scores shape the tuner uses.
fn partition_rows<W: Workload>(
    pipeline: Pipeline<W>,
    workload: &str,
    layout: String,
    networks: &[NetworkKind],
    mach: &Machine,
    q: &PartitionQuality,
) -> Result<Vec<partition::PartitionRow>, String> {
    let t = pipeline.transform().map_err(|e| e.to_string())?;
    let grid = sweep::SweepGrid {
        inputs: vec![t.sweep_input()],
        networks: networks.to_vec(),
        alphas: vec![mach.alpha],
        threads: vec![mach.threads],
        beta: mach.beta,
        gamma: mach.gamma,
        jobs: 0,
    };
    let cells = sweep::run(&grid)?;
    Ok(networks
        .iter()
        .zip(&cells)
        .map(|(kind, cell)| partition::PartitionRow {
            workload: workload.to_string(),
            layout: layout.clone(),
            network: kind.key(),
            makespan: cell.makespan,
            messages: cell.messages,
            words: cell.words,
            edge_cut_words: q.edge_cut_words,
            edge_cut_nnz: q.edge_cut_nnz,
            imbalance: q.imbalance,
            max_neighbors: q.max_neighbors,
        })
        .collect())
}

/// The data-layout study: every grid shape (heat2d) and every graph
/// partitioner (banded+random SpMV) simulated under every wire model,
/// with each cell pairing the simulated makespan against the layout's
/// static [`PartitionQuality`].
fn cmd_partition(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_partition_smoke() } else { preset_partition() };
    let (cfg, _) = config_from(defaults, args);
    let p: u32 = cfg.require("p")?;
    let m: u32 = cfg.require("m")?;
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    let networks = networks_from(&cfg)?;
    let t0 = std::time::Instant::now();
    let mut rows: Vec<partition::PartitionRow> = Vec::new();

    // Structured section: heat2d under each processor-grid shape.  The
    // five-point pattern doubles as the quality metric's dependence graph.
    let (h, w): (u64, u64) = (cfg.require("h")?, cfg.require("w")?);
    let pattern = CsrMatrix::laplace2d(h as usize, w as usize);
    for tag in cfg.require::<String>("grids")?.split(',') {
        let tag = tag.trim();
        if tag.is_empty() {
            continue;
        }
        let grid = ProcGrid::parse(tag)?;
        let dist = grid.distribution_2d(h, w, p)?;
        let q = PartitionQuality::evaluate(&pattern, &partition::assignment_of(&dist), p);
        println!("heat2d {:>10}: {}", grid.key(), q.summary());
        rows.extend(partition_rows(
            Pipeline::new(Heat2d { h, w, steps: m })
                .procs(p)
                .naive()
                .partitioning(Partitioning::Grid(grid)),
            "heat2d",
            grid.key(),
            &networks,
            &mach,
            &q,
        )?);
    }

    // Irregular section: banded+random SpMV under each graph partitioner.
    let (sh, sw): (usize, usize) = (cfg.require("spmv_h")?, cfg.require("spmv_w")?);
    let a = partition::banded_random(sh, sw, cfg.require("chords")?);
    for tag in cfg.require::<String>("partitioners")?.split(',') {
        let tag = tag.trim();
        if tag.is_empty() {
            continue;
        }
        let part = Partitioner::parse(tag)?;
        let q = PartitionQuality::evaluate(&a, &part.assign(&a, p), p);
        println!("spmv   {:>10}: {}", part.key(), q.summary());
        rows.extend(partition_rows(
            Pipeline::new(Spmv { matrix: a.clone(), steps: m })
                .procs(p)
                .naive()
                .partitioning(Partitioning::Graph(part)),
            "spmv",
            part.key().to_string(),
            &networks,
            &mach,
            &q,
        )?);
    }

    println!("{} cells in {:.2}s", rows.len(), t0.elapsed().as_secs_f64());
    let out = cfg.get_or("out", "results/partition.json".to_string());
    let json = partition::rows_to_json(if smoke { "smoke" } else { "partition" }, &rows);
    write_json_report(&out, &json)
}

/// Bind-serve-unlink over a Unix socket; a stub error elsewhere so the
/// command table stays platform-independent.
#[cfg(unix)]
fn serve_unix_at(server: &Server, path: &str) -> Result<usize, String> {
    let _ = std::fs::remove_file(path);
    let listener = std::os::unix::net::UnixListener::bind(path)
        .map_err(|e| format!("bind unix:{path}: {e}"))?;
    eprintln!("serve: listening on unix:{path} (SIGINT/SIGTERM to stop)");
    let result = server.serve_unix(listener, signals::flag()).map_err(|e| e.to_string());
    let _ = std::fs::remove_file(path);
    result.map(|()| 0)
}

#[cfg(not(unix))]
fn serve_unix_at(_server: &Server, path: &str) -> Result<usize, String> {
    Err(format!("unix sockets are unsupported on this platform (listen=unix:{path})"))
}

/// One tuning problem audited with and without lower-bound pruning.
struct PruneAudit {
    workload: String,
    network: String,
    /// Distinct candidates the un-pruned search considered.
    considered: usize,
    /// Candidates the pruning run skipped on analytic lower bounds.
    pruned: usize,
    engine_runs_full: usize,
    engine_runs_pruned: usize,
}

/// Tune one named workload under each wire twice — un-pruned and with
/// analytic lower-bound pruning, each search on its own in-memory cache
/// — and fail unless both runs pick the identical winner (and agree on
/// its makespan and the naive baseline bit-for-bit).
fn prune_audit_for(
    name: &str,
    cfg: &Config,
    networks: &[NetworkKind],
) -> Result<Vec<PruneAudit>, String> {
    struct V<'a> {
        cfg: &'a Config,
        networks: &'a [NetworkKind],
    }
    impl WorkloadVisitor for V<'_> {
        type Out = Result<Vec<PruneAudit>, String>;
        fn visit<W: Workload + Clone>(&mut self, w: W) -> Self::Out {
            let cfg = self.cfg;
            let p: u32 = cfg.require("p")?;
            let mach = Machine::new(
                p,
                cfg.require("tune_threads")?,
                cfg.require("tune_alpha")?,
                cfg.require("beta")?,
                cfg.require("gamma")?,
            );
            let mut plain = Tuner::exhaustive();
            let mut pruning = Tuner::exhaustive().with_pruning();
            let mut audits = Vec::new();
            for &kind in self.networks {
                let base =
                    Pipeline::new(w.clone()).procs(p).machine(mach).network(kind);
                let full = base.clone().autotune(&mut plain).map_err(|e| e.to_string())?;
                let full = full.tune_report().expect("autotune attaches a report").clone();
                let cut = base.autotune(&mut pruning).map_err(|e| e.to_string())?;
                let cut = cut.tune_report().expect("autotune attaches a report").clone();
                if cut.chosen != full.chosen
                    || cut.makespan != full.makespan
                    || cut.naive_makespan != full.naive_makespan
                {
                    return Err(format!(
                        "pruning changed the verdict on {}/{}: {} (makespan {}) vs {} \
                         (makespan {})",
                        full.workload,
                        full.network,
                        cut.chosen.label(),
                        cut.makespan,
                        full.chosen.label(),
                        full.makespan
                    ));
                }
                println!(
                    "  {:<8} {:<22} winner {:<16} unchanged; {} of {} candidates pruned \
                     ({} → {} engine runs)",
                    full.workload,
                    full.network,
                    full.chosen.label(),
                    cut.pruned,
                    full.evaluations,
                    full.engine_runs,
                    cut.engine_runs
                );
                audits.push(PruneAudit {
                    workload: full.workload.clone(),
                    network: full.network.clone(),
                    considered: full.evaluations,
                    pruned: cut.pruned,
                    engine_runs_full: full.engine_runs,
                    engine_runs_pruned: cut.engine_runs,
                });
            }
            Ok(audits)
        }
    }
    dispatch_workload(name, cfg, &mut V { cfg, networks })?
}

fn analyze_to_json(
    tag: &str,
    plans: usize,
    repeat: usize,
    verify_secs: f64,
    cells: usize,
    min_ratio: f64,
    mean_ratio: f64,
    exact_cells: usize,
    audits: &[PruneAudit],
) -> String {
    let verified = (plans * repeat) as f64;
    let considered: usize = audits.iter().map(|a| a.considered).sum();
    let pruned: usize = audits.iter().map(|a| a.pruned).sum();
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"analyze\": {tag:?},\n"));
    s.push_str(&format!("  \"plans\": {plans},\n"));
    s.push_str(&format!("  \"repeat\": {repeat},\n"));
    s.push_str(&format!("  \"verify_secs\": {verify_secs},\n"));
    s.push_str(&format!(
        "  \"plans_per_sec\": {},\n",
        verified / verify_secs.max(1e-12)
    ));
    s.push_str(&format!("  \"cells\": {cells},\n"));
    s.push_str(&format!("  \"bound_min_ratio\": {min_ratio},\n"));
    s.push_str(&format!("  \"bound_mean_ratio\": {mean_ratio},\n"));
    s.push_str(&format!("  \"exact_cells\": {exact_cells},\n"));
    s.push_str(&format!(
        "  \"exact_fraction\": {},\n",
        exact_cells as f64 / (cells as f64).max(1.0)
    ));
    s.push_str(&format!("  \"considered\": {considered},\n"));
    s.push_str(&format!("  \"pruned\": {pruned},\n"));
    s.push_str(&format!(
        "  \"prune_rate\": {},\n",
        pruned as f64 / (considered as f64).max(1.0)
    ));
    s.push_str("  \"tunings\": [\n");
    for (i, a) in audits.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": {:?}, \"network\": {:?}, \"considered\": {}, \
             \"pruned\": {}, \"engine_runs_full\": {}, \"engine_runs_pruned\": {}}}{}",
            a.workload,
            a.network,
            a.considered,
            a.pruned,
            a.engine_runs_full,
            a.engine_runs_pruned,
            if i + 1 == audits.len() { "" } else { "," }
        ));
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}

/// The static-analysis study behind `BENCH_analyze.json`, in three
/// gated phases:
///
/// 1. **Verify**: every pipeline-built plan of the grid must pass
///    [`analysis::analyze`] with zero diagnostics, timed `repeat`× for a
///    plans-verified/sec figure (no engine involved).
/// 2. **Bound**: on every (plan × wire × α × threads) cell the analytic
///    critical-path lower bound must not exceed the simulated makespan,
///    and on stateless wires (α-β, hierarchical — and every wire at the
///    α=0 corner rows) it must equal it bit-for-bit.
/// 3. **Prune**: each `tune_workloads` × wire tuning problem is solved
///    un-pruned and with lower-bound pruning; the winner must be
///    identical and the aggregate prune rate at least 20%.
///
/// Any violated gate fails the run (and `make analyze-smoke` / CI).
fn cmd_analyze(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_analyze_smoke() } else { preset_analyze() };
    let (cfg, _) = config_from(defaults, args);

    let workloads = workloads_from(&cfg)?;
    let networks = networks_from(&cfg)?;
    let alphas: Vec<f64> = parse_list(&cfg.require::<String>("alphas")?)?;
    let threads: Vec<u32> = parse_list(&cfg.require::<String>("threads")?)?;
    let blocks: Vec<u32> = parse_list(&cfg.require::<String>("blocks")?)?;
    let beta: f64 = cfg.require("beta")?;
    let gamma: f64 = cfg.require("gamma")?;
    let repeat: usize = cfg.get_or("repeat", 1).max(1);

    let mut inputs = Vec::new();
    for wl in &workloads {
        inputs.extend(sweep_inputs_for(wl, &cfg, &blocks)?);
    }

    // Phase 1: the verifier itself — every built plan must come back
    // clean, and quickly (the whole point is running *before* the
    // engine).
    let t0 = std::time::Instant::now();
    for _ in 0..repeat {
        for input in &inputs {
            let report = analysis::analyze(&input.graph, &input.plan);
            if !report.is_clean() {
                return Err(format!(
                    "pipeline-built plan failed static analysis: {}",
                    report.summary()
                ));
            }
        }
    }
    let verify_secs = t0.elapsed().as_secs_f64();
    let plans_per_sec = (inputs.len() * repeat) as f64 / verify_secs.max(1e-12);
    println!(
        "analyze: {} plans statically verified clean, {repeat}× in {verify_secs:.3}s \
         ({plans_per_sec:.0} plans/sec)",
        inputs.len()
    );

    // Phase 2: the bound against the engine on every regime cell.
    let grid = sweep::SweepGrid {
        inputs,
        networks: networks.clone(),
        alphas,
        threads,
        beta,
        gamma,
        jobs: cfg.get_or("jobs", 0),
    };
    let cells = sweep::run(&grid)?;
    let (mut min_ratio, mut sum_ratio, mut exact_cells) = (f64::INFINITY, 0.0, 0usize);
    let mut k = 0;
    for input in &grid.inputs {
        for kind in &grid.networks {
            for &alpha in &grid.alphas {
                for &t in &grid.threads {
                    let cell = &cells[k];
                    k += 1;
                    let tag = format!(
                        "{}/{}/{}/α={alpha}/t={t}",
                        input.workload,
                        input.strategy,
                        kind.label()
                    );
                    let mach = Machine::new(
                        input.plan.per_proc.len() as u32,
                        t,
                        alpha,
                        beta * input.words_per_value as f64,
                        gamma,
                    );
                    let net = kind.build_for(&mach, input.layout.as_ref());
                    let cp = analysis::critical_path(
                        &input.graph,
                        &input.plan,
                        &mach,
                        net.as_ref(),
                        input.cost.as_ref(),
                    )
                    .map_err(|e| format!("{tag}: {e}"))?;
                    if cp.makespan > cell.makespan * (1.0 + 1e-9) {
                        return Err(format!(
                            "{tag}: lower bound {} exceeds simulated makespan {}",
                            cp.makespan, cell.makespan
                        ));
                    }
                    if cp.exact_wire {
                        exact_cells += 1;
                        if (cp.makespan - cell.makespan).abs()
                            > 1e-9 * cell.makespan.max(1.0)
                        {
                            return Err(format!(
                                "{tag}: stateless-wire bound {} must equal the simulated \
                                 makespan {}",
                                cp.makespan, cell.makespan
                            ));
                        }
                    }
                    let ratio = cp.makespan / cell.makespan.max(1e-12);
                    min_ratio = min_ratio.min(ratio);
                    sum_ratio += ratio;
                }
            }
        }
    }
    let mean_ratio = sum_ratio / (cells.len() as f64).max(1.0);
    if exact_cells == 0 {
        return Err("no stateless-wire cells: the exactness gate never ran".into());
    }
    println!(
        "bound ≤ makespan on all {} cells (tightness: min {min_ratio:.3}, mean \
         {mean_ratio:.3}; {exact_cells} cells bit-exact)",
        cells.len()
    );

    // Phase 3: pruning must speed the tuner up without touching its
    // verdict.
    let tune_workloads: Vec<String> = cfg
        .require::<String>("tune_workloads")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let mut audits = Vec::new();
    for wl in &tune_workloads {
        audits.extend(prune_audit_for(wl, &cfg, &networks)?);
    }
    let considered: usize = audits.iter().map(|a| a.considered).sum();
    let pruned: usize = audits.iter().map(|a| a.pruned).sum();
    if pruned * 5 < considered {
        return Err(format!(
            "prune rate {pruned}/{considered} below the 20% gate"
        ));
    }
    println!(
        "pruning: {pruned} of {considered} candidates skipped ({:.0}%), every winner \
         unchanged",
        100.0 * pruned as f64 / considered as f64
    );

    let out = cfg.get_or("out", "results/analyze.json".to_string());
    let tag = if smoke { "smoke" } else { "analyze" };
    let json = analyze_to_json(
        tag,
        grid.inputs.len(),
        repeat,
        verify_secs,
        cells.len(),
        min_ratio,
        mean_ratio,
        exact_cells,
        &audits,
    );
    write_json_report(&out, &json)
}

/// The serving story.  `--smoke` drives the scripted cold → warm →
/// duplicate-burst → batch mix into `BENCH_serve.json` and *gates* on
/// the serving claims (warm strictly faster than cold at zero engine
/// runs; concurrent duplicates dedupe onto one search).  Otherwise the
/// daemon answers request waves from a stdin/file batch (`requests=`)
/// or a TCP/Unix socket (`listen=`) until EOF or a shutdown signal,
/// then flushes every cache shard.
/// Deterministic fault-injection ensembles ([`imp_latency::chaos`]):
/// every (workload × strategy × wire × straggler-rate) group runs
/// `seeds` perturbed members against one clean baseline.  The report
/// carries tail percentiles and degradation ratios; the determinism,
/// blame-closure, lower-bound, and degradation gates fail the run
/// *after* the JSON is written, so CI keeps the evidence.
fn cmd_chaos(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_chaos_smoke() } else { preset_chaos() };
    let (cfg, _) = config_from(defaults, args);

    let workloads = workloads_from(&cfg)?;
    let blocks: Vec<u32> = parse_list(&cfg.require::<String>("blocks")?)?;
    let mut inputs = Vec::new();
    for wl in &workloads {
        inputs.extend(sweep_inputs_for(wl, &cfg, &blocks)?);
    }
    let ecfg = EnsembleConfig {
        networks: networks_from(&cfg)?,
        rates: parse_list(&cfg.require::<String>("rates")?)?,
        seeds: cfg.require("seeds")?,
        base: FaultConfig {
            seed: cfg.require("seed")?,
            hetero: cfg.require("hetero")?,
            jitter: cfg.require("jitter")?,
            // Overridden per ensemble group by each `rates` entry.
            straggler_rate: 0.0,
            straggler_factor: cfg.require("straggler_factor")?,
            wire: WireFault::parse(&cfg.require::<String>("wire")?)?,
        },
        alpha: cfg.require("alpha")?,
        beta: cfg.require("beta")?,
        gamma: cfg.require("gamma")?,
        threads: cfg.require("threads")?,
        jobs: cfg.get_or("jobs", 0),
        gate_rate: cfg.require("gate_rate")?,
    };
    println!(
        "chaos: {} plans × {} wires × {} rates × {} seeds = {} perturbed sims (+{} clean)",
        inputs.len(),
        ecfg.networks.len(),
        ecfg.rates.len(),
        ecfg.seeds,
        inputs.len() * ecfg.networks.len() * ecfg.rates.len() * ecfg.seeds as usize,
        inputs.len() * ecfg.networks.len(),
    );

    let report = chaos::run_ensemble(&inputs, &ecfg)?;
    println!(
        "{} sims in {:.2}s: {} determinism checks, {} blame closures, {} LB violations",
        report.sims,
        report.wall_secs,
        report.determinism_checks,
        report.blame_checks,
        report.lb_violations
    );
    for c in &report.cells {
        println!(
            "  {}/{} {} rate={} clean={:.2} p50x{:.3} p99x{:.3}",
            c.workload, c.strategy, c.network, c.rate, c.clean, c.ratio_p50, c.ratio_p99
        );
    }

    let out = cfg.get_or("out", "results/chaos.json".to_string());
    let tag = if smoke { "smoke" } else { "chaos" };
    write_json_report(&out, &chaos::to_json(tag, &report))?;
    if !report.gate_failures.is_empty() {
        for f in &report.gate_failures {
            eprintln!("gate failure: {f}");
        }
        return Err(format!(
            "chaos: {} gate failure(s); see {out}",
            report.gate_failures.len()
        ));
    }
    println!("chaos: all gates passed");
    Ok(())
}

fn cmd_serve(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_serve_smoke() } else { preset_serve() };
    let (cfg, _) = config_from(defaults, args);
    signals::install();

    if smoke {
        let outcome = serve::run_smoke(&cfg, signals::flag())?;
        let out = cfg.get_or("out", "BENCH_serve.json".to_string());
        write_json_report(&out, &outcome.json)?;
        if outcome.interrupted {
            return Err(format!("serve --smoke interrupted; partial {out} written"));
        }
        let (cold, warm) = match (&outcome.cold, &outcome.warm) {
            (Some(cold), Some(warm)) => (cold.clone(), warm.clone()),
            _ => return Err("serve --smoke finished without cold and warm phases".into()),
        };
        println!(
            "serve smoke: cold {:.1} req/s ({} engine runs) → warm {:.1} req/s ({} engine \
             runs); {} duplicate(s) deduped onto {} search(es); {} grid(s) / {} cell(s) \
             batched; p50 {:.2} ms, p99 {:.2} ms; {} shed",
            cold.rps,
            cold.engine_runs,
            warm.rps,
            warm.engine_runs,
            outcome.dedupe_hits,
            outcome.dedupe_searches,
            outcome.batch_grids,
            outcome.batch_cells,
            outcome.p50_ms,
            outcome.p99_ms,
            outcome.overloaded,
        );
        // The hard serving gates; any miss fails `make serve-smoke` / CI.
        if warm.rps <= cold.rps {
            return Err(format!(
                "warm throughput {:.1} req/s must strictly beat cold {:.1} req/s",
                warm.rps, cold.rps
            ));
        }
        if warm.engine_runs != 0 {
            return Err(format!(
                "warm wave cost {} engine runs; cache hits must be free",
                warm.engine_runs
            ));
        }
        if outcome.dedupe_hits < 1 {
            return Err("duplicate burst produced no deduped requests".into());
        }
        return Ok(());
    }

    // `telemetry=1` installs (and enables) the global recorder, so every
    // request gets a sequence id and a phase-tiled lifecycle span and the
    // `metrics` op has aggregates to report; `metrics=N` additionally
    // dumps the Prometheus text exposition to stderr every N waves.
    if cfg.get_or("telemetry", 0u32) != 0 {
        telemetry::init();
    }
    let server = Server::new(ServeConfig::from_config(&cfg))
        .with_metrics_every(cfg.get_or("metrics", 0u64));
    let listen = cfg.get_or("listen", String::new());
    let served = if let Some(addr) = listen.strip_prefix("tcp:") {
        let listener =
            std::net::TcpListener::bind(addr).map_err(|e| format!("bind tcp:{addr}: {e}"))?;
        eprintln!("serve: listening on tcp:{addr} (SIGINT/SIGTERM to stop)");
        server.serve_tcp(listener, signals::flag()).map_err(|e| e.to_string()).map(|()| 0)?
    } else if let Some(path) = listen.strip_prefix("unix:") {
        serve_unix_at(&server, path)?
    } else if listen.is_empty() {
        // Batch mode: responses own stdout; everything else is stderr.
        let requests = cfg.get_or("requests", "-".to_string());
        let mut out = std::io::stdout().lock();
        let written = if requests == "-" {
            server.serve_reader(std::io::stdin().lock(), &mut out, signals::flag())
        } else {
            let file = std::fs::File::open(&requests)
                .map_err(|e| format!("requests file {requests:?}: {e}"))?;
            server.serve_reader(std::io::BufReader::new(file), &mut out, signals::flag())
        };
        written.map_err(|e| e.to_string())?
    } else {
        return Err(format!("listen must be tcp:HOST:PORT or unix:PATH, got {listen:?}"));
    };

    server.flush().map_err(|e| format!("cache flush: {e}"))?;
    let totals = server.cache_totals();
    let stats = server.stats();
    use std::sync::atomic::Ordering::Relaxed;
    eprintln!(
        "serve: {served} response(s); cache {} entries / {} shards ({} hits, {} misses); \
         {} search(es), {} deduped, {} shed",
        totals.entries,
        totals.shards,
        totals.hits,
        totals.misses,
        stats.searches.load(Relaxed),
        stats.deduped.load(Relaxed),
        server.admission().shed(),
    );
    if signals::shutdown_requested() {
        eprintln!("serve: shutdown signal honoured; cache shards flushed");
    }
    Ok(())
}

/// One engine throughput measurement: `repeat` compiled simulations per
/// trial, best of `trials` trials (the max filters scheduler noise on
/// loaded CI machines), in events/sec.
fn engine_events_per_sec(
    input: &sweep::SweepInput,
    mach: &Machine,
    kind: NetworkKind,
    scratch: &mut EngineScratch,
    repeat: usize,
    trials: usize,
) -> Result<f64, String> {
    let mut best = 0.0f64;
    for _ in 0..trials.max(1) {
        let t0 = std::time::Instant::now();
        let mut events = 0u64;
        for _ in 0..repeat {
            let mut net = kind.build_for(mach, input.layout.as_ref());
            simulate_compiled(&input.compiled, mach, net.as_mut(), scratch, false)
                .map_err(|e| e.to_string())?;
            events += scratch.events();
        }
        best = best.max(events as f64 / t0.elapsed().as_secs_f64().max(1e-12));
    }
    Ok(best)
}

/// The observability study behind `BENCH_trace.json`, in three gated
/// phases:
///
/// 1. **Overhead**: compiled-engine events/sec is measured with the
///    telemetry gate off, and re-measured (gate off again) after the
///    instrumented phase; the dormant instrumentation must keep the
///    engine within 3% of the baseline.
/// 2. **Fidelity**: with a recorder installed, one simulation (engine
///    counters + `BusySpan`s), a serve wave of tune requests (request
///    lifecycles + phase marks), and the tuner searches they trigger
///    all record into the same recorder; every request's phase
///    breakdown must sum — within max(10%, 0.3 ms) — to its measured
///    latency.
/// 3. **Export**: simulator spans and telemetry spans merge into one
///    Perfetto-loadable Chrome trace that must contain sim spans, at
///    least one serve request lifecycle, and at least one tuner search
///    timeline.
///
/// Any violated gate fails the run (and `make trace-smoke` / CI).
fn cmd_trace(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_trace_smoke() } else { preset_trace() };
    let (cfg, _) = config_from(defaults, args);
    let repeat: usize = cfg.get_or("repeat", 30).max(1);
    let trials: usize = cfg.get_or("trials", 3).max(1);
    let (n, m, p): (u64, u32, u32) = (cfg.require("n")?, cfg.require("m")?, cfg.require("p")?);
    let kind = NetworkKind::parse(&cfg.get_or("network", "alphabeta".to_string()))?;

    // Phase 1a: the baseline — telemetry off (the process default), one
    // CA plan on the compiled engine.
    telemetry::set_enabled(false);
    let t = Pipeline::new(Heat1d { n, steps: m, radius: 1 })
        .procs(p)
        .transform()
        .map_err(|e| e.to_string())?;
    let input = t.sweep_input();
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require::<f64>("beta")? * input.words_per_value as f64,
        cfg.require("gamma")?,
    );
    let mut scratch = EngineScratch::new();
    // One warm-up run so both measurements see a hot scratch.
    let mut net = kind.build_for(&mach, input.layout.as_ref());
    simulate_compiled(&input.compiled, &mach, net.as_mut(), &mut scratch, false)
        .map_err(|e| e.to_string())?;
    let baseline_eps = engine_events_per_sec(&input, &mach, kind, &mut scratch, repeat, trials)?;

    // Phase 2: everything instrumented into one recorder — a sim run
    // (recording spans), an enabled-gate engine measurement, and a
    // serve wave of tune requests whose searches land on the same
    // recorder through the global gate.
    let rec = Arc::new(Recorder::new());
    telemetry::install(Arc::clone(&rec));
    let mut net = kind.build_for(&mach, input.layout.as_ref());
    let sim = simulate_compiled(&input.compiled, &mach, net.as_mut(), &mut scratch, true)
        .map_err(|e| e.to_string())?;
    let enabled_eps = engine_events_per_sec(&input, &mach, kind, &mut scratch, repeat, 1)?;

    let server = Server::new(ServeConfig {
        workers: 2,
        max_in_flight: 64,
        reserve: 0,
        budget: None,
        cache_dir: None,
        slots: 4,
        search: "exhaustive".to_string(),
    })
    .with_recorder(Arc::clone(&rec));
    // One request per wave, so each response's latency is the handler's
    // own wall time: two cold searches, then a warm hit of the first.
    let lines = [
        r#"{"id": "c1", "op": "tune", "workload": "heat1d", "n": 64, "m": 8, "p": 2, "threads": 4, "alpha": 50.0, "beta": 1.0, "gamma": 1.0}"#,
        r#"{"id": "c2", "op": "tune", "workload": "heat1d", "n": 64, "m": 8, "p": 2, "threads": 4, "alpha": 120.0, "beta": 1.0, "gamma": 1.0}"#,
        r#"{"id": "w1", "op": "tune", "workload": "heat1d", "n": 64, "m": 8, "p": 2, "threads": 4, "alpha": 50.0, "beta": 1.0, "gamma": 1.0}"#,
    ];
    let mut tuned: Vec<(String, f64)> = Vec::new();
    for line in lines {
        let wave = server.run_wave(vec![Request::parse(line)]);
        let r = wave.into_iter().next().expect("one response per wave");
        if let Err(e) = &r.result {
            return Err(format!("trace serve request {:?} failed: {e:?}", r.id));
        }
        tuned.push((r.id, r.latency_ms));
    }

    // Phase 1b: gate off again, re-measure — the 3% overhead gate.
    telemetry::set_enabled(false);
    let disabled_eps = engine_events_per_sec(&input, &mach, kind, &mut scratch, repeat, trials)?;
    let overhead_ratio = disabled_eps / baseline_eps.max(1e-12);
    if disabled_eps < baseline_eps * 0.97 {
        return Err(format!(
            "disabled-telemetry engine throughput {disabled_eps:.0} events/s fell more than \
             3% below the baseline {baseline_eps:.0} events/s"
        ));
    }

    // Phase 2's fidelity gate: each request's phase breakdown must sum
    // to its measured latency.
    let spans = rec.drain_spans();
    let mut checked = 0usize;
    let mut max_gap_ms = 0.0f64;
    for (id, latency_ms) in &tuned {
        let latency_ms = *latency_ms;
        let name = format!("request:tune:{id}");
        let lifecycle = spans
            .iter()
            .find(|s| s.track == "serve" && s.name == name)
            .ok_or_else(|| format!("no lifecycle span recorded for request {id:?}"))?;
        let phase_sum_ms = spans
            .iter()
            .filter(|s| s.track == "serve.phase" && s.tid == lifecycle.tid)
            .map(|s| s.dur_us)
            .sum::<f64>()
            / 1e3;
        let tol_ms = (0.10 * latency_ms).max(0.3);
        let gap = (phase_sum_ms - latency_ms).abs();
        max_gap_ms = max_gap_ms.max(gap);
        if gap > tol_ms {
            return Err(format!(
                "request {id:?}: phase breakdown sums to {phase_sum_ms:.3} ms but measured \
                 latency is {latency_ms:.3} ms (tolerance {tol_ms:.3} ms)"
            ));
        }
        checked += 1;
    }

    // Phase 3: the merged export, with all three tracks present.
    let have_serve = spans.iter().any(|s| s.track == "serve" && s.name.starts_with("request:"));
    let have_search = spans.iter().any(|s| s.track == "tune" && s.name.starts_with("search:"));
    if sim.spans.is_empty() || !have_serve || !have_search {
        return Err(format!(
            "merged trace is missing a required track: {} sim spans, serve lifecycle \
             {have_serve}, tuner search {have_search}",
            sim.spans.len()
        ));
    }
    let chrome = chrome_trace_with_telemetry(&sim.spans, &spans);
    let chrome_out = cfg.get_or("chrome", "results/trace_chrome.json".to_string());
    write_json_report(&chrome_out, &chrome)?;

    let engine_runs = rec.counter("engine.runs").get();
    let engine_events = rec.counter("engine.events").get();
    let searches = rec.counter("tune.searches").get();
    println!(
        "trace: engine {baseline_eps:.0} events/s off → {enabled_eps:.0} on → \
         {disabled_eps:.0} off again ({:.1}% of baseline); {checked} request(s) \
         phase-checked (max gap {max_gap_ms:.3} ms); {} sim + {} telemetry spans merged \
         ({engine_runs} instrumented engine runs, {engine_events} events, {searches} \
         search(es))",
        100.0 * overhead_ratio,
        sim.spans.len(),
        spans.len(),
    );

    let mut json = String::from("{\n");
    json.push_str(&format!("  \"trace\": {:?},\n", if smoke { "smoke" } else { "trace" }));
    json.push_str(&format!("  \"repeat\": {repeat},\n"));
    json.push_str(&format!("  \"trials\": {trials},\n"));
    json.push_str(&format!("  \"baseline_events_per_sec\": {baseline_eps},\n"));
    json.push_str(&format!("  \"enabled_events_per_sec\": {enabled_eps},\n"));
    json.push_str(&format!("  \"disabled_events_per_sec\": {disabled_eps},\n"));
    json.push_str(&format!("  \"overhead_ratio\": {overhead_ratio},\n"));
    json.push_str(&format!("  \"requests_checked\": {checked},\n"));
    json.push_str(&format!("  \"max_phase_gap_ms\": {max_gap_ms},\n"));
    json.push_str(&format!("  \"sim_spans\": {},\n", sim.spans.len()));
    json.push_str(&format!("  \"telemetry_spans\": {},\n", spans.len()));
    json.push_str(&format!("  \"dropped_spans\": {},\n", rec.dropped_spans()));
    json.push_str(&format!("  \"engine_runs\": {engine_runs},\n"));
    json.push_str(&format!("  \"engine_events\": {engine_events},\n"));
    json.push_str(&format!("  \"searches\": {searches},\n"));
    json.push_str(&format!("  \"chrome\": {chrome_out:?}\n"));
    json.push_str("}\n");
    let out = cfg.get_or("out", "results/trace.json".to_string());
    write_json_report(&out, &json)
}

/// The causal-profiling study behind `BENCH_explain.json`, in four
/// gated phases:
///
/// 1. **Blame matrix**: every `workloads` × naive/overlap/CA(b) ×
///    `networks` cell runs the provenance-recording engine
///    ([`imp_latency::explain`]) and its makespan is decomposed into
///    compute / exposed-latency / bandwidth / idle terms, which must
///    sum back to the observed makespan **bit-exactly** and never
///    undercut the analytic critical-path bound (bit-equal on exact
///    wires).
/// 2. **Differential**: on the α-β wire, each workload's overlap/CA
///    cells are diffed against naive; for the stencil workloads the CA
///    transform must *strictly* reduce exposed latency — the default
///    α = 500 sits deep in the latency-dominated regime where the
///    paper's §3 claim has to show up in the observed path.
/// 3. **Tuned winner**: an exhaustive heat1d tune runs and the winner
///    is explained against naive; the differential summary rides on
///    the tune report (`why:` line).
/// 4. **Overhead**: compiled-engine throughput is measured with
///    provenance off before and after the observed runs; the dormant
///    one-branch gate must keep the engine within 3% of baseline, and
///    an observed run must reproduce the plain run's makespan
///    bit-for-bit.
///
/// The heat1d CA cell's observed critical path is exported as a Chrome
/// trace: `crit:*` spans on a reserved lane plus flow arrows for the
/// on-path message flights.
fn cmd_explain(args: &[&str]) -> Result<(), String> {
    let smoke = args.contains(&"--smoke");
    let defaults = if smoke { preset_explain_smoke() } else { preset_explain() };
    let (cfg, _) = config_from(defaults, args);
    let workloads = workloads_from(&cfg)?;
    let networks = networks_from(&cfg)?;
    let block: u32 = cfg.require("b")?;
    let repeat: usize = cfg.get_or("repeat", 30).max(1);
    let trials: usize = cfg.get_or("trials", 3).max(1);
    let p: u32 = cfg.require("p")?;
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    telemetry::set_enabled(false);
    let mut scratch = EngineScratch::new();

    // Phase 4a: the overhead baseline, first thing — the heat1d CA plan
    // on the plain compiled engine, after one warm-up run (mirrors
    // `trace`'s measurement discipline).
    let heat_inputs = sweep_inputs_for("heat1d", &cfg, &[block])?;
    let probe = heat_inputs.last().expect("strategy inputs end with the CA plan");
    let probe_mach = Machine::new(
        p,
        mach.threads,
        mach.alpha,
        mach.beta * probe.words_per_value as f64,
        mach.gamma,
    );
    let mut net = NetworkKind::AlphaBeta.build_for(&probe_mach, probe.layout.as_ref());
    simulate_compiled(&probe.compiled, &probe_mach, net.as_mut(), &mut scratch, false)
        .map_err(|e| e.to_string())?;
    let baseline_eps =
        engine_events_per_sec(probe, &probe_mach, NetworkKind::AlphaBeta, &mut scratch, repeat, trials)?;

    // Phase 1: the blame matrix, with the exact-sum and bound gates on
    // every cell; phase 2's differential table rides on the α-β column.
    let mut cells: Vec<explain::ExplainCell> = Vec::new();
    let mut diff_lines: Vec<String> = Vec::new();
    for wl in &workloads {
        let inputs = sweep_inputs_for(wl, &cfg, &[block])?;
        let mut summaries: Vec<BlameSummary> = Vec::new();
        for input in &inputs {
            for &kind in &networks {
                let e = explain::explain_input(input, &mach, kind, &mut scratch)?;
                if let Err(err) = e.blame.verify() {
                    return Err(format!(
                        "{wl}/{} on {}: inexact blame decomposition: {err}",
                        e.strategy,
                        kind.label()
                    ));
                }
                if !e.cross.ok() {
                    return Err(format!(
                        "{wl}/{} on {}: observed {} vs analytic bound {} violates the \
                         cross-check (exact wire: {})",
                        e.strategy,
                        kind.label(),
                        e.cross.observed,
                        e.cross.bound,
                        e.cross.exact_wire
                    ));
                }
                if kind == NetworkKind::AlphaBeta {
                    summaries.push(BlameSummary::from_blame(e.strategy.clone(), &e.blame));
                }
                cells.push(explain::ExplainCell::from_explanation(&e));
            }
        }
        let naive = summaries
            .iter()
            .find(|s| s.strategy == "naive")
            .cloned()
            .ok_or_else(|| format!("{wl}: no naive baseline on the alphabeta wire"))?;
        for cand in summaries.iter().filter(|s| s.strategy != "naive") {
            let d = PlanDiff::between(naive.clone(), cand.clone());
            // The stencil CA gate: at high α the transform must have
            // moved exposed latency off the observed critical path.
            if wl.starts_with("heat")
                && cand.strategy.starts_with("ca")
                && d.latency_moved_off_path() <= 0.0
            {
                return Err(format!(
                    "{wl}: CA moved no exposed latency off the observed critical path at \
                     α={} (naive {} vs {} {})",
                    mach.alpha, naive.latency, cand.strategy, cand.latency
                ));
            }
            println!("explain {wl:<8} {}", d.summary());
            diff_lines.push(format!("{wl}: {}", d.summary()));
        }
    }

    // Phase 3: tune heat1d and attach the winner's differential
    // explanation to its report.
    let pipe = Pipeline::new(Heat1d { n: cfg.require("n")?, steps: cfg.require("m")?, radius: 1 })
        .procs(p)
        .machine(mach)
        .network(NetworkKind::AlphaBeta);
    let mut tuner = Tuner::exhaustive();
    let outcome = tune::tune_pipeline(&pipe, &mut tuner).map_err(|e| e.to_string())?;
    let win = outcome.chosen;
    let win_input =
        imp_latency::pipeline::candidate_sweep_input(&pipe, win.strategy, win.block, Some(win.halo))
            .map_err(|e| e.to_string())?;
    let naive_input =
        imp_latency::pipeline::candidate_sweep_input(&pipe, Strategy::Naive, None, None)
            .map_err(|e| e.to_string())?;
    let win_e = explain::explain_input(&win_input, &mach, NetworkKind::AlphaBeta, &mut scratch)?;
    let naive_e =
        explain::explain_input(&naive_input, &mach, NetworkKind::AlphaBeta, &mut scratch)?;
    let tuned_diff = PlanDiff::between(
        BlameSummary::from_blame("naive", &naive_e.blame),
        BlameSummary::from_blame(win_e.strategy.clone(), &win_e.blame),
    );
    let mut report = outcome.report;
    report.explanation = Some(tuned_diff.summary());
    println!("{}", report.summary());

    // Phase 4b: observed runs between the two provenance-off
    // measurements, then the 3% gate and the bit-identity gate.
    let probe_e = explain::explain_input(probe, &mach, NetworkKind::AlphaBeta, &mut scratch)?;
    let mut prov = ProvenanceBuffer::new();
    let t0 = std::time::Instant::now();
    let mut observed_events = 0u64;
    for _ in 0..repeat {
        let mut net = NetworkKind::AlphaBeta.build_for(&probe_mach, probe.layout.as_ref());
        simulate_observed(&probe.compiled, &probe_mach, net.as_mut(), &mut scratch, false, &mut prov)
            .map_err(|e| e.to_string())?;
        observed_events += scratch.events();
    }
    let observed_eps = observed_events as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    let disabled_eps =
        engine_events_per_sec(probe, &probe_mach, NetworkKind::AlphaBeta, &mut scratch, repeat, trials)?;
    let overhead_ratio = disabled_eps / baseline_eps.max(1e-12);
    if disabled_eps < baseline_eps * 0.97 {
        return Err(format!(
            "provenance-off engine throughput {disabled_eps:.0} events/s fell more than 3% \
             below the baseline {baseline_eps:.0} events/s"
        ));
    }
    let mut net = NetworkKind::AlphaBeta.build_for(&probe_mach, probe.layout.as_ref());
    let sim = simulate_compiled(&probe.compiled, &probe_mach, net.as_mut(), &mut scratch, true)
        .map_err(|e| e.to_string())?;
    if sim.total_time.to_bits() != probe_e.blame.makespan.to_bits() {
        return Err(format!(
            "observed makespan {} is not bit-identical to the plain run's {}",
            probe_e.blame.makespan, sim.total_time
        ));
    }
    println!("explain heat1d/{}: {}", probe_e.strategy, explain::report::share_line(&probe_e.blame));
    println!("explain heat1d/{}: {}", probe_e.strategy, explain::report::crosscheck_line(&probe_e.cross));

    // The critical-path-highlighted Chrome trace: normal sim spans plus
    // `crit:*` lane spans plus flow arrows for on-path flights.
    let mut spans = sim.spans.clone();
    spans.extend(explain::report::path_spans(&probe_e.blame));
    let flows = explain::report::path_flows(&probe_e.blame);
    let chrome = chrome_trace_with_flows(&spans, &flows);
    let chrome_out = cfg.get_or("chrome", "results/explain_chrome.json".to_string());
    write_json_report(&chrome_out, &chrome)?;

    println!(
        "explain: {} cells gated bit-exact; engine {baseline_eps:.0} events/s off → \
         {observed_eps:.0} observed → {disabled_eps:.0} off again ({:.1}% of baseline); \
         {} on-path flights exported",
        cells.len(),
        100.0 * overhead_ratio,
        flows.len(),
    );

    let mut json = String::from("{\n");
    json.push_str(&format!("  \"explain\": {:?},\n", if smoke { "smoke" } else { "explain" }));
    json.push_str(&format!("  \"alpha\": {},\n", mach.alpha));
    json.push_str(&format!("  \"block\": {block},\n"));
    json.push_str(&format!("  \"cells\": {},\n", explain::report::cells_to_json(&cells, "  ")));
    json.push_str("  \"diffs\": [\n");
    for (i, d) in diff_lines.iter().enumerate() {
        json.push_str(&format!("    {:?}{}\n", d, if i + 1 < diff_lines.len() { "," } else { "" }));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"tuned\": {:?},\n", win.label()));
    json.push_str(&format!("  \"tuned_explanation\": {:?},\n", tuned_diff.summary()));
    json.push_str(&format!("  \"path_messages\": {},\n", flows.len()));
    json.push_str(&format!("  \"baseline_events_per_sec\": {baseline_eps},\n"));
    json.push_str(&format!("  \"observed_events_per_sec\": {observed_eps},\n"));
    json.push_str(&format!("  \"disabled_events_per_sec\": {disabled_eps},\n"));
    json.push_str(&format!("  \"overhead_ratio\": {overhead_ratio},\n"));
    json.push_str(&format!("  \"chrome\": {chrome_out:?}\n"));
    json.push_str("}\n");
    let out = cfg.get_or("out", "results/explain.json".to_string());
    write_json_report(&out, &json)
}

/// Diff the current `BENCH_*.json` smoke artifacts against the
/// committed `BENCH_baseline/` snapshots ([`imp_latency::trace`]'s
/// comparer).  Advisory by design: drift is *reported*, never fatal —
/// the gating happens inside each smoke's own invariants, while this
/// surfaces slow regressions across pushes.
fn cmd_bench_compare(args: &[&str]) -> Result<(), String> {
    let (cfg, _) = config_from(Config::new(), args);
    let dir = cfg.get_or("dir", "BENCH_baseline".to_string());
    let files = cfg.get_or(
        "files",
        "BENCH_sim.json,BENCH_engine.json,BENCH_tune.json,BENCH_partition.json,\
         BENCH_serve.json,BENCH_analyze.json,BENCH_trace.json,BENCH_explain.json"
            .to_string(),
    );
    let names: Vec<&str> = files.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    print!("{}", imp_latency::trace::compare_bench_files(&dir, &names));
    Ok(())
}

fn cmd_dot(args: &[&str]) -> Result<(), String> {
    let mut defaults = Config::new();
    defaults.set("n", 16);
    defaults.set("m", 3);
    defaults.set("p", 2);
    let (cfg, _) = config_from(defaults, args);
    let run = Pipeline::new(Heat1d { n: cfg.require("n")?, steps: cfg.require("m")?, radius: 1 })
        .procs(cfg.require("p")?)
        .transform()
        .map_err(|e| e.to_string())?;
    let g = &run.graph;
    let s = run.full_schedule().expect("CA strategy");
    let annot = |t: imp_latency::graph::TaskId| -> String {
        let ps = &s.per_proc[g.owner(t).idx()];
        for (name, set) in
            [("L0", &ps.l0), ("L1", &ps.l1), ("L2", &ps.l2), ("L3", &ps.l3)]
        {
            if set.binary_search(&t.0).is_ok() {
                return name.to_string();
            }
        }
        String::new()
    };
    print!("{}", g.to_dot_annotated("transformed", annot));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::{COMMANDS, HELP};

    /// The cleanup gate: every subcommand registered in the dispatch
    /// table must be documented in `--help` (as the first word of a
    /// COMMANDS line), so new commands cannot ship invisible.
    #[test]
    fn help_names_every_registered_subcommand() {
        for (name, _) in COMMANDS {
            let documented = HELP
                .lines()
                .any(|line| matches!(line.strip_prefix("  "), Some(l) if l.starts_with(name)));
            assert!(documented, "--help does not document subcommand {name:?}");
        }
        // And the table really is the full surface: no stray duplicates.
        let mut names: Vec<&str> = COMMANDS.iter().map(|(n, _)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), COMMANDS.len(), "duplicate subcommand registration");
    }
}
