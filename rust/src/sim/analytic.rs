//! Closed-form (BSP-style) runtime evaluation — the fast path for the
//! figure-7/8 parameter sweeps.
//!
//! The discrete simulator is exact but walks every task; for a sweep over
//! thread counts × block factors the phase structure is what matters, so
//! this module extracts per-processor *set sizes* from one transformed
//! superstep and evaluates runtimes in O(p) per machine point.  Agreement
//! with the discrete simulator is asserted in the test-suite (and the
//! benches cross-check a sample point).

use super::machine::Machine;
use crate::graph::TaskGraph;
use crate::transform::{communication_avoiding, superstep_graphs, TransformOptions};

/// Phase-size summary of one processor within one superstep.
#[derive(Debug, Clone, Default)]
pub struct ProcPhaseCost {
    pub l1: usize,
    pub l2: usize,
    pub l3: usize,
    /// (peer, words) for every outgoing message.
    pub send: Vec<(u32, usize)>,
    /// (peer, words) for every incoming message.
    pub recv: Vec<(u32, usize)>,
}

/// Phase-size summary of a full superstep.
#[derive(Debug, Clone)]
pub struct SuperstepCosts {
    pub per_proc: Vec<ProcPhaseCost>,
    /// Compute tasks actually executed in the superstep (incl. redundant).
    pub executed: usize,
}

/// Transform one superstep graph and summarize the phase sizes.
pub fn superstep_costs(g_ss: &TaskGraph, options: TransformOptions) -> SuperstepCosts {
    let s = communication_avoiding(g_ss, options);
    let per_proc = s
        .per_proc
        .iter()
        .map(|ps| ProcPhaseCost {
            l1: ps.l1.len(),
            l2: ps.l2.len(),
            l3: ps.l3.len(),
            send: ps.send.iter().map(|m| (m.peer.0, m.tasks.len())).collect(),
            recv: ps.recv.iter().map(|m| (m.peer.0, m.tasks.len())).collect(),
        })
        .collect();
    SuperstepCosts { per_proc, executed: s.total_computed() }
}

/// Evaluate the runtime of `nsupersteps` repetitions of a transformed
/// superstep on machine `m`.
///
/// Per processor: `T_p = max(c1_p + c2_p, arrival_p) + c3_p` where
/// `arrival_p = max_q (c1_q + α + β·w_{q→p})` — phase 1 computes `L^(1)`,
/// the messages fly while `L^(2)` computes, and `L^(3)` starts when both
/// the local phase-2 work and the slowest incoming message are done.
/// The superstep time is `max_p T_p` (bulk-synchronous coupling between
/// supersteps; the discrete simulator captures the softer pipelining and
/// is used for validation).
pub fn ca_time(c: &SuperstepCosts, m: &Machine, nsupersteps: u32) -> f64 {
    let c1: Vec<f64> = c.per_proc.iter().map(|p| m.compute_time(p.l1)).collect();
    let mut worst: f64 = 0.0;
    for (pid, p) in c.per_proc.iter().enumerate() {
        let local = c1[pid] + m.compute_time(p.l2);
        let arrival = p
            .recv
            .iter()
            .map(|&(q, w)| c1[q as usize] + m.message_time(w))
            .fold(0.0, f64::max);
        let tp = local.max(arrival) + m.compute_time(p.l3);
        worst = worst.max(tp);
    }
    worst * nsupersteps as f64
}

/// Non-overlapped evaluation of the same superstep: `T_p = c1 + msg + c2
/// + c3` with the message time fully exposed.  This is the execution the
/// paper's §2.1 cost model describes (figure 1 without the figure-2
/// overlap); the cost-model ablation validates `T(b)` against it, while
/// [`ca_time`] shows what the overlap additionally buys.
pub fn ca_time_sequential(c: &SuperstepCosts, m: &Machine, nsupersteps: u32) -> f64 {
    let mut worst: f64 = 0.0;
    for p in &c.per_proc {
        let msg = p.recv.iter().map(|&(_, w)| m.message_time(w)).fold(0.0, f64::max);
        let tp = m.compute_time(p.l1) + msg + m.compute_time(p.l2) + m.compute_time(p.l3);
        worst = worst.max(tp);
    }
    worst * nsupersteps as f64
}

/// [`ca_time_for`]'s counterpart using the sequential evaluation.
pub fn ca_time_sequential_for(
    g: &TaskGraph,
    b: u32,
    options: TransformOptions,
    m: &Machine,
) -> f64 {
    let ss = superstep_graphs(g, b).expect("sliceable graph");
    let costs = superstep_costs(&ss[0].graph, options);
    if ss.len() > 1 && ss.last().unwrap().depth() != ss[0].depth() {
        let tail = superstep_costs(&ss.last().unwrap().graph, options);
        ca_time_sequential(&costs, m, (ss.len() - 1) as u32) + ca_time_sequential(&tail, m, 1)
    } else {
        ca_time_sequential(&costs, m, ss.len() as u32)
    }
}

/// Full pipeline for a (graph, b) pair: slice into supersteps, transform
/// the first (steady-state representative), and evaluate.  For the
/// homogeneous iterated-kernel graphs the paper studies, every superstep
/// has identical structure; heterogeneous graphs should instead be
/// evaluated superstep-by-superstep (see `ca_time_exact`).
pub fn ca_time_for(g: &TaskGraph, b: u32, options: TransformOptions, m: &Machine) -> f64 {
    let ss = superstep_graphs(g, b).expect("sliceable graph");
    let costs = superstep_costs(&ss[0].graph, options);
    // Last superstep may be shallower; evaluate it separately.
    if ss.len() > 1 && ss.last().unwrap().depth() != ss[0].depth() {
        let tail = superstep_costs(&ss.last().unwrap().graph, options);
        ca_time(&costs, m, (ss.len() - 1) as u32) + ca_time(&tail, m, 1)
    } else {
        ca_time(&costs, m, ss.len() as u32)
    }
}

/// Superstep-by-superstep evaluation (no steady-state assumption).
pub fn ca_time_exact(g: &TaskGraph, b: u32, options: TransformOptions, m: &Machine) -> f64 {
    superstep_graphs(g, b)
        .expect("sliceable graph")
        .iter()
        .map(|ss| ca_time(&superstep_costs(&ss.graph, options), m, 1))
        .sum()
}

/// Closed-form naive runtime for the 1-D radius-1 stencil (paper §2.1's
/// baseline): per level, compute `⌈n_p/t⌉·γ`, then a halo exchange of one
/// word each way (`α + β`).  Multi-processor runs pay the exchange every
/// level; single-processor runs have no exchange.
pub fn naive_time_1d(n: u64, msteps: u32, m: &Machine) -> f64 {
    let np = n.div_ceil(m.nprocs as u64) as usize;
    let per_level = m.compute_time(np)
        + if m.nprocs > 1 { m.message_time(1) } else { 0.0 };
    per_level * msteps as f64
}

/// Closed-form figure-2 overlap runtime for the 1-D radius-1 stencil:
/// per level the boundary exchange overlaps the interior compute.
pub fn overlap_time_1d(n: u64, msteps: u32, m: &Machine) -> f64 {
    let np = n.div_ceil(m.nprocs as u64) as usize;
    if m.nprocs == 1 {
        return m.compute_time(np) * msteps as f64;
    }
    let interior = np.saturating_sub(2);
    let boundary = np - interior;
    let per_level =
        m.compute_time(interior).max(m.message_time(1)) + m.compute_time(boundary);
    per_level * msteps as f64
}

/// The paper's §2.1 closed-form blocked cost (for reference/plots):
/// `T(b) = (M/b)·α + M·β + (MN/p + M·b)·γ`, with the γ-term divided by
/// the node's thread count (the §4 simulation's "threads per node" axis).
pub fn paper_cost(n: u64, msteps: u32, b: u32, m: &Machine) -> f64 {
    let mf = msteps as f64;
    let work = mf * n as f64 / m.nprocs as f64 + mf * b as f64;
    mf / b as f64 * m.alpha + mf * m.beta + work * m.gamma / m.threads as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::simulate;
    use crate::sim::plan::ExecPlan;
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    #[test]
    fn naive_closed_form_matches_discrete() {
        let (n, msteps) = (64u64, 6u32);
        let g = heat1d_graph(n, msteps, 4);
        for threads in [1u32, 2, 8] {
            let m = Machine::new(4, threads, 30.0, 0.5, 1.0);
            let discrete = simulate(&g, &ExecPlan::naive(&g), &m, false).total_time;
            let analytic = naive_time_1d(n, msteps, &m);
            let rel = (discrete - analytic).abs() / analytic;
            assert!(rel < 0.15, "threads={threads}: discrete {discrete} analytic {analytic}");
        }
    }

    #[test]
    fn ca_analytic_matches_discrete() {
        let (n, msteps, p) = (128u64, 8u32, 4u32);
        let g = heat1d_graph(n, msteps, p);
        for b in [2u32, 4, 8] {
            for threads in [1u32, 4] {
                let m = Machine::new(p, threads, 50.0, 0.5, 1.0);
                let opts = TransformOptions::default();
                let discrete =
                    simulate(&g, &ExecPlan::ca(&g, b, opts).unwrap(), &m, false).total_time;
                let analytic = ca_time_for(&g, b, opts, &m);
                // The BSP coupling makes the analytic form an upper-ish
                // estimate; they must agree within 25%.
                let rel = (discrete - analytic).abs() / discrete;
                assert!(
                    rel < 0.25,
                    "b={b} t={threads}: discrete {discrete} analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn ca_exact_equals_for_on_uniform_graphs() {
        let g = heat1d_graph(64, 8, 4);
        let m = Machine::new(4, 2, 20.0, 0.1, 1.0);
        let opts = TransformOptions::default();
        let a = ca_time_for(&g, 4, opts, &m);
        let b = ca_time_exact(&g, 4, opts, &m);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn tail_superstep_handled() {
        let g = heat1d_graph(64, 7, 4); // 7 = 2*3 + 1: tail of depth 1
        let m = Machine::new(4, 2, 20.0, 0.1, 1.0);
        let opts = TransformOptions::default();
        let a = ca_time_for(&g, 3, opts, &m);
        let b = ca_time_exact(&g, 3, opts, &m);
        assert!((a - b).abs() / b < 0.35, "{a} vs {b}");
    }

    #[test]
    fn paper_cost_optimal_b_is_sqrt_alpha_gamma() {
        // argmin_b T(b) at b* = sqrt(α·t/γ·...): with the thread-divided
        // work term the optimum shifts; check against brute force.
        let m = Machine::new(8, 4, 400.0, 0.1, 1.0);
        let best = (1..=64u32)
            .min_by(|&a, &b| {
                paper_cost(4096, 64, a, &m)
                    .partial_cmp(&paper_cost(4096, 64, b, &m))
                    .unwrap()
            })
            .unwrap();
        let predicted = (m.alpha * m.threads as f64 / m.gamma).sqrt().round() as u32;
        assert!(
            best.abs_diff(predicted) <= 2,
            "brute-force {best} vs predicted {predicted}"
        );
    }

    #[test]
    fn ca_beats_naive_at_high_latency() {
        let (n, msteps, p) = (256u64, 8u32, 4u32);
        let g = heat1d_graph(n, msteps, p);
        let m = Machine::new(p, 16, 500.0, 0.1, 1.0);
        let naive = naive_time_1d(n, msteps, &m);
        let ca = ca_time_for(&g, 8, TransformOptions::default(), &m);
        assert!(ca < naive, "ca {ca} naive {naive}");
    }

    #[test]
    fn level0_mode_evaluates_too() {
        let g = heat1d_graph(64, 4, 2);
        let m = Machine::new(2, 2, 50.0, 0.5, 1.0);
        let t = ca_time_for(&g, 4, TransformOptions::level0(), &m);
        assert!(t.is_finite() && t > 0.0);
    }
}
