//! The §4 simulation study: machine model, execution plans, and the
//! runtime evaluators (event-driven engine and closed-form).
//!
//! The scenario is the paper's: **strong scaling** — a fixed problem and
//! task partitioning, a fixed latency-to-flop ratio, runtime evaluated as
//! a function of the threads available per MPI node.  Three strategies
//! are compared: naive per-level exchange, the figure-2 overlap split,
//! and the §3 communication-avoiding transformation at several block
//! factors.
//!
//! Module map:
//!
//! * [`machine`](Machine) — `p` nodes × `t` threads and the α/β/γ constants;
//! * [`plan`](ExecPlan) — the phase programs the strategies compile to;
//! * [`engine`](simulate) — the *interpreting* event-driven simulator
//!   (binary-heap event queue, blocked-receiver wakeup), with pluggable
//!   [`NetworkModel`] wires and a per-task [`TaskCostModel`] hook; the
//!   reference path and one-shot entry point;
//! * [`compile`](CompiledPlan) — the hot path: a one-time lowering of
//!   `(graph, plan, cost model)` into flat CSR phase streams, a dense
//!   channel table, and baked per-task costs, simulated allocation-free
//!   against a reusable [`EngineScratch`] with per-channel wire constants
//!   resolved up front ([`NetworkModel::channel_cost`]).  Data flow:
//!   `ExecPlan ─compile→ CompiledPlan ─simulate_compiled→ SimResult`,
//!   one compile amortized over every cell of a sweep/tune grid;
//!   [`simulate_observed`] is the same engine with a [`ProvenanceBuffer`]
//!   attached — per-phase windows + message arrivals for the
//!   [`crate::explain`] blame walk, bit-identical results;
//! * [`network`](NetworkKind) — [`AlphaBeta`], [`LogGp`], [`Hierarchical`],
//!   [`Contended`] wire models;
//! * [`sweep`] — parallel (α × threads × block × network) grids emitting
//!   JSON/CSV figure data, each worker reusing one scratch across all its
//!   cells; the same worker pool fans out the [`crate::tune`] autotuner's
//!   candidate evaluations (space → search → engine score → cache →
//!   pipeline);
//! * [`analytic`](ca_time) — closed-form BSP evaluation, the fast path for
//!   huge parameter sweeps;
//! * `discrete` — shared result types and, in tests, the seed polling
//!   simulator kept as the engines' equivalence oracle.

mod analytic;
mod compile;
mod discrete;
mod engine;
mod machine;
mod network;
mod plan;
pub mod sweep;

pub use analytic::{
    ca_time, ca_time_exact, ca_time_for, ca_time_sequential, ca_time_sequential_for,
    naive_time_1d, overlap_time_1d, paper_cost, superstep_costs, ProcPhaseCost,
    SuperstepCosts,
};
pub(crate) use compile::CPhase;
pub use compile::{
    compile_count, simulate_compiled, simulate_observed, CompiledPlan, EngineScratch,
    ProvenanceBuffer,
};
pub(crate) use discrete::run_compute;
pub use discrete::{BusySpan, SimResult};
pub use engine::{simulate, try_simulate, ScaledCost, SimError, TaskCostModel, UniformCost};
pub use machine::Machine;
pub use network::{AlphaBeta, Contended, Hierarchical, LogGp, NetworkKind, NetworkModel};
pub use plan::{ExecPlan, Phase, ProcPlan};
