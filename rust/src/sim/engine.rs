//! The event-driven simulator engine.
//!
//! The seed simulator advanced processors round-robin, re-scanning every
//! processor's cursor each round — O(rounds × procs × phases) with a
//! hardwired α+β·words wire and a flat per-task γ.  This engine replaces
//! the polling loop with a global binary-heap event queue holding message
//! arrivals and processor resume points: each processor runs forward
//! until it blocks on a `Recv` whose matching `Send` has not executed
//! yet, and is woken by that message's arrival event — O(events · log
//! events) total, every phase visited at most twice.
//!
//! Two hooks make the timing model pluggable:
//!
//! * [`NetworkModel`] (see [`super::network`]) decides when a posted
//!   message arrives — latency/bandwidth, LogGP injection gaps,
//!   hierarchical intra/inter-node wires, per-NIC contention;
//! * [`TaskCostModel`] weights individual tasks, so irregular workloads
//!   (SpMV rows with different fill, CG's cheap reduction tasks) are no
//!   longer forced onto a uniform γ.
//!
//! [`simulate`] keeps the seed entry point's exact signature and
//! semantics (α/β wire, uniform γ); the equivalence matrix in this
//! module's tests pins it bit-for-bit against the retained polling
//! oracle across every workload × strategy × processor count.
//!
//! This interpreting loop is the *reference* path: it re-sorts phases
//! and routes messages through tuple-keyed hash maps per run, which is
//! fine one-shot but not for the thousands of cells a sweep/tune grid
//! dispatches.  The hot path lowers the plan once with
//! [`super::compile::CompiledPlan`] and replays these exact semantics
//! allocation-free ([`super::compile::simulate_compiled`]); this engine
//! survives as that module's equivalence oracle, the same pattern as
//! [`super::discrete`].

use super::discrete::{run_compute, to_bits, BusySpan, SimResult};
use super::machine::Machine;
use super::network::{AlphaBeta, NetworkModel};
use super::plan::{ExecPlan, Phase};
use crate::graph::{TaskGraph, TaskId};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Per-task execution cost hook: the engine charges
/// `machine.gamma · task_cost(g, t)` per execution of `t`.
///
/// Implementations must be cheap — the hook sits on the innermost
/// simulation loop.  [`UniformCost`] (the default) reproduces the paper's
/// flat-γ model; workloads override
/// [`crate::pipeline::Workload::cost_model`] to supply non-uniform
/// weights.
pub trait TaskCostModel: Send + Sync + std::fmt::Debug {
    /// Relative cost of executing `t`, in γ units (`1.0` ≡ one γ).
    fn task_cost(&self, g: &TaskGraph, t: TaskId) -> f64;
}

/// Every task costs exactly one γ (the paper's §4 model).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UniformCost;

impl TaskCostModel for UniformCost {
    #[inline]
    fn task_cost(&self, _g: &TaskGraph, _t: TaskId) -> f64 {
        1.0
    }
}

/// Every task costs `factor` γ — the [`crate::pipeline::Workload`]
/// `cost_per_task` hint as a cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaledCost(pub f64);

impl TaskCostModel for ScaledCost {
    #[inline]
    fn task_cost(&self, _g: &TaskGraph, _t: TaskId) -> f64 {
        self.0
    }
}

/// Simulation failure: the plan cannot run to completion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Every processor is either finished or blocked in a `Recv` whose
    /// matching `Send` never executed; `stuck` lists the blocked
    /// processors and the phase index each is stuck at.
    Deadlock { stuck: Vec<(u32, usize)> },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock { stuck } => {
                write!(f, "plan deadlocked: ")?;
                for (i, (p, phase)) in stuck.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "p{p} blocked at phase {phase}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Heap events.  `Resume` re-enters a processor's program (initial start
/// or wake-up after a blocking receive); `Arrival` is the wire delivering
/// the `seq`-th message on the `(from, to)` channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    Resume { proc: u32 },
    Arrival { from: u32, to: u32, seq: u32 },
}

struct Engine<'a> {
    g: &'a TaskGraph,
    plan: &'a ExecPlan,
    m: &'a Machine,
    cost: &'a dyn TaskCostModel,
    record_spans: bool,

    clock: Vec<f64>,
    busy: Vec<f64>,
    wait: Vec<f64>,
    cursor: Vec<usize>,
    spans: Vec<BusySpan>,
    messages: usize,
    words: usize,

    /// Posted, undelivered-to-receiver messages: (from, to, seq) →
    /// arrival time.  Drained on consumption (the seed loop leaked these
    /// forever).
    channel: HashMap<(u32, u32, u32), f64>,
    /// Blocked receivers: message key → processor waiting for it.
    waiting: HashMap<(u32, u32, u32), u32>,
    send_seq: HashMap<(u32, u32), u32>,
    recv_seq: HashMap<(u32, u32), u32>,

    /// Min-heap of (time-bits, tiebreak, event).
    heap: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    ev_tiebreak: u64,
}

impl<'a> Engine<'a> {
    fn push_event(&mut self, at: f64, ev: Ev) {
        self.ev_tiebreak += 1;
        self.heap.push(Reverse((to_bits(at), self.ev_tiebreak, ev)));
    }

    /// Run processor `p` forward until it finishes or blocks on an
    /// unposted message.
    fn advance(&mut self, network: &mut dyn NetworkModel, p: usize) {
        let g = self.g;
        let m = self.m;
        let cost = self.cost;
        let plan = self.plan;
        let phases: &'a [Phase] = &plan.per_proc[p].phases;
        while self.cursor[p] < phases.len() {
            match &phases[self.cursor[p]] {
                Phase::Compute(tasks) => {
                    let (end, b) = run_compute(
                        g,
                        tasks,
                        m,
                        self.clock[p],
                        p as u32,
                        cost,
                        self.record_spans.then_some(&mut self.spans),
                    );
                    self.busy[p] += b;
                    self.clock[p] = end;
                }
                Phase::Send { to, tasks } => {
                    let seq = self.send_seq.entry((p as u32, to.0)).or_insert(0);
                    let key = (p as u32, to.0, *seq);
                    *seq += 1;
                    // Zero-word sends cost nothing on the wire and are
                    // not counted as messages; they still traverse the
                    // channel so the matching `Recv` pairs up.
                    let arrival = if tasks.is_empty() {
                        self.clock[p]
                    } else {
                        self.messages += 1;
                        self.words += tasks.len();
                        network.deliver(p as u32, to.0, tasks.len(), self.clock[p])
                    };
                    self.channel.insert(key, arrival);
                    self.push_event(
                        arrival,
                        Ev::Arrival { from: key.0, to: key.1, seq: key.2 },
                    );
                }
                Phase::Recv { from, tasks: _ } => {
                    let seq = *self.recv_seq.entry((from.0, p as u32)).or_insert(0);
                    let key = (from.0, p as u32, seq);
                    let Some(arrival) = self.channel.remove(&key) else {
                        // Sender has not posted yet: block until the
                        // message's arrival event wakes us.
                        self.waiting.insert(key, p as u32);
                        return;
                    };
                    self.recv_seq.insert((from.0, p as u32), seq + 1);
                    if arrival > self.clock[p] {
                        self.wait[p] += arrival - self.clock[p];
                        if self.record_spans {
                            self.spans.push(BusySpan {
                                proc: p as u32,
                                thread: 0,
                                start: self.clock[p],
                                end: arrival,
                                what: "wait",
                            });
                        }
                        self.clock[p] = arrival;
                    }
                }
            }
            self.cursor[p] += 1;
        }
    }
}

/// Simulate `plan` for graph `g` on machine `m` under an explicit wire
/// model and per-task cost model.  Returns [`SimError::Deadlock`] when
/// the plan cannot run to completion (instead of looping or panicking) —
/// the engine's stuck detection.
pub fn try_simulate(
    g: &TaskGraph,
    plan: &ExecPlan,
    m: &Machine,
    network: &mut dyn NetworkModel,
    cost: &dyn TaskCostModel,
    record_spans: bool,
) -> Result<SimResult, SimError> {
    assert_eq!(plan.per_proc.len(), m.nprocs as usize, "plan/machine proc count mismatch");
    let nprocs = plan.per_proc.len();
    network.reset();

    let mut e = Engine {
        g,
        plan,
        m,
        cost,
        record_spans,
        clock: vec![0.0; nprocs],
        busy: vec![0.0; nprocs],
        wait: vec![0.0; nprocs],
        cursor: vec![0; nprocs],
        spans: Vec::new(),
        messages: 0,
        words: 0,
        channel: HashMap::new(),
        waiting: HashMap::new(),
        send_seq: HashMap::new(),
        recv_seq: HashMap::new(),
        heap: BinaryHeap::new(),
        ev_tiebreak: 0,
    };

    for p in 0..nprocs as u32 {
        e.push_event(0.0, Ev::Resume { proc: p });
    }

    while let Some(Reverse((_, _, ev))) = e.heap.pop() {
        match ev {
            Ev::Resume { proc } => e.advance(network, proc as usize),
            Ev::Arrival { from, to, seq } => {
                let key = (from, to, seq);
                if e.waiting.remove(&key).is_some() {
                    // The receiver blocked on exactly this message; wake
                    // it at the later of its own clock and the arrival.
                    let at = e.clock[to as usize].max(from_arrival(&e, key));
                    e.push_event(at, Ev::Resume { proc: to });
                }
            }
        }
    }

    let stuck: Vec<(u32, usize)> = (0..nprocs)
        .filter(|&p| e.cursor[p] < plan.per_proc[p].phases.len())
        .map(|p| (p as u32, e.cursor[p]))
        .collect();
    if !stuck.is_empty() {
        return Err(SimError::Deadlock { stuck });
    }

    Ok(SimResult {
        total_time: e.clock.iter().copied().fold(0.0, f64::max),
        proc_finish: e.clock,
        proc_busy: e.busy,
        proc_wait: e.wait,
        messages: e.messages,
        words: e.words,
        spans: e.spans,
    })
}

fn from_arrival(e: &Engine<'_>, key: (u32, u32, u32)) -> f64 {
    e.channel.get(&key).copied().unwrap_or(0.0)
}

/// Simulate `plan` on machine `m` with the classical α+β·words wire and
/// uniform task cost γ — the seed simulator's exact contract, now served
/// by the event engine.
///
/// `record_spans` controls whether per-thread Gantt spans are collected
/// (costly for large runs).  Panics if the plan deadlocks (plans built by
/// [`super::plan`] never do); use [`try_simulate`] to handle deadlocks as
/// values.
pub fn simulate(g: &TaskGraph, plan: &ExecPlan, m: &Machine, record_spans: bool) -> SimResult {
    let mut network = AlphaBeta::from_machine(m);
    try_simulate(g, plan, m, &mut network, &UniformCost, record_spans)
        .unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::ExecPlan;
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    fn m(nprocs: u32, threads: u32, alpha: f64) -> Machine {
        Machine::new(nprocs, threads, alpha, 0.0, 1.0)
    }

    #[test]
    fn single_proc_naive_time_is_levels_times_waves() {
        // 8 points, 1 proc, 2 threads: each level = ceil(8/2) = 4γ.
        let g = heat1d_graph(8, 3, 1);
        let plan = ExecPlan::naive(&g);
        let r = simulate(&g, &plan, &m(1, 2, 100.0), false);
        assert_eq!(r.total_time, 3.0 * 4.0);
        assert_eq!(r.messages, 0);
    }

    #[test]
    fn zero_latency_naive_matches_ideal() {
        let g = heat1d_graph(16, 4, 2);
        let plan = ExecPlan::naive(&g);
        let r = simulate(&g, &plan, &m(2, 8, 0.0), false);
        // 8 points/proc, 8 threads → 1γ per level, 4 levels.
        assert_eq!(r.total_time, 4.0);
    }

    #[test]
    fn latency_adds_per_level_for_naive() {
        let g = heat1d_graph(16, 4, 2);
        let plan = ExecPlan::naive(&g);
        let alpha = 50.0;
        let r = simulate(&g, &plan, &m(2, 8, alpha), false);
        // Levels 2..4 wait for the (level−1)-value message that was posted
        // after the previous level's compute; level 1's inputs are initial
        // data sent at time 0... every level still pays α on the critical
        // path because compute (1γ) ≪ α.
        assert!(r.total_time >= 3.0 * alpha, "{}", r.total_time);
        assert!(r.total_time <= 4.0 * (alpha + 1.0) + 4.0, "{}", r.total_time);
    }

    #[test]
    fn ca_single_superstep_pays_latency_once() {
        let g = heat1d_graph(16, 4, 2);
        let naive = ExecPlan::naive(&g);
        let ca = ExecPlan::ca(&g, 4, TransformOptions::default()).unwrap();
        let mach = m(2, 8, 50.0);
        let rn = simulate(&g, &naive, &mach, false);
        let rc = simulate(&g, &ca, &mach, false);
        assert!(
            rc.total_time < rn.total_time / 2.0,
            "ca {} vs naive {}",
            rc.total_time,
            rn.total_time
        );
    }

    #[test]
    fn overlap_beats_naive_with_latency() {
        let g = heat1d_graph(256, 8, 2);
        let mach = m(2, 1, 60.0);
        let rn = simulate(&g, &ExecPlan::naive(&g), &mach, false);
        let ro = simulate(&g, &ExecPlan::overlap(&g), &mach, false);
        // With 128 points/proc on one thread, the interior compute
        // (≈126γ) hides the 60-unit latency entirely.
        assert!(ro.total_time < rn.total_time, "overlap {} naive {}", ro.total_time, rn.total_time);
    }

    #[test]
    fn work_conservation() {
        let g = heat1d_graph(32, 4, 4);
        for plan in [
            ExecPlan::naive(&g),
            ExecPlan::overlap(&g),
            ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap(),
        ] {
            let r = simulate(&g, &plan, &m(4, 2, 10.0), false);
            let total_busy: f64 = r.proc_busy.iter().sum();
            assert!(
                (total_busy - plan.executed_tasks() as f64).abs() < 1e-9,
                "{}: busy {} vs tasks {}",
                plan.label,
                total_busy,
                plan.executed_tasks()
            );
        }
    }

    #[test]
    fn times_monotone_and_finite() {
        let g = heat1d_graph(24, 3, 3);
        let plan = ExecPlan::ca(&g, 3, TransformOptions::default()).unwrap();
        let r = simulate(&g, &plan, &m(3, 2, 5.0), true);
        assert!(r.total_time.is_finite() && r.total_time > 0.0);
        for s in &r.spans {
            assert!(s.end >= s.start);
            assert!(s.start >= 0.0);
        }
    }

    #[test]
    fn more_threads_never_slower() {
        let g = heat1d_graph(64, 8, 2);
        let plan = ExecPlan::naive(&g);
        let t1 = simulate(&g, &plan, &m(2, 1, 10.0), false).total_time;
        let t4 = simulate(&g, &plan, &m(2, 4, 10.0), false).total_time;
        let t16 = simulate(&g, &plan, &m(2, 16, 10.0), false).total_time;
        assert!(t4 <= t1 && t16 <= t4);
    }

    #[test]
    fn deadlocked_plan_is_detected() {
        use crate::graph::ProcId;
        use crate::sim::plan::ProcPlan;

        // Cyclic wait: each processor receives before it sends.
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Recv { from: ProcId(1), tasks: vec![0] });
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Send { to: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "deadlock".into() };

        let mach = m(2, 1, 10.0);
        let mut net = AlphaBeta::from_machine(&mach);
        let err = try_simulate(&g, &plan, &mach, &mut net, &UniformCost, false).unwrap_err();
        let SimError::Deadlock { stuck } = &err;
        assert_eq!(stuck.as_slice(), &[(0, 0), (1, 0)]);
        assert!(err.to_string().contains("deadlocked"));
    }

    #[test]
    fn partial_deadlock_reports_only_stuck_procs() {
        use crate::graph::ProcId;
        use crate::sim::plan::ProcPlan;

        // p0 finishes; p1 waits for a message nobody sends.
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Compute(vec![8]));
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "half-deadlock".into() };

        let mach = m(2, 1, 10.0);
        let mut net = AlphaBeta::from_machine(&mach);
        let err = try_simulate(&g, &plan, &mach, &mut net, &UniformCost, false).unwrap_err();
        assert_eq!(err, SimError::Deadlock { stuck: vec![(1, 0)] });
    }

    #[test]
    fn nonuniform_costs_scale_busy_time() {
        #[derive(Debug)]
        struct LevelCost;
        impl TaskCostModel for LevelCost {
            fn task_cost(&self, g: &TaskGraph, t: TaskId) -> f64 {
                g.level(t) as f64 // level-l tasks cost l γ
            }
        }
        let g = heat1d_graph(16, 3, 2);
        let plan = ExecPlan::naive(&g);
        let mach = m(2, 4, 0.0);
        let mut net = AlphaBeta::from_machine(&mach);
        let weighted =
            try_simulate(&g, &plan, &mach, &mut net, &LevelCost, false).unwrap();
        let uniform = simulate(&g, &plan, &mach, false);
        // Levels 1..3 at 16 tasks each: Σ busy = 16·(1+2+3) vs 16·3.
        let wb: f64 = weighted.proc_busy.iter().sum();
        let ub: f64 = uniform.proc_busy.iter().sum();
        assert!((wb - 96.0).abs() < 1e-9, "{wb}");
        assert!((ub - 48.0).abs() < 1e-9, "{ub}");
        assert!(weighted.total_time > uniform.total_time);
    }

    #[test]
    fn contended_network_never_faster_than_ideal_wire() {
        use crate::sim::network::Contended;
        let g = heat1d_graph(64, 6, 4);
        let mach = Machine::new(4, 2, 40.0, 0.5, 1.0);
        for plan in [ExecPlan::naive(&g), ExecPlan::overlap(&g)] {
            let ideal = simulate(&g, &plan, &mach, false);
            let mut net = Contended::from_machine(&mach);
            let cont =
                try_simulate(&g, &plan, &mach, &mut net, &UniformCost, false).unwrap();
            assert!(
                cont.total_time >= ideal.total_time - 1e-9,
                "{}: contended {} < ideal {}",
                plan.label,
                cont.total_time,
                ideal.total_time
            );
            assert_eq!(cont.messages, ideal.messages);
            assert_eq!(cont.words, ideal.words);
        }
    }

    #[test]
    fn hierarchical_all_procs_one_node_is_cheap() {
        use crate::sim::network::Hierarchical;
        let g = heat1d_graph(32, 4, 4);
        let plan = ExecPlan::naive(&g);
        let mach = Machine::new(4, 2, 200.0, 0.0, 1.0);
        // Everyone on one node at 10% α ≈ simulating with α/10 (β = 0 so
        // the scaled intra-node β cannot differ).
        let mut one_node = Hierarchical::contiguous(&mach, 4, 0.1);
        let r = try_simulate(&g, &plan, &mach, &mut one_node, &UniformCost, false).unwrap();
        let cheap = simulate(&g, &plan, &mach.with_alpha(20.0), false);
        assert_eq!(r.total_time, cheap.total_time);
    }
}

/// The equivalence matrix of the ISSUE's acceptance criteria: the event
/// engine must reproduce the retained polling oracle **bit-for-bit** —
/// `total_time`, per-proc clocks/busy/wait, `messages`, `words` — on
/// every workload × strategy × processor count.
#[cfg(test)]
mod equivalence {
    use super::*;
    use crate::pipeline::{
        ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy, Workload,
    };
    use crate::sim::discrete::polling_simulate;
    use crate::stencil::CsrMatrix;

    fn assert_equivalent(g: &TaskGraph, plan: &ExecPlan, mach: &Machine, tag: &str) {
        let oracle = polling_simulate(g, plan, mach, false);
        let engine = simulate(g, plan, mach, false);
        assert_eq!(oracle.total_time, engine.total_time, "{tag}: total_time");
        assert_eq!(oracle.proc_finish, engine.proc_finish, "{tag}: proc_finish");
        assert_eq!(oracle.proc_busy, engine.proc_busy, "{tag}: proc_busy");
        assert_eq!(oracle.proc_wait, engine.proc_wait, "{tag}: proc_wait");
        assert_eq!(oracle.messages, engine.messages, "{tag}: messages");
        assert_eq!(oracle.words, engine.words, "{tag}: words");
    }

    fn run_matrix<W: Workload + Clone>(w: W, procs: &[u32]) {
        for &p in procs {
            for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
                let t = Pipeline::new(w.clone())
                    .procs(p)
                    .strategy(strategy)
                    .block(2)
                    .transform()
                    .unwrap_or_else(|e| panic!("{}/{strategy:?}/p{p}: {e}", w.name()));
                for (threads, alpha, beta) in
                    [(1u32, 50.0, 0.0), (4, 500.0, 0.25), (2, 0.0, 1.0)]
                {
                    let mach = Machine::new(p, threads, alpha, beta, 1.0);
                    let tag = format!(
                        "{}/{}/p{p}/t{threads}/a{alpha}",
                        w.name(),
                        t.plan.label
                    );
                    assert_equivalent(&t.graph, &t.plan, &mach, &tag);
                }
            }
        }
    }

    #[test]
    fn heat1d_matrix() {
        run_matrix(Heat1d::new(48, 4), &[2, 3, 4]);
    }

    #[test]
    fn heat2d_matrix() {
        run_matrix(Heat2d { h: 8, w: 8, steps: 3 }, &[2, 3, 4]);
    }

    #[test]
    fn moore2d_matrix() {
        run_matrix(Moore2d { h: 8, w: 8, steps: 2 }, &[2, 3, 4]);
    }

    #[test]
    fn spmv_matrix() {
        run_matrix(Spmv { matrix: CsrMatrix::laplace2d(4, 5), steps: 3 }, &[2, 3, 4]);
    }

    #[test]
    fn cg_matrix() {
        run_matrix(ConjugateGradient { unknowns: 24, iters: 2 }, &[2, 3, 4]);
    }

    #[test]
    fn spans_agree_when_recorded() {
        let g = crate::stencil::heat1d_graph(32, 4, 2);
        let plan = ExecPlan::ca(&g, 2, crate::transform::TransformOptions::default()).unwrap();
        let mach = Machine::new(2, 2, 25.0, 0.5, 1.0);
        let oracle = polling_simulate(&g, &plan, &mach, true);
        let engine = simulate(&g, &plan, &mach, true);
        // Span *sets* agree; emission order may differ between engines
        // (the oracle interleaves procs per polling round).
        let norm = |mut spans: Vec<BusySpan>| {
            spans.sort_by(|a, b| {
                (a.proc, a.thread, to_bits(a.start), to_bits(a.end), a.what)
                    .cmp(&(b.proc, b.thread, to_bits(b.start), to_bits(b.end), b.what))
            });
            spans
        };
        assert_eq!(norm(oracle.spans), norm(engine.spans));
    }
}
