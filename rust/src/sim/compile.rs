//! Compiled simulation plans: the engine's hot path.
//!
//! [`super::engine::try_simulate`] interprets an [`ExecPlan`]'s nested
//! `Vec<Phase>` directly: every run re-sorts each compute phase, chases
//! graph predecessors through the cost hook, and routes messages through
//! four tuple-keyed `HashMap`s (`channel`/`waiting`/`send_seq`/`recv_seq`)
//! that are allocated from scratch per simulation.  That is fine for one
//! simulation; a `sweep`/`tune` invocation dispatches the *same* plan
//! across thousands of (network × α × threads) cells, so the per-run
//! lowering dominates.
//!
//! [`CompiledPlan::compile`] performs that lowering **once** per
//! `(graph, plan, cost model)`:
//!
//! * phase streams per processor in CSR form — `(kind, offset, len)`
//!   records into one shared `u32` task array, compute phases pre-sorted
//!   in the engine's `(level, id)` execution order;
//! * intra-phase dependencies resolved to *positions within the phase*,
//!   so the hot loop never touches the graph or a hash map;
//! * per-task costs baked into a flat `f64` array indexed by `TaskId`;
//! * a dense **channel table**: every `(from, to)` processor pair gets an
//!   integer channel id, every `Send`/`Recv` its message slot — the
//!   `k`-th send on a channel pairs with the `k`-th receive, so matching
//!   is a single indexed load instead of four hash probes;
//! * per-`Send` word counts, so wire cost needs no task list.
//!
//! [`simulate_compiled`] replays the same event-driven semantics as the
//! interpreting engine — packed-integer events in the heap, per-channel
//! resolved wire constants where the [`NetworkModel`] permits
//! ([`NetworkModel::channel_cost`]: α/β and hierarchical wires are static
//! per channel; LogGP and contended NICs keep their stateful `deliver`) —
//! against a reusable [`EngineScratch`], so a sweep worker allocates once
//! and simulates many cells allocation-free.  The interpreting path
//! survives as this module's equivalence oracle, the same pattern as
//! `sim/discrete.rs`: the matrix below pins the compiled engine
//! **bit-for-bit** against it on every workload × strategy × wire model.

use super::discrete::{to_bits, BusySpan, SimResult};
use super::engine::{SimError, TaskCostModel};
use super::machine::Machine;
use super::network::NetworkModel;
use super::plan::{ExecPlan, Phase};
use crate::graph::{TaskGraph, TaskId};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

thread_local! {
    static COMPILES: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Number of [`CompiledPlan::compile`] invocations performed by the
/// *current thread* — instrumentation for the "exactly one compilation
/// per scored candidate" assertions (plans are compiled on the thread
/// that builds the sweep inputs, never inside sweep workers).
pub fn compile_count() -> usize {
    COMPILES.with(|c| c.get())
}

/// One lowered phase record.  `Compute` indexes the shared task array;
/// `Send`/`Recv` carry their pre-matched message slot (and, for sends,
/// the channel id and word count the wire needs).  Crate-visible so the
/// [`crate::explain`] blame walk can replay the lowered streams.
#[derive(Debug, Clone, Copy)]
pub(crate) enum CPhase {
    Compute { off: u32, len: u32 },
    Send { msg: u32, chan: u32, words: u32 },
    Recv { msg: u32 },
}

/// The one-time lowering of `(TaskGraph, ExecPlan, TaskCostModel)` —
/// everything the event loop needs, in flat arrays.  Compile once, then
/// [`simulate_compiled`] any number of machines/wires against it.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    nprocs: u32,
    /// Phase records of processor `p`: `phases[proc_off[p]..proc_off[p+1]]`.
    phases: Vec<CPhase>,
    proc_off: Vec<u32>,
    /// Shared task array: compute phases' task lists, each pre-sorted by
    /// `(level, id)` — the engine's execution order.
    tasks: Vec<u32>,
    /// Intra-phase dependency CSR aligned with `tasks`: for slot `k`,
    /// `pred_pos[pred_off[k]..pred_off[k+1]]` are the *positions within
    /// the same phase* whose finish times gate this task.
    pred_off: Vec<u32>,
    pred_pos: Vec<u32>,
    /// `cost[t]` = task `t`'s cost in γ units (the cost model, baked).
    cost: Vec<f64>,
    /// Dense channel table: `channels[c]` = the `(from, to)` pair of
    /// integer channel `c`.
    channels: Vec<(u32, u32)>,
    /// Message slots: channel `c`'s `k`-th message is slot
    /// `chan_msg_off[c] + k`; `num_msgs` slots in total.
    num_msgs: usize,
    /// Widest compute phase (sizes the finish-time scratch).
    max_phase: usize,
}

impl CompiledPlan {
    /// Lower `plan` for `g` under `cost`.  The result is immutable and
    /// `Send + Sync` — share it (`Arc`) across sweep workers.
    pub fn compile(g: &TaskGraph, plan: &ExecPlan, cost: &dyn TaskCostModel) -> CompiledPlan {
        COMPILES.with(|c| c.set(c.get() + 1));
        let nprocs = plan.per_proc.len();

        // Pass 1: the dense channel table (every (from, to) pair that any
        // Send or Recv names) and per-channel traffic counts.  Slots are
        // max(sends, recvs) so a malformed plan's unmatched Recv still
        // has a slot to block on (and deadlock-detect through).
        let mut chan_ids: HashMap<(u32, u32), u32> = HashMap::new();
        let mut channels: Vec<(u32, u32)> = Vec::new();
        let mut chan_id = |key: (u32, u32), channels: &mut Vec<(u32, u32)>| -> usize {
            *chan_ids.entry(key).or_insert_with(|| {
                channels.push(key);
                (channels.len() - 1) as u32
            }) as usize
        };
        let mut sends: Vec<u32> = Vec::new();
        let mut recvs: Vec<u32> = Vec::new();
        for (p, pp) in plan.per_proc.iter().enumerate() {
            for ph in &pp.phases {
                let (key, is_send) = match ph {
                    Phase::Send { to, .. } => ((p as u32, to.0), true),
                    Phase::Recv { from, .. } => ((from.0, p as u32), false),
                    Phase::Compute(_) => continue,
                };
                let c = chan_id(key, &mut channels);
                if c >= sends.len() {
                    sends.resize(c + 1, 0);
                    recvs.resize(c + 1, 0);
                }
                if is_send {
                    sends[c] += 1;
                } else {
                    recvs[c] += 1;
                }
            }
        }
        let mut chan_msg_off: Vec<u32> = Vec::with_capacity(channels.len());
        let mut num_msgs = 0u32;
        for c in 0..channels.len() {
            chan_msg_off.push(num_msgs);
            num_msgs += sends[c].max(recvs[c]);
        }

        // Pass 2: lower the phase streams.  Message sequence numbers are
        // assigned in program order, which is execution order — a
        // channel's sends all live on one processor's stream, and a
        // cursor only moves forward.
        let mut phases: Vec<CPhase> = Vec::new();
        let mut proc_off: Vec<u32> = Vec::with_capacity(nprocs + 1);
        proc_off.push(0);
        let mut tasks: Vec<u32> = Vec::new();
        let mut pred_off: Vec<u32> = vec![0];
        let mut pred_pos: Vec<u32> = Vec::new();
        let mut send_seq = vec![0u32; channels.len()];
        let mut recv_seq = vec![0u32; channels.len()];
        let mut pos_of = vec![u32::MAX; g.len()];
        let mut max_phase = 0usize;
        for (p, pp) in plan.per_proc.iter().enumerate() {
            for ph in &pp.phases {
                match ph {
                    Phase::Compute(ts) => {
                        let off = tasks.len() as u32;
                        let mut order = ts.clone();
                        order.sort_unstable_by_key(|&t| (g.level(TaskId(t)), t));
                        max_phase = max_phase.max(order.len());
                        for (j, &t) in order.iter().enumerate() {
                            pos_of[t as usize] = j as u32;
                        }
                        for &t in &order {
                            // Predecessors computed in this same phase
                            // gate the task; everything else was ready at
                            // phase start (phase order + blocking Recv),
                            // exactly as the interpreting engine treats
                            // it.  Levels are longest-path depths, so an
                            // in-phase pred always sorts earlier.
                            for &pr in g.preds(TaskId(t)) {
                                if pos_of[pr as usize] != u32::MAX {
                                    pred_pos.push(pos_of[pr as usize]);
                                }
                            }
                            pred_off.push(pred_pos.len() as u32);
                            tasks.push(t);
                        }
                        for &t in &order {
                            pos_of[t as usize] = u32::MAX;
                        }
                        phases.push(CPhase::Compute { off, len: order.len() as u32 });
                    }
                    Phase::Send { to, tasks: ts } => {
                        let c = chan_id((p as u32, to.0), &mut channels);
                        let msg = chan_msg_off[c] + send_seq[c];
                        send_seq[c] += 1;
                        phases.push(CPhase::Send {
                            msg,
                            chan: c as u32,
                            words: ts.len() as u32,
                        });
                    }
                    Phase::Recv { from, .. } => {
                        let c = chan_id((from.0, p as u32), &mut channels);
                        let msg = chan_msg_off[c] + recv_seq[c];
                        recv_seq[c] += 1;
                        phases.push(CPhase::Recv { msg });
                    }
                }
            }
            proc_off.push(phases.len() as u32);
        }

        let cost: Vec<f64> = g.tasks().map(|t| cost.task_cost(g, t)).collect();

        CompiledPlan {
            nprocs: nprocs as u32,
            phases,
            proc_off,
            tasks,
            pred_off,
            pred_pos,
            cost,
            channels,
            num_msgs: num_msgs as usize,
            max_phase,
        }
    }

    /// Processors the plan runs on.
    pub fn num_procs(&self) -> u32 {
        self.nprocs
    }

    /// Distinct `(from, to)` channels in the dense table.
    pub fn num_channels(&self) -> usize {
        self.channels.len()
    }

    /// Message slots (each send/recv pairing resolved at compile time).
    pub fn num_messages(&self) -> usize {
        self.num_msgs
    }

    /// Global phase records, across all processors.
    pub fn num_phases(&self) -> usize {
        self.phases.len()
    }

    /// Indices of processor `p`'s phase records in the global stream —
    /// the range [`ProvenanceBuffer`] windows are keyed by.
    pub(crate) fn proc_phase_range(&self, p: usize) -> std::ops::Range<usize> {
        self.proc_off[p] as usize..self.proc_off[p + 1] as usize
    }

    /// The `k`-th global phase record.
    pub(crate) fn phase(&self, k: usize) -> CPhase {
        self.phases[k]
    }

    /// The `(from, to)` processor pair of dense channel `c`.
    pub(crate) fn channel(&self, c: usize) -> (u32, u32) {
        self.channels[c]
    }
}

/// Reusable per-worker simulation state: every vector and heap one
/// [`simulate_compiled`] run needs, sized on first use and recycled —
/// after warm-up a sweep worker simulates cell after cell without a
/// single allocation in the event loop.
#[derive(Debug, Default)]
pub struct EngineScratch {
    clock: Vec<f64>,
    busy: Vec<f64>,
    wait: Vec<f64>,
    /// Per-proc *global* phase index into `CompiledPlan::phases`.
    cursor: Vec<u32>,
    /// Min-heap of packed events: `(time bits, tiebreak, payload)` with
    /// `payload = proc << 1` for resumes, `msg << 1 | 1` for arrivals.
    heap: BinaryHeap<Reverse<(u64, u64, u64)>>,
    /// Per message slot: arrival time, or `-1.0` while unposted.
    arrival: Vec<f64>,
    /// Per message slot: the processor blocked on it (`u32::MAX` = none).
    waiting: Vec<u32>,
    /// Intra-phase finish times by position (entries < the running
    /// position are always written before read, so no clearing needed).
    finish: Vec<f64>,
    /// Thread pool min-heap for the list scheduler.
    threads: BinaryHeap<Reverse<(u64, u32)>>,
    /// Per-channel resolved wire constants (static wires only).
    chan_alpha: Vec<f64>,
    chan_beta: Vec<f64>,
    events: u64,
    /// Lifetime run count for this scratch (telemetry: reuse tracking).
    runs: u64,
}

impl EngineScratch {
    pub fn new() -> Self {
        EngineScratch::default()
    }

    /// Heap events processed by the most recent run (for `bench`).
    pub fn events(&self) -> u64 {
        self.events
    }

    fn reset(&mut self, cp: &CompiledPlan) {
        let n = cp.nprocs as usize;
        self.clock.clear();
        self.clock.resize(n, 0.0);
        self.busy.clear();
        self.busy.resize(n, 0.0);
        self.wait.clear();
        self.wait.resize(n, 0.0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&cp.proc_off[..n]);
        self.heap.clear();
        self.arrival.clear();
        self.arrival.resize(cp.num_msgs, -1.0);
        self.waiting.clear();
        self.waiting.resize(cp.num_msgs, u32::MAX);
        self.finish.clear();
        self.finish.resize(cp.max_phase, 0.0);
        self.events = 0;
        self.runs += 1;
    }
}

/// Reusable per-run provenance: the engine's own record of *when* every
/// lowered phase ran — the raw material the [`crate::explain`] blame
/// walk prices the observed critical path from.  Like [`EngineScratch`]
/// it is engine-owned scratch, sized on first use and recycled across
/// runs.  Recording is pure observation (two stores per executed phase,
/// one arrival copy after the run), so an observed run's [`SimResult`]
/// is bit-identical to an unobserved one; when no buffer is attached
/// the hot loop pays exactly one branch per phase, mirroring the
/// telemetry gate.
#[derive(Debug, Default)]
pub struct ProvenanceBuffer {
    /// `start[k]` = the proc clock when global phase `k` began: compute
    /// start, send post time, or the clock a receive found (i.e. when
    /// any exposed wait began).
    start: Vec<f64>,
    /// `end[k]` = the clock after phase `k`: compute end, send post
    /// time, or the receive's satisfied clock `max(start, arrival)`.
    end: Vec<f64>,
    /// Arrival time of every message slot (`-1.0` = never posted),
    /// copied from the run's scratch after the event loop drains.
    arrival: Vec<f64>,
}

impl ProvenanceBuffer {
    /// A fresh buffer; sized by the first observed run.
    pub fn new() -> Self {
        ProvenanceBuffer::default()
    }

    fn reset(&mut self, cp: &CompiledPlan) {
        self.start.clear();
        self.start.resize(cp.phases.len(), -1.0);
        self.end.clear();
        self.end.resize(cp.phases.len(), -1.0);
        self.arrival.clear();
    }

    /// Clock when global phase `k` began (`-1.0` = never executed).
    pub fn phase_start(&self, k: usize) -> f64 {
        self.start[k]
    }

    /// Clock when global phase `k` was satisfied (`-1.0` = never).
    pub fn phase_end(&self, k: usize) -> f64 {
        self.end[k]
    }

    /// Arrival time of message slot `msg` (`-1.0` = never posted).
    pub fn msg_arrival(&self, msg: usize) -> f64 {
        self.arrival[msg]
    }

    /// Phase windows recorded by the last observed run.
    pub fn len(&self) -> usize {
        self.start.len()
    }

    /// True before the first observed run.
    pub fn is_empty(&self) -> bool {
        self.start.is_empty()
    }
}

/// One in-flight run: the compiled plan, the machine, and the scratch it
/// mutates.  Mirrors `engine::Engine`, minus every hash map.
struct CRun<'a> {
    cp: &'a CompiledPlan,
    m: &'a Machine,
    s: &'a mut EngineScratch,
    record_spans: bool,
    spans: Vec<BusySpan>,
    messages: usize,
    words: usize,
    tiebreak: u64,
    /// Every channel's wire cost resolved to constants at run start.
    static_wire: bool,
    /// Provenance observation sink (`None` = the unobserved hot path:
    /// one branch per phase, nothing recorded).
    prov: Option<&'a mut ProvenanceBuffer>,
}

impl CRun<'_> {
    #[inline]
    fn push_event(&mut self, at: f64, payload: u64) {
        self.tiebreak += 1;
        self.s.heap.push(Reverse((to_bits(at), self.tiebreak, payload)));
    }

    /// Run processor `p` forward until it finishes or blocks on an
    /// unposted message slot.
    fn advance(&mut self, network: &mut dyn NetworkModel, p: usize) {
        let end = self.cp.proc_off[p + 1];
        while self.s.cursor[p] < end {
            let gidx = self.s.cursor[p] as usize;
            match self.cp.phases[gidx] {
                CPhase::Compute { off, len } => {
                    let before = self.s.clock[p];
                    let (phase_end, busy) = self.run_compute(p, off as usize, len as usize);
                    self.s.busy[p] += busy;
                    self.s.clock[p] = phase_end;
                    if let Some(prov) = self.prov.as_deref_mut() {
                        prov.start[gidx] = before;
                        prov.end[gidx] = phase_end;
                    }
                }
                CPhase::Send { msg, chan, words } => {
                    let post = self.s.clock[p];
                    // Zero-word sends cost nothing on the wire and are
                    // not counted; they still post so the matching Recv
                    // pairs up.
                    let arrival = if words == 0 {
                        post
                    } else {
                        self.messages += 1;
                        self.words += words as usize;
                        if self.static_wire {
                            let wire = self.s.chan_alpha[chan as usize]
                                + self.s.chan_beta[chan as usize] * words as f64;
                            post + wire
                        } else {
                            let (from, to) = self.cp.channels[chan as usize];
                            network.deliver(from, to, words as usize, post)
                        }
                    };
                    self.s.arrival[msg as usize] = arrival;
                    self.push_event(arrival, ((msg as u64) << 1) | 1);
                    if let Some(prov) = self.prov.as_deref_mut() {
                        prov.start[gidx] = post;
                        prov.end[gidx] = post;
                    }
                }
                CPhase::Recv { msg } => {
                    let arrival = self.s.arrival[msg as usize];
                    if arrival < 0.0 {
                        // Sender has not posted yet: block until the
                        // slot's arrival event wakes us (the window is
                        // recorded on the resumed attempt, when the
                        // clock is still the one the wait began at).
                        self.s.waiting[msg as usize] = p as u32;
                        return;
                    }
                    let before = self.s.clock[p];
                    if arrival > self.s.clock[p] {
                        self.s.wait[p] += arrival - self.s.clock[p];
                        if self.record_spans {
                            self.spans.push(BusySpan {
                                proc: p as u32,
                                thread: 0,
                                start: self.s.clock[p],
                                end: arrival,
                                what: "wait",
                            });
                        }
                        self.s.clock[p] = arrival;
                    }
                    if let Some(prov) = self.prov.as_deref_mut() {
                        prov.start[gidx] = before;
                        prov.end[gidx] = self.s.clock[p];
                    }
                }
            }
            self.s.cursor[p] += 1;
        }
    }

    /// The compiled list scheduler: same semantics (and bit-for-bit the
    /// same arithmetic) as `discrete::run_compute`, but the order is
    /// pre-sorted and the intra-phase dependencies are positional.
    fn run_compute(&mut self, p: usize, off: usize, len: usize) -> (f64, f64) {
        let start = self.s.clock[p];
        self.s.threads.clear();
        for tid in 0..self.m.threads {
            self.s.threads.push(Reverse((to_bits(start), tid)));
        }
        let mut busy = 0.0;
        let mut end = start;
        for j in 0..len {
            let slot = off + j;
            let mut est = start;
            let (p0, p1) = (self.cp.pred_off[slot] as usize, self.cp.pred_off[slot + 1] as usize);
            for &pi in &self.cp.pred_pos[p0..p1] {
                let f = self.s.finish[pi as usize];
                if f > est {
                    est = f;
                }
            }
            let Reverse((free_bits, tid)) = self.s.threads.pop().unwrap();
            let free = f64::from_bits(free_bits);
            let st = est.max(free);
            let dur = self.m.gamma * self.cp.cost[self.cp.tasks[slot] as usize];
            let f = st + dur;
            self.s.finish[j] = f;
            self.s.threads.push(Reverse((to_bits(f), tid)));
            busy += dur;
            if f > end {
                end = f;
            }
            if self.record_spans {
                self.spans.push(BusySpan {
                    proc: p as u32,
                    thread: tid,
                    start: st,
                    end: f,
                    what: "compute",
                });
            }
        }
        (end, busy)
    }
}

/// Simulate a [`CompiledPlan`] on machine `m` under `network`, reusing
/// `scratch` across calls.  Same contract and **bit-for-bit** the same
/// results as [`super::engine::try_simulate`] on the plan it was
/// compiled from (the cost model is baked into the compiled plan).
pub fn simulate_compiled(
    cp: &CompiledPlan,
    m: &Machine,
    network: &mut dyn NetworkModel,
    scratch: &mut EngineScratch,
    record_spans: bool,
) -> Result<SimResult, SimError> {
    simulate_inner(cp, m, network, scratch, record_spans, None)
}

/// [`simulate_compiled`] with provenance observation: additionally
/// records every phase's `(start, end)` window and every message's
/// arrival into `prov` — everything the [`crate::explain`] blame walk
/// needs to extract the *observed* critical path.  The returned
/// [`SimResult`] is **bit-identical** to an unobserved run (recording
/// never feeds back into the timing arithmetic); the cost is two stores
/// per phase plus one arrival copy after the event loop.
pub fn simulate_observed(
    cp: &CompiledPlan,
    m: &Machine,
    network: &mut dyn NetworkModel,
    scratch: &mut EngineScratch,
    record_spans: bool,
    prov: &mut ProvenanceBuffer,
) -> Result<SimResult, SimError> {
    prov.reset(cp);
    simulate_inner(cp, m, network, scratch, record_spans, Some(prov))
}

fn simulate_inner(
    cp: &CompiledPlan,
    m: &Machine,
    network: &mut dyn NetworkModel,
    scratch: &mut EngineScratch,
    record_spans: bool,
    prov: Option<&mut ProvenanceBuffer>,
) -> Result<SimResult, SimError> {
    assert_eq!(cp.nprocs, m.nprocs, "plan/machine proc count mismatch");
    let nprocs = cp.nprocs as usize;
    network.reset();
    scratch.reset(cp);
    // The telemetry gate is hoisted out of the event loop: when off,
    // the hot path pays exactly this one relaxed load.
    let telem = crate::telemetry::enabled();
    let reused = scratch.runs > 1;
    let mut heap_high_water = 0usize;

    // Resolve per-channel wire constants where the model permits: the
    // whole run then never crosses the dyn boundary per message.
    scratch.chan_alpha.clear();
    scratch.chan_beta.clear();
    let mut static_wire = true;
    for &(from, to) in &cp.channels {
        match network.channel_cost(from, to) {
            Some((a, b)) => {
                scratch.chan_alpha.push(a);
                scratch.chan_beta.push(b);
            }
            None => {
                static_wire = false;
                break;
            }
        }
    }

    let mut run = CRun {
        cp,
        m,
        s: scratch,
        record_spans,
        spans: Vec::new(),
        messages: 0,
        words: 0,
        tiebreak: 0,
        static_wire,
        prov,
    };
    for p in 0..nprocs {
        run.push_event(0.0, (p as u64) << 1);
    }
    while let Some(Reverse((_, _, payload))) = run.s.heap.pop() {
        run.s.events += 1;
        if telem {
            // +1: the popped event itself was on the heap a moment ago.
            heap_high_water = heap_high_water.max(run.s.heap.len() + 1);
        }
        if payload & 1 == 0 {
            run.advance(network, (payload >> 1) as usize);
        } else {
            let msg = (payload >> 1) as usize;
            let blocked = run.s.waiting[msg];
            if blocked != u32::MAX {
                // The receiver blocked on exactly this slot; wake it at
                // the later of its own clock and the arrival.
                run.s.waiting[msg] = u32::MAX;
                let at = run.s.clock[blocked as usize].max(run.s.arrival[msg]);
                run.push_event(at, (blocked as u64) << 1);
            }
        }
    }

    if telem {
        crate::telemetry::with(|r| {
            r.counter("engine.runs").add(1);
            r.counter("engine.events").add(run.s.events);
            if reused {
                r.counter("engine.scratch_reuse").add(1);
            }
            r.gauge("engine.heap_depth_high_water").set_max(heap_high_water as u64);
        });
    }

    // Off the hot path: hand the observed arrivals over in one copy.
    if let Some(prov) = run.prov.take() {
        prov.arrival.extend_from_slice(&run.s.arrival);
    }

    let stuck: Vec<(u32, usize)> = (0..nprocs)
        .filter(|&p| run.s.cursor[p] < cp.proc_off[p + 1])
        .map(|p| (p as u32, (run.s.cursor[p] - cp.proc_off[p]) as usize))
        .collect();
    if !stuck.is_empty() {
        return Err(SimError::Deadlock { stuck });
    }

    Ok(SimResult {
        total_time: run.s.clock.iter().copied().fold(0.0, f64::max),
        proc_finish: run.s.clock.clone(),
        proc_busy: run.s.busy.clone(),
        proc_wait: run.s.wait.clone(),
        messages: run.messages,
        words: run.words,
        spans: run.spans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ProcId;
    use crate::sim::engine::{try_simulate, UniformCost};
    use crate::sim::network::{AlphaBeta, NetworkKind};
    use crate::sim::plan::ProcPlan;
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    fn m(nprocs: u32, threads: u32, alpha: f64) -> Machine {
        Machine::new(nprocs, threads, alpha, 0.5, 1.0)
    }

    #[test]
    fn compile_shapes() {
        let g = heat1d_graph(16, 3, 2);
        let plan = ExecPlan::naive(&g);
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        assert_eq!(cp.num_procs(), 2);
        // One channel each way.
        assert_eq!(cp.num_channels(), 2);
        // Three levels × one message each way.
        assert_eq!(cp.num_messages(), 6);
        assert_eq!(cp.cost.len(), g.len());
    }

    #[test]
    fn compile_count_increments_per_compile() {
        let g = heat1d_graph(8, 2, 2);
        let plan = ExecPlan::naive(&g);
        let before = compile_count();
        let _a = CompiledPlan::compile(&g, &plan, &UniformCost);
        let _b = CompiledPlan::compile(&g, &plan, &UniformCost);
        assert_eq!(compile_count() - before, 2);
    }

    #[test]
    fn scratch_is_reusable_across_different_plans() {
        let g1 = heat1d_graph(32, 4, 2);
        let g2 = heat1d_graph(48, 6, 3);
        let p1 = ExecPlan::naive(&g1);
        let p2 = ExecPlan::ca(&g2, 3, TransformOptions::default()).unwrap();
        let (m1, m2) = (m(2, 2, 50.0), m(3, 4, 10.0));
        let cp1 = CompiledPlan::compile(&g1, &p1, &UniformCost);
        let cp2 = CompiledPlan::compile(&g2, &p2, &UniformCost);

        let mut shared = EngineScratch::new();
        for _ in 0..2 {
            // Interleave plans of different sizes through one scratch;
            // every pass must reproduce the fresh-scratch result exactly.
            for (cp, mach) in [(&cp1, &m1), (&cp2, &m2)] {
                let mut net = AlphaBeta::from_machine(mach);
                let r = simulate_compiled(cp, mach, &mut net, &mut shared, false).unwrap();
                let mut fresh = EngineScratch::new();
                let mut net2 = AlphaBeta::from_machine(mach);
                let f = simulate_compiled(cp, mach, &mut net2, &mut fresh, false).unwrap();
                assert_eq!(r.total_time, f.total_time);
                assert_eq!(r.proc_finish, f.proc_finish);
                assert_eq!(r.messages, f.messages);
            }
        }
        assert!(shared.events() > 0);
    }

    #[test]
    fn deadlocked_plan_is_detected_through_compiled_plan() {
        // Cyclic wait: each processor receives before it sends — the
        // engine.rs deadlock scenario, through the compiled path.
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Recv { from: ProcId(1), tasks: vec![0] });
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Send { to: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "deadlock".into() };

        let mach = m(2, 1, 10.0);
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        let mut net = AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let err = simulate_compiled(&cp, &mach, &mut net, &mut scratch, false).unwrap_err();
        let SimError::Deadlock { stuck } = &err;
        assert_eq!(stuck.as_slice(), &[(0, 0), (1, 0)]);
    }

    #[test]
    fn partial_deadlock_with_unmatched_recv() {
        // p0 finishes; p1 waits for a message nobody ever sends — the
        // channel has recvs but zero sends, exercising the
        // max(sends, recvs) slot sizing.
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Compute(vec![8]));
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "half-deadlock".into() };

        let mach = m(2, 1, 10.0);
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        assert_eq!(cp.num_messages(), 1);
        let mut net = AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let err = simulate_compiled(&cp, &mach, &mut net, &mut scratch, false).unwrap_err();
        assert_eq!(err, SimError::Deadlock { stuck: vec![(1, 0)] });
    }

    #[test]
    fn zero_word_sends_pair_but_do_not_count() {
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![] });
        per_proc[1].phases.push(Phase::Compute(vec![8]));
        let plan = ExecPlan { per_proc, label: "zero".into() };

        let mach = m(2, 1, 25.0);
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        let mut net = AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let r = simulate_compiled(&cp, &mach, &mut net, &mut scratch, false).unwrap();
        assert_eq!(r.messages, 0);
        assert_eq!(r.words, 0);
        // The empty message pairs instantly: no α is paid.
        assert_eq!(r.total_time, 1.0);
    }

    #[test]
    fn per_channel_constants_cover_every_static_wire() {
        // A hierarchical wire resolves different constants per channel;
        // the compiled result must still match the interpreted engine
        // exactly (the equivalence module pins the full matrix — this is
        // the targeted unit check with β > 0).
        let g = heat1d_graph(64, 6, 4);
        let plan = ExecPlan::overlap(&g);
        let mach = Machine::new(4, 2, 200.0, 0.7, 1.0);
        let kind = NetworkKind::Hierarchical { node_size: 2, intra_factor: 0.1 };
        let mut net_i = kind.build(&mach);
        let interp = try_simulate(&g, &plan, &mach, net_i.as_mut(), &UniformCost, false).unwrap();
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        let mut net_c = kind.build(&mach);
        let mut scratch = EngineScratch::new();
        let comp = simulate_compiled(&cp, &mach, net_c.as_mut(), &mut scratch, false).unwrap();
        assert_eq!(comp.total_time, interp.total_time);
        assert_eq!(comp.proc_finish, interp.proc_finish);
        assert_eq!(comp.proc_wait, interp.proc_wait);
    }

    #[test]
    fn observed_runs_are_bit_identical_and_tile_each_proc() {
        // Provenance is pure observation: the observed SimResult is the
        // unobserved one bit-for-bit, and the recorded phase windows
        // tile every processor's [0, finish] contiguously.
        let g = heat1d_graph(48, 4, 3);
        let plan = ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap();
        let mach = Machine::new(3, 2, 80.0, 0.5, 1.0);
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        let mut scratch = EngineScratch::new();
        for kind in NetworkKind::all_default() {
            let mut net_a = kind.build(&mach);
            let plain = simulate_compiled(&cp, &mach, net_a.as_mut(), &mut scratch, false).unwrap();
            let mut prov = ProvenanceBuffer::new();
            let mut net_b = kind.build(&mach);
            let obs =
                simulate_observed(&cp, &mach, net_b.as_mut(), &mut scratch, false, &mut prov)
                    .unwrap();
            assert_eq!(plain.total_time, obs.total_time, "{}", kind.label());
            assert_eq!(plain.proc_finish, obs.proc_finish, "{}", kind.label());
            assert_eq!(plain.proc_busy, obs.proc_busy, "{}", kind.label());
            assert_eq!(plain.proc_wait, obs.proc_wait, "{}", kind.label());
            assert_eq!(prov.len(), cp.num_phases());
            for p in 0..3usize {
                let mut clock = 0.0;
                for k in cp.proc_phase_range(p) {
                    assert_eq!(prov.phase_start(k), clock, "{} phase {k}", kind.label());
                    assert!(prov.phase_end(k) >= prov.phase_start(k));
                    clock = prov.phase_end(k);
                }
                assert_eq!(clock, obs.proc_finish[p], "{} proc {p}", kind.label());
            }
            // Every message slot's arrival was captured.
            for msg in 0..cp.num_messages() {
                assert!(prov.msg_arrival(msg) >= 0.0, "{} msg {msg}", kind.label());
            }
        }
    }
}

/// The compiled engine's equivalence matrix (ISSUE 5 acceptance): the
/// compiled path must reproduce the interpreting engine — and, under the
/// α/β wire, the retained `sim/discrete.rs` polling oracle —
/// **bit-for-bit** (`total_time`, per-proc clocks/busy/wait, `messages`,
/// `words`) on every workload × strategy × processor count × wire model.
#[cfg(test)]
mod equivalence {
    use super::*;
    use crate::pipeline::{
        ConjugateGradient, Heat1d, Heat2d, Moore2d, Pipeline, Spmv, Strategy, Workload,
    };
    use crate::sim::discrete::polling_simulate;
    use crate::sim::engine::{try_simulate, UniformCost};
    use crate::sim::network::NetworkKind;
    use crate::stencil::CsrMatrix;

    fn assert_equal(a: &SimResult, b: &SimResult, tag: &str) {
        assert_eq!(a.total_time, b.total_time, "{tag}: total_time");
        assert_eq!(a.proc_finish, b.proc_finish, "{tag}: proc_finish");
        assert_eq!(a.proc_busy, b.proc_busy, "{tag}: proc_busy");
        assert_eq!(a.proc_wait, b.proc_wait, "{tag}: proc_wait");
        assert_eq!(a.messages, b.messages, "{tag}: messages");
        assert_eq!(a.words, b.words, "{tag}: words");
    }

    fn run_matrix<W: Workload + Clone>(w: W, procs: &[u32]) {
        let mut scratch = EngineScratch::new();
        for &p in procs {
            for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
                let t = Pipeline::new(w.clone())
                    .procs(p)
                    .strategy(strategy)
                    .block(2)
                    .transform()
                    .unwrap_or_else(|e| panic!("{}/{strategy:?}/p{p}: {e}", w.name()));
                // The workload's own cost model rides in the sweep input,
                // compiled exactly as sweep/tune consume it.
                let input = t.sweep_input();
                for kind in NetworkKind::all_default() {
                    for (threads, alpha, beta) in [(1u32, 50.0, 0.25), (4, 500.0, 0.0)] {
                        let mach = Machine::new(p, threads, alpha, beta, 1.0);
                        let tag = format!(
                            "{}/{}/p{p}/{}/t{threads}/a{alpha}",
                            input.workload,
                            t.plan.label,
                            kind.label()
                        );
                        let mut net_i = kind.build_for(&mach, input.layout.as_ref());
                        let interp = try_simulate(
                            &input.graph,
                            &input.plan,
                            &mach,
                            net_i.as_mut(),
                            input.cost.as_ref(),
                            false,
                        )
                        .unwrap_or_else(|e| panic!("{tag}: {e}"));
                        let mut net_c = kind.build_for(&mach, input.layout.as_ref());
                        let comp = simulate_compiled(
                            &input.compiled,
                            &mach,
                            net_c.as_mut(),
                            &mut scratch,
                            false,
                        )
                        .unwrap_or_else(|e| panic!("{tag}: {e}"));
                        assert_equal(&comp, &interp, &tag);

                        // Under the α/β wire with uniform costs the seed
                        // polling loop is the ground truth for both.
                        if kind == NetworkKind::AlphaBeta {
                            let cp = CompiledPlan::compile(&t.graph, &t.plan, &UniformCost);
                            let mut net = kind.build(&mach);
                            let comp_u =
                                simulate_compiled(&cp, &mach, net.as_mut(), &mut scratch, false)
                                    .unwrap();
                            let oracle = polling_simulate(&t.graph, &t.plan, &mach, false);
                            assert_equal(&comp_u, &oracle, &format!("{tag} vs oracle"));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn heat1d_matrix() {
        run_matrix(Heat1d::new(48, 4), &[2, 3, 4]);
    }

    #[test]
    fn heat2d_matrix() {
        run_matrix(Heat2d { h: 8, w: 8, steps: 3 }, &[2, 3, 4]);
    }

    #[test]
    fn moore2d_matrix() {
        run_matrix(Moore2d { h: 8, w: 8, steps: 2 }, &[2, 3, 4]);
    }

    #[test]
    fn spmv_matrix() {
        run_matrix(Spmv { matrix: CsrMatrix::laplace2d(4, 5), steps: 3 }, &[2, 3, 4]);
    }

    #[test]
    fn cg_matrix() {
        run_matrix(ConjugateGradient { unknowns: 24, iters: 2 }, &[2, 3, 4]);
    }

    #[test]
    fn spans_agree_with_the_interpreting_engine() {
        let g = crate::stencil::heat1d_graph(32, 4, 2);
        let plan =
            ExecPlan::ca(&g, 2, crate::transform::TransformOptions::default()).unwrap();
        let mach = Machine::new(2, 2, 25.0, 0.5, 1.0);
        let mut net_i = crate::sim::network::AlphaBeta::from_machine(&mach);
        let interp = try_simulate(&g, &plan, &mach, &mut net_i, &UniformCost, true).unwrap();
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        let mut net_c = crate::sim::network::AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let comp = simulate_compiled(&cp, &mach, &mut net_c, &mut scratch, true).unwrap();
        let norm = |mut spans: Vec<BusySpan>| {
            spans.sort_by(|a, b| {
                (a.proc, a.thread, to_bits(a.start), to_bits(a.end), a.what)
                    .cmp(&(b.proc, b.thread, to_bits(b.start), to_bits(b.end), b.what))
            });
            spans
        };
        assert_eq!(norm(interp.spans), norm(comp.spans));
    }

    #[test]
    fn chrome_export_is_byte_equal_across_engines() {
        // Satellite pin: the two engines' BusySpan streams are not just
        // equivalent — rendered through chrome_trace_json (after the
        // same deterministic ordering) they are the *same bytes*.
        let g = crate::stencil::heat1d_graph(48, 5, 3);
        let plan =
            ExecPlan::ca(&g, 2, crate::transform::TransformOptions::default()).unwrap();
        let mach = Machine::new(3, 2, 40.0, 0.25, 1.0);
        let mut net_i = crate::sim::network::AlphaBeta::from_machine(&mach);
        let interp = try_simulate(&g, &plan, &mach, &mut net_i, &UniformCost, true).unwrap();
        let cp = CompiledPlan::compile(&g, &plan, &UniformCost);
        let mut net_c = crate::sim::network::AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let comp = simulate_compiled(&cp, &mach, &mut net_c, &mut scratch, true).unwrap();
        let norm = |mut spans: Vec<BusySpan>| {
            spans.sort_by(|a, b| {
                (a.proc, a.thread, to_bits(a.start), to_bits(a.end), a.what)
                    .cmp(&(b.proc, b.thread, to_bits(b.start), to_bits(b.end), b.what))
            });
            spans
        };
        let a = crate::trace::chrome_trace_json(&norm(interp.spans));
        let b = crate::trace::chrome_trace_json(&norm(comp.spans));
        assert!(!a.is_empty() && a.contains("compute"));
        assert_eq!(a, b, "chrome exports diverge between engines");
    }
}
