//! Pluggable wire models for the event-driven simulator.
//!
//! The seed simulator hardwired one cost: every message arrives
//! `α + β·words` after it is posted.  That is [`AlphaBeta`] here; three
//! further models widen the scenario space the §4 study can cover:
//!
//! | model | extra physics | paper-figure regime |
//! |---|---|---|
//! | [`AlphaBeta`] | none — pure latency/bandwidth | figures 7/8 as published |
//! | [`LogGp`] | per-message injection gap `g`, per-word gap `G`, CPU overhead `o` | figure 7's "moderate latency" with send-rate limits: blocking also amortizes the injection gap, so CA wins slightly earlier |
//! | [`Hierarchical`] | cheap intra-node vs. expensive inter-node latency from a proc→node mapping | multi-node figure 8: only the node-boundary messages pay full α, so the optimal block factor sits between the intra and inter predictions |
//! | [`Contended`] | per-NIC serialization of concurrent sends | figure 8 with fan-out: naive's per-level message bursts queue at the NIC, widening CA's win |
//!
//! A model is *stateful* (NIC clocks, injection clocks), so the engine
//! takes `&mut dyn NetworkModel` and calls [`NetworkModel::reset`] at the
//! start of every run.  Cloneable *descriptions* live in [`NetworkKind`],
//! which the [`crate::pipeline::Pipeline`] builder and the sweep grid
//! store and instantiate per run:
//!
//! ```
//! use imp_latency::pipeline::{Heat1d, Pipeline};
//! use imp_latency::sim::{Machine, NetworkKind};
//!
//! let base = Pipeline::new(Heat1d::new(32, 4)).procs(2).machine(Machine::high_latency(2, 4));
//! let ideal = base.clone().transform().unwrap().simulate_configured().unwrap();
//! let contended = base
//!     .network(NetworkKind::Contended)
//!     .transform()
//!     .unwrap()
//!     .simulate_configured()
//!     .unwrap();
//! // Serialized NICs can only delay messages relative to the ideal wire.
//! assert!(contended.time.value() >= ideal.time.value());
//! ```

use super::machine::Machine;
use crate::partition::Partitioning;
use std::collections::HashMap;

/// A wire model: given a posted message, when does it arrive?
///
/// Implementations may keep per-resource clocks (`&mut self`); the engine
/// guarantees `deliver` is called in global simulation order *per sender*
/// (a processor posts its sends at non-decreasing local times), and calls
/// [`NetworkModel::reset`] before every simulation run.
pub trait NetworkModel: Send {
    /// Short tag for reports ("alphabeta", "loggp", ...).
    fn label(&self) -> &'static str;

    /// Arrival time at `to` of a `words`-word message posted by `from` at
    /// time `post`.  Must be ≥ `post`.
    fn deliver(&mut self, from: u32, to: u32, words: usize, post: f64) -> f64;

    /// Clear any per-run state (NIC clocks etc.).
    fn reset(&mut self) {}

    /// Resolved per-channel wire constants, when this model's cost for
    /// the `(from, to)` channel is the **stateless** postal form
    /// `arrival = post + (α_c + β_c · words)`: return `Some((α_c, β_c))`
    /// and the compiled engine ([`crate::sim::simulate_compiled`]) skips
    /// the dyn `deliver` call per message.  Stateful models (LogGP
    /// injection clocks, contended NICs) keep the default `None` and are
    /// consulted per message.  Implementations must agree with `deliver`
    /// bit-for-bit — the compiled/interpreted equivalence matrix pins it.
    fn channel_cost(&self, from: u32, to: u32) -> Option<(f64, f64)> {
        let _ = (from, to);
        None
    }

    /// A state-independent lower bound on the wire delay of a
    /// `words`-word message on the `(from, to)` channel:
    /// `deliver(from, to, words, post) ≥ post + message_lower_bound(..)`
    /// must hold for every post time and every prior traffic history.
    /// Stateless wires return their exact cost (so the static analyzer's
    /// critical path ([`crate::analysis::critical_path`]) is exact);
    /// stateful wires drop the history-dependent terms (injection gaps,
    /// NIC queueing).  `0.0` is always sound and is the default when no
    /// per-channel constants are resolvable.
    fn message_lower_bound(&self, from: u32, to: u32, words: usize) -> f64 {
        if words == 0 {
            return 0.0;
        }
        match self.channel_cost(from, to) {
            Some((a, b)) => a + b * words as f64,
            None => 0.0,
        }
    }

    /// The `(latency, bandwidth)` decomposition of one message's
    /// state-free wire cost — the α/β split the blame attribution in
    /// [`crate::explain`] prices exposed waits with.  The two terms must
    /// sum to [`NetworkModel::message_lower_bound`]: stateless wires
    /// split their exact cost into `(α_c, β_c·words)`, stateful wires
    /// split the history-free flight time (LogGP: `2o + L` latency vs.
    /// `(words−1)·G` bandwidth; contended NICs: `α` vs. `β·words`) and
    /// the dropped queueing terms surface as *idle* in the blame walk
    /// (flight time above the state-free cost is queueing, not wire
    /// physics).  Zero-word messages never touch the wire.
    fn message_cost_split(&self, from: u32, to: u32, words: usize) -> (f64, f64) {
        if words == 0 {
            return (0.0, 0.0);
        }
        match self.channel_cost(from, to) {
            Some((a, b)) => (a, b * words as f64),
            None => (0.0, 0.0),
        }
    }
}

/// The classical postal model: every message arrives `α + β·words` after
/// it is posted, regardless of what else is in flight.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlphaBeta {
    pub alpha: f64,
    pub beta: f64,
}

impl AlphaBeta {
    pub fn from_machine(m: &Machine) -> Self {
        AlphaBeta { alpha: m.alpha, beta: m.beta }
    }
}

impl NetworkModel for AlphaBeta {
    fn label(&self) -> &'static str {
        "alphabeta"
    }

    fn deliver(&mut self, _from: u32, _to: u32, words: usize, post: f64) -> f64 {
        // Same association as `Machine::message_time` so the event engine
        // reproduces the legacy simulator bit-for-bit under this model.
        let wire = self.alpha + self.beta * words as f64;
        post + wire
    }

    fn channel_cost(&self, _from: u32, _to: u32) -> Option<(f64, f64)> {
        Some((self.alpha, self.beta))
    }
}

/// The LogGP model (Alexandrov et al.): wire latency `L`, per-end CPU
/// overhead `o`, inter-message injection gap `g` (a sender's NIC accepts
/// at most one message per `g`), and per-word gap `G` for long messages.
///
/// Arrival = `inject + o + L + (words−1)·G + o` where `inject` is the
/// post time delayed behind the sender's previous injection by `g`.
#[derive(Debug, Clone)]
pub struct LogGp {
    pub latency: f64,
    pub overhead: f64,
    pub gap: f64,
    pub per_word_gap: f64,
    next_inject: HashMap<u32, f64>,
}

impl LogGp {
    pub fn new(latency: f64, overhead: f64, gap: f64, per_word_gap: f64) -> Self {
        LogGp { latency, overhead, gap, per_word_gap, next_inject: HashMap::new() }
    }

    /// `L = α`, `G = β` from the machine; `o` and `g` supplied.
    pub fn from_machine(m: &Machine, overhead: f64, gap: f64) -> Self {
        LogGp::new(m.alpha, overhead, gap, m.beta)
    }
}

impl NetworkModel for LogGp {
    fn label(&self) -> &'static str {
        "loggp"
    }

    fn deliver(&mut self, from: u32, _to: u32, words: usize, post: f64) -> f64 {
        let free = self.next_inject.get(&from).copied().unwrap_or(0.0);
        let inject = post.max(free);
        self.next_inject.insert(from, inject + self.gap);
        inject
            + self.overhead
            + self.latency
            + words.saturating_sub(1) as f64 * self.per_word_gap
            + self.overhead
    }

    fn reset(&mut self) {
        self.next_inject.clear();
    }

    fn message_lower_bound(&self, _from: u32, _to: u32, words: usize) -> f64 {
        if words == 0 {
            return 0.0;
        }
        // Drop the injection gap (inject ≥ post always): what remains is
        // the state-free flight time of a single message.
        self.overhead + self.latency + words.saturating_sub(1) as f64 * self.per_word_gap
            + self.overhead
    }

    fn message_cost_split(&self, _from: u32, _to: u32, words: usize) -> (f64, f64) {
        if words == 0 {
            return (0.0, 0.0);
        }
        // Per-message fixed cost (two CPU overheads + flight latency) vs.
        // the per-word streaming term; the injection gap is queueing and
        // is deliberately not here.
        (
            self.overhead + self.latency + self.overhead,
            words.saturating_sub(1) as f64 * self.per_word_gap,
        )
    }
}

/// Two-tier network: processors are grouped onto nodes by an explicit
/// proc→node mapping; messages that stay on a node use the cheap
/// (`intra_alpha`, `intra_beta`) wire, messages that cross nodes pay the
/// full (`inter_alpha`, `inter_beta`).
#[derive(Debug, Clone)]
pub struct Hierarchical {
    /// `node_of[p]` = node hosting processor `p`.
    pub node_of: Vec<u32>,
    pub intra_alpha: f64,
    pub intra_beta: f64,
    pub inter_alpha: f64,
    pub inter_beta: f64,
}

impl Hierarchical {
    /// Contiguous packing: processors `[k·node_size, (k+1)·node_size)`
    /// share node `k`.  Intra-node costs are `intra_factor` of the
    /// machine's α/β.
    pub fn contiguous(m: &Machine, node_size: u32, intra_factor: f64) -> Self {
        let node_size = node_size.max(1);
        Hierarchical::with_node_map(m, (0..m.nprocs).map(|p| p / node_size).collect(), intra_factor)
    }

    /// Explicit proc→node mapping (e.g. from
    /// [`crate::partition::ProcGrid::node_map`], which keeps grid-adjacent
    /// tiles on one node); intra-node costs are `intra_factor` of the
    /// machine's α/β.
    pub fn with_node_map(m: &Machine, node_of: Vec<u32>, intra_factor: f64) -> Self {
        Hierarchical {
            node_of,
            intra_alpha: m.alpha * intra_factor,
            intra_beta: m.beta * intra_factor,
            inter_alpha: m.alpha,
            inter_beta: m.beta,
        }
    }
}

impl NetworkModel for Hierarchical {
    fn label(&self) -> &'static str {
        "hier"
    }

    fn deliver(&mut self, from: u32, to: u32, words: usize, post: f64) -> f64 {
        // Grouped as `post + (α + β·words)` — the same association as
        // `AlphaBeta` and the compiled engine's per-channel fast path, so
        // all three agree bit-for-bit.
        let (a, b) = self.channel_cost(from, to).expect("hierarchical wires are static");
        let wire = a + b * words as f64;
        post + wire
    }

    fn channel_cost(&self, from: u32, to: u32) -> Option<(f64, f64)> {
        let same = self.node_of.get(from as usize) == self.node_of.get(to as usize);
        Some(if same {
            (self.intra_alpha, self.intra_beta)
        } else {
            (self.inter_alpha, self.inter_beta)
        })
    }
}

/// α/β wire with per-NIC serialization: a sender's NIC transmits one
/// message at a time, occupying the link for `β·words`; concurrent sends
/// queue behind it.  Latency α is flight time and overlaps freely.
#[derive(Debug, Clone)]
pub struct Contended {
    pub alpha: f64,
    pub beta: f64,
    nic_free: HashMap<u32, f64>,
}

impl Contended {
    pub fn new(alpha: f64, beta: f64) -> Self {
        Contended { alpha, beta, nic_free: HashMap::new() }
    }

    pub fn from_machine(m: &Machine) -> Self {
        Contended::new(m.alpha, m.beta)
    }
}

impl NetworkModel for Contended {
    fn label(&self) -> &'static str {
        "contended"
    }

    fn deliver(&mut self, from: u32, _to: u32, words: usize, post: f64) -> f64 {
        let occupy = self.beta * words as f64;
        let free = self.nic_free.get(&from).copied().unwrap_or(0.0);
        let start = post.max(free);
        self.nic_free.insert(from, start + occupy);
        start + self.alpha + occupy
    }

    fn reset(&mut self) {
        self.nic_free.clear();
    }

    fn message_lower_bound(&self, _from: u32, _to: u32, words: usize) -> f64 {
        if words == 0 {
            return 0.0;
        }
        // Drop the NIC queue (start ≥ post always): flight time plus the
        // message's own link occupancy remain.
        self.alpha + self.beta * words as f64
    }

    fn message_cost_split(&self, _from: u32, _to: u32, words: usize) -> (f64, f64) {
        if words == 0 {
            return (0.0, 0.0);
        }
        // Flight latency vs. the message's own link occupancy; NIC
        // queueing behind earlier messages is deliberately not here.
        (self.alpha, self.beta * words as f64)
    }
}

/// A cloneable, parseable *description* of a network model — what the
/// [`crate::pipeline::Pipeline`] builder and the sweep grid carry; a
/// fresh stateful [`NetworkModel`] is built per run with
/// [`NetworkKind::build`] (α/β and the processor count come from the
/// [`Machine`] of that run).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum NetworkKind {
    /// [`AlphaBeta`] — the seed simulator's wire (the default).
    #[default]
    AlphaBeta,
    /// [`LogGp`] with `L = α`, `G = β` and these `o`/`g` (γ units).
    LogGp { overhead: f64, gap: f64 },
    /// [`Hierarchical`] with contiguous `node_size`-wide nodes and
    /// intra-node α/β scaled by `intra_factor`.
    Hierarchical { node_size: u32, intra_factor: f64 },
    /// [`Contended`] — per-NIC serialized sends.
    Contended,
}

impl NetworkKind {
    /// The four models at their default parameters — the sweep's network
    /// axis.
    pub fn all_default() -> Vec<NetworkKind> {
        vec![
            NetworkKind::AlphaBeta,
            NetworkKind::LogGp { overhead: 1.0, gap: 2.0 },
            NetworkKind::Hierarchical { node_size: 2, intra_factor: 0.1 },
            NetworkKind::Contended,
        ]
    }

    /// Parse a CLI tag: `alphabeta`, `loggp`, `hier`, `contended` (default
    /// parameters).
    pub fn parse(s: &str) -> Result<NetworkKind, String> {
        match s.trim() {
            "alphabeta" | "ab" => Ok(NetworkKind::AlphaBeta),
            "loggp" => Ok(NetworkKind::LogGp { overhead: 1.0, gap: 2.0 }),
            "hier" | "hierarchical" => {
                Ok(NetworkKind::Hierarchical { node_size: 2, intra_factor: 0.1 })
            }
            "contended" => Ok(NetworkKind::Contended),
            other => Err(format!(
                "unknown network model {other:?} (alphabeta|loggp|hier|contended)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            NetworkKind::AlphaBeta => "alphabeta",
            NetworkKind::LogGp { .. } => "loggp",
            NetworkKind::Hierarchical { .. } => "hier",
            NetworkKind::Contended => "contended",
        }
    }

    /// Parameter-bearing identity string — unlike [`NetworkKind::label`]
    /// it distinguishes two LogGP wires with different gaps, so it is
    /// what the [`crate::tune`] cache keys on.
    pub fn key(&self) -> String {
        match *self {
            NetworkKind::AlphaBeta => "alphabeta".to_string(),
            NetworkKind::LogGp { overhead, gap } => format!("loggp(o={overhead},g={gap})"),
            NetworkKind::Hierarchical { node_size, intra_factor } => {
                format!("hier(node={node_size},intra={intra_factor})")
            }
            NetworkKind::Contended => "contended".to_string(),
        }
    }

    /// Instantiate a fresh model for one simulation run on machine `m`.
    pub fn build(&self, m: &Machine) -> Box<dyn NetworkModel> {
        match *self {
            NetworkKind::AlphaBeta => Box::new(AlphaBeta::from_machine(m)),
            NetworkKind::LogGp { overhead, gap } => {
                Box::new(LogGp::from_machine(m, overhead, gap))
            }
            NetworkKind::Hierarchical { node_size, intra_factor } => {
                Box::new(Hierarchical::contiguous(m, node_size, intra_factor))
            }
            NetworkKind::Contended => Box::new(Contended::from_machine(m)),
        }
    }

    /// [`NetworkKind::build`], layout-aware: a [`Hierarchical`] wire takes
    /// its proc→node mapping from the run's processor grid when the
    /// layout carries one (grid-adjacent tiles share a node), and falls
    /// back to contiguous packing otherwise.  The other wires ignore the
    /// layout — their physics has no node structure.
    pub fn build_for(&self, m: &Machine, layout: Option<&Partitioning>) -> Box<dyn NetworkModel> {
        if let NetworkKind::Hierarchical { node_size, intra_factor } = *self {
            if let Some(Partitioning::Grid(g)) = layout {
                if let Some(node_of) = g.node_map(m.nprocs, node_size) {
                    return Box::new(Hierarchical::with_node_map(m, node_of, intra_factor));
                }
            }
        }
        self.build(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Machine {
        Machine::new(4, 2, 100.0, 0.5, 1.0)
    }

    #[test]
    fn alphabeta_matches_machine_message_time() {
        let mach = m();
        let mut n = AlphaBeta::from_machine(&mach);
        for w in [1usize, 7, 100] {
            assert_eq!(n.deliver(0, 1, w, 3.0), 3.0 + mach.message_time(w));
        }
    }

    #[test]
    fn loggp_injection_gap_serializes_bursts() {
        let mut n = LogGp::new(100.0, 1.0, 10.0, 0.5);
        let a1 = n.deliver(0, 1, 1, 0.0);
        let a2 = n.deliver(0, 2, 1, 0.0); // same sender, same instant
        assert_eq!(a2 - a1, 10.0); // delayed by one gap
        let a3 = n.deliver(3, 2, 1, 0.0); // different sender: no gap
        assert_eq!(a3, a1);
        n.reset();
        assert_eq!(n.deliver(0, 1, 1, 0.0), a1);
    }

    #[test]
    fn hierarchical_intra_cheaper_than_inter() {
        let mut n = Hierarchical::contiguous(&m(), 2, 0.1);
        let intra = n.deliver(0, 1, 4, 0.0); // procs 0,1 share node 0
        let inter = n.deliver(0, 2, 4, 0.0); // proc 2 is on node 1
        assert!(intra < inter, "intra {intra} inter {inter}");
        assert_eq!(inter, 100.0 + 0.5 * 4.0);
    }

    #[test]
    fn contended_serializes_same_nic_only() {
        let mut n = Contended::new(10.0, 2.0);
        let a1 = n.deliver(0, 1, 3, 0.0); // occupies NIC 0 for 6.0
        let a2 = n.deliver(0, 2, 3, 0.0); // queued behind it
        assert_eq!(a1, 10.0 + 6.0);
        assert_eq!(a2, 6.0 + 10.0 + 6.0);
        let b = n.deliver(1, 2, 3, 0.0); // other NIC: unaffected
        assert_eq!(b, a1);
    }

    #[test]
    fn kind_key_carries_parameters() {
        assert_eq!(NetworkKind::AlphaBeta.key(), "alphabeta");
        let a = NetworkKind::LogGp { overhead: 1.0, gap: 2.0 };
        let b = NetworkKind::LogGp { overhead: 1.0, gap: 4.0 };
        assert_ne!(a.key(), b.key());
        assert_eq!(a.label(), b.label());
        assert_eq!(
            NetworkKind::Hierarchical { node_size: 2, intra_factor: 0.1 }.key(),
            "hier(node=2,intra=0.1)"
        );
    }

    #[test]
    fn kind_parse_build_roundtrip() {
        let mach = m();
        for tag in ["alphabeta", "loggp", "hier", "contended"] {
            let kind = NetworkKind::parse(tag).unwrap();
            assert_eq!(kind.label(), tag);
            let mut model = kind.build(&mach);
            let arr = model.deliver(0, 1, 1, 5.0);
            assert!(arr >= 5.0, "{tag}: {arr}");
        }
        assert!(NetworkKind::parse("token-ring").is_err());
    }

    #[test]
    fn build_for_maps_hier_nodes_from_the_grid() {
        use crate::partition::{Partitioning, ProcGrid};
        // 3x3 proc grid, 2-proc nodes.  Grid mapping pairs procs within a
        // proc-grid row ({0,1},{2},{3,4},{5},…), so grid-adjacent 3 and 4
        // share a node while contiguous packing ({2,3},{4,5},…) splits
        // them.
        let mach = Machine::new(9, 2, 100.0, 0.5, 1.0);
        let kind = NetworkKind::Hierarchical { node_size: 2, intra_factor: 0.1 };
        let layout = Partitioning::Grid(ProcGrid::Grid { px: 3, py: 3 });
        let mut gridwise = kind.build_for(&mach, Some(&layout));
        let mut contiguous = kind.build(&mach);
        // 3 → 4: same grid row — intra under the grid mapping only.
        assert!(gridwise.deliver(3, 4, 4, 0.0) < contiguous.deliver(3, 4, 4, 0.0));
        // 0 → 3: different grid rows — inter under both mappings.
        assert_eq!(gridwise.deliver(0, 3, 4, 0.0), contiguous.deliver(0, 3, 4, 0.0));
        // A strip layout reproduces contiguous packing exactly.
        let strip = Partitioning::Grid(ProcGrid::Strip);
        let mut stripwise = kind.build_for(&mach, Some(&strip));
        for (from, to) in [(0u32, 1u32), (2, 3), (4, 8)] {
            assert_eq!(
                stripwise.deliver(from, to, 2, 1.0),
                kind.build(&mach).deliver(from, to, 2, 1.0)
            );
        }
        // Non-hier wires ignore the layout.
        let mut ab = NetworkKind::AlphaBeta.build_for(&mach, Some(&layout));
        assert_eq!(ab.deliver(0, 5, 4, 0.0), 0.0 + 100.0 + 0.5 * 4.0);
    }

    #[test]
    fn channel_cost_agrees_with_deliver_on_static_wires() {
        let mach = m();
        // Static wires resolve constants; stateful ones decline.
        assert!(AlphaBeta::from_machine(&mach).channel_cost(0, 1).is_some());
        assert!(Hierarchical::contiguous(&mach, 2, 0.1).channel_cost(0, 3).is_some());
        assert!(LogGp::from_machine(&mach, 1.0, 2.0).channel_cost(0, 1).is_none());
        assert!(Contended::from_machine(&mach).channel_cost(0, 1).is_none());
        // Where constants exist, `post + (α_c + β_c·words)` is bitwise
        // the `deliver` result — the compiled engine's fast-path contract.
        for kind in NetworkKind::all_default() {
            let mut model = kind.build(&mach);
            for (from, to) in [(0u32, 1u32), (0, 2), (3, 1)] {
                let Some((a, b)) = model.channel_cost(from, to) else { continue };
                for words in [1usize, 7, 100] {
                    let wire = a + b * words as f64;
                    assert_eq!(
                        model.deliver(from, to, words, 2.5),
                        2.5 + wire,
                        "{}: ({from},{to}) x {words}",
                        kind.label()
                    );
                }
            }
        }
    }

    #[test]
    fn message_lower_bound_never_exceeds_deliver() {
        let mach = m();
        for kind in NetworkKind::all_default() {
            let mut model = kind.build(&mach);
            // Burst traffic so the stateful wires accumulate queueing: the
            // bound must stay below every actual delivery regardless.
            for i in 0..30u32 {
                let (from, to) = (i % 4, (i + 1) % 4);
                let words = (i as usize % 5) + 1;
                let post = (i as f64) * 0.25;
                let lb = model.message_lower_bound(from, to, words);
                let arr = model.deliver(from, to, words, post);
                assert!(
                    arr >= post + lb - 1e-12,
                    "{}: deliver {arr} < post {post} + lb {lb}",
                    kind.label()
                );
            }
            // Where per-channel constants resolve, the bound is exact.
            let model = kind.build(&mach);
            if let Some((a, b)) = model.channel_cost(0, 1) {
                assert_eq!(model.message_lower_bound(0, 1, 7), a + b * 7.0);
            }
            // Zero-word messages never touch the wire.
            assert_eq!(model.message_lower_bound(0, 1, 0), 0.0);
        }
    }

    #[test]
    fn message_cost_split_tiles_the_lower_bound() {
        let mach = m();
        for kind in NetworkKind::all_default() {
            let model = kind.build(&mach);
            for words in [1usize, 7, 100] {
                let (lat, bw) = model.message_cost_split(0, 1, words);
                assert!(lat >= 0.0 && bw >= 0.0, "{}", kind.label());
                let lb = model.message_lower_bound(0, 1, words);
                assert!(
                    (lat + bw - lb).abs() <= 1e-12 * lb.max(1.0),
                    "{}: split {lat}+{bw} != lb {lb}",
                    kind.label()
                );
            }
            // Zero-word messages never touch the wire.
            assert_eq!(model.message_cost_split(0, 1, 0), (0.0, 0.0));
        }
        // On static wires the split is the exact engine arithmetic:
        // `(α_c, β_c·words)` bit-for-bit.
        let ab = AlphaBeta::from_machine(&mach);
        assert_eq!(ab.message_cost_split(0, 1, 7), (mach.alpha, mach.beta * 7.0));
    }

    #[test]
    fn arrival_never_precedes_post() {
        let mach = m();
        for kind in NetworkKind::all_default() {
            let mut model = kind.build(&mach);
            let mut post = 0.0;
            for i in 0..20u32 {
                let arr = model.deliver(i % 4, (i + 1) % 4, (i as usize % 5) + 1, post);
                assert!(arr >= post, "{}: {arr} < {post}", kind.label());
                post += 1.5;
            }
        }
    }
}
