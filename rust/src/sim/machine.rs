//! The machine model of paper §2.1/§4: `p` nodes, `t` threads per node,
//! and the classical α/β/γ parameters.

/// Machine parameters.  Times are in arbitrary consistent units; the
/// figures use "γ = 1 op" normalization so runtimes read as op-counts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Machine {
    /// Number of nodes ("MPI processes" in the paper's simulation).
    pub nprocs: u32,
    /// Threads available for the task graph on each node (figure 7/8's
    /// x-axis).
    pub threads: u32,
    /// Message latency α (per message).
    pub alpha: f64,
    /// Per-word transmission time β.
    pub beta: f64,
    /// Time per task execution γ (one `f` evaluation).
    pub gamma: f64,
}

impl Machine {
    pub fn new(nprocs: u32, threads: u32, alpha: f64, beta: f64, gamma: f64) -> Self {
        assert!(nprocs > 0 && threads > 0);
        assert!(alpha >= 0.0 && beta >= 0.0 && gamma > 0.0);
        Machine { nprocs, threads, alpha, beta, gamma }
    }

    /// The paper's figure-7 regime: latency "moderate" relative to an
    /// operation (α of order the block factor × γ) — blocking pays off
    /// only at very high thread counts, where the per-node compute no
    /// longer hides the redundant work.  Matches
    /// [`crate::config::preset_fig7`].
    pub fn moderate_latency(nprocs: u32, threads: u32) -> Self {
        Machine::new(nprocs, threads, 8.0, 0.1, 1.0)
    }

    /// The paper's figure-8 regime: latency ≫ b·γ — blocking pays off
    /// from moderate thread counts.  Matches [`crate::config::preset_fig8`].
    pub fn high_latency(nprocs: u32, threads: u32) -> Self {
        Machine::new(nprocs, threads, 500.0, 0.1, 1.0)
    }

    /// Time to compute `k` unit tasks on this node's thread pool
    /// (list-scheduling bound for independent uniform tasks).
    #[inline]
    pub fn compute_time(&self, k: usize) -> f64 {
        (k as f64 / self.threads as f64).ceil() * self.gamma
    }

    /// Wire time of one `words`-word message under the classical
    /// α+β·words postal model ([`super::AlphaBeta`]); the richer wire
    /// models ([`super::NetworkKind`]) replace this in the event-driven
    /// engine.
    #[inline]
    pub fn message_time(&self, words: usize) -> f64 {
        if words == 0 {
            0.0
        } else {
            self.alpha + self.beta * words as f64
        }
    }

    /// Latency/compute ratio α/γ — the architectural constant that fixes
    /// the optimal block size (paper §2.1).
    pub fn latency_ratio(&self) -> f64 {
        self.alpha / self.gamma
    }

    pub fn with_threads(self, threads: u32) -> Self {
        Machine { threads, ..self }
    }

    pub fn with_alpha(self, alpha: f64) -> Self {
        Machine { alpha, ..self }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_rounds_up_to_thread_waves() {
        let m = Machine::new(2, 4, 0.0, 0.0, 1.0);
        assert_eq!(m.compute_time(0), 0.0);
        assert_eq!(m.compute_time(1), 1.0);
        assert_eq!(m.compute_time(4), 1.0);
        assert_eq!(m.compute_time(5), 2.0);
    }

    #[test]
    fn message_time_zero_for_empty() {
        let m = Machine::new(2, 1, 100.0, 1.0, 1.0);
        assert_eq!(m.message_time(0), 0.0);
        assert_eq!(m.message_time(8), 108.0);
    }

    #[test]
    fn regimes_ordered() {
        let lo = Machine::moderate_latency(4, 8);
        let hi = Machine::high_latency(4, 8);
        assert!(hi.latency_ratio() > lo.latency_ratio());
    }
}
