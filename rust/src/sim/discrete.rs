//! Shared simulator data types and the per-phase list scheduler.
//!
//! The production simulator is the event-driven engine in
//! [`super::engine`]; this module keeps what both engines share — the
//! [`SimResult`] / [`BusySpan`] result types and [`run_compute`], the
//! intra-phase list scheduler — plus, behind `#[cfg(test)]`, the seed
//! repository's original round-robin polling loop, retained verbatim as
//! the *oracle* the engine's equivalence matrix is checked against.

use super::engine::TaskCostModel;
use super::machine::Machine;
use crate::graph::{TaskGraph, TaskId};
use std::collections::{BinaryHeap, HashMap};

/// One busy interval of one thread (for Gantt rendering).
#[derive(Debug, Clone, PartialEq)]
pub struct BusySpan {
    pub proc: u32,
    pub thread: u32,
    pub start: f64,
    pub end: f64,
    /// What the span was: "compute", "wait" (blocked in Recv).
    pub what: &'static str,
}

/// Result of simulating a plan.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Makespan: when the last processor finishes its program.
    pub total_time: f64,
    /// Per-processor finish times.
    pub proc_finish: Vec<f64>,
    /// Per-processor busy (computing) time.
    pub proc_busy: Vec<f64>,
    /// Per-processor time spent blocked in receives.
    pub proc_wait: Vec<f64>,
    /// Messages delivered.
    pub messages: usize,
    /// Words moved.
    pub words: usize,
    /// Thread-level busy spans (only recorded when `trace` is requested).
    pub spans: Vec<BusySpan>,
}

impl SimResult {
    /// Fraction of total machine time spent computing.
    ///
    /// `proc_busy` already sums *thread*-busy time (each task execution
    /// contributes its duration once), so the capacity denominator
    /// `total_time · nprocs · threads` is the whole normalization — the
    /// seed version multiplied the numerator by `threads` again, which
    /// inflated utilization ×t and exceeded 1.0 on saturated runs.
    pub fn utilization(&self, m: &Machine) -> f64 {
        let cap = self.total_time * m.nprocs as f64 * m.threads as f64;
        if cap == 0.0 {
            0.0
        } else {
            self.proc_busy.iter().sum::<f64>() / cap
        }
    }
}

/// List-schedule one compute phase on `m.threads` threads starting at
/// `start`.  Returns (phase end time, total busy thread-time).
///
/// Tasks are visited in `(level, id)` order (a topological order).  Each
/// task starts at `max(latest intra-phase pred finish, earliest free
/// thread)` and runs for `m.gamma · cost.task_cost(g, t)`.  For uniform
/// task costs this matches the optimal level-by-level schedule.  Values
/// produced *outside* the phase — earlier phases on this processor, or
/// received messages — are available from `start` on (phase ordering plus
/// the blocking `Recv` guarantee it), so only intra-phase predecessors
/// are tracked; this also keeps the simulator correct under redundant
/// computation, where the same task id is executed on several processors
/// at different times.
pub(crate) fn run_compute(
    g: &TaskGraph,
    tasks: &[u32],
    m: &Machine,
    start: f64,
    proc: u32,
    cost: &dyn TaskCostModel,
    mut spans: Option<&mut Vec<BusySpan>>,
) -> (f64, f64) {
    let mut order: Vec<u32> = tasks.to_vec();
    order.sort_unstable_by_key(|&t| (g.level(TaskId(t)), t));

    // Finish times of tasks computed in *this* phase only.
    let mut finish: HashMap<u32, f64> = HashMap::with_capacity(order.len());

    // Min-heap of (free_at, thread-id).
    let mut threads: BinaryHeap<std::cmp::Reverse<(u64, u32)>> = (0..m.threads)
        .map(|i| std::cmp::Reverse((to_bits(start), i)))
        .collect();

    let mut busy = 0.0;
    let mut end = start;
    for &t in &order {
        let mut est = start;
        for &pr in g.preds(TaskId(t)) {
            if let Some(&f) = finish.get(&pr) {
                if f > est {
                    est = f;
                }
            }
        }
        let std::cmp::Reverse((free_bits, tid)) = threads.pop().unwrap();
        let free = from_bits(free_bits);
        let s = est.max(free);
        let dur = m.gamma * cost.task_cost(g, TaskId(t));
        let f = s + dur;
        finish.insert(t, f);
        threads.push(std::cmp::Reverse((to_bits(f), tid)));
        busy += dur;
        if f > end {
            end = f;
        }
        if let Some(sp) = spans.as_deref_mut() {
            sp.push(BusySpan { proc, thread: tid, start: s, end: f, what: "compute" });
        }
    }
    (end, busy)
}

// f64 ordering in the heap via monotone bit transform (times are finite
// and non-negative here).
#[inline]
pub(crate) fn to_bits(x: f64) -> u64 {
    debug_assert!(x >= 0.0 && x.is_finite());
    x.to_bits()
}

#[inline]
pub(crate) fn from_bits(b: u64) -> f64 {
    f64::from_bits(b)
}

/// The seed repository's round-robin polling simulator, kept only as the
/// oracle for the event-driven engine's equivalence matrix.  O(rounds ×
/// procs × phases) — every round re-scans all processors — which is why
/// it was replaced; its *semantics* are the contract the engine must
/// reproduce bit-for-bit.  Two accounting fixes are applied here as in
/// the engine: delivered messages are drained from the channel map, and
/// zero-word sends (which cost `message_time(0) = 0` on the wire) are not
/// counted as messages.
#[cfg(test)]
pub(crate) fn polling_simulate(
    g: &TaskGraph,
    plan: &super::plan::ExecPlan,
    m: &Machine,
    record_spans: bool,
) -> SimResult {
    use super::engine::UniformCost;
    use super::plan::Phase;

    assert_eq!(plan.per_proc.len(), m.nprocs as usize, "plan/machine proc count mismatch");
    let nprocs = plan.per_proc.len();

    // Message channel: (from, to, seq) -> arrival time; entries are
    // removed when the matching Recv consumes them.
    let mut in_flight: HashMap<(u32, u32, u32), f64> = HashMap::new();
    let mut send_seq: HashMap<(u32, u32), u32> = HashMap::new();
    let mut recv_seq: HashMap<(u32, u32), u32> = HashMap::new();

    let mut clock = vec![0.0f64; nprocs];
    let mut busy = vec![0.0f64; nprocs];
    let mut wait = vec![0.0f64; nprocs];
    let mut cursor = vec![0usize; nprocs];
    let mut spans: Vec<BusySpan> = Vec::new();
    let mut messages = 0usize;
    let mut words = 0usize;

    loop {
        let mut progressed = false;
        for p in 0..nprocs {
            while cursor[p] < plan.per_proc[p].phases.len() {
                let phase = &plan.per_proc[p].phases[cursor[p]];
                match phase {
                    Phase::Compute(tasks) => {
                        let (end, b) = run_compute(
                            g,
                            tasks,
                            m,
                            clock[p],
                            p as u32,
                            &UniformCost,
                            record_spans.then_some(&mut spans),
                        );
                        busy[p] += b;
                        clock[p] = end;
                    }
                    Phase::Send { to, tasks } => {
                        let seq = send_seq.entry((p as u32, to.0)).or_insert(0);
                        let arrival = clock[p] + m.message_time(tasks.len());
                        in_flight.insert((p as u32, to.0, *seq), arrival);
                        *seq += 1;
                        if !tasks.is_empty() {
                            messages += 1;
                            words += tasks.len();
                        }
                    }
                    Phase::Recv { from, tasks } => {
                        let seq = *recv_seq.entry((from.0, p as u32)).or_insert(0);
                        let Some(arrival) = in_flight.remove(&(from.0, p as u32, seq)) else {
                            break; // sender not there yet — try another proc
                        };
                        recv_seq.insert((from.0, p as u32), seq + 1);
                        if arrival > clock[p] {
                            wait[p] += arrival - clock[p];
                            if record_spans {
                                spans.push(BusySpan {
                                    proc: p as u32,
                                    thread: 0,
                                    start: clock[p],
                                    end: arrival,
                                    what: "wait",
                                });
                            }
                            clock[p] = arrival;
                        }
                        // Received values are available from `clock[p]` on;
                        // the blocking wait above is all the timing needed
                        // (later phases treat them as phase-start inputs).
                        let _ = tasks;
                    }
                }
                cursor[p] += 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }

    // All cursors must have completed — otherwise the plan deadlocked.
    for p in 0..nprocs {
        assert_eq!(
            cursor[p],
            plan.per_proc[p].phases.len(),
            "plan deadlocked on p{p} at phase {}",
            cursor[p]
        );
    }

    SimResult {
        total_time: clock.iter().copied().fold(0.0, f64::max),
        proc_finish: clock,
        proc_busy: busy,
        proc_wait: wait,
        messages,
        words,
        spans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::ExecPlan;
    use crate::sim::simulate;
    use crate::stencil::heat1d_graph;

    #[test]
    fn utilization_bounded() {
        let g = heat1d_graph(64, 4, 4);
        let plan = ExecPlan::naive(&g);
        let mach = Machine::new(4, 2, 10.0, 0.0, 1.0);
        let r = simulate(&g, &plan, &mach, false);
        let u = r.utilization(&mach);
        assert!(u > 0.0 && u <= 1.0, "{u}");
    }

    #[test]
    fn utilization_regression_alpha_zero_saturated() {
        // One processor, zero latency: every thread is busy the whole run,
        // so a correct utilization is exactly 1.0.  The seed formula
        // multiplied the summed thread-busy time by `threads` again and
        // reported t (= 4.0 here).
        let threads = 4u32;
        let g = heat1d_graph(64, 4, 1);
        let plan = ExecPlan::naive(&g);
        let mach = Machine::new(1, threads, 0.0, 0.0, 1.0);
        let r = simulate(&g, &plan, &mach, false);
        let u = r.utilization(&mach);
        assert!((u - 1.0).abs() < 1e-12, "{u}");
        let cap = r.total_time * mach.nprocs as f64 * mach.threads as f64;
        let seed_formula = r.proc_busy.iter().sum::<f64>() * threads as f64 / cap;
        assert!((seed_formula - threads as f64).abs() < 1e-12, "{seed_formula}");
    }

    #[test]
    fn utilization_zero_time_is_zero() {
        let r = SimResult {
            total_time: 0.0,
            proc_finish: vec![0.0],
            proc_busy: vec![0.0],
            proc_wait: vec![0.0],
            messages: 0,
            words: 0,
            spans: Vec::new(),
        };
        assert_eq!(r.utilization(&Machine::new(1, 4, 0.0, 0.0, 1.0)), 0.0);
    }
}
