//! Discrete-event simulation of an [`ExecPlan`] on a [`Machine`].
//!
//! This is the re-implementation of the paper's §4 simulator (the original
//! lived in the IMP demo repository's `pocs/avoid`, now unavailable).  It
//! executes the plan's phases per processor, list-scheduling each
//! `Compute` phase's tasks onto the node's `t` threads while honouring
//! intra-phase dependence edges, and models every message as arriving
//! `α + β·words` after it is posted.
//!
//! The engine advances processors round-robin; a `Recv` blocks until the
//! matching `Send` has executed on the peer, so the loop terminates for
//! every deadlock-free plan (all plans built by [`super::plan`] are —
//! sends always precede the matching receive's level/superstep).

use super::machine::Machine;
use super::plan::{ExecPlan, Phase};
use crate::graph::{TaskGraph, TaskId};
use std::collections::{BinaryHeap, HashMap};

/// One busy interval of one thread (for Gantt rendering).
#[derive(Debug, Clone, PartialEq)]
pub struct BusySpan {
    pub proc: u32,
    pub thread: u32,
    pub start: f64,
    pub end: f64,
    /// What the span was: "compute", "wait" (blocked in Recv).
    pub what: &'static str,
}

/// Result of simulating a plan.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Makespan: when the last processor finishes its program.
    pub total_time: f64,
    /// Per-processor finish times.
    pub proc_finish: Vec<f64>,
    /// Per-processor busy (computing) time.
    pub proc_busy: Vec<f64>,
    /// Per-processor time spent blocked in receives.
    pub proc_wait: Vec<f64>,
    /// Messages delivered.
    pub messages: usize,
    /// Words moved.
    pub words: usize,
    /// Thread-level busy spans (only recorded when `trace` is requested).
    pub spans: Vec<BusySpan>,
}

impl SimResult {
    /// Fraction of total machine time spent computing.
    pub fn utilization(&self, m: &Machine) -> f64 {
        let cap = self.total_time * m.nprocs as f64 * m.threads as f64;
        if cap == 0.0 {
            0.0
        } else {
            self.proc_busy.iter().sum::<f64>() * m.threads as f64 / cap
        }
    }
}

/// Simulate `plan` for graph `g` on machine `m`.
///
/// `record_spans` controls whether per-thread Gantt spans are collected
/// (costly for large runs).
pub fn simulate(g: &TaskGraph, plan: &ExecPlan, m: &Machine, record_spans: bool) -> SimResult {
    assert_eq!(plan.per_proc.len(), m.nprocs as usize, "plan/machine proc count mismatch");
    let nprocs = plan.per_proc.len();

    // Message channel: (from, to, first-task-id) -> arrival time.  The
    // first id disambiguates multiple messages on the same edge; plans
    // never post two messages with identical (from, to, head) pairs
    // because task sets differ per level/superstep.
    let mut in_flight: HashMap<(u32, u32, u32), f64> = HashMap::new();
    let mut send_seq: HashMap<(u32, u32), u32> = HashMap::new();
    let mut recv_seq: HashMap<(u32, u32), u32> = HashMap::new();

    let mut clock = vec![0.0f64; nprocs];
    let mut busy = vec![0.0f64; nprocs];
    let mut wait = vec![0.0f64; nprocs];
    let mut cursor = vec![0usize; nprocs];
    let mut spans: Vec<BusySpan> = Vec::new();
    let mut messages = 0usize;
    let mut words = 0usize;

    loop {
        let mut progressed = false;
        for p in 0..nprocs {
            while cursor[p] < plan.per_proc[p].phases.len() {
                let phase = &plan.per_proc[p].phases[cursor[p]];
                match phase {
                    Phase::Compute(tasks) => {
                        let (end, b) = run_compute(
                            g,
                            tasks,
                            m,
                            clock[p],
                            p as u32,
                            record_spans.then_some(&mut spans),
                        );
                        busy[p] += b;
                        clock[p] = end;
                    }
                    Phase::Send { to, tasks } => {
                        let seq = send_seq.entry((p as u32, to.0)).or_insert(0);
                        let arrival = clock[p] + m.message_time(tasks.len());
                        in_flight.insert((p as u32, to.0, *seq), arrival);
                        *seq += 1;
                        messages += 1;
                        words += tasks.len();
                    }
                    Phase::Recv { from, tasks } => {
                        let seq = *recv_seq.entry((from.0, p as u32)).or_insert(0);
                        let Some(&arrival) = in_flight.get(&(from.0, p as u32, seq)) else {
                            break; // sender not there yet — try another proc
                        };
                        recv_seq.insert((from.0, p as u32), seq + 1);
                        if arrival > clock[p] {
                            wait[p] += arrival - clock[p];
                            if record_spans {
                                spans.push(BusySpan {
                                    proc: p as u32,
                                    thread: 0,
                                    start: clock[p],
                                    end: arrival,
                                    what: "wait",
                                });
                            }
                            clock[p] = arrival;
                        }
                        // Received values are available from `clock[p]` on;
                        // the blocking wait above is all the timing needed
                        // (later phases treat them as phase-start inputs).
                        let _ = tasks;
                    }
                }
                cursor[p] += 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }

    // All cursors must have completed — otherwise the plan deadlocked.
    for p in 0..nprocs {
        assert_eq!(
            cursor[p],
            plan.per_proc[p].phases.len(),
            "plan deadlocked on p{p} at phase {}",
            cursor[p]
        );
    }

    SimResult {
        total_time: clock.iter().copied().fold(0.0, f64::max),
        proc_finish: clock,
        proc_busy: busy,
        proc_wait: wait,
        messages,
        words,
        spans,
    }
}

/// List-schedule one compute phase on `m.threads` threads starting at
/// `start`.  Returns (phase end time, total busy thread-time).
///
/// Tasks are visited in `(level, id)` order (a topological order).  Each
/// task starts at `max(latest intra-phase pred finish, earliest free
/// thread)`.  For uniform task costs this matches the optimal
/// level-by-level schedule.  Values produced *outside* the phase —
/// earlier phases on this processor, or received messages — are available
/// from `start` on (phase ordering plus the blocking `Recv` guarantee
/// it), so only intra-phase predecessors are tracked; this also keeps the
/// simulator correct under redundant computation, where the same task id
/// is executed on several processors at different times.
fn run_compute(
    g: &TaskGraph,
    tasks: &[u32],
    m: &Machine,
    start: f64,
    proc: u32,
    mut spans: Option<&mut Vec<BusySpan>>,
) -> (f64, f64) {
    let mut order: Vec<u32> = tasks.to_vec();
    order.sort_unstable_by_key(|&t| (g.level(TaskId(t)), t));

    // Finish times of tasks computed in *this* phase only.
    let mut finish: HashMap<u32, f64> = HashMap::with_capacity(order.len());

    // Min-heap of (free_at, thread-id).
    let mut threads: BinaryHeap<std::cmp::Reverse<(u64, u32)>> = (0..m.threads)
        .map(|i| std::cmp::Reverse((to_bits(start), i)))
        .collect();

    let mut busy = 0.0;
    let mut end = start;
    for &t in &order {
        let mut est = start;
        for &pr in g.preds(TaskId(t)) {
            if let Some(&f) = finish.get(&pr) {
                if f > est {
                    est = f;
                }
            }
        }
        let std::cmp::Reverse((free_bits, tid)) = threads.pop().unwrap();
        let free = from_bits(free_bits);
        let s = est.max(free);
        let f = s + m.gamma;
        finish.insert(t, f);
        threads.push(std::cmp::Reverse((to_bits(f), tid)));
        busy += m.gamma;
        if f > end {
            end = f;
        }
        if let Some(sp) = spans.as_deref_mut() {
            sp.push(BusySpan { proc, thread: tid, start: s, end: f, what: "compute" });
        }
    }
    (end, busy)
}

// f64 ordering in the heap via monotone bit transform (times are finite
// and non-negative here).
#[inline]
fn to_bits(x: f64) -> u64 {
    debug_assert!(x >= 0.0 && x.is_finite());
    x.to_bits()
}

#[inline]
fn from_bits(b: u64) -> f64 {
    f64::from_bits(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::ExecPlan;
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    fn m(nprocs: u32, threads: u32, alpha: f64) -> Machine {
        Machine::new(nprocs, threads, alpha, 0.0, 1.0)
    }

    #[test]
    fn single_proc_naive_time_is_levels_times_waves() {
        // 8 points, 1 proc, 2 threads: each level = ceil(8/2) = 4γ.
        let g = heat1d_graph(8, 3, 1);
        let plan = ExecPlan::naive(&g);
        let r = simulate(&g, &plan, &m(1, 2, 100.0), false);
        assert_eq!(r.total_time, 3.0 * 4.0);
        assert_eq!(r.messages, 0);
    }

    #[test]
    fn zero_latency_naive_matches_ideal() {
        let g = heat1d_graph(16, 4, 2);
        let plan = ExecPlan::naive(&g);
        let r = simulate(&g, &plan, &m(2, 8, 0.0), false);
        // 8 points/proc, 8 threads → 1γ per level, 4 levels.
        assert_eq!(r.total_time, 4.0);
    }

    #[test]
    fn latency_adds_per_level_for_naive() {
        let g = heat1d_graph(16, 4, 2);
        let plan = ExecPlan::naive(&g);
        let alpha = 50.0;
        let r = simulate(&g, &plan, &m(2, 8, alpha), false);
        // Levels 2..4 wait for the (level−1)-value message that was posted
        // after the previous level's compute; level 1's inputs are initial
        // data sent at time 0... every level still pays α on the critical
        // path because compute (1γ) ≪ α.
        assert!(r.total_time >= 3.0 * alpha, "{}", r.total_time);
        assert!(r.total_time <= 4.0 * (alpha + 1.0) + 4.0, "{}", r.total_time);
    }

    #[test]
    fn ca_single_superstep_pays_latency_once() {
        let g = heat1d_graph(16, 4, 2);
        let naive = ExecPlan::naive(&g);
        let ca = ExecPlan::ca(&g, 4, TransformOptions::default()).unwrap();
        let mach = m(2, 8, 50.0);
        let rn = simulate(&g, &naive, &mach, false);
        let rc = simulate(&g, &ca, &mach, false);
        assert!(
            rc.total_time < rn.total_time / 2.0,
            "ca {} vs naive {}",
            rc.total_time,
            rn.total_time
        );
    }

    #[test]
    fn overlap_beats_naive_with_latency() {
        let g = heat1d_graph(256, 8, 2);
        let mach = m(2, 1, 60.0);
        let rn = simulate(&g, &ExecPlan::naive(&g), &mach, false);
        let ro = simulate(&g, &ExecPlan::overlap(&g), &mach, false);
        // With 128 points/proc on one thread, the interior compute
        // (≈126γ) hides the 60-unit latency entirely.
        assert!(ro.total_time < rn.total_time, "overlap {} naive {}", ro.total_time, rn.total_time);
    }

    #[test]
    fn work_conservation() {
        let g = heat1d_graph(32, 4, 4);
        for plan in [
            ExecPlan::naive(&g),
            ExecPlan::overlap(&g),
            ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap(),
        ] {
            let r = simulate(&g, &plan, &m(4, 2, 10.0), false);
            let total_busy: f64 = r.proc_busy.iter().sum();
            assert!(
                (total_busy - plan.executed_tasks() as f64).abs() < 1e-9,
                "{}: busy {} vs tasks {}",
                plan.label,
                total_busy,
                plan.executed_tasks()
            );
        }
    }

    #[test]
    fn times_monotone_and_finite() {
        let g = heat1d_graph(24, 3, 3);
        let plan = ExecPlan::ca(&g, 3, TransformOptions::default()).unwrap();
        let r = simulate(&g, &plan, &m(3, 2, 5.0), true);
        assert!(r.total_time.is_finite() && r.total_time > 0.0);
        for s in &r.spans {
            assert!(s.end >= s.start);
            assert!(s.start >= 0.0);
        }
    }

    #[test]
    fn more_threads_never_slower() {
        let g = heat1d_graph(64, 8, 2);
        let plan = ExecPlan::naive(&g);
        let t1 = simulate(&g, &plan, &m(2, 1, 10.0), false).total_time;
        let t4 = simulate(&g, &plan, &m(2, 4, 10.0), false).total_time;
        let t16 = simulate(&g, &plan, &m(2, 16, 10.0), false).total_time;
        assert!(t4 <= t1 && t16 <= t4);
    }

    #[test]
    fn utilization_bounded() {
        let g = heat1d_graph(64, 4, 4);
        let plan = ExecPlan::naive(&g);
        let mach = m(4, 2, 10.0);
        let r = simulate(&g, &plan, &mach, false);
        let u = r.utilization(&mach);
        assert!(u > 0.0 && u <= 1.0, "{u}");
    }
}
