//! Parallel parameter sweeps over the event-driven engine — the machinery
//! behind the `sweep` CLI subcommand and the figure-7/8 data files.
//!
//! A sweep is a cartesian grid: prepared `(workload, strategy)` inputs ×
//! network models × α values × thread counts.  Each input's plan is
//! lowered once into a [`CompiledPlan`] ([`SweepInput::new`]); cells are
//! independent simulations of that compiled form, so they fan out across
//! `std::thread` workers pulling from a shared atomic counter — each
//! worker reusing one [`EngineScratch`] across all its cells — and
//! results come back in deterministic grid order regardless of
//! scheduling.  [`to_json`] / [`to_csv`] render the cells as figure data.

use super::compile::{simulate_compiled, CompiledPlan, EngineScratch};
use super::engine::TaskCostModel;
use super::machine::Machine;
use super::network::NetworkKind;
use super::plan::ExecPlan;
use crate::chaos::{FaultConfig, JitterWire};
use crate::graph::TaskGraph;
use crate::partition::Partitioning;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// One prepared (workload, strategy) pair: the graph, plan, and its
/// compiled form are built **once** (see [`SweepInput::new`]) and shared
/// read-only across every cell and worker thread.  Labels are interned
/// `Arc<str>` so a 10k-cell grid clones refcounts, not strings.
#[derive(Clone)]
pub struct SweepInput {
    /// Workload tag ("heat1d", "cg", ...).
    pub workload: Arc<str>,
    /// Strategy label ("naive", "overlap", "ca(b=4)").
    pub strategy: Arc<str>,
    pub graph: Arc<TaskGraph>,
    pub plan: Arc<ExecPlan>,
    /// The plan lowered once per (plan, cost model) — what every cell
    /// actually simulates ([`super::simulate_compiled`]).
    pub compiled: Arc<CompiledPlan>,
    /// Per-task cost model (the workload's hint; already baked into
    /// `compiled`, carried for the interpreting oracle and re-compiles).
    pub cost: Arc<dyn TaskCostModel>,
    /// Words per transmitted value (scales β).
    pub words_per_value: usize,
    /// Data layout the plan was derived from (`None` for hand-built
    /// inputs); a Hierarchical wire maps procs onto nodes grid-aware
    /// ([`NetworkKind::build_for`]).
    pub layout: Option<Partitioning>,
    /// Fault scenario this input was prepared under (`None` = clean).
    /// The compute half is already baked into `compiled` via
    /// [`crate::chaos::PerturbedCost`]; the wire half makes every cell
    /// wrap its network in a [`JitterWire`] so perturbed runs stay
    /// seed-deterministic per cell.  Set by
    /// [`crate::chaos::perturb_input`], never by [`SweepInput::new`].
    pub fault: Option<FaultConfig>,
}

impl SweepInput {
    /// Prepare one input: compiles the plan under `cost` exactly once;
    /// every grid cell (and every tuner evaluation of this candidate)
    /// then simulates the compiled form.
    pub fn new(
        workload: impl Into<Arc<str>>,
        strategy: impl Into<Arc<str>>,
        graph: Arc<TaskGraph>,
        plan: Arc<ExecPlan>,
        cost: Arc<dyn TaskCostModel>,
        words_per_value: usize,
        layout: Option<Partitioning>,
    ) -> SweepInput {
        let compiled = Arc::new(CompiledPlan::compile(&graph, &plan, cost.as_ref()));
        SweepInput {
            workload: workload.into(),
            strategy: strategy.into(),
            graph,
            plan,
            compiled,
            cost,
            words_per_value,
            layout,
            fault: None,
        }
    }
}

/// The sweep grid: `inputs × networks × alphas × threads` cells.
pub struct SweepGrid {
    pub inputs: Vec<SweepInput>,
    pub networks: Vec<NetworkKind>,
    pub alphas: Vec<f64>,
    pub threads: Vec<u32>,
    pub beta: f64,
    pub gamma: f64,
    /// Worker threads; 0 = one per available core.
    pub jobs: usize,
}

impl SweepGrid {
    pub fn num_cells(&self) -> usize {
        self.inputs.len() * self.networks.len() * self.alphas.len() * self.threads.len()
    }
}

/// One simulated grid cell.  Labels share the input's interned
/// `Arc<str>`s (and the wire model's static tag) instead of cloning
/// fresh `String`s per cell.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepCell {
    pub workload: Arc<str>,
    pub strategy: Arc<str>,
    pub network: &'static str,
    pub procs: u32,
    pub alpha: f64,
    pub threads: u32,
    /// Simulated makespan (γ units).
    pub makespan: f64,
    pub messages: usize,
    pub words: usize,
    /// Fraction of machine capacity spent computing (≤ 1).
    pub utilization: f64,
    /// Wall-clock seconds the simulation itself took.
    pub sim_wall_secs: f64,
}

fn eval_cell(
    grid: &SweepGrid,
    i: usize,
    scratch: &mut EngineScratch,
) -> Result<SweepCell, String> {
    let (nt, na, nn) = (grid.threads.len(), grid.alphas.len(), grid.networks.len());
    let threads = grid.threads[i % nt];
    let alpha = grid.alphas[(i / nt) % na];
    let kind = grid.networks[(i / (nt * na)) % nn];
    let input = &grid.inputs[i / (nt * na * nn)];
    let procs = input.plan.per_proc.len() as u32;
    let mach = Machine::new(
        procs,
        threads,
        alpha,
        grid.beta * input.words_per_value as f64,
        grid.gamma,
    );
    let mut net = kind.build_for(&mach, input.layout.as_ref());
    if let Some(fault) = &input.fault {
        // Wire faults ride as a decorator per cell: the wrap keeps the
        // draw counters private to this cell, so parallel workers and
        // repeated evaluations see identical jitter streams.
        net = JitterWire::wrap(net, fault);
    }
    let t0 = std::time::Instant::now();
    let r = simulate_compiled(&input.compiled, &mach, net.as_mut(), scratch, false).map_err(
        |e| {
            format!(
                "{}/{}/{}/α={alpha}/t={threads}: {e}",
                input.workload,
                input.strategy,
                kind.label()
            )
        },
    )?;
    Ok(SweepCell {
        workload: Arc::clone(&input.workload),
        strategy: Arc::clone(&input.strategy),
        network: kind.label(),
        procs,
        alpha,
        threads,
        makespan: r.total_time,
        messages: r.messages,
        words: r.words,
        utilization: r.utilization(&mach),
        sim_wall_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Outcome of a stop-flag-aware sweep ([`run_with_stop`]).
#[derive(Debug)]
pub enum SweepRun {
    /// Every cell was evaluated.
    Complete(Vec<SweepCell>),
    /// The stop flag was raised mid-sweep: `cells` holds the cells that
    /// finished (grid order, with gaps) so partial results can still be
    /// flushed on SIGINT/SIGTERM.
    Interrupted { cells: Vec<SweepCell>, completed: usize, total: usize },
}

impl SweepRun {
    /// The evaluated cells, complete or not.
    pub fn cells(self) -> Vec<SweepCell> {
        match self {
            SweepRun::Complete(cells) => cells,
            SweepRun::Interrupted { cells, .. } => cells,
        }
    }
}

/// Best-effort human-readable message out of a caught panic payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Label a cell for error messages without touching the engine (cell
/// construction itself may be what panicked).
fn cell_tag(grid: &SweepGrid, i: usize) -> String {
    let (nt, na, nn) = (grid.threads.len(), grid.alphas.len(), grid.networks.len());
    let input = &grid.inputs[i / (nt * na * nn)];
    format!(
        "{}/{}/{}/α={}/t={}",
        input.workload,
        input.strategy,
        grid.networks[(i / (nt * na)) % nn].label(),
        grid.alphas[(i / nt) % na],
        grid.threads[i % nt],
    )
}

/// Run every cell of the grid, fanned across worker threads.  Cells come
/// back in grid order (inputs outermost, threads innermost) independent
/// of scheduling; any deadlocked or panicking cell fails the sweep with
/// its tag (a panic is caught per cell — it cannot strand the other
/// workers on the shared counter or take down a long-running daemon).
pub fn run(grid: &SweepGrid) -> Result<Vec<SweepCell>, String> {
    static NEVER: AtomicBool = AtomicBool::new(false);
    match run_with_stop(grid, &NEVER)? {
        SweepRun::Complete(cells) => Ok(cells),
        SweepRun::Interrupted { .. } => unreachable!("stop flag is never set"),
    }
}

/// [`run`], but checking `stop` between cells: raising the flag (e.g.
/// from a SIGINT handler) drains the workers and returns the cells that
/// already finished instead of discarding them.
pub fn run_with_stop(grid: &SweepGrid, stop: &AtomicBool) -> Result<SweepRun, String> {
    let total = grid.num_cells();
    if total == 0 {
        return Ok(SweepRun::Complete(Vec::new()));
    }
    let jobs = if grid.jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        grid.jobs
    }
    .clamp(1, total);

    let next = AtomicUsize::new(0);
    let mut cells: Vec<(usize, SweepCell)> = Vec::with_capacity(total);
    let mut errors: Vec<String> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                s.spawn(|| {
                    let mut local: Vec<(usize, SweepCell)> = Vec::new();
                    let mut errs: Vec<String> = Vec::new();
                    // One scratch per worker, reused across all its
                    // cells: after the first cell the engine's event
                    // loop runs allocation-free.
                    let mut scratch = EngineScratch::new();
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= total {
                            break;
                        }
                        // A panicking cell (bad machine parameters, a
                        // buggy cost model) must not unwind through the
                        // worker: the other workers would keep pulling
                        // from the counter while the scope waits forever
                        // on a thread that already died.  Catch it, fail
                        // the cell, keep draining.
                        match catch_unwind(AssertUnwindSafe(|| eval_cell(grid, i, &mut scratch))) {
                            Ok(Ok(c)) => local.push((i, c)),
                            Ok(Err(e)) => errs.push(e),
                            Err(payload) => {
                                errs.push(format!(
                                    "{}: cell panicked: {}",
                                    cell_tag(grid, i),
                                    panic_message(payload.as_ref())
                                ));
                                // The unwound cell may have left the
                                // scratch mid-update; start clean.
                                scratch = EngineScratch::new();
                            }
                        }
                    }
                    (local, errs)
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok((local, errs)) => {
                    cells.extend(local);
                    errors.extend(errs);
                }
                // Unreachable now that cells catch their own unwinds,
                // but a dead worker must degrade to an error, not abort
                // the whole process from inside a daemon.
                Err(payload) => {
                    errors.push(format!("sweep worker died: {}", panic_message(payload.as_ref())))
                }
            }
        }
    });
    if !errors.is_empty() {
        return Err(errors.join("; "));
    }
    cells.sort_by_key(|&(i, _)| i);
    let completed = cells.len();
    let cells: Vec<SweepCell> = cells.into_iter().map(|(_, c)| c).collect();
    if completed < total {
        Ok(SweepRun::Interrupted { cells, completed, total })
    } else {
        Ok(SweepRun::Complete(cells))
    }
}

/// Render cells as a JSON document: `{"sweep": tag, "cells": [...]}`.
pub fn to_json(tag: &str, cells: &[SweepCell]) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"sweep\": {tag:?},\n  \"cells\": [\n"));
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": {:?}, \"strategy\": {:?}, \"network\": {:?}, \
             \"procs\": {}, \"alpha\": {}, \"threads\": {}, \"makespan\": {}, \
             \"messages\": {}, \"words\": {}, \"utilization\": {}, \
             \"sim_wall_secs\": {}}}{}",
            c.workload,
            c.strategy,
            c.network,
            c.procs,
            c.alpha,
            c.threads,
            c.makespan,
            c.messages,
            c.words,
            c.utilization,
            c.sim_wall_secs,
            if i + 1 == cells.len() { "" } else { "," }
        ));
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}

/// Render cells as CSV (one row per cell).
pub fn to_csv(cells: &[SweepCell]) -> String {
    let mut s = String::from(
        "workload,strategy,network,procs,alpha,threads,makespan,messages,words,utilization,sim_wall_secs\n",
    );
    for c in cells {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{}\n",
            c.workload,
            c.strategy,
            c.network,
            c.procs,
            c.alpha,
            c.threads,
            c.makespan,
            c.messages,
            c.words,
            c.utilization,
            c.sim_wall_secs,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::UniformCost;
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    fn inputs() -> Vec<SweepInput> {
        let g = Arc::new(heat1d_graph(32, 4, 2));
        let naive = Arc::new(ExecPlan::naive(&g));
        let ca = Arc::new(ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap());
        vec![
            SweepInput::new(
                "heat1d",
                naive.label.clone(),
                Arc::clone(&g),
                naive,
                Arc::new(UniformCost),
                1,
                None,
            ),
            SweepInput::new("heat1d", ca.label.clone(), g, ca, Arc::new(UniformCost), 1, None),
        ]
    }

    fn grid(jobs: usize) -> SweepGrid {
        SweepGrid {
            inputs: inputs(),
            networks: NetworkKind::all_default(),
            alphas: vec![1.0, 100.0],
            threads: vec![1, 4],
            beta: 0.1,
            gamma: 1.0,
            jobs,
        }
    }

    #[test]
    fn covers_grid_deterministically_and_bounds_utilization() {
        let g3 = grid(3);
        let cells = run(&g3).unwrap();
        assert_eq!(cells.len(), g3.num_cells());
        assert_eq!(cells.len(), 2 * 4 * 2 * 2);
        for c in &cells {
            assert!(c.makespan.is_finite() && c.makespan > 0.0, "{c:?}");
            assert!(c.utilization > 0.0 && c.utilization <= 1.0 + 1e-12, "{c:?}");
            assert!(c.messages > 0 && c.words > 0, "{c:?}");
        }
        // Parallel scheduling must not change results or order.
        let serial: Vec<SweepCell> = run(&grid(1)).unwrap();
        let key = |c: &SweepCell| {
            (c.workload.clone(), c.strategy.clone(), c.network.clone(), c.threads)
        };
        assert_eq!(
            cells.iter().map(key).collect::<Vec<_>>(),
            serial.iter().map(key).collect::<Vec<_>>()
        );
        for (a, b) in cells.iter().zip(&serial) {
            assert_eq!(a.makespan, b.makespan, "{a:?} vs {b:?}");
            assert_eq!(a.messages, b.messages);
        }
    }

    #[test]
    fn grid_order_is_inputs_networks_alphas_threads() {
        let cells = run(&grid(2)).unwrap();
        // Innermost axis: threads; then alpha; then network; then input.
        assert_eq!(cells[0].threads, 1);
        assert_eq!(cells[1].threads, 4);
        assert_eq!(cells[0].alpha, 1.0);
        assert_eq!(cells[2].alpha, 100.0);
        assert_eq!(cells[0].network, "alphabeta");
        assert_eq!(cells[4].network, "loggp");
        assert_eq!(&*cells[0].strategy, "naive");
        assert_eq!(&*cells[16].strategy, "ca(b=2)");
    }

    #[test]
    fn alphabeta_cell_matches_direct_simulation() {
        let g = grid(2);
        let cells = run(&g).unwrap();
        let input = &g.inputs[0];
        let mach = Machine::new(2, 4, 100.0, 0.1, 1.0);
        let direct = crate::sim::simulate(&input.graph, &input.plan, &mach, false);
        let cell = cells
            .iter()
            .find(|c| {
                &*c.strategy == "naive"
                    && c.network == "alphabeta"
                    && c.alpha == 100.0
                    && c.threads == 4
            })
            .unwrap();
        assert_eq!(cell.makespan, direct.total_time);
        assert_eq!(cell.messages, direct.messages);
        assert_eq!(cell.words, direct.words);
    }

    #[test]
    fn json_and_csv_shapes() {
        let cells = run(&SweepGrid {
            inputs: inputs(),
            networks: vec![NetworkKind::AlphaBeta],
            alphas: vec![8.0],
            threads: vec![2],
            beta: 0.1,
            gamma: 1.0,
            jobs: 1,
        })
        .unwrap();
        let json = to_json("smoke", &cells);
        assert!(json.contains("\"sweep\": \"smoke\""));
        assert!(json.contains("\"workload\": \"heat1d\""));
        assert!(json.contains("\"makespan\":"));
        assert!(json.contains("\"utilization\":"));
        // Each cell is one line; no trailing comma before the closing `]`.
        assert_eq!(json.matches("\"workload\"").count(), cells.len());
        assert!(!json.contains("},\n  ]"));
        let csv = to_csv(&cells);
        assert!(csv.starts_with("workload,strategy,network,procs,alpha,"));
        assert_eq!(csv.lines().count(), cells.len() + 1);
    }

    #[test]
    fn cells_share_interned_labels_and_compiled_plans() {
        let g = grid(1);
        let cells = run(&g).unwrap();
        // Labels are refcount clones of the input's interned strings —
        // no per-cell String allocation.
        assert!(Arc::ptr_eq(&cells[0].workload, &g.inputs[0].workload));
        assert!(Arc::ptr_eq(&cells[0].strategy, &g.inputs[0].strategy));
        // And preparing the input compiled the plan exactly once, up
        // front: cloning the input shares it.
        let clone = g.inputs[0].clone();
        assert!(Arc::ptr_eq(&clone.compiled, &g.inputs[0].compiled));
    }

    #[test]
    fn panicking_cell_fails_the_sweep_instead_of_hanging() {
        // threads=0 trips Machine::new's assert inside the worker
        // thread — exactly the shape of panic that used to strand the
        // pool on the work-stealing counter (the join unwound, the
        // remaining cells were never collected, and callers saw a
        // process abort instead of an error).
        let g = SweepGrid {
            inputs: inputs(),
            networks: vec![NetworkKind::AlphaBeta],
            alphas: vec![8.0],
            threads: vec![0, 2],
            beta: 0.1,
            gamma: 1.0,
            jobs: 2,
        };
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence expected unwind reports
        let err = run(&g).unwrap_err();
        std::panic::set_hook(hook);
        assert!(err.contains("panicked"), "panic must surface as an error: {err}");
        assert!(err.contains("heat1d"), "error must name the failing cell: {err}");
        assert!(err.contains("t=0"), "error must carry the cell axes: {err}");
    }

    #[test]
    fn stop_flag_returns_partial_results() {
        let g = grid(1);
        let stop = AtomicBool::new(true); // raised before the sweep starts
        match run_with_stop(&g, &stop).unwrap() {
            SweepRun::Interrupted { cells, completed, total } => {
                assert_eq!(total, g.num_cells());
                assert!(completed < total);
                assert_eq!(cells.len(), completed);
            }
            SweepRun::Complete(_) => panic!("a pre-raised stop flag must interrupt the sweep"),
        }
        // Unset flag: identical to run().
        let stop = AtomicBool::new(false);
        match run_with_stop(&g, &stop).unwrap() {
            SweepRun::Complete(cells) => assert_eq!(cells.len(), g.num_cells()),
            SweepRun::Interrupted { .. } => panic!("nothing raised the flag"),
        }
    }

    #[test]
    fn empty_grid_is_empty() {
        let g = SweepGrid {
            inputs: Vec::new(),
            networks: vec![NetworkKind::AlphaBeta],
            alphas: vec![1.0],
            threads: vec![1],
            beta: 0.0,
            gamma: 1.0,
            jobs: 0,
        };
        assert_eq!(run(&g).unwrap().len(), 0);
    }
}
