//! Execution plans: the phase-by-phase program each processor runs.
//!
//! A plan is the *operational* form of a schedule — the common input
//! format for both the discrete-event simulator ([`super::discrete`]) and
//! the real coordinator ([`crate::coordinator`]).  Three builders cover
//! the paper's three strategies:
//!
//! * [`ExecPlan::naive`] — per-level halo exchange, no overlap (the
//!   baseline of §4's simulation);
//! * [`ExecPlan::overlap`] — paper figure 2 / the PETSc split: post the
//!   sends, compute the interior while messages fly, then the boundary;
//! * [`ExecPlan::ca`] — the §3 transformation applied per superstep of
//!   `b` levels.

use crate::graph::{ProcId, TaskGraph, TaskId, TaskKind};
use crate::transform::{
    check_schedule, communication_avoiding, superstep_graphs, CaSchedule, TransformOptions,
};

/// One step in a processor's program.
#[derive(Debug, Clone, PartialEq)]
pub enum Phase {
    /// Execute these tasks (original-graph ids, pre-sorted topologically
    /// by `(level, id)`).  Dependencies *within* the list are honoured by
    /// the simulator/coordinator; dependencies on earlier phases are
    /// implicit in phase order.
    Compute(Vec<u32>),
    /// Post a message to `to` carrying the outputs of `tasks`
    /// (non-blocking; the values are available from earlier phases).
    Send { to: ProcId, tasks: Vec<u32> },
    /// Block until the message from `from` carrying `tasks` has arrived.
    Recv { from: ProcId, tasks: Vec<u32> },
}

/// Per-processor phase program.
#[derive(Debug, Clone, Default)]
pub struct ProcPlan {
    pub phases: Vec<Phase>,
}

/// A whole-machine execution plan.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub per_proc: Vec<ProcPlan>,
    /// Human-readable strategy tag ("naive", "overlap", "ca(b=4)").
    pub label: String,
}

impl ExecPlan {
    /// Total tasks executed across all processors (counts redundant work).
    pub fn executed_tasks(&self) -> usize {
        self.per_proc
            .iter()
            .flat_map(|p| &p.phases)
            .map(|ph| match ph {
                Phase::Compute(ts) => ts.len(),
                _ => 0,
            })
            .sum()
    }

    /// Total messages posted.  Zero-word sends are excluded — they cost
    /// nothing on the wire and the simulator does not count them either
    /// (plans built by this module never emit them; the filter keeps the
    /// static accounting consistent for hand-built plans too).
    pub fn messages(&self) -> usize {
        self.per_proc
            .iter()
            .flat_map(|p| &p.phases)
            .filter(|ph| matches!(ph, Phase::Send { tasks, .. } if !tasks.is_empty()))
            .count()
    }

    /// Total words sent.
    pub fn words(&self) -> usize {
        self.per_proc
            .iter()
            .flat_map(|p| &p.phases)
            .map(|ph| match ph {
                Phase::Send { tasks, .. } => tasks.len(),
                _ => 0,
            })
            .sum()
    }

    /// Naive per-level execution: for every level, send the boundary
    /// values just computed, wait for the mirror receives, then compute
    /// the level.  No overlap: receives precede all of the level's work.
    pub fn naive(g: &TaskGraph) -> ExecPlan {
        build_levelwise(g, false, "naive")
    }

    /// Figure-2 overlap: sends posted first, the interior (tasks with all
    /// preds local) computed while messages fly, boundary tasks after the
    /// receives.
    pub fn overlap(g: &TaskGraph) -> ExecPlan {
        build_levelwise(g, true, "overlap")
    }

    /// The paper's communication-avoiding plan: slice `g` into supersteps
    /// of `b` levels, transform each (§3), and emit
    /// `L1 → sends → L2 → recvs → L3` per superstep.
    pub fn ca(g: &TaskGraph, b: u32, options: TransformOptions) -> Result<ExecPlan, String> {
        Self::ca_impl(g, b, options, false)
    }

    /// [`ExecPlan::ca`] with the Theorem-1 checker run on every superstep
    /// schedule as it is built — the paranoid path the [`crate::pipeline`]
    /// builder uses, so an ill-formed schedule surfaces as an error at
    /// transform time instead of a panic (or silent corruption) at
    /// execution time.
    pub fn ca_checked(g: &TaskGraph, b: u32, options: TransformOptions) -> Result<ExecPlan, String> {
        Self::ca_impl(g, b, options, true)
    }

    fn ca_impl(
        g: &TaskGraph,
        b: u32,
        options: TransformOptions,
        check: bool,
    ) -> Result<ExecPlan, String> {
        let mut per_proc = vec![ProcPlan::default(); g.num_procs() as usize];
        for ss in superstep_graphs(g, b)? {
            let schedule = communication_avoiding(&ss.graph, options);
            if check {
                check_schedule(&ss.graph, &schedule)
                    .map_err(|v| format!("superstep levels [{}, {}]: {v}", ss.lo, ss.hi))?;
            }
            append_ca_superstep(&mut per_proc, &schedule, &ss.orig);
        }
        Ok(ExecPlan { per_proc, label: format!("ca(b={b})") })
    }

    /// A CA plan from an already-computed schedule of a single-superstep
    /// graph (ids are the graph's own).
    pub fn from_schedule(s: &CaSchedule) -> ExecPlan {
        let mut per_proc = vec![ProcPlan::default(); s.per_proc.len()];
        append_ca(&mut per_proc, s, None);
        ExecPlan { per_proc, label: "ca".into() }
    }
}

fn append_ca_superstep(per_proc: &mut [ProcPlan], s: &CaSchedule, orig: &[u32]) {
    append_ca(per_proc, s, Some(orig));
}

fn append_ca(per_proc: &mut [ProcPlan], s: &CaSchedule, orig: Option<&[u32]>) {
    let map = |ts: &[u32]| -> Vec<u32> {
        match orig {
            Some(o) => ts.iter().map(|&t| o[t as usize]).collect(),
            None => ts.to_vec(),
        }
    };
    for ps in &s.per_proc {
        let plan = &mut per_proc[ps.proc.idx()];
        if !ps.l1.is_empty() {
            plan.phases.push(Phase::Compute(map(&ps.l1)));
        }
        for m in &ps.send {
            plan.phases.push(Phase::Send { to: m.peer, tasks: map(&m.tasks) });
        }
        if !ps.l2.is_empty() {
            plan.phases.push(Phase::Compute(map(&ps.l2)));
        }
        for m in &ps.recv {
            plan.phases.push(Phase::Recv { from: m.peer, tasks: map(&m.tasks) });
        }
        if !ps.l3.is_empty() {
            plan.phases.push(Phase::Compute(map(&ps.l3)));
        }
    }
}

/// Shared builder for the two level-wise strategies.
fn build_levelwise(g: &TaskGraph, overlap: bool, label: &str) -> ExecPlan {
    let nprocs = g.num_procs() as usize;
    let nlevels = g.num_levels();
    let mut per_proc = vec![ProcPlan::default(); nprocs];

    // tasks_by_proc_level[p][l] = owned tasks of p at level l.
    let mut by_pl: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); nlevels as usize]; nprocs];
    for t in g.tasks() {
        by_pl[g.owner(t).idx()][g.level(t) as usize].push(t.0);
    }

    for lvl in 1..nlevels {
        // Cross-processor values consumed at this level:
        // crossings[(from, to)] = sorted pred ids.
        let mut crossings: std::collections::BTreeMap<(u32, u32), Vec<u32>> =
            std::collections::BTreeMap::new();
        for t in g.tasks() {
            if g.level(t) != lvl || g.kind(t) != TaskKind::Compute {
                continue;
            }
            let to = g.owner(t).0;
            for &pr in g.preds(t) {
                let from = g.owner(TaskId(pr)).0;
                if from != to {
                    crossings.entry((from, to)).or_default().push(pr);
                }
            }
        }
        for v in crossings.values_mut() {
            v.sort_unstable();
            v.dedup();
        }

        for p in 0..nprocs {
            let plan = &mut per_proc[p];
            // Post this level's sends (values from level lvl−1, already
            // computed or initial).
            for ((from, to), vals) in &crossings {
                if *from == p as u32 {
                    plan.phases
                        .push(Phase::Send { to: ProcId(*to), tasks: vals.clone() });
                }
            }
            let mine = &by_pl[p][lvl as usize];
            if overlap {
                // Interior first (all preds owned locally), then receives,
                // then the boundary tasks.
                let (interior, boundary): (Vec<u32>, Vec<u32>) =
                    mine.iter().partition(|&&t| {
                        g.preds(TaskId(t))
                            .iter()
                            .all(|&pr| g.owner(TaskId(pr)).0 == p as u32)
                    });
                if !interior.is_empty() {
                    plan.phases.push(Phase::Compute(interior));
                }
                for ((from, to), vals) in &crossings {
                    if *to == p as u32 {
                        plan.phases
                            .push(Phase::Recv { from: ProcId(*from), tasks: vals.clone() });
                    }
                }
                if !boundary.is_empty() {
                    plan.phases.push(Phase::Compute(boundary));
                }
            } else {
                // Naive: all receives, then the whole level.
                for ((from, to), vals) in &crossings {
                    if *to == p as u32 {
                        plan.phases
                            .push(Phase::Recv { from: ProcId(*from), tasks: vals.clone() });
                    }
                }
                if !mine.is_empty() {
                    plan.phases.push(Phase::Compute(mine.clone()));
                }
            }
        }
    }
    ExecPlan { per_proc, label: label.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::heat1d_graph;

    #[test]
    fn naive_plan_message_count() {
        // 2 procs, 3 levels: one crossing each way per level = 6 sends.
        let g = heat1d_graph(16, 3, 2);
        let plan = ExecPlan::naive(&g);
        assert_eq!(plan.messages(), 6);
        assert_eq!(plan.executed_tasks(), g.num_compute_tasks());
    }

    #[test]
    fn overlap_plan_interleaves_interior() {
        let g = heat1d_graph(16, 2, 2);
        let plan = ExecPlan::overlap(&g);
        // p0's phases per level: Send, Compute(interior), Recv, Compute(boundary)
        let p0 = &plan.per_proc[0];
        assert!(matches!(p0.phases[0], Phase::Send { .. }));
        assert!(matches!(p0.phases[1], Phase::Compute(_)));
        assert!(matches!(p0.phases[2], Phase::Recv { .. }));
        assert!(matches!(p0.phases[3], Phase::Compute(_)));
        assert_eq!(plan.executed_tasks(), g.num_compute_tasks());
    }

    #[test]
    fn ca_plan_message_count_scales_with_supersteps() {
        let g = heat1d_graph(32, 8, 2);
        let p1 = ExecPlan::ca(&g, 8, TransformOptions::default()).unwrap();
        let p2 = ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap();
        // One superstep: 2 messages; four supersteps: 8.
        assert_eq!(p1.messages(), 2);
        assert_eq!(p2.messages(), 8);
    }

    #[test]
    fn ca_plan_has_redundant_tasks() {
        let g = heat1d_graph(32, 4, 4);
        let plan = ExecPlan::ca(&g, 4, TransformOptions::level0()).unwrap();
        assert!(plan.executed_tasks() > g.num_compute_tasks());
    }

    #[test]
    fn ca_plan_ids_are_original() {
        let g = heat1d_graph(16, 4, 2);
        let plan = ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap();
        let max_id = g.len() as u32;
        for pp in &plan.per_proc {
            for ph in &pp.phases {
                let ts = match ph {
                    Phase::Compute(t) | Phase::Send { tasks: t, .. } | Phase::Recv { tasks: t, .. } => t,
                };
                assert!(ts.iter().all(|&t| t < max_id));
            }
        }
    }

    #[test]
    fn ca_checked_builds_the_same_plan() {
        let g = heat1d_graph(32, 4, 2);
        let unchecked = ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap();
        let checked = ExecPlan::ca_checked(&g, 2, TransformOptions::default()).unwrap();
        assert_eq!(unchecked.messages(), checked.messages());
        assert_eq!(unchecked.executed_tasks(), checked.executed_tasks());
        assert_eq!(unchecked.words(), checked.words());
    }

    #[test]
    fn zero_word_sends_not_counted() {
        let mut plan = ExecPlan { per_proc: vec![ProcPlan::default(); 2], label: "t".into() };
        plan.per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![] });
        plan.per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![3, 4] });
        assert_eq!(plan.messages(), 1);
        assert_eq!(plan.words(), 2);
    }

    #[test]
    fn naive_vs_ca_words() {
        // CA with Level0Only sends b ghost points once per superstep;
        // naive sends 1 point per level.  Words comparable, messages fewer.
        let g = heat1d_graph(64, 8, 2);
        let naive = ExecPlan::naive(&g);
        let ca = ExecPlan::ca(&g, 8, TransformOptions::level0()).unwrap();
        assert!(ca.messages() < naive.messages());
    }
}
