//! The §2.1 analytic cost model.
//!
//! For the 1-D 3-point stencil, `N` points over `p` processors, `M` update
//! steps blocked `b` at a time, the paper derives
//!
//! ```text
//! T(b) = (M/b)·α + M·β + (MN/p + M·b)·γ
//! ```
//!
//! with two observations this module mechanizes and the tests verify
//! against the simulator:
//!
//! 1. the overhead `αM/b + γMb` is independent of `p`;
//! 2. the optimal block factor `b* = sqrt(α/γ)` depends only on the
//!    architecture, not on the problem (`N`, `M`) or the machine size `p`.

use crate::sim::Machine;

/// The blocked-stencil cost model with explicit parameters.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Points to update.
    pub n: u64,
    /// Update steps.
    pub m: u32,
    /// Processors.
    pub p: u32,
    /// Latency per message.
    pub alpha: f64,
    /// Transmission time per point.
    pub beta: f64,
    /// Time per point update.
    pub gamma: f64,
}

impl CostModel {
    pub fn new(n: u64, m: u32, p: u32, alpha: f64, beta: f64, gamma: f64) -> Self {
        assert!(p > 0 && m > 0 && gamma > 0.0);
        CostModel { n, m, p, alpha, beta, gamma }
    }

    pub fn from_machine(n: u64, m: u32, mach: &Machine) -> Self {
        // The per-node thread pool divides the γ work term: an effective
        // per-point cost of γ/t (the §4 simulation's knob).
        CostModel::new(
            n,
            m,
            mach.nprocs,
            mach.alpha,
            mach.beta,
            mach.gamma / mach.threads as f64,
        )
    }

    /// `T(b)` — the paper's total cost at block factor `b`.
    pub fn cost(&self, b: u32) -> f64 {
        assert!(b > 0);
        let mf = self.m as f64;
        let bf = b as f64;
        (mf / bf) * self.alpha
            + mf * self.beta
            + (mf * self.n as f64 / self.p as f64 + mf * bf) * self.gamma
    }

    /// The blocking overhead `αM/b + γMb` (everything that is not the
    /// ideal `MN/p·γ + Mβ`).  Independent of `p` — asserted in tests.
    pub fn overhead(&self, b: u32) -> f64 {
        let mf = self.m as f64;
        let bf = b as f64;
        mf / bf * self.alpha + mf * bf * self.gamma
    }

    /// Continuous optimizer: `b* = sqrt(α/γ)` — architecture-only.
    pub fn optimal_b_continuous(&self) -> f64 {
        (self.alpha / self.gamma).sqrt()
    }

    /// Discrete optimizer over `1..=max_b` (what an autotuner would pick).
    pub fn optimal_b(&self, max_b: u32) -> u32 {
        (1..=max_b)
            .min_by(|&a, &b| self.cost(a).partial_cmp(&self.cost(b)).unwrap())
            .unwrap()
    }

    /// Speedup of blocking at `b` over the unblocked `b = 1` execution.
    pub fn speedup(&self, b: u32) -> f64 {
        self.cost(1) / self.cost(b)
    }

    /// The latency below which blocking at `b` stops paying: solves
    /// `T(b) = T(1)` for α, i.e. `α_xover = γ·b` (from
    /// `αM(1 − 1/b) = γM(b − 1)`).
    pub fn crossover_alpha(&self, b: u32) -> f64 {
        assert!(b > 1);
        self.gamma * b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::new(1 << 16, 128, 16, 300.0, 0.2, 1.0)
    }

    #[test]
    fn cost_decomposition() {
        let c = model();
        let ideal = c.m as f64 * (c.n as f64 / c.p as f64) * c.gamma + c.m as f64 * c.beta;
        for b in [1u32, 2, 5, 17] {
            assert!((c.cost(b) - (ideal + c.overhead(b))).abs() < 1e-6);
        }
    }

    #[test]
    fn overhead_independent_of_p() {
        for p in [1u32, 4, 64, 1024] {
            let c = CostModel::new(1 << 16, 128, p, 300.0, 0.2, 1.0);
            assert_eq!(c.overhead(8), model().overhead(8));
        }
    }

    #[test]
    fn optimal_b_is_sqrt_alpha_over_gamma() {
        let c = model();
        let cont = c.optimal_b_continuous(); // sqrt(300) ≈ 17.32
        let disc = c.optimal_b(256);
        assert!((cont - 17.32).abs() < 0.01);
        assert!(disc == 17 || disc == 18, "{disc}");
    }

    #[test]
    fn optimal_b_independent_of_problem_and_p() {
        let base = model().optimal_b(256);
        for (n, m, p) in [(1u64 << 10, 16u32, 2u32), (1 << 20, 512, 256)] {
            let c = CostModel::new(n, m, p, 300.0, 0.2, 1.0);
            assert_eq!(c.optimal_b(256), base, "n={n} m={m} p={p}");
        }
    }

    #[test]
    fn optimal_b_scales_with_latency() {
        let lo = CostModel::new(1 << 16, 128, 16, 25.0, 0.2, 1.0);
        let hi = CostModel::new(1 << 16, 128, 16, 2500.0, 0.2, 1.0);
        assert_eq!(lo.optimal_b(256), 5);
        assert_eq!(hi.optimal_b(256), 50);
    }

    #[test]
    fn speedup_above_one_when_latency_dominates() {
        let c = CostModel::new(1 << 12, 64, 64, 1000.0, 0.1, 1.0);
        assert!(c.speedup(16) > 1.0);
    }

    #[test]
    fn no_speedup_without_latency() {
        let c = CostModel::new(1 << 12, 64, 4, 0.0, 0.1, 1.0);
        // With α = 0 blocking only adds redundant work.
        assert!(c.speedup(8) < 1.0);
        assert_eq!(c.optimal_b(64), 1);
    }

    #[test]
    fn crossover_alpha_consistent() {
        let c = model();
        let b = 8;
        let ax = c.crossover_alpha(b);
        let at = CostModel { alpha: ax, ..c };
        assert!((at.cost(b) - at.cost(1)).abs() < 1e-6);
        // Slightly above: blocking wins; slightly below: loses.
        let hi = CostModel { alpha: ax * 1.1, ..c };
        assert!(hi.cost(b) < hi.cost(1));
        let lo = CostModel { alpha: ax * 0.9, ..c };
        assert!(lo.cost(b) > lo.cost(1));
    }

    #[test]
    fn from_machine_divides_gamma_by_threads() {
        let mach = Machine::new(4, 8, 100.0, 0.1, 1.0);
        let c = CostModel::from_machine(1024, 32, &mach);
        assert!((c.gamma - 0.125).abs() < 1e-12);
    }
}
