//! Regeneration of every figure in the paper.
//!
//! | figure | content | function |
//! |---|---|---|
//! | 1 | blocked multi-step update, width-`b` ghost (Level0Only) | [`fig1`] |
//! | 2 | overlap of halo communication with local compute | [`fig2`] |
//! | 3 | multi-level halo (less redundant work) | [`fig3`] |
//! | 4 | the `L^(k)` subsets of one processor | [`fig4`] |
//! | 5 | communicated sets (sent `L^(0)`/`L^(1)`, received halo) | [`fig5`] |
//! | 6 | k₁/k₂/k₃ sets for a 1-D heat-equation processor | [`fig6`] |
//! | 7 | runtime vs. threads/node, moderate latency | [`fig78_sweep`] |
//! | 8 | runtime vs. threads/node, high latency | [`fig78_sweep`] |
//! | 9 | tuned vs. fixed-b vs. naive makespan per wire model (beyond the paper) | [`fig9_tuned`] |
//! | 10 | SpMV partition quality (edge-cut words) vs. makespan per wire model (beyond the paper) | [`fig10_partition`] |
//!
//! Figures 1–6 are structural (the paper draws diagrams; we render the
//! *computed* sets as ASCII grids, which doubles as a check that the
//! transformation produces the shapes the paper draws).  Figures 7/8 are
//! the simulation study; the benches write their CSVs via these functions.
//! Figure 9 extends the study with the [`crate::tune`] autotuner: it shows
//! where the §2.1 machine-constant `b*` stops being optimal once the wire
//! stops being the ideal α/β model.

use crate::config::{parse_list, Config};
use crate::partition::{banded_random, Partitioner, Partitioning, PartitionQuality};
use crate::pipeline::{strategy_sweep_inputs, Heat1d, Pipeline, Spmv};
use crate::sim::{ca_time_for, naive_time_1d, overlap_time_1d, sweep, Machine, NetworkKind};
use crate::stencil::heat1d_graph;
use crate::trace::FigureSeries;
use crate::transform::{CaSchedule, ScheduleStats, TransformOptions};
use crate::tune::{Tuner, TuningSpace};
use std::sync::Arc;

/// The figures' common front end: run a 1-D heat problem through the
/// [`Pipeline`] and return the graph plus the whole-graph §3 schedule
/// whose subsets they render.
///
/// The plan built inside `transform()` derives the same schedule once
/// more than strictly necessary; figure graphs are tiny (tens of points,
/// single-digit levels), so the uniform Pipeline front end wins over the
/// saved microseconds.  Checking happens once, on the schedule returned.
///
/// A bad figure configuration (too few points per processor, zero
/// steps, ...) surfaces as a structured error the CLI prints, not a
/// panic.
fn heat1d_schedule(
    n: u64,
    m: u32,
    p: u32,
    options: TransformOptions,
) -> Result<(Arc<crate::graph::TaskGraph>, CaSchedule), String> {
    let t = Pipeline::new(Heat1d { n, steps: m, radius: 1 })
        .procs(p)
        .options(options)
        .skip_check()
        .transform()
        .map_err(|e| format!("figure configuration {n}x{m} on {p} procs: {e}"))?;
    let s = t
        .full_schedule()
        .ok_or_else(|| format!("figure configuration {n}x{m} on {p} procs has no CA schedule"))?;
    crate::transform::check_schedule(&t.graph, &s)
        .map_err(|e| format!("figure schedule {n}x{m} on {p} procs violates Theorem 1: {e}"))?;
    Ok((t.graph, s))
}

/// Render the (point × level) membership of one processor's subsets as an
/// ASCII grid.  Rows are levels (top = latest), columns are grid points;
/// the glyph shows which subset a task belongs to on processor `proc`.
///
/// Glyphs: `0` = L⁰ (input), `1/2/3` = L¹/L²/L³, `r` = received,
/// `.` = not touched by this processor.
pub fn subset_grid(n: u64, m: u32, _p: u32, proc: u32, s: &CaSchedule) -> String {
    let sets = &s.per_proc[proc as usize];
    let id = |point: u64, level: u32| (level as u64 * n + point) as u32;
    let glyph = |t: u32| -> char {
        let has = |v: &Vec<u32>| v.binary_search(&t).is_ok();
        if has(&sets.l1) {
            '1'
        } else if has(&sets.l2) {
            '2'
        } else if has(&sets.l3) {
            '3'
        } else if has(&sets.l0) {
            '0'
        } else if sets.recv.iter().any(|msg| msg.tasks.binary_search(&t).is_ok()) {
            'r'
        } else {
            '.'
        }
    };
    let mut out = String::new();
    for level in (0..=m).rev() {
        out.push_str(&format!("lvl {level:>2} |"));
        for point in 0..n {
            out.push(glyph(id(point, level)));
        }
        out.push_str("|\n");
    }
    out
}

/// Figure 1: the blocked update with a width-`b` level-0 ghost region and
/// fully redundant intermediate recomputation (HaloMode::Level0Only).
pub fn fig1(n: u64, b: u32, p: u32) -> Result<String, String> {
    let (g, s) = heat1d_schedule(n, b, p, TransformOptions::level0())?;
    let stats = ScheduleStats::compute(&g, &s);
    let mut out = format!(
        "Figure 1 — blocked computation, {n} points × {b} steps on {p} procs (level-0 halo)\n\
         middle processor's sets ('0' input, '2' local, '3' recomputed-after-recv, 'r' received):\n"
    );
    out.push_str(&subset_grid(n, b, p, p / 2, &s));
    out.push_str(&format!(
        "ghost width = {b} (received level-0 points per side), redundant tasks = {}\n",
        stats.redundant_tasks
    ));
    Ok(out)
}

/// Figure 2: the overlap schedule — what each phase contains and what the
/// message flight hides.
pub fn fig2(n: u64, b: u32, p: u32) -> Result<String, String> {
    let (_, s) = heat1d_schedule(n, b, p, TransformOptions::default())?;
    let sets = &s.per_proc[(p / 2) as usize];
    Ok(format!(
        "Figure 2 — overlap of communication and computation ({n}×{b} on {p} procs)\n\
         phase 1: compute L1 ({} tasks) and post sends ({} msgs)\n\
         phase 2: compute L2 ({} tasks)  ← the {} in-flight messages hide behind this\n\
         phase 3: after receives, compute L3 ({} tasks)\n",
        sets.l1.len(),
        sets.send.len(),
        sets.l2.len(),
        sets.recv.len(),
        sets.l3.len(),
    ))
}

/// Figure 3: the multi-level halo — intermediate-level values travel, so
/// less is recomputed than under the level-0 scheme.
pub fn fig3(n: u64, b: u32, p: u32) -> Result<String, String> {
    let (g, multi) = heat1d_schedule(n, b, p, TransformOptions::default())?;
    let (_, lvl0) = heat1d_schedule(n, b, p, TransformOptions::level0())?;
    let sm = ScheduleStats::compute(&g, &multi);
    let s0 = ScheduleStats::compute(&g, &lvl0);
    let mut out = format!(
        "Figure 3 — multi-level halo ({n}×{b} on {p} procs)\n\
         middle processor ('1' sent-early, '2' local, '3' after-recv, 'r' received):\n"
    );
    out.push_str(&subset_grid(n, b, p, p / 2, &multi));
    out.push_str(&format!(
        "redundant work: level-0 halo {} tasks  →  multi-level halo {} tasks\n\
         words moved:   level-0 halo {}        →  multi-level halo {}\n",
        s0.redundant_tasks, sm.redundant_tasks, s0.words, sm.words
    ));
    Ok(out)
}

/// Figure 4: full subset listing of one processor.
pub fn fig4(n: u64, m: u32, p: u32) -> Result<String, String> {
    let (_, s) = heat1d_schedule(n, m, p, TransformOptions::default())?;
    let sets = &s.per_proc[(p / 2) as usize];
    let fmt_set = |name: &str, v: &Vec<u32>| {
        format!("  {name:<5} ({:>4} tasks): {}\n", v.len(), preview(v))
    };
    let mut out = format!("Figure 4 — subsets of processor {} ({n}×{m} on {p} procs)\n", p / 2);
    out.push_str(&fmt_set("L(0)", &sets.l0));
    out.push_str(&fmt_set("L(1)", &sets.l1));
    out.push_str(&fmt_set("L(2)", &sets.l2));
    out.push_str(&fmt_set("L(3)", &sets.l3));
    out.push_str(&fmt_set("L(4)", &sets.l4));
    out.push_str(&fmt_set("L(5)", &sets.l5));
    Ok(out)
}

/// Figure 5: the communicated sets — what is sent (parts of L⁰ and L¹)
/// and what is received, per processor pair.
pub fn fig5(n: u64, m: u32, p: u32) -> Result<String, String> {
    let (_, s) = heat1d_schedule(n, m, p, TransformOptions::default())?;
    let mut out = format!("Figure 5 — communicated sets ({n}×{m} on {p} procs)\n");
    for ps in &s.per_proc {
        for msg in &ps.send {
            let inputs =
                msg.tasks.iter().filter(|&&t| ps.l0.binary_search(&t).is_ok()).count();
            out.push_str(&format!(
                "  {} → {}: {:>3} values ({} from L(0), {} from L(1)): {}\n",
                ps.proc,
                msg.peer,
                msg.tasks.len(),
                inputs,
                msg.tasks.len() - inputs,
                preview(&msg.tasks)
            ));
        }
    }
    Ok(out)
}

/// Figure 6 data: the k₁/k₂/k₃ set sizes for a middle processor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fig6Data {
    pub k1: usize,
    pub k2: usize,
    pub k3: usize,
    pub received: usize,
    pub redundant: usize,
}

/// Figure 6: the k₁/k₂/k₃ sets for a processor doing a 1-D heat equation.
pub fn fig6(n: u64, m: u32, p: u32) -> Result<(String, Fig6Data), String> {
    let (g, s) = heat1d_schedule(n, m, p, TransformOptions::default())?;
    let proc = p / 2;
    let sets = &s.per_proc[proc as usize];
    let mut out = format!(
        "Figure 6 — k1/k2/k3 sets, processor {proc} of a 1-D heat equation ({n}×{m} on {p} procs)\n"
    );
    out.push_str(&subset_grid(n, m, p, proc, &s));
    let owned: usize = g.owned_by(crate::graph::ProcId(proc)).len()
        - sets.l0.len();
    let data = Fig6Data {
        k1: sets.l1.len(),
        k2: sets.l2.len(),
        k3: sets.l3.len(),
        received: sets.recv.iter().map(|m| m.tasks.len()).sum(),
        redundant: sets.computed().saturating_sub(owned),
    };
    out.push_str(&format!(
        "k1 = {} (computed first, sent)   k2 = {} (overlaps comms)   k3 = {} (after recv)\n\
         received {} values; {} redundant task executions on this processor\n",
        data.k1, data.k2, data.k3, data.received, data.redundant
    ));
    Ok((out, data))
}

/// The figure-7/8 sweep: strong-scaling runtime vs. threads per node.
/// Series: naive, overlap, and CA at each configured block factor.
///
/// `cfg` keys: `n, m, p, alpha, beta, gamma, threads, blocks` (see
/// [`crate::config::preset_fig7`]).
pub fn fig78_sweep(cfg: &Config) -> Result<FigureSeries, String> {
    let n: u64 = cfg.require("n")?;
    let m: u32 = cfg.require("m")?;
    let p: u32 = cfg.require("p")?;
    let alpha: f64 = cfg.require("alpha")?;
    let beta: f64 = cfg.require("beta")?;
    let gamma: f64 = cfg.require("gamma")?;
    let threads: Vec<u32> = parse_list(cfg.require::<String>("threads")?.as_str())?;
    let blocks: Vec<u32> = parse_list(cfg.require::<String>("blocks")?.as_str())?;

    let labels: Vec<String> = std::iter::once("naive".to_string())
        .chain(std::iter::once("overlap".to_string()))
        .chain(blocks.iter().map(|b| format!("ca_b{b}")))
        .collect();
    let label_refs: Vec<&str> = labels.iter().map(String::as_str).collect();
    let mut fig = FigureSeries::new("threads", &label_refs);

    let g = heat1d_graph(n, m, p);
    for &t in &threads {
        let mach = Machine::new(p, t, alpha, beta, gamma);
        let mut ys = vec![naive_time_1d(n, m, &mach), overlap_time_1d(n, m, &mach)];
        for &b in &blocks {
            ys.push(ca_time_for(&g, b, TransformOptions::default(), &mach));
        }
        fig.push(t as f64, ys);
    }
    Ok(fig)
}

/// The figure-7/8 sweep on the event-driven engine: the same series as
/// [`fig78_sweep`] — naive, overlap, CA per block factor vs. threads per
/// node — but each point is a full discrete simulation under `network`
/// (the analytic path cannot express LogGP gaps, hierarchy, or NIC
/// contention).  Cells fan out across the [`sweep`] worker pool.
pub fn fig78_sweep_sim(cfg: &Config, network: NetworkKind) -> Result<FigureSeries, String> {
    let n: u64 = cfg.require("n")?;
    let m: u32 = cfg.require("m")?;
    let p: u32 = cfg.require("p")?;
    let alpha: f64 = cfg.require("alpha")?;
    let beta: f64 = cfg.require("beta")?;
    let gamma: f64 = cfg.require("gamma")?;
    let threads: Vec<u32> = parse_list(cfg.require::<String>("threads")?.as_str())?;
    let blocks: Vec<u32> = parse_list(cfg.require::<String>("blocks")?.as_str())?;

    let labels: Vec<String> = std::iter::once("naive".to_string())
        .chain(std::iter::once("overlap".to_string()))
        .chain(blocks.iter().map(|b| format!("ca_b{b}")))
        .collect();
    let label_refs: Vec<&str> = labels.iter().map(String::as_str).collect();
    let mut fig = FigureSeries::new("threads", &label_refs);

    let base = Pipeline::new(Heat1d { n, steps: m, radius: 1 }).procs(p);
    let inputs = strategy_sweep_inputs(&base, &blocks).map_err(|e| e.to_string())?;
    let nseries = inputs.len();
    let grid = sweep::SweepGrid {
        inputs,
        networks: vec![network],
        alphas: vec![alpha],
        threads: threads.clone(),
        beta,
        gamma,
        jobs: 0,
    };
    let cells = sweep::run(&grid)?;
    // Cell order: inputs outermost, threads innermost.
    let nt = threads.len();
    for (ti, &t) in threads.iter().enumerate() {
        let ys: Vec<f64> = (0..nseries).map(|si| cells[si * nt + ti].makespan).collect();
        fig.push(t as f64, ys);
    }
    Ok(fig)
}

/// Figure 9 (beyond the paper): makespan of naive, the §2.1 fixed-b
/// closed-form pick, and the [`crate::tune`] autotuned configuration,
/// across the four wire models (x = network index in
/// [`NetworkKind::all_default`] order: alphabeta, loggp, hier,
/// contended).  One [`crate::tune::Tuner`] serves all four tunings, so
/// the run also exercises the cache keying across networks.
///
/// `cfg` keys: `n, m, p, threads, alpha, beta, gamma` (see
/// [`crate::config::preset_fig9`]).
pub fn fig9_tuned(cfg: &Config) -> Result<FigureSeries, String> {
    let n: u64 = cfg.require("n")?;
    let m: u32 = cfg.require("m")?;
    let p: u32 = cfg.require("p")?;
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    // Radius-1 heat1d has depth = m; the closed form clamps into it.
    let fixed_b = TuningSpace::closed_form_seed(&mach, m).unwrap_or(1);
    let mut fig = FigureSeries::new("network", &["naive", "fixed_b", "tuned"]);
    let mut tuner = Tuner::exhaustive();
    for (i, kind) in NetworkKind::all_default().into_iter().enumerate() {
        let base =
            Pipeline::new(Heat1d { n, steps: m, radius: 1 }).procs(p).machine(mach).network(kind);
        let naive = base
            .clone()
            .naive()
            .transform()
            .map_err(|e| e.to_string())?
            .simulate_configured()
            .map_err(|e| e.to_string())?;
        let fixed = base
            .clone()
            .block(fixed_b)
            .transform()
            .map_err(|e| e.to_string())?
            .simulate_configured()
            .map_err(|e| e.to_string())?;
        let tuned = base.autotune(&mut tuner).map_err(|e| e.to_string())?;
        let report = tuned.tune_report().expect("autotune attaches a report");
        fig.push(i as f64, vec![naive.time.value(), fixed.time.value(), report.makespan]);
    }
    Ok(fig)
}

/// Figure-9 shape assertion: on every wire the tuned configuration is
/// never (beyond the plateau tolerance) slower than naive or the
/// closed-form fixed-b pick — the tuner searched a space containing
/// both.
pub fn check_fig9_claims(fig: &FigureSeries) -> Result<String, String> {
    for (x, row) in &fig.rows {
        let (naive, fixed, tuned) = (row[0], row[1], row[2]);
        if tuned > naive * 1.02 {
            return Err(format!("network {x}: tuned {tuned} slower than naive {naive}"));
        }
        if tuned > fixed * 1.02 {
            return Err(format!("network {x}: tuned {tuned} slower than fixed-b {fixed}"));
        }
    }
    let gain = |i: usize| {
        fig.rows.iter().map(|(_, r)| r[i] / r[2]).fold(1.0f64, f64::max)
    };
    Ok(format!(
        "figure 9 claims hold: tuned ≤ min(naive, fixed-b) on all {} wires; \
         best gain over naive {:.2}x, over fixed-b {:.2}x",
        fig.rows.len(),
        gain(0),
        gain(1)
    ))
}

/// Figure 10 (beyond the paper): SpMV partition quality vs. simulated
/// makespan per wire model.  Each row is one [`Partitioner`] of the
/// banded+random matrix ([`banded_random`]): x = the partition's edge
/// cut in words ([`PartitionQuality::edge_cut_words`] — exactly what one
/// naive exchange level sends), y = the naive plan's makespan under each
/// of the four wire models.
///
/// `cfg` keys: `h, w, chords, m, p, threads, alpha, beta, gamma` (see
/// [`crate::config::preset_fig10`]).
pub fn fig10_partition(cfg: &Config) -> Result<FigureSeries, String> {
    let h: usize = cfg.require("h")?;
    let w: usize = cfg.require("w")?;
    let m: u32 = cfg.require("m")?;
    let p: u32 = cfg.require("p")?;
    let mach = Machine::new(
        p,
        cfg.require("threads")?,
        cfg.require("alpha")?,
        cfg.require("beta")?,
        cfg.require("gamma")?,
    );
    let a = banded_random(h, w, cfg.require("chords")?);
    let kinds = NetworkKind::all_default();
    let labels: Vec<&str> = kinds.iter().map(NetworkKind::label).collect();
    let mut fig = FigureSeries::new("edge_cut_words", &labels);
    for part in Partitioner::all() {
        let q = PartitionQuality::evaluate(&a, &part.assign(&a, p), p);
        // One transform per partition; the shared plan fans across the
        // wire models through the sweep worker pool.
        let t = Pipeline::new(Spmv { matrix: a.clone(), steps: m })
            .procs(p)
            .naive()
            .partitioning(Partitioning::Graph(part))
            .transform()
            .map_err(|e| e.to_string())?;
        let grid = sweep::SweepGrid {
            inputs: vec![t.sweep_input()],
            networks: kinds.clone(),
            alphas: vec![mach.alpha],
            threads: vec![mach.threads],
            beta: mach.beta,
            gamma: mach.gamma,
            jobs: 0,
        };
        let cells = sweep::run(&grid)?;
        fig.push(q.edge_cut_words as f64, cells.iter().map(|c| c.makespan).collect());
    }
    Ok(fig)
}

/// Figure-10 shape assertion: the partitioner family spans a real
/// edge-cut range, and on every wire the lowest-cut partition is not
/// slower (beyond tolerance) than the highest-cut one — words you do not
/// send are time you do not spend, under every wire model.
pub fn check_fig10_claims(fig: &FigureSeries) -> Result<String, String> {
    let lo = fig
        .rows
        .iter()
        .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
        .ok_or("figure 10 is empty")?;
    let hi = fig
        .rows
        .iter()
        .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
        .ok_or("figure 10 is empty")?;
    if lo.0 >= hi.0 {
        return Err(format!("edge-cut axis is degenerate: every partition cuts {} words", lo.0));
    }
    for (i, wire) in fig.labels.iter().enumerate() {
        if lo.1[i] > hi.1[i] * 1.02 {
            return Err(format!(
                "{wire}: min-cut partition is slower ({} vs {})",
                lo.1[i], hi.1[i]
            ));
        }
    }
    Ok(format!(
        "figure 10 claims hold: cut range {}..{} words; min-cut no slower on all {} wires",
        lo.0,
        hi.0,
        fig.labels.len()
    ))
}

/// Shape assertions for figures 7/8 — the paper's qualitative claims,
/// checked programmatically (see DESIGN.md §4 acceptance criteria).
/// Returns a human-readable verdict; `Err` when a claim fails.
pub fn check_fig78_claims(
    moderate: &FigureSeries,
    high: &FigureSeries,
) -> Result<String, String> {
    let naive = 0usize;
    let best_ca = |row: &Vec<f64>| row[2..].iter().cloned().fold(f64::INFINITY, f64::min);

    // Claim (a): at moderate latency, blocking does not win at the low
    // end of the thread sweep.
    let (_, low_row) = &moderate.rows[0];
    if best_ca(low_row) < low_row[naive] * 0.98 {
        return Err(format!(
            "moderate latency: CA already wins at {} threads ({} vs naive {})",
            moderate.rows[0].0,
            best_ca(low_row),
            low_row[naive]
        ));
    }
    // ...but does win at the top.
    let (_, top_row) = moderate.rows.last().unwrap();
    if best_ca(top_row) >= top_row[naive] {
        return Err("moderate latency: CA never wins even at max threads".into());
    }

    // Claim (b): at high latency, CA wins from a moderate thread count on
    // — find the crossover indices and compare.
    let xover = |f: &FigureSeries| {
        f.rows
            .iter()
            .position(|(_, row)| best_ca(row) < row[naive])
            .unwrap_or(f.rows.len())
    };
    let (xm, xh) = (xover(moderate), xover(high));
    if xh > xm {
        return Err(format!(
            "high-latency crossover (idx {xh}) later than moderate (idx {xm})"
        ));
    }

    // Claim (c): the relative gain at max threads is larger at high
    // latency.
    let gain = |f: &FigureSeries| {
        let (_, row) = f.rows.last().unwrap();
        row[naive] / best_ca(row)
    };
    let (gm, gh) = (gain(moderate), gain(high));
    if gh <= gm {
        return Err(format!("gain at max threads: high {gh:.2} ≤ moderate {gm:.2}"));
    }

    Ok(format!(
        "claims hold: crossover idx moderate={xm} high={xh}; max-thread gain moderate={gm:.2}x high={gh:.2}x"
    ))
}

fn preview(v: &[u32]) -> String {
    const K: usize = 8;
    if v.len() <= 2 * K {
        format!("{v:?}")
    } else {
        let head: Vec<u32> = v[..K].to_vec();
        let tail: Vec<u32> = v[v.len() - K..].to_vec();
        format!("{head:?} … {tail:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset_fig7, preset_fig8};
    use crate::transform::communication_avoiding;

    #[test]
    fn fig1_renders_and_counts_ghost() {
        let s = fig1(32, 4, 4).unwrap();
        assert!(s.contains("ghost width = 4"));
        assert!(s.contains("redundant tasks"));
    }

    #[test]
    fn fig2_phases_nonempty() {
        let s = fig2(64, 4, 4).unwrap();
        assert!(s.contains("phase 2"));
    }

    #[test]
    fn impossible_figure_configuration_is_an_error_not_a_panic() {
        // 2 points cannot strip over 8 procs: the graph build fails and
        // the error carries the offending configuration.
        let err = fig1(2, 4, 8).unwrap_err();
        assert!(err.contains("2x4 on 8 procs"), "{err}");
    }

    #[test]
    fn fig3_multilevel_less_redundant() {
        let g = heat1d_graph(64, 6, 4);
        let multi = communication_avoiding(&g, TransformOptions::default());
        let lvl0 = communication_avoiding(&g, TransformOptions::level0());
        let rm = ScheduleStats::compute(&g, &multi).redundant_tasks;
        let r0 = ScheduleStats::compute(&g, &lvl0).redundant_tasks;
        assert!(rm < r0, "multi {rm} vs level0 {r0}");
        let s = fig3(64, 6, 4).unwrap();
        assert!(s.contains("redundant work"));
    }

    #[test]
    fn fig6_sets_match_1d_geometry() {
        // Middle processor, n/p = 16 points, m = 4 levels, multilevel.
        let (_, d) = fig6(64, 4, 4).unwrap();
        // k2 is the interior trapezoid: Σ_{s=1..4} (16 − 2s) ≥ ... exact:
        // L4 = Σ max(0, 16 − 2s) = 14+12+10+8 = 44; k1 are the wedge tasks
        // needed by neighbours.
        assert_eq!(d.k1 + d.k2, 44);
        assert!(d.k1 > 0 && d.k3 > 0);
        // Conservation: every owned compute task is produced once:
        // k1+k2+k3+received ≥ owned tasks (64/4 points × 4 levels = 16×4).
        assert!(d.k1 + d.k2 + d.k3 + d.received >= 16 * 4 / 4 * 4);
    }

    #[test]
    fn subset_grid_dimensions() {
        let g = heat1d_graph(16, 3, 2);
        let s = communication_avoiding(&g, TransformOptions::default());
        let grid = subset_grid(16, 3, 2, 0, &s);
        assert_eq!(grid.lines().count(), 4); // levels 3,2,1,0
        assert!(grid.lines().all(|l| l.contains('|')));
    }

    #[test]
    fn fig78_sim_engine_tracks_analytic() {
        let mut c = preset_fig8();
        c.set("n", 2048);
        c.set("m", 8);
        c.set("p", 4);
        c.set("threads", "1,8,64");
        c.set("blocks", "4");
        let analytic = fig78_sweep(&c).unwrap();
        let sim = fig78_sweep_sim(&c, NetworkKind::AlphaBeta).unwrap();
        assert_eq!(analytic.labels, sim.labels);
        assert_eq!(analytic.rows.len(), sim.rows.len());
        // Naive has an exact closed form; the discrete engine must agree
        // closely (the CA columns differ more: BSP coupling vs. pipelining).
        for ((xa, ra), (xs, rs)) in analytic.rows.iter().zip(&sim.rows) {
            assert_eq!(xa, xs);
            let rel = (ra[0] - rs[0]).abs() / rs[0];
            assert!(rel < 0.15, "threads={xa}: analytic {} sim {}", ra[0], rs[0]);
        }
        // Under NIC contention every point is at least as slow.
        let cont = fig78_sweep_sim(&c, NetworkKind::Contended).unwrap();
        for ((_, ideal), (_, slow)) in sim.rows.iter().zip(&cont.rows) {
            for (a, b) in ideal.iter().zip(slow) {
                assert!(b >= a, "contended {b} < ideal {a}");
            }
        }
    }

    #[test]
    fn fig9_tuned_never_loses_to_fixed_or_naive() {
        let mut c = crate::config::preset_fig9();
        // Shrink for test speed; α·t keeps the closed form in-grid.
        c.set("n", 256);
        c.set("m", 8);
        c.set("p", 2);
        c.set("threads", 4);
        c.set("alpha", 32.0);
        let fig = fig9_tuned(&c).unwrap();
        assert_eq!(fig.rows.len(), 4); // one row per wire model
        assert_eq!(fig.labels, vec!["naive", "fixed_b", "tuned"]);
        let verdict = check_fig9_claims(&fig).unwrap();
        assert!(verdict.contains("claims hold"), "{verdict}");
    }

    #[test]
    fn fig10_low_cut_partitions_do_not_lose() {
        let mut c = crate::config::preset_fig10();
        // Shrink for test speed; β stays dominant so the cut matters.
        c.set("h", 4);
        c.set("w", 16);
        c.set("chords", 4);
        c.set("m", 4);
        let fig = fig10_partition(&c).unwrap();
        assert_eq!(fig.rows.len(), 3); // rowblock, rcb, rcb+refine
        assert_eq!(fig.labels, vec!["alphabeta", "loggp", "hier", "contended"]);
        let verdict = check_fig10_claims(&fig).unwrap();
        assert!(verdict.contains("claims hold"), "{verdict}");
    }

    #[test]
    fn fig78_sweep_and_claims() {
        let mut c7 = preset_fig7();
        let mut c8 = preset_fig8();
        // Shrink for test speed; keep the regime ratio.
        for c in [&mut c7, &mut c8] {
            c.set("n", 8192);
            c.set("m", 16);
            c.set("p", 8);
            c.set("threads", "1,4,16,64,256");
            c.set("blocks", "2,4,8");
        }
        let f7 = fig78_sweep(&c7).unwrap();
        let f8 = fig78_sweep(&c8).unwrap();
        let verdict = check_fig78_claims(&f7, &f8).unwrap();
        assert!(verdict.contains("claims hold"), "{verdict}");
    }
}
