//! Compressed sparse row matrices — the substrate for the paper's
//! motivating application (repeated sparse matrix-vector products, §2).
//!
//! The transformation itself only consumes task graphs; this module
//! provides the *irregular* graph source: `A`'s sparsity pattern is an
//! arbitrary dependence signature, so SpMV chains exercise the transform
//! beyond the regular stencil case.

use crate::imp::Signature;

/// A square CSR matrix.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    pub n: usize,
    pub rowptr: Vec<u32>,
    pub colidx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl CsrMatrix {
    /// Build from per-row (col, val) lists; columns need not be sorted.
    pub fn from_rows(rows: Vec<Vec<(u32, f32)>>) -> Self {
        let n = rows.len();
        let mut rowptr = Vec::with_capacity(n + 1);
        let mut colidx = Vec::new();
        let mut vals = Vec::new();
        rowptr.push(0u32);
        for mut row in rows {
            row.sort_unstable_by_key(|&(c, _)| c);
            for (c, v) in row {
                assert!((c as usize) < n, "column {c} out of range {n}");
                colidx.push(c);
                vals.push(v);
            }
            rowptr.push(colidx.len() as u32);
        }
        CsrMatrix { n, rowptr, colidx, vals }
    }

    /// The 1-D Laplacian `tridiag(-1, 2, -1)` (zero Dirichlet).
    pub fn laplace1d(n: usize) -> Self {
        let rows = (0..n)
            .map(|i| {
                let mut r = Vec::with_capacity(3);
                if i > 0 {
                    r.push((i as u32 - 1, -1.0));
                }
                r.push((i as u32, 2.0));
                if i + 1 < n {
                    r.push((i as u32 + 1, -1.0));
                }
                r
            })
            .collect();
        Self::from_rows(rows)
    }

    /// The 2-D five-point Laplacian on an `h × w` grid (row-major).
    pub fn laplace2d(h: usize, w: usize) -> Self {
        let idx = |r: usize, c: usize| (r * w + c) as u32;
        let rows = (0..h * w)
            .map(|k| {
                let (r, c) = (k / w, k % w);
                let mut row = Vec::with_capacity(5);
                if r > 0 {
                    row.push((idx(r - 1, c), -1.0));
                }
                if c > 0 {
                    row.push((idx(r, c - 1), -1.0));
                }
                row.push((idx(r, c), 4.0));
                if c + 1 < w {
                    row.push((idx(r, c + 1), -1.0));
                }
                if r + 1 < h {
                    row.push((idx(r + 1, c), -1.0));
                }
                row
            })
            .collect();
        Self::from_rows(rows)
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    /// Columns of row `i`.
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.colidx[self.rowptr[i] as usize..self.rowptr[i + 1] as usize]
    }

    /// Values of row `i`.
    pub fn row_vals(&self, i: usize) -> &[f32] {
        &self.vals[self.rowptr[i] as usize..self.rowptr[i + 1] as usize]
    }

    /// y = A x (sequential; the distributed version lives in `krylov`).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n);
        (0..self.n)
            .map(|i| {
                self.row_cols(i)
                    .iter()
                    .zip(self.row_vals(i))
                    .map(|(&c, &v)| v * x[c as usize])
                    .sum()
            })
            .collect()
    }

    /// The matrix's sparsity pattern as an IMP dependence signature, so
    /// SpMV chains can be unrolled into task graphs via [`crate::imp::Program`].
    pub fn signature(&self) -> Signature {
        Signature::Sparse { rowptr: self.rowptr.clone(), colidx: self.colidx.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplace1d_structure() {
        let a = CsrMatrix::laplace1d(5);
        assert_eq!(a.nnz(), 13); // 3*5 - 2
        assert_eq!(a.row_cols(0), &[0, 1]);
        assert_eq!(a.row_cols(2), &[1, 2, 3]);
        assert_eq!(a.row_vals(2), &[-1.0, 2.0, -1.0]);
    }

    #[test]
    fn laplace1d_spmv_of_ones() {
        // A * ones: interior rows sum to 0, boundary rows to 1.
        let a = CsrMatrix::laplace1d(6);
        let y = a.spmv(&[1.0; 6]);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn laplace2d_structure() {
        let a = CsrMatrix::laplace2d(3, 3);
        assert_eq!(a.n, 9);
        // centre point has 5 entries
        assert_eq!(a.row_cols(4), &[1, 3, 4, 5, 7]);
        // corner has 3
        assert_eq!(a.row_cols(0), &[0, 1, 3]);
    }

    #[test]
    fn laplace2d_spmv_of_ones() {
        let a = CsrMatrix::laplace2d(3, 3);
        let y = a.spmv(&[1.0; 9]);
        // corner: 4 - 2 = 2; edge: 4 - 3 = 1; centre: 0
        assert_eq!(y, vec![2.0, 1.0, 2.0, 1.0, 0.0, 1.0, 2.0, 1.0, 2.0]);
    }

    #[test]
    fn signature_matches_pattern() {
        let a = CsrMatrix::laplace1d(4);
        let sig = a.signature();
        assert_eq!(sig.of_index(1, 4), vec![0, 1, 2]);
    }
}
