//! Concrete problem generators: the grids and matrices whose task graphs
//! the paper transforms.
//!
//! Everything here reduces to [`crate::imp::Program`] — the generators
//! assemble the right distributions and signatures, so the transformation
//! never sees anything problem-specific.

mod csr;

pub use csr::CsrMatrix;

use crate::graph::TaskGraph;
use crate::imp::{Distribution, Program, Signature};

/// The paper's running example (eq. (1)): `m` steps of a radius-`r`
/// 1-D stencil over `n` points, block-distributed over `p` processors.
/// `r = 1` is the 3-point heat update.
pub fn heat1d_program(n: u64, m: u32, p: u32, r: u32) -> Program {
    Program::new(Distribution::block(n, p)).iterate("heat1d", Signature::stencil_radius(r), m)
}

/// Convenience: the unrolled graph of [`heat1d_program`].
pub fn heat1d_graph(n: u64, m: u32, p: u32) -> TaskGraph {
    heat1d_program(n, m, p, 1).unroll()
}

/// `m` steps of the 2-D five-point stencil on an `h × w` grid (row-major
/// flattening), distributed over a `px × py` processor grid.
pub fn heat2d_program(h: u64, w: u64, m: u32, px: u32, py: u32) -> Program {
    heat2d_program_on(h, w, m, block2d(h, w, px, py))
}

/// [`heat2d_program`] under an explicit distribution — the entry point
/// the [`crate::partition`] layer's grid shapes feed.
pub fn heat2d_program_on(h: u64, w: u64, m: u32, dist: Distribution) -> Program {
    Program::new(dist).iterate("heat2d", five_point_signature(h, w), m)
}

/// Convenience: the unrolled graph of [`heat2d_program`].
pub fn heat2d_graph(h: u64, w: u64, m: u32, px: u32, py: u32) -> TaskGraph {
    heat2d_program(h, w, m, px, py).unroll()
}

/// `m` repeated SpMVs with an arbitrary CSR matrix: the paper's motivating
/// irregular workload ("repeated sequence of sparse matrix-vector
/// products").
pub fn spmv_program(a: &CsrMatrix, m: u32, p: u32) -> Program {
    spmv_program_on(a, m, Distribution::block(a.n as u64, p))
}

/// [`spmv_program`] under an explicit distribution — the entry point the
/// [`crate::partition`] layer's graph partitioners feed.
pub fn spmv_program_on(a: &CsrMatrix, m: u32, dist: Distribution) -> Program {
    Program::new(dist).iterate("spmv", a.signature(), m)
}

/// 2-D block distribution over a row-major `h × w` grid: processor
/// `(qx, qy)` owns the cartesian block, flattened.
pub fn block2d(h: u64, w: u64, px: u32, py: u32) -> Distribution {
    use crate::imp::{block_bounds, IndexSet};
    let mut parts = Vec::with_capacity((px * py) as usize);
    for qr in 0..px {
        let (rlo, rhi) = block_bounds(h, px, qr);
        for qc in 0..py {
            let (clo, chi) = block_bounds(w, py, qc);
            let mut v = Vec::with_capacity(((rhi - rlo) * (chi - clo)) as usize);
            for rr in rlo..rhi {
                for cc in clo..chi {
                    v.push(rr * w + cc);
                }
            }
            parts.push(IndexSet::from_indices(v));
        }
    }
    Distribution::irregular(h * w, parts).expect("block2d partitions the grid")
}

/// `m` steps of the 2-D **nine-point** (Moore neighbourhood) stencil on an
/// `h × w` grid, distributed over a `px × py` processor grid.  Unlike the
/// five-point cross, every diagonal is a *direct* dependence, so even the
/// `b = 1` naive exchange needs corner traffic — the workload that makes
/// the 2-D transformation earn its 8-neighbour messages at every block
/// factor.
pub fn moore2d_program(h: u64, w: u64, m: u32, px: u32, py: u32) -> Program {
    moore2d_program_on(h, w, m, block2d(h, w, px, py))
}

/// [`moore2d_program`] under an explicit distribution — the entry point
/// the [`crate::partition`] layer's grid shapes feed.
pub fn moore2d_program_on(h: u64, w: u64, m: u32, dist: Distribution) -> Program {
    Program::new(dist).iterate("moore2d", nine_point_signature(h, w), m)
}

/// Convenience: the unrolled graph of [`moore2d_program`].
pub fn moore2d_graph(h: u64, w: u64, m: u32, px: u32, py: u32) -> TaskGraph {
    moore2d_program(h, w, m, px, py).unroll()
}

/// The nine-point (3×3 Moore block) dependence pattern on a flattened
/// `h × w` grid as a sparse signature, clipped at the domain boundary.
pub fn nine_point_signature(h: u64, w: u64) -> Signature {
    let n = (h * w) as usize;
    let mut rowptr = Vec::with_capacity(n + 1);
    let mut colidx = Vec::with_capacity(n * 9);
    rowptr.push(0u32);
    for k in 0..n as u64 {
        let (r, c) = (k / w, k % w);
        for dr in -1i64..=1 {
            let rr = r as i64 + dr;
            if rr < 0 || rr >= h as i64 {
                continue;
            }
            for dc in -1i64..=1 {
                let cc = c as i64 + dc;
                if cc < 0 || cc >= w as i64 {
                    continue;
                }
                colidx.push((rr as u64 * w + cc as u64) as u32);
            }
        }
        rowptr.push(colidx.len() as u32);
    }
    Signature::Sparse { rowptr, colidx }
}

/// The five-point-cross dependence pattern on a flattened `h × w` grid as
/// a sparse signature (offsets ±1 are only valid within a row, so a plain
/// 1-D stencil signature cannot express it).
pub fn five_point_signature(h: u64, w: u64) -> Signature {
    let n = (h * w) as usize;
    let mut rowptr = Vec::with_capacity(n + 1);
    let mut colidx = Vec::with_capacity(n * 5);
    rowptr.push(0u32);
    for k in 0..n as u64 {
        let (r, c) = (k / w, k % w);
        if r > 0 {
            colidx.push((k - w) as u32);
        }
        if c > 0 {
            colidx.push((k - 1) as u32);
        }
        colidx.push(k as u32);
        if c + 1 < w {
            colidx.push((k + 1) as u32);
        }
        if r + 1 < h {
            colidx.push((k + w) as u32);
        }
        rowptr.push(colidx.len() as u32);
    }
    Signature::Sparse { rowptr, colidx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ProcId, TaskId};

    #[test]
    fn heat1d_graph_shape() {
        let g = heat1d_graph(12, 3, 4);
        assert_eq!(g.len(), 12 * 4);
        assert_eq!(g.num_levels(), 4);
        assert_eq!(g.num_procs(), 4);
        // Every proc owns 3 points per level.
        for p in 0..4 {
            assert_eq!(g.owned_by(ProcId(p)).len(), 3 * 4);
        }
    }

    #[test]
    fn heat2d_graph_shape() {
        let g = heat2d_graph(4, 6, 2, 2, 2);
        assert_eq!(g.len(), 24 * 3);
        assert_eq!(g.num_procs(), 4);
        // Interior point dependence count is 5.
        // point (1,1) = index 7 at level 1 → id 24 + 7.
        assert_eq!(g.preds(TaskId(24 + 7)).len(), 5);
        // corner (0,0) has 3 preds.
        assert_eq!(g.preds(TaskId(24)).len(), 3);
    }

    #[test]
    fn block2d_partitions() {
        let d = block2d(4, 6, 2, 3);
        let total: usize = (0..6).map(|p| d.owned(ProcId(p)).len()).sum();
        assert_eq!(total, 24);
        // proc (0,0) owns rows 0-1, cols 0-1 → {0,1,6,7}
        assert_eq!(d.owned(ProcId(0)).to_vec(), vec![0, 1, 6, 7]);
    }

    #[test]
    fn five_point_matches_laplace2d_pattern() {
        let sig = five_point_signature(3, 3);
        let a = CsrMatrix::laplace2d(3, 3);
        for i in 0..9usize {
            let from_sig = sig.of_index(i as u64, 9);
            let from_mat: Vec<u64> = a.row_cols(i).iter().map(|&c| c as u64).collect();
            assert_eq!(from_sig, from_mat, "row {i}");
        }
    }

    #[test]
    fn nine_point_interior_has_nine_preds() {
        let g = moore2d_graph(4, 4, 1, 2, 2);
        // Interior point (1,1) = index 5 at level 1 → id 16 + 5.
        assert_eq!(g.preds(TaskId(16 + 5)).len(), 9);
        // Corner (0,0) sees a 2×2 block.
        assert_eq!(g.preds(TaskId(16)).len(), 4);
        // Edge midpoint (0,1) sees a 2×3 block.
        assert_eq!(g.preds(TaskId(16 + 1)).len(), 6);
    }

    #[test]
    fn nine_point_supersets_five_point() {
        let nine = nine_point_signature(3, 3);
        let five = five_point_signature(3, 3);
        for i in 0..9u64 {
            let n9 = nine.of_index(i, 9);
            for d in five.of_index(i, 9) {
                assert!(n9.contains(&d), "row {i} missing {d}");
            }
        }
        // Centre row has all 9 deps.
        assert_eq!(nine.of_index(4, 9).len(), 9);
    }

    #[test]
    fn spmv_graph_edges_match_nnz() {
        let a = CsrMatrix::laplace1d(10);
        let g = spmv_program(&a, 2, 2).unroll();
        assert_eq!(g.num_edges(), 2 * a.nnz());
    }
}
