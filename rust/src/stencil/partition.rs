//! Graph partitioning for irregular problems.
//!
//! The paper's transformation is distribution-agnostic, but *which*
//! distribution it starts from decides how much halo traffic exists to
//! avoid.  This module provides a dependency-aware recursive-bisection
//! partitioner (a METIS-lite: grow one half by BFS from a peripheral
//! vertex, recurse) over arbitrary sparsity patterns, plus quality
//! metrics (balance, edge cut) so the SpMV experiments can compare
//! block vs. bisection distributions.

use crate::imp::{Distribution, IndexSet};
use crate::stencil::CsrMatrix;

/// Partition quality metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionQuality {
    /// max part size / mean part size (1.0 = perfect balance).
    pub imbalance: f64,
    /// Matrix nonzeros whose row and column land in different parts.
    pub edge_cut: usize,
    /// Total nonzeros (for normalizing).
    pub nnz: usize,
}

impl PartitionQuality {
    /// Fraction of dependencies that cross parts.
    pub fn cut_fraction(&self) -> f64 {
        self.edge_cut as f64 / self.nnz.max(1) as f64
    }
}

/// Recursive-bisection partitioning of `a`'s vertex set into `nparts`.
///
/// Each bisection BFS-grows one side from a peripheral vertex (found by a
/// double-sweep), which keeps parts connected on mesh-like patterns and
/// is deterministic.  `nparts` need not be a power of two: sizes are
/// balanced by splitting counts proportionally.
pub fn bisect(a: &CsrMatrix, nparts: u32) -> Vec<u32> {
    assert!(nparts > 0);
    let mut assign = vec![0u32; a.n];
    let all: Vec<u32> = (0..a.n as u32).collect();
    recurse(a, &all, 0, nparts, &mut assign);
    assign
}

fn recurse(a: &CsrMatrix, verts: &[u32], first_part: u32, nparts: u32, assign: &mut [u32]) {
    if nparts == 1 {
        for &v in verts {
            assign[v as usize] = first_part;
        }
        return;
    }
    let left_parts = nparts / 2;
    // Proportional split point.
    let left_target = verts.len() * left_parts as usize / nparts as usize;

    // BFS from a peripheral vertex (double sweep for a long diameter).
    let far = bfs_last(a, verts, verts[0]);
    let order = bfs_order(a, verts, far);
    let (left, right): (Vec<u32>, Vec<u32>) = {
        let left: Vec<u32> = order[..left_target].to_vec();
        let right: Vec<u32> = order[left_target..].to_vec();
        (left, right)
    };
    recurse(a, &left, first_part, left_parts, assign);
    recurse(a, &right, first_part + left_parts, nparts - left_parts, assign);
}

/// BFS over the sub-graph induced by `verts`; returns the last vertex
/// reached (peripheral heuristic).  Disconnected leftovers are appended
/// in index order, so the result is always `verts`-complete.
fn bfs_last(a: &CsrMatrix, verts: &[u32], start: u32) -> u32 {
    *bfs_order(a, verts, start).last().unwrap()
}

fn bfs_order(a: &CsrMatrix, verts: &[u32], start: u32) -> Vec<u32> {
    use std::collections::VecDeque;
    let inset: std::collections::HashSet<u32> = verts.iter().copied().collect();
    let mut seen: std::collections::HashSet<u32> = std::collections::HashSet::new();
    let mut order = Vec::with_capacity(verts.len());
    let mut queue = VecDeque::new();
    queue.push_back(start);
    seen.insert(start);
    while let Some(v) = queue.pop_front() {
        order.push(v);
        for &c in a.row_cols(v as usize) {
            if inset.contains(&c) && seen.insert(c) {
                queue.push_back(c);
            }
        }
    }
    // Disconnected components: continue from remaining vertices in order.
    for &v in verts {
        if seen.insert(v) {
            let mut sub = VecDeque::new();
            sub.push_back(v);
            while let Some(u) = sub.pop_front() {
                order.push(u);
                for &c in a.row_cols(u as usize) {
                    if inset.contains(&c) && seen.insert(c) {
                        sub.push_back(c);
                    }
                }
            }
        }
    }
    order
}

/// Wrap an assignment vector as an IMP [`Distribution`].
pub fn to_distribution(assign: &[u32], nparts: u32) -> Distribution {
    let mut parts: Vec<Vec<u64>> = vec![Vec::new(); nparts as usize];
    for (v, &p) in assign.iter().enumerate() {
        parts[p as usize].push(v as u64);
    }
    Distribution::irregular(
        assign.len() as u64,
        parts.into_iter().map(IndexSet::from_indices).collect(),
    )
    .expect("assignment is a partition")
}

/// Evaluate an assignment against the matrix it partitions.
pub fn quality(a: &CsrMatrix, assign: &[u32], nparts: u32) -> PartitionQuality {
    let mut sizes = vec![0usize; nparts as usize];
    for &p in assign {
        sizes[p as usize] += 1;
    }
    let mean = a.n as f64 / nparts as f64;
    let imbalance = sizes.iter().copied().max().unwrap_or(0) as f64 / mean.max(1e-12);
    let mut cut = 0usize;
    for r in 0..a.n {
        for &c in a.row_cols(r) {
            if assign[r] != assign[c as usize] {
                cut += 1;
            }
        }
    }
    PartitionQuality { imbalance, edge_cut: cut, nnz: a.nnz() }
}

/// Naive block partition of the same vertex set (the baseline).
pub fn block_assign(n: usize, nparts: u32) -> Vec<u32> {
    use crate::imp::block_bounds;
    let mut assign = vec![0u32; n];
    for p in 0..nparts {
        let (lo, hi) = block_bounds(n as u64, nparts, p);
        for v in lo..hi {
            assign[v as usize] = p;
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_partition(assign: &[u32], nparts: u32) {
        assert!(assign.iter().all(|&p| p < nparts));
        let mut sizes = vec![0usize; nparts as usize];
        for &p in assign {
            sizes[p as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
    }

    #[test]
    fn bisect_1d_chain_gives_contiguous_halves() {
        let a = CsrMatrix::laplace1d(16);
        let assign = bisect(&a, 2);
        is_partition(&assign, 2);
        let q = quality(&a, &assign, 2);
        // A chain cut once: exactly 2 cut nonzeros (one edge, both dirs).
        assert_eq!(q.edge_cut, 2);
        assert!((q.imbalance - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bisect_2d_grid_beats_row_blocks() {
        // On a tall skinny grid, 1-D row blocking cuts long rows;
        // bisection should find the short direction.
        let (h, w) = (4usize, 32usize);
        let a = CsrMatrix::laplace2d(h, w);
        let bis = bisect(&a, 4);
        is_partition(&bis, 4);
        let blk = block_assign(a.n, 4);
        let qb = quality(&a, &bis, 4);
        let qn = quality(&a, &blk, 4);
        assert!(
            qb.edge_cut <= qn.edge_cut,
            "bisection {} vs block {}",
            qb.edge_cut,
            qn.edge_cut
        );
    }

    #[test]
    fn nonpow2_parts() {
        let a = CsrMatrix::laplace1d(30);
        let assign = bisect(&a, 3);
        is_partition(&assign, 3);
        let q = quality(&a, &assign, 3);
        assert!(q.imbalance < 1.2, "{q:?}");
    }

    #[test]
    fn to_distribution_roundtrip() {
        let a = CsrMatrix::laplace1d(12);
        let assign = bisect(&a, 3);
        let d = to_distribution(&assign, 3);
        for v in 0..12u64 {
            assert_eq!(d.owner_of(v).0, assign[v as usize]);
        }
    }

    #[test]
    fn transform_runs_on_bisected_spmv() {
        use crate::imp::Program;
        use crate::transform::{check_schedule, communication_avoiding_default};
        let a = CsrMatrix::laplace2d(6, 6);
        let d = to_distribution(&bisect(&a, 4), 4);
        let g = Program::new(d).iterate("spmv", a.signature(), 3).unroll();
        let s = communication_avoiding_default(&g);
        check_schedule(&g, &s).unwrap();
    }

    #[test]
    fn disconnected_graph_partitions() {
        // Two disjoint chains.
        let rows: Vec<Vec<(u32, f32)>> = (0..8)
            .map(|i| {
                let mut r = vec![(i as u32, 2.0)];
                if i % 4 > 0 {
                    r.push((i as u32 - 1, -1.0));
                }
                if i % 4 < 3 {
                    r.push((i as u32 + 1, -1.0));
                }
                r
            })
            .collect();
        let a = CsrMatrix::from_rows(rows);
        let assign = bisect(&a, 2);
        is_partition(&assign, 2);
    }
}
