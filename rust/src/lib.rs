//! # imp-latency — Task Graph Transformations for Latency Tolerance
//!
//! A production-quality reproduction of Victor Eijkhout, *Task Graph
//! Transformations for Latency Tolerance* (CS.DC 2018): the Integrative
//! Model for Parallelism (IMP) derivation of distributed task graphs, the
//! paper's §3 communication-avoiding transformation into the
//! `L^(1)/L^(2)/L^(3)` subsets, a discrete-event simulator reproducing the
//! §4 strong-scaling study (figures 7/8), and a real leader/worker runtime
//! that executes the transformed schedules with AOT-compiled XLA compute.
//!
//! ## Layer map
//!
//! * [`graph`] — the task-graph IR every other module consumes.
//! * [`imp`] — the IMP formalism: index sets, distributions, signature
//!   functions; derives task graphs from data-parallel programs.
//! * [`stencil`] — concrete problem generators (1-D/2-D heat, CSR SpMV).
//! * [`transform`] — **the paper's contribution**: the subset derivation,
//!   Theorem-1 checker, blocking, and redundancy accounting.
//! * [`sim`] — α/β/γ discrete-event simulator for naive / overlap /
//!   communication-avoiding schedules (paper §4).
//! * [`cost`] — the §2.1 analytic cost model `T(b) = (M/b)α + Mβ + (MN/p + Mb)γ`.
//! * [`krylov`] — the motivating application: classic and latency-tolerant CG.
//! * [`runtime`] — PJRT artifact loading/execution (`xla` crate).
//! * [`coordinator`] — real threads+channels execution of transformed graphs.
//! * [`trace`] — Gantt charts and CSV series for the figures.
//! * [`config`] — experiment presets and a small key=value config parser.
//! * [`figures`] — regenerates every paper figure's data.
//! * [`prop`] — in-repo property-testing harness (no external deps vendored).

pub mod config;
pub mod coordinator;
pub mod cost;
pub mod figures;
pub mod graph;
pub mod imp;
pub mod krylov;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod stencil;
pub mod trace;
pub mod transform;
pub mod util;

pub use graph::{ProcId, TaskGraph, TaskId};
pub use transform::{CaSchedule, HaloMode, TransformOptions};
