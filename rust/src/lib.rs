//! # imp-latency — Task Graph Transformations for Latency Tolerance
//!
//! A production-quality reproduction of Victor Eijkhout, *Task Graph
//! Transformations for Latency Tolerance* (CS.DC 2018): the Integrative
//! Model for Parallelism (IMP) derivation of distributed task graphs, the
//! paper's §3 communication-avoiding transformation into the
//! `L^(1)/L^(2)/L^(3)` subsets, a discrete-event simulator reproducing the
//! §4 strong-scaling study (figures 7/8), and a real leader/worker runtime
//! that executes the transformed schedules with AOT-compiled XLA compute.
//!
//! ## Start here: the pipeline
//!
//! [`pipeline`] is the front door — one fluent builder from a problem
//! description to a transformed schedule, a simulated run, and a real
//! (threads + channels) verified execution:
//!
//! ```
//! use imp_latency::pipeline::{Heat1d, Pipeline};
//! use imp_latency::sim::Machine;
//!
//! let run = Pipeline::new(Heat1d::new(128, 8)).procs(4).block(4).transform().unwrap();
//! println!("{}", run.simulate(&Machine::high_latency(4, 16)).summary());
//! println!("{}", run.execute().unwrap().summary());
//! ```
//!
//! ## Layer map
//!
//! * [`graph`] — the task-graph IR every other module consumes.
//! * [`imp`] — the IMP formalism: index sets, distributions, signature
//!   functions; derives task graphs from data-parallel programs.
//! * [`stencil`] — concrete problem generators (1-D/2-D heat, 9-point
//!   Moore stencil, CSR SpMV).
//! * [`partition`] — data layout as a first-class dimension: processor
//!   grids ([`partition::ProcGrid`]: strips, 2-D `px × py` grids, block /
//!   block-cyclic tilings) for the structured stencils, and graph
//!   partitioners ([`partition::Partitioner`]: row-block, recursive
//!   coordinate bisection, greedy edge-cut refinement) for SpMV/CG, with
//!   a [`partition::PartitionQuality`] report (edge cut in words, load
//!   imbalance, max neighbor count); flows through
//!   `Pipeline::partitioning`, the tuner's layout axis, and the
//!   grid-aware hierarchical wire.
//! * [`transform`] — **the paper's contribution**: the subset derivation,
//!   Theorem-1 checker, blocking, and redundancy accounting.
//! * [`analysis`] — static plan verification (verify → prune → report):
//!   channel-safety census, deadlock-freedom proof pinned against the
//!   engine's dynamic verdict, whole-plan RAW/WAW hazard analysis, and
//!   an analytic critical-path makespan lower bound
//!   ([`analysis::critical_path`], exact on stateless wires) that
//!   pre-flights every [`pipeline::Pipeline::transform`], prunes tuner
//!   candidates branch-and-bound style, and backs the `analyze` CLI
//!   subcommand / `serve` op.
//! * [`sim`] — the §4 simulation stack: an event-driven engine
//!   (binary-heap event queue, blocked-receiver wakeup) with pluggable
//!   wire models ([`sim::NetworkKind`]: α+β·words, LogGP, hierarchical,
//!   contended NICs) and a per-task [`sim::TaskCostModel`] hook.  Hot
//!   path: plans are lowered **once** into a [`sim::CompiledPlan`] (flat
//!   CSR phase streams, dense channel table, baked costs) and simulated
//!   allocation-free against a reusable [`sim::EngineScratch`] — the
//!   compile→simulate flow every [`sim::sweep`] grid and tuner candidate
//!   rides (`bench` CLI tracks it); closed-form BSP evaluation covers
//!   naive / overlap / communication-avoiding schedules analytically.
//! * [`pipeline`] — **the front door**: the [`pipeline::Workload`] trait
//!   and the [`pipeline::Pipeline`] builder tying every layer below into
//!   one expression, with a shared [`pipeline::RunReport`].
//! * [`tune`] — simulation-in-the-loop autotuning: a
//!   [`tune::TuningSpace`] (strategy × halo × block × procs) explored by
//!   pluggable [`tune::SearchStrategy`] impls, every candidate scored by
//!   the event-driven engine via the [`sim::sweep`] worker pool, winners
//!   persisted in a JSON [`tune::TuningCache`] — sharded into
//!   per-workload-signature files with single-writer file locks;
//!   surfaced as [`pipeline::Pipeline::autotune`] and the `tune` CLI
//!   subcommand.
//! * [`serve`] — the serving story: a long-running daemon
//!   ([`serve::Server`], `serve` CLI subcommand) answering JSON
//!   tune/simulate request streams over stdin batches or TCP/Unix
//!   sockets — cache-first (warm hits cost zero engine runs), in-flight
//!   requests deduped by cache key, compatible simulations batched into
//!   shared [`sim::sweep`] grids, overload shed by admission control,
//!   SIGINT/SIGTERM flushing shards cleanly ([`serve::signals`]).
//! * [`chaos`] — deterministic fault injection: seeded per-proc speed
//!   heterogeneity, compute jitter, and probabilistic stragglers as a
//!   [`sim::TaskCostModel`] decorator ([`chaos::PerturbedCost`]), seeded
//!   per-message latency distributions as a network-model decorator
//!   ([`chaos::JitterWire`]) — slowdown-only, so the clean analytic
//!   bounds stay sound, and pure per-entity draws, so compiled and
//!   interpreting engines stay bit-for-bit equivalent per seed; the
//!   `chaos` CLI subcommand runs N-seed ensembles and gates on tail
//!   degradation ratios (`make chaos-smoke`).
//! * [`explain`] — causal profiling: run the compiled engine with
//!   provenance observation on ([`sim::simulate_observed`], bit-identical
//!   results, one branch per phase when off), walk back from the
//!   makespan-defining finish to the *observed* critical path, and
//!   decompose the makespan into compute / exposed-latency / bandwidth /
//!   idle blame terms that sum bit-exactly ([`explain::Blame`]),
//!   cross-checked against [`analysis::critical_path`]; differential
//!   reports ([`explain::PlanDiff`]) show which α terms the overlap/CA
//!   transforms moved off the path — surfaced as the `explain` CLI
//!   subcommand, a `serve` op, and Perfetto flow events.
//! * [`cost`] — the §2.1 analytic cost model `T(b) = (M/b)α + Mβ + (MN/p + Mb)γ`.
//! * [`krylov`] — the motivating application: classic and latency-tolerant CG.
//! * [`runtime`] — PJRT artifact loading/execution (`xla` crate).
//! * [`coordinator`] — real threads+channels execution: the generic plan
//!   engine behind [`pipeline::Transformed::execute`], and the tiled PJRT
//!   engine ([`coordinator::tile`]) with its per-problem geometries.
//! * [`telemetry`] — observability: a serde-free metrics registry
//!   (counters / gauges / log-bucketed histograms with p50/p90/p99) and
//!   structured [`telemetry::SpanRecord`]s behind a global-but-injectable
//!   [`telemetry::Recorder`] whose disabled path is a single branch —
//!   serve requests get ids and phase breakdowns, tuner searches get
//!   per-candidate eval/prune timelines, and the compiled engine samples
//!   event-loop stats without giving up its allocation-free hot loop
//!   (`trace` CLI subcommand gates the overhead in CI).
//! * [`trace`] — exporters: Gantt charts, CSV series for the figures,
//!   and the Chrome/Perfetto trace writer that merges simulator spans
//!   with telemetry spans ([`trace::chrome`]).
//! * [`config`] — experiment presets and a small key=value config parser.
//! * [`figures`] — regenerates every paper figure's data.
//! * [`prop`] — in-repo property-testing harness (no external deps vendored).

pub mod analysis;
pub mod chaos;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod explain;
pub mod figures;
pub mod graph;
pub mod imp;
pub mod krylov;
pub mod partition;
pub mod pipeline;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod stencil;
pub mod telemetry;
pub mod trace;
pub mod transform;
pub mod tune;
pub mod util;

pub use graph::{ProcId, TaskGraph, TaskId};
pub use pipeline::{Pipeline, RunReport, Workload};
pub use transform::{CaSchedule, HaloMode, TransformOptions};
pub use tune::Tuner;
