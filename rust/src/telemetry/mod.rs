//! Telemetry: zero-overhead-when-off metrics and structured spans.
//!
//! ## Module map
//!
//! | item | role |
//! |------|------|
//! | [`metrics`] | counters, gauges, log-bucketed histograms, [`Registry`] |
//! | [`span`] | [`SpanRecord`] — one closed interval on a named track |
//! | [`Recorder`] | a registry + span buffer + monotonic clock epoch |
//! | [`enabled`] / [`with`] | the single-branch gate every hot path uses |
//!
//! ## Record → aggregate → export
//!
//! Instrumentation sites (serve request handling, tuner searches, the
//! compiled engine's event loop) *record* into a [`Recorder`]: scalar
//! facts go to the [`Registry`] (atomics, wait-free), intervals become
//! [`SpanRecord`]s in a bounded buffer.  The registry *aggregates* in
//! place — histograms bucket as they record, so p50/p90/p99 are O(512)
//! reads at any time.  *Export* is pull-based: `Registry::prometheus()`
//! renders text exposition (the serve `metrics` op and `metrics=`
//! periodic dump), and `trace::chrome::chrome_trace_with_telemetry`
//! merges drained spans with simulator `BusySpan`s into one
//! Perfetto-loadable Chrome trace.
//!
//! ## The zero-overhead contract
//!
//! The global recorder is gated by one `AtomicBool`: when telemetry is
//! disabled, instrumented code pays exactly one relaxed load and a
//! branch ([`enabled`]) — no locks, no allocation, no time reads.  The
//! compiled engine additionally hoists that branch out of its event
//! loop, so the allocation-free hot path of PR 5 is untouched when
//! telemetry is off.  `make trace-smoke` gates this: disabled-telemetry
//! engine throughput must stay within 3% of the un-instrumented
//! baseline.
//!
//! The recorder is global-but-injectable: library code reads the global
//! via [`with`], while servers and tests can carry their own
//! `Arc<Recorder>` (e.g. `Server::with_recorder`) so parallel tests
//! never share state through the global.

#![deny(missing_docs)]

pub mod metrics;
pub mod span;

pub use metrics::{Counter, Gauge, Histogram, Registry};
pub use span::SpanRecord;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Cap on buffered spans per recorder; past this, spans are counted as
/// dropped instead of buffered (bounded memory under runaway load).
const SPAN_CAP: usize = 1 << 16;

/// A metrics registry plus span buffer with a common clock epoch.
#[derive(Debug)]
pub struct Recorder {
    /// Counters / gauges / histograms recorded against this recorder.
    pub registry: Registry,
    spans: Mutex<Vec<SpanRecord>>,
    dropped: AtomicU64,
    next_search: AtomicU64,
    epoch: Instant,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder {
            registry: Registry::default(),
            spans: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
            next_search: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }
}

impl Recorder {
    /// A fresh recorder whose epoch is "now".
    pub fn new() -> Self {
        Self::default()
    }

    /// Microseconds since this recorder's epoch (monotonic).
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Buffer one closed span; drops (and counts) past [`SPAN_CAP`].
    pub fn record_span(&self, track: &'static str, tid: u64, name: String, start_us: f64, end_us: f64) {
        let mut spans = self.spans.lock().unwrap_or_else(|p| p.into_inner());
        if spans.len() >= SPAN_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        spans.push(SpanRecord { track, name, tid, start_us, dur_us: (end_us - start_us).max(0.0) });
    }

    /// Take all buffered spans, leaving the buffer empty.
    pub fn drain_spans(&self) -> Vec<SpanRecord> {
        let mut spans = self.spans.lock().unwrap_or_else(|p| p.into_inner());
        std::mem::take(&mut *spans)
    }

    /// Copy of the buffered spans without draining them.
    pub fn snapshot_spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Number of currently buffered spans.
    pub fn span_count(&self) -> usize {
        self.spans.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Spans dropped because the buffer was full.
    pub fn dropped_spans(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Allocate the next tuner search id (unique per recorder).
    pub fn next_search_id(&self) -> u64 {
        self.next_search.fetch_add(1, Ordering::Relaxed)
    }

    /// The full Prometheus text exposition: the registry's metrics plus
    /// the span buffer's own health — `telemetry_spans_dropped` (spans
    /// lost past the buffer cap; silent loss must be observable) and
    /// `telemetry_spans_buffered` (current depth).  Surfaces everywhere
    /// [`Registry::prometheus`] used to be dumped directly.
    pub fn prometheus(&self) -> String {
        let mut out = self.registry.prometheus();
        out.push_str(&format!(
            "# TYPE telemetry_spans_dropped counter\ntelemetry_spans_dropped {}\n",
            self.dropped_spans()
        ));
        out.push_str(&format!(
            "# TYPE telemetry_spans_buffered gauge\ntelemetry_spans_buffered {}\n",
            self.span_count()
        ));
        out
    }

    /// Shorthand: get-or-create a counter in this recorder's registry.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.registry.counter(name)
    }

    /// Shorthand: get-or-create a gauge in this recorder's registry.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.registry.gauge(name)
    }

    /// Shorthand: get-or-create a histogram in this recorder's registry.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.registry.histogram(name)
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: RwLock<Option<Arc<Recorder>>> = RwLock::new(None);

/// Is global telemetry on?  One relaxed load — this is the single
/// branch disabled hot paths pay.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the global gate on or off (the installed recorder is kept).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Install `rec` as the global recorder (replacing any previous one)
/// and enable telemetry.
pub fn install(rec: Arc<Recorder>) {
    let mut g = GLOBAL.write().unwrap_or_else(|p| p.into_inner());
    *g = Some(rec);
    drop(g);
    set_enabled(true);
}

/// Install a fresh recorder if none is present, enable telemetry, and
/// return the active recorder.
pub fn init() -> Arc<Recorder> {
    let mut g = GLOBAL.write().unwrap_or_else(|p| p.into_inner());
    let rec = g.get_or_insert_with(|| Arc::new(Recorder::new())).clone();
    drop(g);
    set_enabled(true);
    rec
}

/// The global recorder, if telemetry is enabled and one is installed.
pub fn recorder() -> Option<Arc<Recorder>> {
    if !enabled() {
        return None;
    }
    GLOBAL.read().unwrap_or_else(|p| p.into_inner()).clone()
}

/// Run `f` against the global recorder when telemetry is enabled.
///
/// The canonical instrumentation shape:
/// `telemetry::with(|r| r.counter("engine.runs").add(1));`
#[inline]
pub fn with<R>(f: impl FnOnce(&Recorder) -> R) -> Option<R> {
    recorder().map(|r| f(&r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_spans_round_trip() {
        let r = Recorder::new();
        let t0 = r.now_us();
        r.record_span("serve", 1, "request:tune:1".into(), t0, t0 + 100.0);
        r.record_span("tune", 0, "search:heat1d".into(), t0, t0 + 50.0);
        assert_eq!(r.span_count(), 2);
        let spans = r.drain_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(r.span_count(), 0);
        assert_eq!(spans[0].track, "serve");
        assert!((spans[0].dur_us - 100.0).abs() < 1e-9);
        assert_eq!(r.dropped_spans(), 0);
    }

    #[test]
    fn negative_durations_clamp_to_zero() {
        let r = Recorder::new();
        r.record_span("serve", 0, "x".into(), 10.0, 5.0);
        assert_eq!(r.drain_spans()[0].dur_us, 0.0);
    }

    #[test]
    fn recorder_prometheus_surfaces_span_buffer_health() {
        let r = Recorder::new();
        r.counter("serve.requests").add(3);
        r.record_span("serve", 0, "request:tune:1".into(), 0.0, 5.0);
        let text = r.prometheus();
        assert!(text.contains("# TYPE serve_requests counter\nserve_requests 3\n"));
        assert!(text.contains("# TYPE telemetry_spans_dropped counter\ntelemetry_spans_dropped 0\n"));
        assert!(text.contains("# TYPE telemetry_spans_buffered gauge\ntelemetry_spans_buffered 1\n"));
        // Appending the buffer health must keep the exposition shape:
        // every non-comment line is `name maybe{labels} value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn search_ids_are_unique_and_monotone() {
        let r = Recorder::new();
        let a = r.next_search_id();
        let b = r.next_search_id();
        assert!(b > a);
    }

    // The one test that touches global state: install/enable/disable in
    // a single #[test] so parallel unit tests never race on the global.
    #[test]
    fn global_gate_is_a_single_branch() {
        assert!(!enabled());
        assert!(recorder().is_none());
        assert!(with(|_| ()).is_none());
        let rec = Arc::new(Recorder::new());
        install(rec.clone());
        assert!(enabled());
        with(|r| r.counter("t.test").add(1)).expect("installed");
        assert_eq!(rec.counter("t.test").get(), 1);
        set_enabled(false);
        assert!(recorder().is_none(), "disabled gate hides the recorder");
        set_enabled(true);
        let again = init(); // init keeps the installed recorder
        assert_eq!(again.counter("t.test").get(), 1);
        set_enabled(false);
    }
}
