//! Structured span records: who did what, on which track, and when.
//!
//! A [`SpanRecord`] is one closed interval on a named track — the
//! telemetry-side analogue of the simulator's `BusySpan`.  Tracks are
//! static strings ("serve", "serve.phase", "tune", "engine") so
//! recording never allocates for the track name; the span name is the
//! only owned string, built once per span by the instrumentation site.

/// One recorded interval on a telemetry track.
///
/// Times are microseconds since the owning recorder's epoch, matching
/// the Chrome trace format's `ts`/`dur` units so export is a straight
/// copy.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Track the span belongs to ("serve", "serve.phase", "tune", "engine").
    pub track: &'static str,
    /// Human-readable span name, e.g. `request:tune:42` or `eval:b4`.
    pub name: String,
    /// Lane within the track: serve request id, tuner search id, …
    pub tid: u64,
    /// Start time in microseconds since the recorder's epoch.
    pub start_us: f64,
    /// Duration in microseconds (>= 0).
    pub dur_us: f64,
}

impl SpanRecord {
    /// End time in microseconds since the recorder's epoch.
    pub fn end_us(&self) -> f64 {
        self.start_us + self.dur_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_is_start_plus_duration() {
        let s = SpanRecord {
            track: "serve",
            name: "request:tune:1".into(),
            tid: 1,
            start_us: 10.0,
            dur_us: 5.0,
        };
        assert_eq!(s.end_us(), 15.0);
    }
}
