//! Metric primitives: counters, gauges, and log-bucketed histograms.
//!
//! Everything here is lock-free on the record path (atomics only); the
//! registry maps are behind mutexes but are touched once per metric
//! *lookup*, and callers are expected to either hold the returned `Arc`
//! or look up by name outside hot loops.  No serde: the Prometheus
//! exposition is hand-rolled text, like every other serializer in this
//! repo.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A last-written-wins instantaneous value (with a high-water helper).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is larger (high-water mark).
    pub fn set_max(&self, v: u64) {
        self.value.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Number of histogram buckets: 64 octaves x 8 sub-buckets.
const BUCKETS: usize = 512;
/// Sub-buckets per octave (power of two) — resolution ~9% per bucket.
const SUBS: f64 = 8.0;
/// The smallest representable exponent: bucket 0 starts at 2^-20
/// (~1 microsecond when values are milliseconds).
const MIN_EXP: f64 = -20.0;

/// A log-linear latency histogram.
///
/// Values are bucketed by `floor((log2(v) - MIN_EXP) * SUBS)` into 512
/// buckets spanning 2^-20 .. 2^44, giving ~9% relative error across 19
/// decades — plenty for micro-benchmark-to-batch-job latencies.  All
/// state is atomic; `record` is wait-free.
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    count: AtomicU64,
    /// Sum of recorded values, stored as f64 bits and updated by CAS.
    sum_bits: AtomicU64,
    /// Maximum recorded value, stored as f64 bits (values are >= 0).
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
            max_bits: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_of(v: f64) -> usize {
        if v <= 0.0 || !v.is_finite() {
            return 0;
        }
        let b = ((v.log2() - MIN_EXP) * SUBS).floor();
        b.clamp(0.0, (BUCKETS - 1) as f64) as usize
    }

    /// The representative (geometric-midpoint) value of bucket `i`.
    fn bucket_value(i: usize) -> f64 {
        let exp = MIN_EXP + (i as f64 + 0.5) / SUBS;
        exp.exp2()
    }

    /// Record one observation.
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let mut cur = self.max_bits.load(Ordering::Relaxed);
        while v > f64::from_bits(cur) {
            match self.max_bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded observations.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Largest recorded observation (0.0 when empty).
    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Mean of recorded observations (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// The value at quantile `q` in [0, 1], by cumulative bucket walk.
    ///
    /// Returns the geometric midpoint of the bucket holding the q-th
    /// observation, so the answer carries the bucket's ~9% resolution.
    /// Returns 0.0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Self::bucket_value(i);
            }
        }
        self.max()
    }
}

/// A named collection of counters, gauges, and histograms.
///
/// Lookups are get-or-create by name; the maps are `BTreeMap`s so the
/// Prometheus exposition is deterministically ordered.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Registry {
    /// Get or create the counter named `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = lock_recover(&self.counters);
        map.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = lock_recover(&self.gauges);
        map.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = lock_recover(&self.histograms);
        map.entry(name.to_string()).or_default().clone()
    }

    /// Look up a histogram without creating it.
    pub fn find_histogram(&self, name: &str) -> Option<Arc<Histogram>> {
        lock_recover(&self.histograms).get(name).cloned()
    }

    /// Names of all registered histograms (sorted).
    pub fn histogram_names(&self) -> Vec<String> {
        lock_recover(&self.histograms).keys().cloned().collect()
    }

    /// Render the whole registry as Prometheus text exposition.
    ///
    /// Counters and gauges become plain samples; histograms become
    /// summary-style quantile samples plus `_sum`/`_count`.  Metric
    /// names are sanitized to `[a-zA-Z0-9_]` (dots become underscores).
    pub fn prometheus(&self) -> String {
        fn sane(name: &str) -> String {
            name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
        }
        let mut out = String::new();
        for (name, c) in lock_recover(&self.counters).iter() {
            let n = sane(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
        }
        for (name, g) in lock_recover(&self.gauges).iter() {
            let n = sane(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
        }
        for (name, h) in lock_recover(&self.histograms).iter() {
            let n = sane(name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            for (label, q) in [("0.5", 0.50), ("0.9", 0.90), ("0.99", 0.99)] {
                out.push_str(&format!("{n}{{quantile=\"{label}\"}} {}\n", h.percentile(q)));
            }
            out.push_str(&format!("{n}_sum {}\n{n}_count {}\n", h.sum(), h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::default();
        r.counter("a").add(2);
        r.counter("a").add(3);
        assert_eq!(r.counter("a").get(), 5);
        r.gauge("g").set(7);
        r.gauge("g").set_max(3); // lower: no-op
        assert_eq!(r.gauge("g").get(), 7);
        r.gauge("g").set_max(11);
        assert_eq!(r.gauge("g").get(), 11);
    }

    #[test]
    fn histogram_percentiles_track_the_distribution() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record(i as f64); // uniform 1..1000
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        // Bucket resolution is ~9%, so allow 15% slack on each side.
        assert!((p50 - 500.0).abs() / 500.0 < 0.15, "p50 = {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.15, "p99 = {p99}");
        assert!(p50 < p99);
        assert_eq!(h.max(), 1000.0);
    }

    #[test]
    fn histogram_edge_values_do_not_panic() {
        let h = Histogram::default();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN); // dropped
        h.record(f64::INFINITY); // dropped
        h.record(1e300); // clamped to top bucket
        h.record(1e-300); // clamped to bottom bucket
        assert_eq!(h.count(), 4);
        assert!(h.percentile(0.5).is_finite());
    }

    #[test]
    fn empty_histogram_percentile_is_exactly_zero() {
        // Regression: an empty histogram must pin every quantile to
        // 0.0 — never NaN — so the Prometheus exposition and JSON
        // reports stay parseable before the first observation lands.
        let h = Histogram::default();
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let p = h.percentile(q);
            assert_eq!(p.to_bits(), 0.0f64.to_bits(), "percentile({q}) = {p}");
        }
        assert_eq!(h.mean(), 0.0);
        let r = Registry::default();
        r.histogram("never.recorded");
        assert!(!r.prometheus().contains("NaN"), "{}", r.prometheus());
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::default();
        r.counter("serve.requests").add(4);
        r.gauge("engine.heap_depth_high_water").set(9);
        r.histogram("serve.request_latency_ms").record(2.0);
        let text = r.prometheus();
        assert!(text.contains("# TYPE serve_requests counter\nserve_requests 4\n"));
        assert!(text.contains("# TYPE engine_heap_depth_high_water gauge\n"));
        assert!(text.contains("serve_request_latency_ms{quantile=\"0.99\"}"));
        assert!(text.contains("serve_request_latency_ms_count 1\n"));
        // Every non-comment line is `name maybe{labels} value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "bad line: {line}");
        }
    }

    #[test]
    fn registry_lookup_is_get_or_create() {
        let r = Registry::default();
        let a = r.histogram("x");
        let b = r.histogram("x");
        a.record(1.0);
        assert_eq!(b.count(), 1);
        assert!(r.find_histogram("y").is_none());
        assert_eq!(r.histogram_names(), vec!["x".to_string()]);
    }
}
