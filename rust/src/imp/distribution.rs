//! Distributions: the IMP assignment of global indices to processors.
//!
//! A [`Distribution`] is the `u: P → 2^N` mapping of [Eijkhout 2016] — for
//! each processor, the set of indices whose values it owns.  The paper's
//! task graphs are *derived* from distributions: task `(i, step)` is owned
//! by the processor that owns index `i` under the output distribution of
//! the step's kernel.

use super::index_set::IndexSet;
use crate::graph::ProcId;

/// An assignment of the domain `[0, size)` to `nprocs` processors.
#[derive(Debug, Clone)]
pub enum Distribution {
    /// Contiguous blocks of (nearly) equal size — `⌈N/p⌉`-style splitting.
    Block { size: u64, nprocs: u32 },
    /// Round-robin: index `i` on processor `i mod p`.
    Cyclic { size: u64, nprocs: u32 },
    /// Blocks of `block` indices dealt round-robin.
    BlockCyclic { size: u64, nprocs: u32, block: u64 },
    /// Arbitrary per-processor sets (must partition the domain).
    Irregular { size: u64, parts: Vec<IndexSet> },
}

impl Distribution {
    pub fn block(size: u64, nprocs: u32) -> Self {
        assert!(nprocs > 0);
        Distribution::Block { size, nprocs }
    }

    pub fn cyclic(size: u64, nprocs: u32) -> Self {
        assert!(nprocs > 0);
        Distribution::Cyclic { size, nprocs }
    }

    pub fn block_cyclic(size: u64, nprocs: u32, block: u64) -> Self {
        assert!(nprocs > 0 && block > 0);
        Distribution::BlockCyclic { size, nprocs, block }
    }

    /// Build an irregular distribution; validates that `parts` partition
    /// the domain `[0, size)`.
    pub fn irregular(size: u64, parts: Vec<IndexSet>) -> Result<Self, String> {
        let total: usize = parts.iter().map(|s| s.len()).sum();
        if total as u64 != size {
            return Err(format!("parts cover {total} of {size} indices"));
        }
        let mut seen = vec![false; size as usize];
        for part in &parts {
            for i in part.iter() {
                if i >= size {
                    return Err(format!("index {i} out of domain {size}"));
                }
                if seen[i as usize] {
                    return Err(format!("index {i} assigned twice"));
                }
                seen[i as usize] = true;
            }
        }
        Ok(Distribution::Irregular { size, parts })
    }

    /// Domain size `N`.
    pub fn size(&self) -> u64 {
        match self {
            Distribution::Block { size, .. }
            | Distribution::Cyclic { size, .. }
            | Distribution::BlockCyclic { size, .. }
            | Distribution::Irregular { size, .. } => *size,
        }
    }

    /// Processor count `p`.
    pub fn nprocs(&self) -> u32 {
        match self {
            Distribution::Block { nprocs, .. }
            | Distribution::Cyclic { nprocs, .. }
            | Distribution::BlockCyclic { nprocs, .. } => *nprocs,
            Distribution::Irregular { parts, .. } => parts.len() as u32,
        }
    }

    /// The index set owned by processor `p` (the paper's `u(p)`).
    pub fn owned(&self, p: ProcId) -> IndexSet {
        let p64 = p.0 as u64;
        match self {
            Distribution::Block { size, nprocs } => {
                let (lo, hi) = block_bounds(*size, *nprocs, p.0);
                IndexSet::contiguous(lo, hi)
            }
            Distribution::Cyclic { size, nprocs } => {
                if p64 >= *size {
                    IndexSet::Empty
                } else {
                    IndexSet::strided(p64, *size, *nprocs as u64)
                }
            }
            Distribution::BlockCyclic { size, nprocs, block } => {
                let mut v = Vec::new();
                let mut start = p64 * block;
                while start < *size {
                    let end = (start + block).min(*size);
                    v.extend(start..end);
                    start += *nprocs as u64 * block;
                }
                IndexSet::from_indices(v)
            }
            Distribution::Irregular { parts, .. } => {
                parts.get(p.idx()).cloned().unwrap_or(IndexSet::Empty)
            }
        }
    }

    /// Owner of a single index.
    pub fn owner_of(&self, i: u64) -> ProcId {
        debug_assert!(i < self.size());
        match self {
            Distribution::Block { size, nprocs } => {
                ProcId(block_owner(*size, *nprocs, i))
            }
            Distribution::Cyclic { nprocs, .. } => ProcId((i % *nprocs as u64) as u32),
            Distribution::BlockCyclic { nprocs, block, .. } => {
                ProcId(((i / block) % *nprocs as u64) as u32)
            }
            Distribution::Irregular { parts, .. } => {
                for (p, part) in parts.iter().enumerate() {
                    if part.contains(i) {
                        return ProcId(p as u32);
                    }
                }
                unreachable!("irregular distribution validated as a partition")
            }
        }
    }
}

/// `[lo, hi)` bounds of processor `p`'s block under balanced block
/// distribution: the first `size mod p` processors get one extra index.
pub fn block_bounds(size: u64, nprocs: u32, p: u32) -> (u64, u64) {
    let np = nprocs as u64;
    let p = p as u64;
    let base = size / np;
    let extra = size % np;
    let lo = p * base + p.min(extra);
    let hi = lo + base + if p < extra { 1 } else { 0 };
    (lo, hi.min(size))
}

fn block_owner(size: u64, nprocs: u32, i: u64) -> u32 {
    // Inverse of block_bounds; O(1).
    let np = nprocs as u64;
    let base = size / np;
    let extra = size % np;
    let big = (base + 1) * extra; // indices held by the "one extra" procs
    if base == 0 {
        return i as u32; // more procs than points: point i on proc i
    }
    if i < big {
        (i / (base + 1)) as u32
    } else {
        (extra + (i - big) / base) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_partition(d: &Distribution) {
        let mut seen = vec![false; d.size() as usize];
        for p in 0..d.nprocs() {
            for i in d.owned(ProcId(p)).iter() {
                assert!(!seen[i as usize], "index {i} owned twice");
                seen[i as usize] = true;
                assert_eq!(d.owner_of(i), ProcId(p), "owner_of({i}) mismatch");
            }
        }
        assert!(seen.iter().all(|&s| s), "unowned indices remain");
    }

    #[test]
    fn block_partition_even() {
        check_partition(&Distribution::block(16, 4));
    }

    #[test]
    fn block_partition_uneven() {
        check_partition(&Distribution::block(17, 4));
        check_partition(&Distribution::block(3, 4)); // more procs than points
    }

    #[test]
    fn block_bounds_balanced() {
        // 10 over 3: sizes 4,3,3
        assert_eq!(block_bounds(10, 3, 0), (0, 4));
        assert_eq!(block_bounds(10, 3, 1), (4, 7));
        assert_eq!(block_bounds(10, 3, 2), (7, 10));
    }

    #[test]
    fn cyclic_partition() {
        check_partition(&Distribution::cyclic(13, 4));
        let d = Distribution::cyclic(10, 3);
        assert_eq!(d.owned(ProcId(1)).to_vec(), vec![1, 4, 7]);
    }

    #[test]
    fn block_cyclic_partition() {
        check_partition(&Distribution::block_cyclic(20, 3, 2));
        let d = Distribution::block_cyclic(12, 2, 3);
        assert_eq!(d.owned(ProcId(0)).to_vec(), vec![0, 1, 2, 6, 7, 8]);
    }

    #[test]
    fn irregular_partition_validated() {
        let parts = vec![IndexSet::contiguous(0, 3), IndexSet::contiguous(3, 8)];
        let d = Distribution::irregular(8, parts).unwrap();
        check_partition(&d);
        // Overlap rejected:
        let bad = Distribution::irregular(
            4,
            vec![IndexSet::contiguous(0, 3), IndexSet::contiguous(2, 4)],
        );
        assert!(bad.is_err());
        // Hole rejected:
        let bad2 = Distribution::irregular(
            5,
            vec![IndexSet::contiguous(0, 2), IndexSet::contiguous(3, 5)],
        );
        assert!(bad2.is_err());
    }
}
