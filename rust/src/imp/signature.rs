//! Signature functions: the IMP description of data dependence.
//!
//! A kernel's **signature** σ maps an output index to the set of input
//! indices it reads — for a 3-point stencil, `σ(i) = {i−1, i, i+1}`.  The
//! **β-distribution** of [Eijkhout 2016] is `β(p) = σ(u(p))`: what
//! processor `p` must *have* to compute what it *owns*; `β(p) − u(p)` is
//! exactly the ghost region, and its derivation is what lets IMP construct
//! the task graph (and this paper transform it) mechanically.

use super::distribution::Distribution;
use super::index_set::IndexSet;
use crate::graph::ProcId;

/// A dependence signature over a 1-D domain.
#[derive(Debug, Clone)]
pub enum Signature {
    /// σ(i) = {i + o : o ∈ offsets}, clipped to the domain.
    /// `Stencil(vec![-1, 0, 1])` is the paper's eq. (1).
    Stencil(Vec<i64>),
    /// σ(i) = sparsity row i of a CSR matrix (irregular dependence).
    Sparse { rowptr: Vec<u32>, colidx: Vec<u32> },
    /// σ(i) = the whole domain (a reduction / collective).
    AllToAll,
}

impl Signature {
    /// Symmetric stencil of radius `r`: offsets `-r..=r`.
    pub fn stencil_radius(r: u32) -> Self {
        Signature::Stencil((-(r as i64)..=r as i64).collect())
    }

    /// σ applied to a single index, clipped to `[0, domain)`.
    pub fn of_index(&self, i: u64, domain: u64) -> Vec<u64> {
        match self {
            Signature::Stencil(offsets) => offsets
                .iter()
                .filter_map(|&o| {
                    let v = i as i64 + o;
                    (v >= 0 && (v as u64) < domain).then_some(v as u64)
                })
                .collect(),
            Signature::Sparse { rowptr, colidx } => {
                let (a, b) = (rowptr[i as usize] as usize, rowptr[i as usize + 1] as usize);
                colidx[a..b].iter().map(|&c| c as u64).collect()
            }
            Signature::AllToAll => (0..domain).collect(),
        }
    }

    /// σ applied to a set: `σ(S) = ∪_{i∈S} σ(i)`, clipped to the domain.
    pub fn of_set(&self, s: &IndexSet, domain: u64) -> IndexSet {
        match self {
            Signature::Stencil(offsets) => {
                let mut acc = IndexSet::Empty;
                for &o in offsets {
                    acc = acc.union(&s.shift_clipped(o, domain));
                }
                acc
            }
            Signature::Sparse { .. } => {
                let mut v: Vec<u64> = Vec::new();
                for i in s.iter() {
                    v.extend(self.of_index(i, domain));
                }
                IndexSet::from_indices(v)
            }
            Signature::AllToAll => IndexSet::contiguous(0, domain),
        }
    }

    /// The β-distribution: `β(p) = σ(u(p))` — everything `p` needs.
    pub fn beta(&self, u: &Distribution, p: ProcId) -> IndexSet {
        self.of_set(&u.owned(p), u.size())
    }

    /// The ghost region: `β(p) − u(p)` — what `p` must receive.
    pub fn ghost(&self, u: &Distribution, p: ProcId) -> IndexSet {
        self.beta(u, p).difference(&u.owned(p))
    }

    /// Maximum dependence radius (for stencils; `None` for irregular).
    pub fn radius(&self) -> Option<u32> {
        match self {
            Signature::Stencil(offsets) => {
                offsets.iter().map(|o| o.unsigned_abs() as u32).max()
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stencil_of_index_clips_boundaries() {
        let s = Signature::stencil_radius(1);
        assert_eq!(s.of_index(0, 10), vec![0, 1]);
        assert_eq!(s.of_index(5, 10), vec![4, 5, 6]);
        assert_eq!(s.of_index(9, 10), vec![8, 9]);
    }

    #[test]
    fn beta_is_block_plus_halo() {
        // p1 of 3 over [0,12): owns [4,8); β = [3,9); ghost = {3, 8}.
        let u = Distribution::block(12, 3);
        let s = Signature::stencil_radius(1);
        assert_eq!(s.beta(&u, ProcId(1)), IndexSet::contiguous(3, 9));
        assert_eq!(s.ghost(&u, ProcId(1)).to_vec(), vec![3, 8]);
    }

    #[test]
    fn edge_proc_ghost_one_sided() {
        let u = Distribution::block(12, 3);
        let s = Signature::stencil_radius(1);
        assert_eq!(s.ghost(&u, ProcId(0)).to_vec(), vec![4]);
        assert_eq!(s.ghost(&u, ProcId(2)).to_vec(), vec![7]);
    }

    #[test]
    fn wider_stencil_wider_ghost() {
        let u = Distribution::block(20, 2);
        let s = Signature::stencil_radius(3);
        assert_eq!(s.ghost(&u, ProcId(0)).to_vec(), vec![10, 11, 12]);
    }

    #[test]
    fn sparse_signature_rows() {
        // 3 rows: row0 -> {0,1}, row1 -> {0,1,2}, row2 -> {2}
        let sig = Signature::Sparse { rowptr: vec![0, 2, 5, 6], colidx: vec![0, 1, 0, 1, 2, 2] };
        assert_eq!(sig.of_index(1, 3), vec![0, 1, 2]);
        let s = sig.of_set(&IndexSet::contiguous(0, 2), 3);
        assert_eq!(s.to_vec(), vec![0, 1, 2]);
    }

    #[test]
    fn all_to_all_signature() {
        let u = Distribution::block(6, 2);
        let s = Signature::AllToAll;
        assert_eq!(s.ghost(&u, ProcId(0)), IndexSet::contiguous(3, 6));
    }

    #[test]
    fn radius_reporting() {
        assert_eq!(Signature::stencil_radius(2).radius(), Some(2));
        assert_eq!(Signature::AllToAll.radius(), None);
    }
}
