//! The Integrative Model for Parallelism (IMP) formalism.
//!
//! Implements the machinery of [Eijkhout 2016, arXiv:1602.02409] that the
//! paper builds on: index sets, distributions `u: P → 2^N`, dependence
//! signatures σ, the derived β-distribution `β(p) = σ(u(p))`, and the
//! unrolling of data-parallel programs into distributed task graphs.
//!
//! The pipeline is:
//!
//! ```text
//! Program (distributions + signatures)
//!     --unroll()-->  TaskGraph  --transform::communication_avoiding-->  CaSchedule
//! ```
//!
//! which is exactly the paper's claim of a "communication avoiding
//! compiler": an *arbitrary* computation expressed as data-parallel steps
//! is turned into a latency-tolerant one mechanically.

mod distribution;
mod index_set;
mod program;
mod signature;

pub use distribution::{block_bounds, Distribution};
pub use index_set::IndexSet;
pub use program::{Program, Step};
pub use signature::Signature;
