//! Index sets — the ground objects of the IMP formalism [Eijkhout 2016].
//!
//! An [`IndexSet`] is a finite set of global indices with structure-aware
//! representations (contiguous / strided / explicit) so the common cases
//! (block distributions, stencil shifts) stay O(1) in memory.

/// A finite set of `u64` indices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IndexSet {
    /// Empty set.
    Empty,
    /// `[lo, hi)` — the workhorse of block distributions.
    Contiguous { lo: u64, hi: u64 },
    /// `{lo, lo+stride, ...} ∩ [lo, hi)`.
    Strided { lo: u64, hi: u64, stride: u64 },
    /// Explicit sorted, deduplicated indices (irregular sets).
    Indexed(Vec<u64>),
}

impl IndexSet {
    /// The half-open interval `[lo, hi)`; empty if `lo >= hi`.
    pub fn contiguous(lo: u64, hi: u64) -> Self {
        if lo >= hi {
            IndexSet::Empty
        } else {
            IndexSet::Contiguous { lo, hi }
        }
    }

    /// Strided set; normalizes trivial cases.
    pub fn strided(lo: u64, hi: u64, stride: u64) -> Self {
        assert!(stride > 0);
        if lo >= hi {
            IndexSet::Empty
        } else if stride == 1 {
            IndexSet::Contiguous { lo, hi }
        } else {
            // Normalize hi to the last element + 1 for canonical equality.
            let last = lo + ((hi - 1 - lo) / stride) * stride;
            IndexSet::Strided { lo, hi: last + 1, stride }
        }
    }

    /// From an arbitrary list (sorted + deduplicated internally, and
    /// downgraded to `Contiguous` when dense).
    pub fn from_indices(mut v: Vec<u64>) -> Self {
        v.sort_unstable();
        v.dedup();
        if v.is_empty() {
            return IndexSet::Empty;
        }
        let (lo, hi) = (v[0], *v.last().unwrap() + 1);
        if (hi - lo) as usize == v.len() {
            return IndexSet::Contiguous { lo, hi };
        }
        IndexSet::Indexed(v)
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, IndexSet::Empty)
    }

    /// Cardinality.
    pub fn len(&self) -> usize {
        match self {
            IndexSet::Empty => 0,
            IndexSet::Contiguous { lo, hi } => (hi - lo) as usize,
            IndexSet::Strided { lo, hi, stride } => ((hi - lo) as usize).div_ceil(*stride as usize),
            IndexSet::Indexed(v) => v.len(),
        }
    }

    /// Membership test.
    pub fn contains(&self, i: u64) -> bool {
        match self {
            IndexSet::Empty => false,
            IndexSet::Contiguous { lo, hi } => (*lo..*hi).contains(&i),
            IndexSet::Strided { lo, hi, stride } => i >= *lo && i < *hi && (i - lo) % stride == 0,
            IndexSet::Indexed(v) => v.binary_search(&i).is_ok(),
        }
    }

    /// Iterate in ascending order.
    pub fn iter(&self) -> Box<dyn Iterator<Item = u64> + '_> {
        match self {
            IndexSet::Empty => Box::new(std::iter::empty()),
            IndexSet::Contiguous { lo, hi } => Box::new(*lo..*hi),
            IndexSet::Strided { lo, hi, stride } => Box::new((*lo..*hi).step_by(*stride as usize)),
            IndexSet::Indexed(v) => Box::new(v.iter().copied()),
        }
    }

    /// Materialize to a sorted vector.
    pub fn to_vec(&self) -> Vec<u64> {
        self.iter().collect()
    }

    /// Shift every index by `delta`, dropping results outside `[0, domain)`.
    /// This is the σ-application for one stencil offset.
    pub fn shift_clipped(&self, delta: i64, domain: u64) -> IndexSet {
        let sh = |i: u64| -> Option<u64> {
            let v = i as i64 + delta;
            if v < 0 || v as u64 >= domain {
                None
            } else {
                Some(v as u64)
            }
        };
        match self {
            IndexSet::Empty => IndexSet::Empty,
            IndexSet::Contiguous { lo, hi } => {
                let nlo = (*lo as i64 + delta).max(0) as u64;
                let nhi_i = *hi as i64 + delta;
                let nhi = (nhi_i.max(0) as u64).min(domain);
                IndexSet::contiguous(nlo.min(domain), nhi)
            }
            IndexSet::Strided { .. } | IndexSet::Indexed(_) => {
                IndexSet::from_indices(self.iter().filter_map(sh).collect())
            }
        }
    }

    /// Set union.
    pub fn union(&self, other: &IndexSet) -> IndexSet {
        match (self, other) {
            (IndexSet::Empty, x) | (x, IndexSet::Empty) => x.clone(),
            (IndexSet::Contiguous { lo: a, hi: b }, IndexSet::Contiguous { lo: c, hi: d })
                if *c <= *b && *a <= *d =>
            {
                IndexSet::contiguous(*a.min(c), *b.max(d))
            }
            _ => {
                let mut v = self.to_vec();
                v.extend(other.iter());
                IndexSet::from_indices(v)
            }
        }
    }

    /// Set intersection.
    pub fn intersect(&self, other: &IndexSet) -> IndexSet {
        match (self, other) {
            (IndexSet::Empty, _) | (_, IndexSet::Empty) => IndexSet::Empty,
            (IndexSet::Contiguous { lo: a, hi: b }, IndexSet::Contiguous { lo: c, hi: d }) => {
                IndexSet::contiguous(*a.max(c), *b.min(d))
            }
            _ => IndexSet::from_indices(self.iter().filter(|&i| other.contains(i)).collect()),
        }
    }

    /// Set difference `self − other`.
    pub fn difference(&self, other: &IndexSet) -> IndexSet {
        match (self, other) {
            (IndexSet::Empty, _) => IndexSet::Empty,
            (x, IndexSet::Empty) => x.clone(),
            _ => IndexSet::from_indices(self.iter().filter(|&i| !other.contains(i)).collect()),
        }
    }

    /// True iff `self ⊆ other`.
    pub fn is_subset(&self, other: &IndexSet) -> bool {
        self.iter().all(|i| other.contains(i))
    }

    /// Smallest and largest element, if non-empty.
    pub fn bounds(&self) -> Option<(u64, u64)> {
        match self {
            IndexSet::Empty => None,
            IndexSet::Contiguous { lo, hi } => Some((*lo, hi - 1)),
            IndexSet::Strided { lo, hi, stride } => {
                Some((*lo, lo + ((hi - 1 - lo) / stride) * stride))
            }
            IndexSet::Indexed(v) => Some((v[0], *v.last().unwrap())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_basics() {
        let s = IndexSet::contiguous(3, 8);
        assert_eq!(s.len(), 5);
        assert!(s.contains(3) && s.contains(7) && !s.contains(8));
        assert_eq!(s.to_vec(), vec![3, 4, 5, 6, 7]);
        assert_eq!(s.bounds(), Some((3, 7)));
    }

    #[test]
    fn empty_normalization() {
        assert!(IndexSet::contiguous(5, 5).is_empty());
        assert!(IndexSet::from_indices(vec![]).is_empty());
        assert_eq!(IndexSet::from_indices(vec![2, 3, 4]), IndexSet::contiguous(2, 5));
    }

    #[test]
    fn strided_basics() {
        let s = IndexSet::strided(0, 10, 3); // {0,3,6,9}
        assert_eq!(s.len(), 4);
        assert!(s.contains(6) && !s.contains(7));
        assert_eq!(s.to_vec(), vec![0, 3, 6, 9]);
        assert_eq!(s.bounds(), Some((0, 9)));
    }

    #[test]
    fn strided_normalizes_to_contiguous() {
        assert_eq!(IndexSet::strided(2, 6, 1), IndexSet::contiguous(2, 6));
    }

    #[test]
    fn shift_clipped_contiguous() {
        let s = IndexSet::contiguous(0, 5);
        assert_eq!(s.shift_clipped(-1, 100), IndexSet::contiguous(0, 4));
        assert_eq!(s.shift_clipped(2, 6), IndexSet::contiguous(2, 6));
        assert!(s.shift_clipped(-10, 100).is_empty());
    }

    #[test]
    fn shift_clipped_indexed() {
        let s = IndexSet::from_indices(vec![0, 5, 9]);
        assert_eq!(s.shift_clipped(1, 10).to_vec(), vec![1, 6]);
    }

    #[test]
    fn union_merges_overlapping_intervals() {
        let a = IndexSet::contiguous(0, 5);
        let b = IndexSet::contiguous(3, 9);
        assert_eq!(a.union(&b), IndexSet::contiguous(0, 9));
        // Adjacent intervals merge too.
        let c = IndexSet::contiguous(9, 12);
        assert_eq!(b.union(&c), IndexSet::contiguous(3, 12));
    }

    #[test]
    fn union_disjoint_goes_indexed() {
        let a = IndexSet::contiguous(0, 2);
        let b = IndexSet::contiguous(5, 7);
        let u = a.union(&b);
        assert_eq!(u.to_vec(), vec![0, 1, 5, 6]);
    }

    #[test]
    fn intersect_and_difference() {
        let a = IndexSet::contiguous(0, 10);
        let b = IndexSet::contiguous(5, 15);
        assert_eq!(a.intersect(&b), IndexSet::contiguous(5, 10));
        assert_eq!(a.difference(&b), IndexSet::contiguous(0, 5));
        let s = IndexSet::strided(0, 10, 2);
        assert_eq!(a.intersect(&s).to_vec(), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn subset_relation() {
        assert!(IndexSet::contiguous(2, 4).is_subset(&IndexSet::contiguous(0, 10)));
        assert!(!IndexSet::contiguous(2, 11).is_subset(&IndexSet::contiguous(0, 10)));
        assert!(IndexSet::Empty.is_subset(&IndexSet::Empty));
    }
}
