//! Data-parallel programs and their unrolling into distributed task graphs.
//!
//! This is the "higher level description of parallel algorithms" the paper
//! derives task graphs from: a [`Program`] is a sequence of data-parallel
//! steps, each step a *kernel* in IMP terms — an output [`Distribution`]
//! plus a dependence [`Signature`].  `unroll()` mechanically produces the
//! task graph that §3 then transforms; the "communication avoiding
//! compiler" of the paper is `Program::unroll` + `transform::communication_avoiding`.

use super::distribution::Distribution;
use super::signature::Signature;
use crate::graph::{GraphBuilder, ProcId, TaskGraph, TaskId};

/// One data-parallel operation: produce a new dataset distributed as
/// `out`, where element `i` reads `sig.of_index(i)` of the previous level.
#[derive(Debug, Clone)]
pub struct Step {
    pub sig: Signature,
    pub out: Distribution,
    pub name: String,
}

/// A straight-line sequence of data-parallel steps over one dataset chain.
#[derive(Debug, Clone)]
pub struct Program {
    /// Distribution of the initial data (level 0).
    pub input: Distribution,
    pub steps: Vec<Step>,
}

impl Program {
    pub fn new(input: Distribution) -> Self {
        Program { input, steps: Vec::new() }
    }

    /// Append a step with the same distribution as the input (the common
    /// "iterate in place" pattern of grid updates).
    pub fn then(mut self, name: &str, sig: Signature) -> Self {
        let out = self.steps.last().map(|s| s.out.clone()).unwrap_or_else(|| self.input.clone());
        self.steps.push(Step { sig, out, name: name.to_string() });
        self
    }

    /// Append a step with an explicit output distribution (redistribution).
    pub fn then_dist(mut self, name: &str, sig: Signature, out: Distribution) -> Self {
        self.steps.push(Step { sig, out, name: name.to_string() });
        self
    }

    /// `m` repetitions of the same step (the paper's "sequence of sparse
    /// matrix-vector products", eq. (1) iterated).
    pub fn iterate(mut self, name: &str, sig: Signature, m: u32) -> Self {
        for k in 0..m {
            let s = sig.clone();
            self = self.then(&format!("{name}[{k}]"), s);
        }
        self
    }

    /// Number of levels in the unrolled graph (steps + input level).
    pub fn num_levels(&self) -> u32 {
        self.steps.len() as u32 + 1
    }

    /// Unroll into a distributed task graph.
    ///
    /// Task `(i, k)` (element `i` of level `k`) is owned by
    /// `steps[k-1].out.owner_of(i)` and depends on the level `k−1` tasks at
    /// `σ_k(i)`.  Level-0 tasks are `Input` data under `self.input`.
    pub fn unroll(&self) -> TaskGraph {
        let n = self.input.size();
        let nprocs = self
            .steps
            .iter()
            .map(|s| s.out.nprocs())
            .chain(std::iter::once(self.input.nprocs()))
            .max()
            .unwrap();
        let nlevels = self.steps.len();
        let approx_edges: usize = self
            .steps
            .iter()
            .map(|s| match &s.sig {
                Signature::Stencil(o) => o.len() * n as usize,
                Signature::Sparse { colidx, .. } => colidx.len(),
                Signature::AllToAll => (n * n) as usize,
            })
            .sum();
        let mut b = GraphBuilder::with_capacity(
            nprocs,
            (nlevels + 1) * n as usize,
            approx_edges,
        );

        // Level 0: inputs.
        let mut prev: Vec<TaskId> =
            (0..n).map(|i| b.add_input(self.input.owner_of(i), i)).collect();

        let mut scratch: Vec<TaskId> = Vec::new();
        for (k, step) in self.steps.iter().enumerate() {
            debug_assert_eq!(step.out.size(), n, "domain size must be constant along the chain");
            scratch.clear();
            scratch.reserve(n as usize);
            for i in 0..n {
                let owner: ProcId = step.out.owner_of(i);
                // Hot path: add the task bare and push edges directly —
                // `sig.of_index` allocation + a preds Vec per task costs
                // ~25% of build time on multi-million-task graphs.
                let t = b.add_task(owner, (k + 1) as u32, i, &[]);
                match &step.sig {
                    Signature::Stencil(offsets) => {
                        for &o in offsets {
                            let j = i as i64 + o;
                            if j >= 0 && (j as u64) < n {
                                b.add_pred(t, prev[j as usize]);
                            }
                        }
                    }
                    Signature::Sparse { rowptr, colidx } => {
                        let (a0, a1) =
                            (rowptr[i as usize] as usize, rowptr[i as usize + 1] as usize);
                        for &c in &colidx[a0..a1] {
                            b.add_pred(t, prev[c as usize]);
                        }
                    }
                    Signature::AllToAll => {
                        for &pt in prev.iter() {
                            b.add_pred(t, pt);
                        }
                    }
                }
                scratch.push(t);
            }
            std::mem::swap(&mut prev, &mut scratch);
        }
        b.finish().expect("unrolled program graphs are acyclic by construction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskKind;

    #[test]
    fn unroll_sizes() {
        let p = Program::new(Distribution::block(8, 2))
            .iterate("heat", Signature::stencil_radius(1), 3);
        let g = p.unroll();
        assert_eq!(g.len(), 8 * 4);
        assert_eq!(g.num_levels(), 4);
        // Interior points have 3 preds, boundary points 2: per level
        // 2*2 + 6*3 = 22 edges.
        assert_eq!(g.num_edges(), 3 * 22);
    }

    #[test]
    fn unroll_ownership_follows_distribution() {
        let p = Program::new(Distribution::block(10, 2))
            .iterate("heat", Signature::stencil_radius(1), 1);
        let g = p.unroll();
        for t in g.tasks() {
            let expected = if g.item(t) < 5 { 0 } else { 1 };
            assert_eq!(g.owner(t).0, expected);
        }
    }

    #[test]
    fn unroll_input_level_is_input_kind() {
        let p = Program::new(Distribution::block(4, 1))
            .iterate("s", Signature::stencil_radius(1), 2);
        let g = p.unroll();
        for t in g.tasks() {
            if g.level(t) == 0 {
                assert_eq!(g.kind(t), TaskKind::Input);
            } else {
                assert_eq!(g.kind(t), TaskKind::Compute);
            }
        }
    }

    #[test]
    fn unroll_stencil_dependence_pattern() {
        let p = Program::new(Distribution::block(5, 1))
            .iterate("s", Signature::stencil_radius(1), 1);
        let g = p.unroll();
        // Task for point 2 at level 1 (id 5+2=7) depends on inputs 1,2,3.
        let preds = g.preds(crate::graph::TaskId(7));
        assert_eq!(preds, &[1, 2, 3]);
    }

    #[test]
    fn redistribution_step_changes_owners() {
        let p = Program::new(Distribution::block(6, 2)).then_dist(
            "shuffle",
            Signature::stencil_radius(0),
            Distribution::cyclic(6, 2),
        );
        let g = p.unroll();
        // Level-1 point 1 is cyclic-owned by p1, though input point 1 is
        // block-owned by p0.
        let t = crate::graph::TaskId(6 + 1);
        assert_eq!(g.owner(t).0, 1);
    }

    #[test]
    fn all_to_all_step() {
        let p = Program::new(Distribution::block(4, 2)).then("reduce", Signature::AllToAll);
        let g = p.unroll();
        let t = crate::graph::TaskId(4);
        assert_eq!(g.preds(t).len(), 4);
    }
}
