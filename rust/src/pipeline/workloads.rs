//! [`Workload`] implementations for every scenario the repository ships,
//! plus [`GraphWorkload`] for bringing your own task graph.
//!
//! Each type is a small plain-data description; the graph is derived on
//! demand for whatever processor count the [`super::Pipeline`] requests,
//! so one description serves naive/overlap/CA comparisons at any scale.

use super::{PipelineError, Workload};
use crate::graph::{TaskGraph, TaskId};
use crate::imp::Distribution;
use crate::krylov::cg_program_on;
use crate::partition::{graph_distribution, Partitioner, Partitioning, ProcGrid};
use crate::sim::TaskCostModel;
use crate::stencil::{
    heat1d_program, heat2d_program_on, moore2d_program_on, spmv_program_on, CsrMatrix,
};
use std::sync::Arc;

/// Row-fill-proportional task cost: a task updating matrix row `i` costs
/// `nnz(i) / mean-nnz` γ, so irregular matrices load processors
/// non-uniformly in the simulator exactly as they do on hardware.  The
/// mean normalization keeps the *average* task at 1 γ — simulated times
/// stay comparable with the flat-γ model.
#[derive(Debug, Clone)]
pub struct RowFillCost {
    row_cost: Vec<f64>,
}

impl RowFillCost {
    pub fn new(a: &CsrMatrix) -> Self {
        let mean = (a.nnz() as f64 / a.n.max(1) as f64).max(f64::MIN_POSITIVE);
        RowFillCost {
            row_cost: (0..a.n).map(|i| a.row_cols(i).len() as f64 / mean).collect(),
        }
    }

    fn row(&self, item: u64) -> f64 {
        self.row_cost.get(item as usize).copied().unwrap_or(1.0)
    }
}

impl TaskCostModel for RowFillCost {
    fn task_cost(&self, g: &TaskGraph, t: TaskId) -> f64 {
        self.row(g.item(t))
    }
}

/// CG's per-phase weights: `cg_program` emits `matvec → dot → update`
/// per iteration (levels `3k+1, 3k+2, 3k+3`), so matvec tasks carry the
/// matrix row's fill while the dot/update tasks are single flops.
#[derive(Debug, Clone)]
pub struct CgPhaseCost {
    matvec: RowFillCost,
}

impl TaskCostModel for CgPhaseCost {
    fn task_cost(&self, g: &TaskGraph, t: TaskId) -> f64 {
        if g.level(t) % 3 == 1 {
            self.matvec.row(g.item(t))
        } else {
            1.0
        }
    }
}

/// Resolve a structured layout into a 2-D grid distribution, with the
/// workload-tagged feasibility errors the pipeline reports.
fn grid2d_distribution(
    name: &str,
    layout: &Partitioning,
    procs: u32,
    h: u64,
    w: u64,
) -> Result<Distribution, PipelineError> {
    let grid = match layout {
        Partitioning::Grid(g) => *g,
        Partitioning::Graph(p) => {
            return Err(PipelineError::Graph(format!(
                "{name}: graph partitioner {} needs an irregular workload; pick a ProcGrid",
                p.key()
            )))
        }
    };
    let (px, py) = grid.resolve(procs).map_err(PipelineError::Graph)?;
    if h < px as u64 || w < py as u64 {
        return Err(PipelineError::Graph(format!(
            "{name}: {h}x{w} grid cannot be distributed over {px}x{py} procs"
        )));
    }
    grid.distribution_2d(h, w, procs).map_err(PipelineError::Graph)
}

/// The paper's running example (eq. 1): `steps` applications of a
/// radius-`radius` 1-D stencil over `n` points, block-distributed.
#[derive(Debug, Clone)]
pub struct Heat1d {
    pub n: u64,
    pub steps: u32,
    pub radius: u32,
}

impl Heat1d {
    /// The classic 3-point (radius-1) configuration.
    pub fn new(n: u64, steps: u32) -> Self {
        Heat1d { n, steps, radius: 1 }
    }
}

impl Workload for Heat1d {
    fn name(&self) -> String {
        "heat1d".into()
    }

    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
        if procs == 0 || self.n < procs as u64 {
            return Err(PipelineError::Graph(format!(
                "heat1d: {} points cannot be distributed over {procs} procs",
                self.n
            )));
        }
        Ok(heat1d_program(self.n, self.steps, procs, self.radius).unroll())
    }
}

/// The 2-D five-point heat equation on an `h × w` grid; the processor
/// count is factored into the most square worker grid.
#[derive(Debug, Clone)]
pub struct Heat2d {
    pub h: u64,
    pub w: u64,
    pub steps: u32,
}

impl Workload for Heat2d {
    fn name(&self) -> String {
        "heat2d".into()
    }

    fn partitioning(&self) -> Partitioning {
        Partitioning::Grid(ProcGrid::Square)
    }

    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
        self.build_graph_with(procs, &self.partitioning())
    }

    fn build_graph_with(
        &self,
        procs: u32,
        layout: &Partitioning,
    ) -> Result<TaskGraph, PipelineError> {
        let dist = grid2d_distribution("heat2d", layout, procs, self.h, self.w)?;
        Ok(heat2d_program_on(self.h, self.w, self.steps, dist).unroll())
    }
}

/// The 2-D **nine-point** (Moore neighbourhood) stencil — diagonal
/// dependencies are direct, so corner traffic exists at every block
/// factor.  Proof that a new scenario costs one type, not a new engine.
#[derive(Debug, Clone)]
pub struct Moore2d {
    pub h: u64,
    pub w: u64,
    pub steps: u32,
}

impl Workload for Moore2d {
    fn name(&self) -> String {
        "moore2d".into()
    }

    fn partitioning(&self) -> Partitioning {
        Partitioning::Grid(ProcGrid::Square)
    }

    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
        self.build_graph_with(procs, &self.partitioning())
    }

    fn build_graph_with(
        &self,
        procs: u32,
        layout: &Partitioning,
    ) -> Result<TaskGraph, PipelineError> {
        let dist = grid2d_distribution("moore2d", layout, procs, self.h, self.w)?;
        Ok(moore2d_program_on(self.h, self.w, self.steps, dist).unroll())
    }
}

/// Repeated SpMV with an arbitrary CSR matrix — the paper's motivating
/// irregular workload.  The matrix's sparsity *is* the dependence
/// structure; no stencil assumptions anywhere downstream.
#[derive(Debug, Clone)]
pub struct Spmv {
    pub matrix: CsrMatrix,
    pub steps: u32,
}

impl Workload for Spmv {
    fn name(&self) -> String {
        "spmv".into()
    }

    fn partitioning(&self) -> Partitioning {
        Partitioning::Graph(Partitioner::RowBlock)
    }

    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
        self.build_graph_with(procs, &self.partitioning())
    }

    fn build_graph_with(
        &self,
        procs: u32,
        layout: &Partitioning,
    ) -> Result<TaskGraph, PipelineError> {
        if procs == 0 || self.matrix.n < procs as usize {
            return Err(PipelineError::Graph(format!(
                "spmv: {} rows cannot be distributed over {procs} procs",
                self.matrix.n
            )));
        }
        let dist =
            graph_distribution(&self.matrix, procs, layout).map_err(PipelineError::Graph)?;
        Ok(spmv_program_on(&self.matrix, self.steps, dist).unroll())
    }

    fn cost_model(&self) -> Arc<dyn TaskCostModel> {
        Arc::new(RowFillCost::new(&self.matrix))
    }
}

/// Conjugate gradient on the 1-D Laplacian: matvec + `AllToAll` inner
/// product + vector update per iteration.  The collectives bound what
/// blocking can do — exactly the graph shape the s-step literature
/// removes — making this the stress case for the transformation.
#[derive(Debug, Clone)]
pub struct ConjugateGradient {
    pub unknowns: usize,
    pub iters: u32,
}

impl Workload for ConjugateGradient {
    fn name(&self) -> String {
        "cg".into()
    }

    fn partitioning(&self) -> Partitioning {
        Partitioning::Graph(Partitioner::RowBlock)
    }

    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
        self.build_graph_with(procs, &self.partitioning())
    }

    fn build_graph_with(
        &self,
        procs: u32,
        layout: &Partitioning,
    ) -> Result<TaskGraph, PipelineError> {
        if procs == 0 || self.unknowns < procs as usize {
            return Err(PipelineError::Graph(format!(
                "cg: {} unknowns cannot be distributed over {procs} procs",
                self.unknowns
            )));
        }
        let a = CsrMatrix::laplace1d(self.unknowns);
        let dist = graph_distribution(&a, procs, layout).map_err(PipelineError::Graph)?;
        Ok(cg_program_on(&a, dist, self.iters).unroll())
    }

    fn cost_model(&self) -> Arc<dyn TaskCostModel> {
        Arc::new(CgPhaseCost { matvec: RowFillCost::new(&CsrMatrix::laplace1d(self.unknowns)) })
    }
}

/// Bring-your-own-graph workload: wraps an existing [`TaskGraph`] (with
/// its baked-in distribution) so ad-hoc graphs ride the same pipeline.
#[derive(Debug, Clone)]
pub struct GraphWorkload {
    pub label: String,
    pub graph: Arc<TaskGraph>,
}

impl GraphWorkload {
    pub fn new(label: impl Into<String>, graph: TaskGraph) -> Self {
        GraphWorkload { label: label.into(), graph: Arc::new(graph) }
    }
}

impl Workload for GraphWorkload {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn default_procs(&self) -> u32 {
        self.graph.num_procs()
    }

    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
        if procs != self.graph.num_procs() {
            return Err(PipelineError::Graph(format!(
                "{}: graph is distributed over {} procs, {procs} requested",
                self.label,
                self.graph.num_procs()
            )));
        }
        Ok((*self.graph).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_layouts_match_the_seed_distributions() {
        // Heat2d's hint is the most-square grid grid_factor always chose.
        let via_default = Heat2d { h: 6, w: 6, steps: 2 }.build_graph(4).unwrap();
        let via_layout = Heat2d { h: 6, w: 6, steps: 2 }
            .build_graph_with(4, &Partitioning::Grid(ProcGrid::Grid { px: 2, py: 2 }))
            .unwrap();
        for t in via_default.tasks() {
            assert_eq!(via_default.owner(t), via_layout.owner(t), "{t}");
        }
        // Spmv's hint is the row-block distribution the seed hardcoded.
        let w = Spmv { matrix: CsrMatrix::laplace1d(12), steps: 1 };
        let rows = w.build_graph(3).unwrap();
        let strip = w
            .build_graph_with(3, &Partitioning::Grid(ProcGrid::Strip))
            .unwrap();
        for t in rows.tasks() {
            assert_eq!(rows.owner(t), strip.owner(t), "{t}");
        }
    }

    #[test]
    fn partitioned_spmv_and_cg_build_and_cut_less() {
        use crate::partition::{assignment_of, PartitionQuality};
        let a = CsrMatrix::laplace2d(4, 8);
        let w = Spmv { matrix: a.clone(), steps: 2 };
        let layout = Partitioning::Graph(Partitioner::RcbRefined);
        let g = w.build_graph_with(4, &layout).unwrap();
        assert_eq!(g.num_procs(), 4);
        // The refined layout's static quality is reflected in the graph:
        // words per naive level == edge-cut words of the partition.
        let dist = crate::partition::graph_distribution(&a, 4, &layout).unwrap();
        let q = PartitionQuality::evaluate(&a, &assignment_of(&dist), 4);
        assert!(q.edge_cut_words > 0);
        // CG accepts the same layouts on its Laplacian row space.
        let cg = ConjugateGradient { unknowns: 16, iters: 1 };
        assert!(cg.build_graph_with(4, &layout).is_ok());
    }

    #[test]
    fn heat1d_graph_shape() {
        let g = Heat1d::new(32, 4).build_graph(4).unwrap();
        assert_eq!(g.len(), 32 * 5);
        assert_eq!(g.num_procs(), 4);
    }

    #[test]
    fn infeasible_distribution_rejected() {
        assert!(Heat1d::new(2, 4).build_graph(4).is_err());
        assert!(Spmv { matrix: CsrMatrix::laplace1d(3), steps: 1 }.build_graph(8).is_err());
        assert!(Heat2d { h: 1, w: 1, steps: 1 }.build_graph(4).is_err());
    }

    #[test]
    fn graph_workload_pins_procs() {
        let g = crate::stencil::heat1d_graph(16, 2, 2);
        let w = GraphWorkload::new("custom", g);
        assert_eq!(w.default_procs(), 2);
        assert!(w.build_graph(2).is_ok());
        assert!(w.build_graph(3).is_err());
    }

    #[test]
    fn row_fill_cost_is_mean_normalized() {
        let a = CsrMatrix::laplace2d(4, 4);
        let c = RowFillCost::new(&a);
        let g = Spmv { matrix: a, steps: 1 }.build_graph(2).unwrap();
        // One task per row at level 1; mean normalization makes the
        // total equal the row count.
        let total: f64 =
            g.tasks().filter(|&t| g.level(t) == 1).map(|t| c.task_cost(&g, t)).sum();
        assert!((total - 16.0).abs() < 1e-9, "{total}");
        // A corner row (2 off-diagonal neighbours) is cheaper than an
        // interior row (4).
        let cost_of = |item: u64| {
            let t = g.tasks().find(|&t| g.level(t) == 1 && g.item(t) == item).unwrap();
            c.task_cost(&g, t)
        };
        assert!(cost_of(0) < cost_of(5), "corner {} interior {}", cost_of(0), cost_of(5));
    }

    #[test]
    fn cg_cost_weights_matvec_rows_over_reductions() {
        let w = ConjugateGradient { unknowns: 8, iters: 1 };
        let g = w.build_graph(2).unwrap();
        let c = w.cost_model();
        let matvec =
            g.tasks().find(|&t| g.level(t) == 1 && g.item(t) == 4).unwrap();
        let dot = g.tasks().find(|&t| g.level(t) == 2).unwrap();
        assert!(
            c.task_cost(&g, matvec) > c.task_cost(&g, dot),
            "matvec {} dot {}",
            c.task_cost(&g, matvec),
            c.task_cost(&g, dot)
        );
        assert_eq!(c.task_cost(&g, dot), 1.0);
    }

    #[test]
    fn moore2d_has_more_edges_than_heat2d() {
        let nine = Moore2d { h: 6, w: 6, steps: 2 }.build_graph(4).unwrap();
        let five = Heat2d { h: 6, w: 6, steps: 2 }.build_graph(4).unwrap();
        assert!(nine.num_edges() > five.num_edges());
    }
}
