//! One builder from problem description to transformed schedule,
//! simulation, and real execution.
//!
//! The paper's pipeline — data-parallel description → IMP task graph →
//! §3 communication-avoiding transformation → simulated or real run —
//! used to be re-wired by hand per scenario.  This module makes it one
//! fluent expression over a [`Workload`]:
//!
//! ```
//! use imp_latency::pipeline::{Heat1d, Pipeline};
//! use imp_latency::sim::Machine;
//!
//! let run = Pipeline::new(Heat1d { n: 64, steps: 8, radius: 1 })
//!     .procs(4)
//!     .block(4)
//!     .transform()
//!     .expect("Theorem 1 holds");
//!
//! // §4 discrete-event simulation on an α/β/γ machine...
//! let sim = run.simulate(&Machine::high_latency(4, 8));
//! // ...and a real threads-and-channels execution, value-checked
//! // against the workload's sequential reference solution.
//! let real = run.execute().expect("distributed values match reference");
//!
//! assert!(real.verification.is_verified());
//! assert_eq!(sim.messages, real.messages);
//! println!("{}", real.summary());
//! ```
//!
//! A [`Workload`] provides the task graph (for any processor count),
//! per-task cost hints for the simulator, and the input-value/reference
//! semantics the real run is verified against.  Five ship in
//! [`workloads`] — [`Heat1d`], [`Heat2d`], [`Moore2d`], [`Spmv`],
//! [`ConjugateGradient`] — plus [`GraphWorkload`] for ad-hoc graphs;
//! adding a scenario means implementing the trait, nothing else.
//!
//! The simulation side is fully configurable on the builder:
//! `.machine(..)` fixes the α/β/γ machine for
//! [`Transformed::simulate_configured`], `.network(..)` picks the wire model
//! ([`crate::sim::NetworkKind`]: α+β·words, LogGP, hierarchical,
//! contended), and `.costs(..)` overrides the workload's per-task
//! [`crate::sim::TaskCostModel`].  [`Transformed::sweep_input`] packages
//! a run for the parallel [`crate::sim::sweep`] grids.

mod report;
pub mod workloads;

pub use report::{PipelineStats, RunReport, RunTime, Verification};
pub use workloads::{
    CgPhaseCost, ConjugateGradient, GraphWorkload, Heat1d, Heat2d, Moore2d, RowFillCost, Spmv,
};

use crate::analysis::AnalysisError;
use crate::chaos::{perturb_cost, FaultConfig, JitterWire};
use crate::config::Config;
use crate::coordinator::{run_and_verify_with, ValueSemantics};
use crate::graph::TaskGraph;
use crate::stencil::CsrMatrix;
use crate::partition::Partitioning;
use crate::sim::sweep::SweepInput;
use crate::sim::{try_simulate, ExecPlan, Machine, NetworkKind, ScaledCost, TaskCostModel};
use crate::transform::{communication_avoiding, CaSchedule, HaloMode, TransformOptions};
use crate::tune::{TuneReport, Tuner};
use std::sync::Arc;

/// A problem the pipeline can carry end to end.
///
/// Implementations are cheap descriptions; the graph is derived on demand
/// so the same description serves any processor count and strategy.
pub trait Workload {
    /// Short identifier used in reports ("heat1d", "spmv", ...).
    fn name(&self) -> String;

    /// Derive the distributed task graph for `procs` processors.
    fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError>;

    /// The data layout this workload distributes over by default — a
    /// [`crate::partition::ProcGrid`] for structured domains, a
    /// [`crate::partition::Partitioner`] for irregular ones.  The 1-D
    /// strip default is what every workload did before the layout became
    /// a first-class dimension.
    fn partitioning(&self) -> Partitioning {
        Partitioning::default()
    }

    /// Derive the graph under an explicit layout
    /// ([`Pipeline::partitioning`] and the [`crate::tune`] layout axis
    /// call this).  The default supports only the workload's own
    /// [`Workload::partitioning`] hint and rejects everything else, so a
    /// layout can never be silently ignored; workloads with a real
    /// layout degree of freedom override it.
    fn build_graph_with(
        &self,
        procs: u32,
        layout: &Partitioning,
    ) -> Result<TaskGraph, PipelineError> {
        if *layout != self.partitioning() {
            return Err(PipelineError::Graph(format!(
                "{}: workload does not support the {} layout",
                self.name(),
                layout.key()
            )));
        }
        self.build_graph(procs)
    }

    /// Processor count used when the builder does not specify one.
    fn default_procs(&self) -> u32 {
        4
    }

    /// Per-task cost hint in γ units (scales the simulator's `gamma`).
    fn cost_per_task(&self) -> f64 {
        1.0
    }

    /// Per-task cost model for the simulator.  The default charges every
    /// task the flat [`Workload::cost_per_task`] hint; irregular
    /// workloads override this to weight individual tasks (e.g.
    /// [`Spmv`] charges each row its fill).
    fn cost_model(&self) -> Arc<dyn TaskCostModel> {
        Arc::new(ScaledCost(self.cost_per_task()))
    }

    /// Words per transmitted value (scales the simulator's `beta`).
    fn words_per_value(&self) -> usize {
        1
    }

    /// Input-value / compute-value semantics for the real run; the same
    /// semantics produce the sequential reference solution the run is
    /// verified against.
    fn semantics(&self) -> ValueSemantics {
        ValueSemantics::default()
    }
}

/// Execution strategy for the plan the pipeline builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Per-level halo exchange, no overlap (§4 baseline).
    Naive,
    /// Figure-2 split: interior compute overlaps the messages.
    Overlap,
    /// The §3 communication-avoiding transformation (the default).
    Ca,
}

/// Everything that can go wrong between description and report.
#[derive(Debug, Clone)]
pub enum PipelineError {
    /// The workload could not produce a graph for the requested layout.
    Graph(String),
    /// Slicing/transforming failed, or a superstep schedule violated
    /// Theorem 1.
    Transform(String),
    /// The real run's values diverged from the reference solution.
    Verify(String),
    /// The builder configuration is incomplete or inconsistent (e.g.
    /// [`Transformed::simulate_configured`] without a machine).
    Config(String),
    /// The built plan failed static verification — it can deadlock or
    /// consumes values it never produces ([`crate::analysis::verify`]).
    Analysis(AnalysisError),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::Graph(m) => write!(f, "graph construction: {m}"),
            PipelineError::Transform(m) => write!(f, "transformation: {m}"),
            PipelineError::Verify(m) => write!(f, "verification: {m}"),
            PipelineError::Config(m) => write!(f, "configuration: {m}"),
            PipelineError::Analysis(e) => write!(f, "static analysis: {e}"),
        }
    }
}

impl std::error::Error for PipelineError {}

/// The fluent builder.  Configure, then [`Pipeline::transform`] into a
/// [`Transformed`] pipeline that can be simulated and executed any number
/// of times.
#[derive(Debug, Clone)]
pub struct Pipeline<W: Workload> {
    workload: W,
    procs: Option<u32>,
    block: Option<u32>,
    strategy: Strategy,
    options: TransformOptions,
    check: bool,
    machine: Option<Machine>,
    network: NetworkKind,
    cost: Option<Arc<dyn TaskCostModel>>,
    partitioning: Option<Partitioning>,
    chaos: Option<FaultConfig>,
}

impl<W: Workload> Pipeline<W> {
    pub fn new(workload: W) -> Self {
        Pipeline {
            workload,
            procs: None,
            block: None,
            strategy: Strategy::Ca,
            options: TransformOptions::default(),
            check: true,
            machine: None,
            network: NetworkKind::AlphaBeta,
            cost: None,
            partitioning: None,
            chaos: None,
        }
    }

    /// Processor count (default: the workload's own default).
    pub fn procs(mut self, procs: u32) -> Self {
        self.procs = Some(procs);
        self
    }

    /// Block factor `b` for the CA strategy — levels per superstep
    /// (default: the whole graph depth, i.e. one superstep).
    pub fn block(mut self, b: u32) -> Self {
        self.block = Some(b);
        self
    }

    /// Halo mode of the transformation (shorthand for
    /// `options(TransformOptions::default().with_halo(..))`).
    pub fn halo(mut self, halo: HaloMode) -> Self {
        self.options = self.options.with_halo(halo);
        self
    }

    /// Full transformation options.
    pub fn options(mut self, options: TransformOptions) -> Self {
        self.options = options;
        self
    }

    /// Execution strategy (default [`Strategy::Ca`]).
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Shorthand for `strategy(Strategy::Naive)`.
    pub fn naive(self) -> Self {
        self.strategy(Strategy::Naive)
    }

    /// Shorthand for `strategy(Strategy::Overlap)`.
    pub fn overlap(self) -> Self {
        self.strategy(Strategy::Overlap)
    }

    /// Skip the per-superstep Theorem-1 check during `transform()` (it is
    /// on by default; skipping trades safety for transform speed on very
    /// large graphs).
    pub fn skip_check(mut self) -> Self {
        self.check = false;
        self
    }

    /// Machine to simulate on ([`Transformed::simulate_configured`]); its
    /// processor count must match the pipeline's.
    pub fn machine(mut self, machine: Machine) -> Self {
        self.machine = Some(machine);
        self
    }

    /// Wire model for simulation (default [`NetworkKind::AlphaBeta`],
    /// the paper's α+β·words postal model).
    pub fn network(mut self, network: NetworkKind) -> Self {
        self.network = network;
        self
    }

    /// Per-task cost model override (default: the workload's own
    /// [`Workload::cost_model`]).
    pub fn costs(mut self, cost: Arc<dyn TaskCostModel>) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Data-layout override (default: the workload's own
    /// [`Workload::partitioning`] hint) — a
    /// [`crate::partition::ProcGrid`] shape for the 2-D stencils, a
    /// [`crate::partition::Partitioner`] for SpMV/CG.  The graph is
    /// derived from the chosen layout, and a Hierarchical wire maps its
    /// processors onto nodes grid-aware (see
    /// [`crate::sim::NetworkKind::build_for`]).
    pub fn partitioning(mut self, layout: Partitioning) -> Self {
        self.partitioning = Some(layout);
        self
    }

    /// Deterministic fault injection ([`crate::chaos`]): the compute
    /// half ([`crate::chaos::PerturbedCost`]) wraps the resolved cost
    /// model during `transform()`, the wire half
    /// ([`crate::chaos::JitterWire`]) decorates the network at every
    /// simulation of the transformed pipeline.  Both halves are pure
    /// functions of the scenario's seed, so repeat runs — and the
    /// compiled vs. interpreting engines — stay bit-for-bit equal.
    pub fn chaos(mut self, fault: FaultConfig) -> Self {
        self.chaos = Some(fault);
        self
    }

    /// The fault scenario set with [`Pipeline::chaos`], if any.
    pub fn chaos_config(&self) -> Option<&FaultConfig> {
        self.chaos.as_ref()
    }

    /// The workload description this builder carries.
    pub fn workload(&self) -> &W {
        &self.workload
    }

    /// Resolved processor count (explicit or the workload's default).
    pub fn resolved_procs(&self) -> u32 {
        self.procs.unwrap_or_else(|| self.workload.default_procs())
    }

    /// The machine configured with [`Pipeline::machine`], if any.
    pub fn machine_config(&self) -> Option<Machine> {
        self.machine
    }

    /// The wire model configured with [`Pipeline::network`].
    pub fn network_config(&self) -> NetworkKind {
        self.network
    }

    /// The per-task cost model override set with [`Pipeline::costs`],
    /// if any (the workload's own model applies otherwise).
    pub fn cost_config(&self) -> Option<&Arc<dyn TaskCostModel>> {
        self.cost.as_ref()
    }

    /// The layout override set with [`Pipeline::partitioning`], if any.
    pub fn partitioning_config(&self) -> Option<Partitioning> {
        self.partitioning
    }

    /// Resolved layout: the explicit override or the workload's own hint.
    pub fn resolved_partitioning(&self) -> Partitioning {
        self.partitioning.unwrap_or_else(|| self.workload.partitioning())
    }

    /// Build (once) the graph this pipeline would transform, ready to
    /// share across many [`Pipeline::transform_on`] calls — the
    /// [`crate::tune`] evaluator uses this so same-layout candidates stop
    /// rebuilding the graph per evaluation.
    pub fn build_graph_shared(&self) -> Result<Arc<TaskGraph>, PipelineError> {
        let procs = self.resolved_procs();
        let layout = self.resolved_partitioning();
        Ok(Arc::new(self.workload.build_graph_with(procs, &layout)?))
    }

    /// Let the [`crate::tune`] subsystem pick the configuration: search
    /// the (strategy × halo × block × procs) space with `tuner`, scoring
    /// every candidate on the event-driven engine under the configured
    /// machine, wire model, and cost model, and build the winning plan.
    /// Requires [`Pipeline::machine`].  Repeat problems are served from
    /// the tuner's [`crate::tune::TuningCache`] without any engine runs;
    /// the [`TuneReport`] rides on the returned pipeline
    /// ([`Transformed::tune_report`]) and inside every [`RunReport`] it
    /// produces.
    pub fn autotune(self, tuner: &mut Tuner) -> Result<Transformed<W>, PipelineError>
    where
        W: Clone,
    {
        let outcome = crate::tune::tune_pipeline(&self, tuner)?;
        let chosen = outcome.chosen;
        let mut next = self.procs(chosen.procs).strategy(chosen.strategy).halo(chosen.halo);
        next.block = chosen.block;
        if let Some(layout) = chosen.layout {
            next = next.partitioning(layout);
        }
        if let Some(machine) = next.machine {
            if machine.nprocs != chosen.procs {
                next.machine = Some(Machine { nprocs: chosen.procs, ..machine });
            }
        }
        let mut t = next.transform()?;
        t.tune = Some(outcome.report);
        Ok(t)
    }

    /// Build the graph and the execution plan.  For the CA strategy every
    /// superstep schedule is verified against Theorem 1 unless
    /// [`Pipeline::skip_check`] was requested.
    pub fn transform(self) -> Result<Transformed<W>, PipelineError> {
        let graph = self.build_graph_shared()?;
        self.transform_on(graph)
    }

    /// [`Pipeline::transform`] against a prebuilt, `Arc`-shared graph —
    /// skips the graph build but keeps everything else of the workload
    /// (cost model, value semantics, words per value), unlike wrapping
    /// the graph in a [`GraphWorkload`].  The graph must be distributed
    /// over exactly the pipeline's resolved processor count.
    pub fn transform_on(self, graph: Arc<TaskGraph>) -> Result<Transformed<W>, PipelineError> {
        // Telemetry: transforms counted and timed on the `pipeline`
        // track; disabled telemetry pays one branch, nothing else.
        let t_start = crate::telemetry::with(|r| r.now_us());
        let procs = self.resolved_procs();
        if graph.num_procs() != procs {
            return Err(PipelineError::Graph(format!(
                "prebuilt graph is distributed over {} procs but the pipeline resolves to {procs}",
                graph.num_procs()
            )));
        }
        let depth = graph.num_levels().saturating_sub(1).max(1);
        let (plan, block) = match self.strategy {
            Strategy::Naive => (ExecPlan::naive(&graph), None),
            Strategy::Overlap => (ExecPlan::overlap(&graph), None),
            Strategy::Ca => {
                let b = self.block.unwrap_or(depth);
                if b == 0 {
                    return Err(PipelineError::Transform(
                        "block factor must be at least 1".into(),
                    ));
                }
                let plan = if self.check {
                    ExecPlan::ca_checked(&graph, b, self.options)
                } else {
                    ExecPlan::ca(&graph, b, self.options)
                }
                .map_err(PipelineError::Transform)?;
                (plan, Some(b))
            }
        };
        // Pre-flight: statically prove the plan channel-safe, hazard-free
        // and deadlock-free before anything simulates, caches, or executes
        // it.  Rides the same switch as the Theorem-1 check so
        // `skip_check` still trades safety for transform speed.
        if self.check {
            crate::analysis::verify(&graph, &plan).map_err(PipelineError::Analysis)?;
        }
        let layout = self.resolved_partitioning();
        let cost = self.cost.unwrap_or_else(|| self.workload.cost_model());
        // Chaos compute half bakes in here, so everything downstream —
        // simulate, sweep inputs, compiled plans — sees the perturbed
        // costs without knowing a fault scenario exists.
        let cost = match &self.chaos {
            Some(fault) => perturb_cost(cost, fault),
            None => cost,
        };
        if let Some(start_us) = t_start {
            crate::telemetry::with(|r| {
                r.counter("pipeline.transforms").add(1);
                r.histogram("pipeline.transform_ms").record((r.now_us() - start_us) / 1e3);
            });
        }
        Ok(Transformed {
            workload: self.workload,
            graph,
            plan: Arc::new(plan),
            procs,
            block,
            options: self.options,
            machine: self.machine,
            network: self.network,
            cost,
            layout,
            fault: self.chaos,
            tune: None,
        })
    }
}

/// Build the sweep input of **one** execution configuration of `base`:
/// strategy, CA block factor (`None` = whole-graph superstep), and an
/// optional halo override.  This is the single path through which both
/// [`strategy_sweep_inputs`] and the [`crate::tune`] candidate
/// evaluator construct their plan families, so the figures, the CLI
/// sweeps, and the autotuner can never drift apart.
pub fn candidate_sweep_input<W: Workload + Clone>(
    base: &Pipeline<W>,
    strategy: Strategy,
    block: Option<u32>,
    halo: Option<HaloMode>,
) -> Result<SweepInput, PipelineError> {
    let mut p = base.clone().strategy(strategy);
    p.block = block; // the configuration *is* the candidate (CA only)
    if let Some(h) = halo {
        p = p.halo(h);
    }
    Ok(p.transform()?.sweep_input())
}

/// [`candidate_sweep_input`] against a prebuilt graph
/// ([`Pipeline::build_graph_shared`]) — the [`crate::tune`] evaluator's
/// path, where every same-layout candidate of a tuning run shares one
/// graph build instead of re-deriving it per evaluation.
pub fn candidate_sweep_input_on<W: Workload + Clone>(
    base: &Pipeline<W>,
    graph: Arc<TaskGraph>,
    strategy: Strategy,
    block: Option<u32>,
    halo: Option<HaloMode>,
) -> Result<SweepInput, PipelineError> {
    let mut p = base.clone().strategy(strategy);
    p.block = block;
    if let Some(h) = halo {
        p = p.halo(h);
    }
    Ok(p.transform_on(graph)?.sweep_input())
}

/// The strategy family of sweep inputs from one base builder: naive,
/// overlap, and one CA plan per block factor in `blocks` — the input
/// list every figure-7/8-shaped sweep wants, assembled through
/// [`candidate_sweep_input`].
pub fn strategy_sweep_inputs<W: Workload + Clone>(
    base: &Pipeline<W>,
    blocks: &[u32],
) -> Result<Vec<SweepInput>, PipelineError> {
    let mut v = vec![
        candidate_sweep_input(base, Strategy::Naive, None, None)?,
        candidate_sweep_input(base, Strategy::Overlap, None, None)?,
    ];
    for &b in blocks {
        v.push(candidate_sweep_input(base, Strategy::Ca, Some(b), None)?);
    }
    Ok(v)
}

/// Callback of [`dispatch_workload`]: one generic method, so each
/// surface (the `sweep`/`tune` subcommands, the `serve` daemon) states
/// *what it does with a workload* exactly once.
pub trait WorkloadVisitor {
    type Out;
    fn visit<W: Workload + Clone>(&mut self, w: W) -> Self::Out;
}

/// The single workload-name → constructor map shared by the `sweep` and
/// `tune` subcommands and the `serve` daemon (key semantics: `n`/`r` for
/// heat1d, `h`×`w` for the 2-D stencils and SpMV; CG's AllToAll dot
/// levels make its graph O(n²) in edges, so its size is the separate,
/// smaller `cg_n` knob).  The CLI `pipeline` subcommand keeps its own
/// mapping on purpose — there `n` names the size of whichever single
/// workload was picked.
pub fn dispatch_workload<V: WorkloadVisitor>(
    name: &str,
    cfg: &Config,
    v: &mut V,
) -> Result<V::Out, String> {
    let m: u32 = cfg.require("m")?;
    let (h, w): (u64, u64) = (cfg.require("h")?, cfg.require("w")?);
    Ok(match name {
        "heat1d" => {
            v.visit(Heat1d { n: cfg.get_or("n", 4096), steps: m, radius: cfg.get_or("r", 1) })
        }
        "heat2d" => v.visit(Heat2d { h, w, steps: m }),
        "moore2d" => v.visit(Moore2d { h, w, steps: m }),
        "spmv" => {
            v.visit(Spmv { matrix: CsrMatrix::laplace2d(h as usize, w as usize), steps: m })
        }
        "cg" => v.visit(ConjugateGradient {
            unknowns: cfg.get_or("cg_n", 256),
            iters: cfg.get_or("iters", 3),
        }),
        other => {
            return Err(format!("unknown workload {other:?} (heat1d|heat2d|moore2d|spmv|cg)"))
        }
    })
}

/// A transformed pipeline: graph + plan, ready to simulate or execute.
#[derive(Debug, Clone)]
pub struct Transformed<W: Workload> {
    workload: W,
    /// The derived task graph (shared with worker threads on execute).
    pub graph: Arc<TaskGraph>,
    /// The per-processor phase program (shared with sweep inputs, which
    /// can hold multi-million-phase plans for figure-scale problems).
    pub plan: Arc<ExecPlan>,
    procs: u32,
    block: Option<u32>,
    options: TransformOptions,
    machine: Option<Machine>,
    network: NetworkKind,
    cost: Arc<dyn TaskCostModel>,
    layout: Partitioning,
    /// Fault scenario ([`Pipeline::chaos`]); the compute half is already
    /// baked into `cost`, the wire half decorates every simulation.
    fault: Option<FaultConfig>,
    /// Set by [`Pipeline::autotune`]: why this configuration won.
    tune: Option<TuneReport>,
}

impl<W: Workload> Transformed<W> {
    pub fn workload(&self) -> &W {
        &self.workload
    }

    /// The resolved data layout the graph was derived from.
    pub fn partitioning(&self) -> Partitioning {
        self.layout
    }

    /// The tuning verdict, when this pipeline came from
    /// [`Pipeline::autotune`].
    pub fn tune_report(&self) -> Option<&TuneReport> {
        self.tune.as_ref()
    }

    /// The fault scenario riding on this pipeline ([`Pipeline::chaos`]).
    pub fn fault(&self) -> Option<&FaultConfig> {
        self.fault.as_ref()
    }

    pub fn procs(&self) -> u32 {
        self.procs
    }

    /// Block factor used (CA strategies only).
    pub fn block(&self) -> Option<u32> {
        self.block
    }

    /// Static accounting: graph size and the plan's work/traffic totals.
    pub fn stats(&self) -> PipelineStats {
        let graph_tasks = self.graph.num_compute_tasks();
        let executed = self.plan.executed_tasks();
        PipelineStats {
            tasks: graph_tasks,
            edges: self.graph.num_edges(),
            levels: self.graph.num_levels(),
            procs: self.procs,
            executed_tasks: executed,
            messages: self.plan.messages(),
            words: self.plan.words(),
            redundancy_factor: if graph_tasks == 0 {
                1.0
            } else {
                executed as f64 / graph_tasks as f64
            },
        }
    }

    /// The whole-graph (single-superstep) §3 schedule — the per-processor
    /// `L^(k)` subsets the figures render.  `None` for naive/overlap
    /// strategies, which have no CA schedule.
    pub fn full_schedule(&self) -> Option<CaSchedule> {
        self.block?;
        Some(communication_avoiding(&self.graph, self.options))
    }

    fn report(&self, time: RunTime, verification: Verification) -> RunReport {
        let stats = self.stats();
        RunReport {
            workload: self.workload.name(),
            strategy: self.plan.label.clone(),
            procs: self.procs,
            block: self.block,
            graph_tasks: stats.tasks,
            executed_tasks: stats.executed_tasks,
            redundancy_factor: stats.redundancy_factor,
            messages: stats.messages,
            words: stats.words,
            time,
            verification,
            tune: self.tune.clone(),
        }
    }

    /// Run the plan on the §4 event-driven simulator.  The machine's
    /// `nprocs` must match the pipeline's processor count; the workload's
    /// hints supply the per-task cost model (unless overridden with
    /// [`Pipeline::costs`]) and scale `beta` (words per value), and the
    /// wire follows the configured [`Pipeline::network`].
    ///
    /// # Panics
    ///
    /// Panics with the [`PipelineError::Analysis`] diagnosis if the plan
    /// deadlocks — impossible for pipeline-built plans unless the check
    /// was skipped; [`Transformed::simulate_checked`] is the fallible
    /// form.
    pub fn simulate(&self, machine: &Machine) -> RunReport {
        self.simulate_checked(machine).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`Transformed::simulate`]: a plan the engine cannot
    /// complete (possible only when the pipeline's static pre-flight was
    /// skipped or the plan was built by hand) yields a structured
    /// [`PipelineError::Analysis`] whose diagnostics name the cause —
    /// the unmatched channel, the hazard, the stuck frontier — instead
    /// of the engine's bare deadlock verdict.
    pub fn simulate_checked(&self, machine: &Machine) -> Result<RunReport, PipelineError> {
        assert_eq!(
            machine.nprocs, self.procs,
            "machine has {} procs but the pipeline was built for {}",
            machine.nprocs, self.procs
        );
        let m = Machine {
            beta: machine.beta * self.workload.words_per_value() as f64,
            ..*machine
        };
        let mut network = self.network.build_for(&m, Some(&self.layout));
        if let Some(fault) = &self.fault {
            network = JitterWire::wrap(network, fault);
        }
        let r = match try_simulate(
            &self.graph,
            &self.plan,
            &m,
            network.as_mut(),
            self.cost.as_ref(),
            false,
        ) {
            Ok(r) => r,
            Err(_) => {
                // Re-diagnose statically so the error explains *why*
                // rather than just reporting where the engine stopped.
                let report = crate::analysis::analyze(&self.graph, &self.plan);
                return Err(PipelineError::Analysis(report.into_error()));
            }
        };
        let max_wait = r.proc_wait.iter().copied().fold(0.0, f64::max);
        Ok(self.report(
            RunTime::Simulated {
                total: r.total_time,
                max_wait,
                utilization: r.utilization(&m),
            },
            Verification::NotChecked,
        ))
    }

    /// [`Transformed::simulate`] on the machine configured with
    /// [`Pipeline::machine`]; errors when none was set or its processor
    /// count disagrees with the pipeline's.
    pub fn simulate_configured(&self) -> Result<RunReport, PipelineError> {
        let machine = self.machine.ok_or_else(|| {
            PipelineError::Config("simulate_configured requires Pipeline::machine(..)".into())
        })?;
        if machine.nprocs != self.procs {
            return Err(PipelineError::Config(format!(
                "configured machine has {} procs but the pipeline was built for {}",
                machine.nprocs, self.procs
            )));
        }
        Ok(self.simulate(&machine))
    }

    /// Package this run as one input of a [`crate::sim::sweep`] grid —
    /// graph and plan are shared, not copied, across the sweep's worker
    /// threads, and the plan is lowered into its
    /// [`crate::sim::CompiledPlan`] exactly once here, so every grid
    /// cell (and every tuner evaluation of this candidate) simulates the
    /// compiled form.
    pub fn sweep_input(&self) -> SweepInput {
        let mut input = SweepInput::new(
            self.workload.name(),
            self.plan.label.clone(),
            Arc::clone(&self.graph),
            Arc::clone(&self.plan),
            Arc::clone(&self.cost),
            self.workload.words_per_value(),
            Some(self.layout),
        );
        // Compute perturbation is already inside `cost`; carrying the
        // scenario lets every grid cell re-wrap its wire.
        input.fault = self.fault.clone();
        input
    }

    /// Execute the plan for real — one OS thread per processor, real
    /// channels — under the workload's value semantics, and verify every
    /// owner-held value against the sequential reference solution.
    pub fn execute(&self) -> Result<RunReport, PipelineError> {
        let r = run_and_verify_with(&self.graph, &self.plan, self.workload.semantics())
            .map_err(PipelineError::Verify)?;
        let mut report = self.report(
            RunTime::Measured { wall_secs: r.wall_secs },
            Verification::Verified { owned_values: r.owned_values.len() },
        );
        // Report what actually moved, not what the plan predicted (they
        // agree — the property suite asserts it — but measurements win).
        report.messages = r.messages as usize;
        report.words = r.words as usize;
        report.executed_tasks = r.executed as usize;
        report.redundancy_factor = if report.graph_tasks == 0 {
            1.0
        } else {
            report.executed_tasks as f64 / report.graph_tasks as f64
        };
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::CsrMatrix;

    #[test]
    fn builder_defaults() {
        let t = Pipeline::new(Heat1d::new(32, 4)).transform().unwrap();
        assert_eq!(t.procs(), 4);
        assert_eq!(t.block(), Some(4)); // whole depth = one superstep
        assert_eq!(t.stats().tasks, 32 * 4);
    }

    #[test]
    fn simulate_and_execute_agree_on_traffic() {
        let t = Pipeline::new(Heat1d::new(64, 8)).procs(4).block(4).transform().unwrap();
        let sim = t.simulate(&Machine::high_latency(4, 8));
        let real = t.execute().unwrap();
        assert_eq!(sim.messages, real.messages);
        assert_eq!(sim.words, real.words);
        assert_eq!(sim.executed_tasks, real.executed_tasks);
        assert!(real.verification.is_verified());
    }

    #[test]
    fn strategies_share_the_graph_level_contract() {
        for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
            let t = Pipeline::new(Heat1d::new(48, 6))
                .procs(3)
                .strategy(strategy)
                .block(3)
                .transform()
                .unwrap();
            let r = t.execute().unwrap();
            assert!(r.verification.is_verified(), "{strategy:?}");
            if strategy == Strategy::Ca {
                assert!(r.executed_tasks >= t.stats().tasks);
            } else {
                assert_eq!(r.executed_tasks, t.stats().tasks);
            }
        }
    }

    #[test]
    fn halo_mode_flows_through() {
        let lvl0 = Pipeline::new(Heat1d::new(64, 4))
            .procs(4)
            .block(4)
            .halo(HaloMode::Level0Only)
            .transform()
            .unwrap();
        let multi =
            Pipeline::new(Heat1d::new(64, 4)).procs(4).block(4).transform().unwrap();
        assert!(lvl0.stats().executed_tasks > multi.stats().executed_tasks);
        lvl0.execute().unwrap();
    }

    #[test]
    fn full_schedule_only_for_ca() {
        let ca = Pipeline::new(Heat1d::new(32, 4)).procs(2).transform().unwrap();
        assert!(ca.full_schedule().is_some());
        let naive = Pipeline::new(Heat1d::new(32, 4)).procs(2).naive().transform().unwrap();
        assert!(naive.full_schedule().is_none());
    }

    #[test]
    fn zero_block_factor_is_an_error() {
        let err = Pipeline::new(Heat1d::new(32, 4)).procs(2).block(0).transform().unwrap_err();
        assert!(matches!(err, PipelineError::Transform(_)));
    }

    #[test]
    fn graph_errors_surface() {
        let err = Pipeline::new(Heat1d::new(2, 4)).procs(8).transform().unwrap_err();
        assert!(matches!(err, PipelineError::Graph(_)));
        assert!(err.to_string().contains("graph construction"));
    }

    #[test]
    fn irregular_workload_end_to_end() {
        let w = Spmv { matrix: CsrMatrix::laplace2d(5, 5), steps: 3 };
        let t = Pipeline::new(w).procs(4).block(3).transform().unwrap();
        let r = t.execute().unwrap();
        assert!(r.verification.is_verified());
        assert!(r.messages > 0);
    }

    #[test]
    fn machine_network_costs_flow_through_builder() {
        let mach = Machine::high_latency(2, 4);
        let base = Pipeline::new(Heat1d::new(64, 4)).procs(2).machine(mach);
        let ideal = base.clone().transform().unwrap();
        let contended = base.clone().network(NetworkKind::Contended).transform().unwrap();
        let ri = ideal.simulate_configured().unwrap();
        let rc = contended.simulate_configured().unwrap();
        assert!(rc.time.value() >= ri.time.value(), "{} < {}", rc.time.value(), ri.time.value());
        assert_eq!(rc.messages, ri.messages);

        let slow = base
            .costs(Arc::new(ScaledCost(3.0)))
            .transform()
            .unwrap()
            .simulate_configured()
            .unwrap();
        assert!(slow.time.value() > ri.time.value());
    }

    #[test]
    fn chaos_scenario_flows_through_builder_deterministically() {
        let fault = crate::chaos::FaultConfig {
            seed: 7,
            hetero: 0.2,
            jitter: 0.1,
            straggler_rate: 0.25,
            straggler_factor: 4.0,
            wire: crate::chaos::WireFault::Exponential { mean: 2.0 },
        };
        let base = Pipeline::new(Heat1d::new(64, 8))
            .procs(4)
            .block(4)
            .machine(Machine::high_latency(4, 8));
        let clean = base.clone().transform().unwrap().simulate_configured().unwrap();
        let perturbed = base.clone().chaos(fault.clone()).transform().unwrap();
        let ra = perturbed.simulate_configured().unwrap();
        let rb = base
            .clone()
            .chaos(fault.clone())
            .transform()
            .unwrap()
            .simulate_configured()
            .unwrap();
        // Same seed: bit-identical; faults never change the traffic;
        // slowdown-only: never faster than the clean run.
        assert_eq!(ra.time.value(), rb.time.value());
        assert_eq!(ra.messages, clean.messages);
        assert_eq!(ra.words, clean.words);
        assert!(ra.time.value() > clean.time.value(), "{} <= {}", ra.time.value(), clean.time.value());
        let other = base
            .chaos(fault.with_seed(8))
            .transform()
            .unwrap()
            .simulate_configured()
            .unwrap();
        assert_ne!(ra.time.value(), other.time.value(), "two seeds drew identical runs");
        // The scenario rides onto sweep inputs for the grid/tuner path.
        let input = perturbed.sweep_input();
        assert_eq!(input.fault.as_ref(), perturbed.fault());
    }

    #[test]
    fn simulate_configured_requires_matching_machine() {
        let t = Pipeline::new(Heat1d::new(32, 4)).procs(2).transform().unwrap();
        assert!(matches!(t.simulate_configured(), Err(PipelineError::Config(_))));
        let t = Pipeline::new(Heat1d::new(32, 4))
            .procs(2)
            .machine(Machine::high_latency(4, 8))
            .transform()
            .unwrap();
        let err = t.simulate_configured().unwrap_err();
        assert!(err.to_string().contains("configuration"));
    }

    #[test]
    fn simulate_checked_diagnoses_a_hand_broken_plan() {
        // Dropping a Send leaves the peer's Recv waiting forever.  The
        // engine would report a bare deadlock; the checked path must
        // instead surface the static diagnosis naming the lost message.
        let mut t = Pipeline::new(Heat1d::new(32, 4)).procs(2).naive().transform().unwrap();
        let mut broken = (*t.plan).clone();
        let phases = &mut broken.per_proc[0].phases;
        let send = phases
            .iter()
            .position(|ph| matches!(ph, crate::sim::Phase::Send { .. }))
            .expect("naive plans communicate");
        phases.remove(send);
        t.plan = Arc::new(broken);
        let err = t.simulate_checked(&Machine::high_latency(2, 4)).unwrap_err();
        let PipelineError::Analysis(e) = &err else {
            panic!("expected an analysis error, got {err:?}");
        };
        assert!(e.fatal.iter().any(|d| d.code() == "unmatched-recv"), "{e}");
        assert!(err.to_string().contains("static analysis"), "{err}");
    }

    #[test]
    fn transform_preflight_verifies_every_built_plan() {
        // The pre-flight runs on the default (checked) path and passes on
        // everything the pipeline itself builds — including level-0 CA.
        for strategy in [Strategy::Naive, Strategy::Overlap, Strategy::Ca] {
            let t = Pipeline::new(Heat1d::new(48, 6))
                .procs(3)
                .strategy(strategy)
                .block(3)
                .halo(HaloMode::Level0Only)
                .transform()
                .unwrap();
            // And the skip_check path still builds the identical plan.
            let unchecked = Pipeline::new(Heat1d::new(48, 6))
                .procs(3)
                .strategy(strategy)
                .block(3)
                .halo(HaloMode::Level0Only)
                .skip_check()
                .transform()
                .unwrap();
            assert_eq!(t.plan.label, unchecked.plan.label, "{strategy:?}");
            assert_eq!(t.plan.messages(), unchecked.plan.messages(), "{strategy:?}");
        }
    }

    #[test]
    fn sweep_input_shares_graph_and_plan() {
        let t = Pipeline::new(Heat1d::new(32, 4)).procs(2).block(2).transform().unwrap();
        let before = crate::sim::compile_count();
        let input = t.sweep_input();
        assert_eq!(&*input.workload, "heat1d");
        assert_eq!(&*input.strategy, "ca(b=2)");
        assert_eq!(input.plan.messages(), t.plan.messages());
        assert!(Arc::ptr_eq(&input.graph, &t.graph));
        assert!(Arc::ptr_eq(&input.plan, &t.plan));
        // Packaging lowers the plan exactly once.
        assert_eq!(crate::sim::compile_count() - before, 1);
        assert_eq!(input.compiled.num_procs(), 2);
    }

    #[test]
    fn strategy_sweep_inputs_builds_the_family() {
        let base = Pipeline::new(Heat1d::new(32, 4)).procs(2);
        let inputs = strategy_sweep_inputs(&base, &[2, 4]).unwrap();
        let labels: Vec<&str> = inputs.iter().map(|i| &*i.strategy).collect();
        assert_eq!(labels, ["naive", "overlap", "ca(b=2)", "ca(b=4)"]);
    }

    #[test]
    fn candidate_sweep_input_covers_every_knob() {
        let base = Pipeline::new(Heat1d::new(32, 4)).procs(2);
        // Whole-graph CA superstep via block = None.
        let whole = candidate_sweep_input(&base, Strategy::Ca, None, None).unwrap();
        assert_eq!(&*whole.strategy, "ca(b=4)");
        // Halo override flows through: level-0 recomputes more.
        let multi = candidate_sweep_input(&base, Strategy::Ca, Some(4), None).unwrap();
        let lvl0 =
            candidate_sweep_input(&base, Strategy::Ca, Some(4), Some(HaloMode::Level0Only))
                .unwrap();
        assert!(lvl0.plan.executed_tasks() > multi.plan.executed_tasks());
        // A stale block on the base does not leak into non-CA inputs.
        let naive =
            candidate_sweep_input(&base.clone().block(2), Strategy::Naive, None, None).unwrap();
        assert_eq!(&*naive.strategy, "naive");
    }

    #[test]
    fn autotune_attaches_the_report_everywhere() {
        let mut tuner = crate::tune::Tuner::exhaustive();
        let t = Pipeline::new(Heat1d::new(64, 8))
            .procs(2)
            .machine(Machine::high_latency(2, 4))
            .autotune(&mut tuner)
            .unwrap();
        let report = t.tune_report().expect("autotune attaches a report");
        assert!(report.makespan <= report.naive_makespan * 1.01 + 1e-9);
        // The verdict is embedded in simulated and executed reports.
        let sim = t.simulate_configured().unwrap();
        assert!(sim.tune.is_some());
        let real = t.execute().unwrap();
        assert_eq!(real.tune.as_ref().unwrap().key, report.key);
        assert!(real.verification.is_verified());
        // And the chosen configuration matches the built plan.
        assert_eq!(t.block(), report.chosen.block.or(t.block()));
    }

    #[test]
    fn autotune_without_machine_is_a_config_error() {
        let mut tuner = crate::tune::Tuner::exhaustive();
        let err =
            Pipeline::new(Heat1d::new(64, 8)).procs(2).autotune(&mut tuner).unwrap_err();
        assert!(matches!(err, PipelineError::Config(_)));
    }

    #[test]
    fn transform_on_shares_a_prebuilt_graph_and_keeps_the_cost_model() {
        let w = Spmv { matrix: CsrMatrix::laplace2d(5, 5), steps: 2 };
        let base = Pipeline::new(w).procs(4);
        let g = base.build_graph_shared().unwrap();
        let t = base.clone().block(2).transform_on(Arc::clone(&g)).unwrap();
        assert!(Arc::ptr_eq(&t.graph, &g), "the prebuilt graph must be shared, not rebuilt");
        // Identical plan and cost model as the self-building path — the
        // workload's RowFillCost survives, unlike a GraphWorkload wrap.
        let mach = Machine::new(4, 2, 10.0, 0.1, 1.0);
        let via_self = base.clone().block(2).transform().unwrap().simulate(&mach);
        let via_shared = t.simulate(&mach);
        assert_eq!(via_shared.time.value(), via_self.time.value());
        assert_eq!(via_shared.words, via_self.words);
        // A procs mismatch is rejected, not silently accepted.
        let err = base.procs(2).transform_on(g).unwrap_err();
        assert!(matches!(err, PipelineError::Graph(_)), "{err}");
    }

    #[test]
    fn partitioning_override_flows_to_graph_and_reports() {
        use crate::partition::{Partitioner, Partitioning, ProcGrid};
        // heat2d: an explicit column-strip grid changes the distribution.
        let base = Pipeline::new(Heat2d { h: 8, w: 8, steps: 2 }).procs(4);
        let square = base.clone().transform().unwrap();
        assert_eq!(square.partitioning(), Partitioning::Grid(ProcGrid::Square));
        let strip = base
            .clone()
            .partitioning(Partitioning::Grid(ProcGrid::Strip))
            .transform()
            .unwrap();
        assert_eq!(strip.partitioning(), Partitioning::Grid(ProcGrid::Strip));
        // Same tasks, different halo traffic: a 2x2 grid cuts both ways.
        assert_eq!(strip.stats().tasks, square.stats().tasks);
        assert_ne!(strip.stats().words, square.stats().words);
        strip.execute().unwrap();
        // A layout the workload cannot honour is a graph error.
        let err = base
            .partitioning(Partitioning::Graph(Partitioner::Rcb))
            .transform()
            .unwrap_err();
        assert!(matches!(err, PipelineError::Graph(_)), "{err}");
        // Workloads without a layout dimension reject non-default layouts
        // instead of silently ignoring them.
        let err = Pipeline::new(Heat1d::new(32, 4))
            .procs(2)
            .partitioning(Partitioning::Grid(ProcGrid::Grid { px: 1, py: 2 }))
            .transform()
            .unwrap_err();
        assert!(err.to_string().contains("does not support"), "{err}");
    }

    #[test]
    fn cost_hints_scale_simulated_time() {
        struct Slow;
        impl Workload for Slow {
            fn name(&self) -> String {
                "slow".into()
            }
            fn build_graph(&self, procs: u32) -> Result<TaskGraph, PipelineError> {
                Heat1d::new(32, 4).build_graph(procs)
            }
            fn cost_per_task(&self) -> f64 {
                10.0
            }
        }
        let fast = Pipeline::new(Heat1d::new(32, 4)).procs(2).transform().unwrap();
        let slow = Pipeline::new(Slow).procs(2).transform().unwrap();
        let m = Machine::new(2, 4, 0.0, 0.0, 1.0);
        let tf = fast.simulate(&m).time.value();
        let ts = slow.simulate(&m).time.value();
        assert!((ts - 10.0 * tf).abs() < 1e-9, "fast {tf} slow {ts}");
    }
}
