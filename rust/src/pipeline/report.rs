//! The shared result types every pipeline run produces, whether it went
//! through the discrete-event simulator or the real threaded coordinator.

use crate::tune::TuneReport;

/// How the run's time was obtained.
#[derive(Debug, Clone, PartialEq)]
pub enum RunTime {
    /// Discrete-event simulation on an α/β/γ machine (time in γ units).
    Simulated {
        total: f64,
        /// Worst per-processor time blocked in receives.
        max_wait: f64,
        /// Fraction of machine capacity spent computing.
        utilization: f64,
    },
    /// Real threads-and-channels execution (seconds).
    Measured { wall_secs: f64 },
}

impl RunTime {
    /// The headline number (simulated total or measured wall-clock).
    pub fn value(&self) -> f64 {
        match self {
            RunTime::Simulated { total, .. } => *total,
            RunTime::Measured { wall_secs } => *wall_secs,
        }
    }
}

/// Whether the run's values were checked against the workload's reference
/// solution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verification {
    /// Simulation only — there are no values to check.
    NotChecked,
    /// Every owner-held value matched the sequential reference.
    Verified {
        /// Number of owned values compared.
        owned_values: usize,
    },
}

impl Verification {
    pub fn is_verified(&self) -> bool {
        matches!(self, Verification::Verified { .. })
    }
}

/// The uniform report of one pipeline run: identity, work/traffic
/// accounting, time, and the correctness verdict.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Workload name ("heat1d", "spmv", ...).
    pub workload: String,
    /// Strategy label ("naive", "overlap", "ca(b=8)").
    pub strategy: String,
    pub procs: u32,
    /// Block factor (CA strategies only).
    pub block: Option<u32>,
    /// Compute tasks in the source graph.
    pub graph_tasks: usize,
    /// Task executions including redundant recomputation.
    pub executed_tasks: usize,
    /// `executed / graph` — the §2 redundancy the blocking bought.
    pub redundancy_factor: f64,
    /// Point-to-point messages.
    pub messages: usize,
    /// Words moved.
    pub words: usize,
    pub time: RunTime,
    pub verification: Verification,
    /// Present when the configuration was chosen by
    /// [`crate::pipeline::Pipeline::autotune`]: what the tuner searched
    /// and why this configuration won.
    pub tune: Option<TuneReport>,
}

impl RunReport {
    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        let time = match &self.time {
            RunTime::Simulated { total, .. } => format!("sim time {total:.1}"),
            RunTime::Measured { wall_secs } => format!("wall {wall_secs:.4}s"),
        };
        let verdict = match self.verification {
            Verification::NotChecked => String::new(),
            Verification::Verified { owned_values } => {
                format!("  verified {owned_values} values ✓")
            }
        };
        format!(
            "{:<10} {:<10} p={:<3} {}  tasks {} (+{} redundant)  msgs {}  words {}{}",
            self.workload,
            self.strategy,
            self.procs,
            time,
            self.graph_tasks,
            self.executed_tasks.saturating_sub(self.graph_tasks),
            self.messages,
            self.words,
            verdict,
        )
    }
}

/// Static (pre-run) accounting of a transformed pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineStats {
    pub tasks: usize,
    pub edges: usize,
    pub levels: u32,
    pub procs: u32,
    pub executed_tasks: usize,
    pub messages: usize,
    pub words: usize,
    pub redundancy_factor: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mentions_key_figures() {
        let r = RunReport {
            workload: "heat1d".into(),
            strategy: "ca(b=4)".into(),
            procs: 4,
            block: Some(4),
            graph_tasks: 100,
            executed_tasks: 112,
            redundancy_factor: 1.12,
            messages: 6,
            words: 24,
            time: RunTime::Measured { wall_secs: 0.25 },
            verification: Verification::Verified { owned_values: 100 },
            tune: None,
        };
        let s = r.summary();
        assert!(s.contains("heat1d") && s.contains("ca(b=4)"));
        assert!(s.contains("+12 redundant"));
        assert!(s.contains("verified 100"));
        assert!(r.verification.is_verified());
        assert!((r.time.value() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn simulated_time_value() {
        let t = RunTime::Simulated { total: 42.0, max_wait: 1.0, utilization: 0.5 };
        assert_eq!(t.value(), 42.0);
        assert!(!Verification::NotChecked.is_verified());
    }
}
