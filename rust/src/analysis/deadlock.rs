//! Static deadlock detection over the plan's wait-for structure.
//!
//! The engine blocks a `Recv` until the matching `Send` has *executed*
//! (message timing decides when it unblocks, never whether).  So
//! completability is a pure dataflow fact about the phase programs: run
//! a timing-free worklist over the (proc, phase-cursor) states where a
//! `Send` always advances (sends are non-blocking) and a `Recv` advances
//! iff its channel has an unconsumed prior send.  The least fixed point
//! either completes every program or leaves a stuck frontier — and that
//! frontier equals [`crate::sim::try_simulate`]'s
//! [`crate::sim::SimError::Deadlock`] list exactly, which the mutation
//! matrix in `rust/tests/analysis_matrix.rs` pins.

use crate::sim::{ExecPlan, Phase};
use std::collections::HashMap;

/// The outcome of [`deadlock_check`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeadlockVerdict {
    /// Every processor's program runs to completion.
    Free,
    /// The listed `(proc, phase index)` pairs block forever — the same
    /// shape as [`crate::sim::SimError::Deadlock`]'s `stuck` list.
    Stuck(Vec<(u32, usize)>),
}

impl DeadlockVerdict {
    /// True iff the plan is deadlock-free.
    pub fn is_free(&self) -> bool {
        matches!(self, DeadlockVerdict::Free)
    }

    /// The stuck frontier (empty when free).
    pub fn stuck(&self) -> &[(u32, usize)] {
        match self {
            DeadlockVerdict::Free => &[],
            DeadlockVerdict::Stuck(s) => s,
        }
    }
}

/// Prove `plan` deadlock-free (or name its stuck frontier) without
/// running the engine.  O(total phases) across worklist rounds.
pub fn deadlock_check(plan: &ExecPlan) -> DeadlockVerdict {
    let nprocs = plan.per_proc.len();
    let mut cursor = vec![0usize; nprocs];
    // Messages emitted / consumed per (from, to) channel so far.
    let mut sent: HashMap<(u32, u32), u32> = HashMap::new();
    let mut rcvd: HashMap<(u32, u32), u32> = HashMap::new();

    loop {
        let mut progressed = false;
        for (p, pp) in plan.per_proc.iter().enumerate() {
            let phases = &pp.phases;
            while cursor[p] < phases.len() {
                match &phases[cursor[p]] {
                    Phase::Compute(_) => {}
                    Phase::Send { to, .. } => {
                        *sent.entry((p as u32, to.0)).or_insert(0) += 1;
                    }
                    Phase::Recv { from, .. } => {
                        let key = (from.0, p as u32);
                        let consumed = rcvd.get(&key).copied().unwrap_or(0);
                        if sent.get(&key).copied().unwrap_or(0) <= consumed {
                            break; // blocked: re-examined next round
                        }
                        rcvd.insert(key, consumed + 1);
                    }
                }
                cursor[p] += 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }

    let stuck: Vec<(u32, usize)> = (0..nprocs)
        .filter(|&p| cursor[p] < plan.per_proc[p].phases.len())
        .map(|p| (p as u32, cursor[p]))
        .collect();
    if stuck.is_empty() {
        DeadlockVerdict::Free
    } else {
        DeadlockVerdict::Stuck(stuck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ProcId;
    use crate::sim::{
        try_simulate, AlphaBeta, ExecPlan, Machine, ProcPlan, SimError, UniformCost,
    };
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    /// The pinning harness: the static verdict must equal the dynamic one.
    fn assert_pinned(plan: &ExecPlan, tag: &str) {
        let g = heat1d_graph(8, 1, plan.per_proc.len() as u32);
        let mach = Machine::new(plan.per_proc.len() as u32, 1, 10.0, 0.1, 1.0);
        let mut net = AlphaBeta::from_machine(&mach);
        let dynamic = try_simulate(&g, plan, &mach, &mut net, &UniformCost, false);
        match (deadlock_check(plan), dynamic) {
            (DeadlockVerdict::Free, Ok(_)) => {}
            (DeadlockVerdict::Stuck(s), Err(SimError::Deadlock { stuck })) => {
                assert_eq!(s, stuck, "{tag}: stuck frontiers differ");
            }
            (stat, dynam) => panic!("{tag}: static {stat:?} vs dynamic {dynam:?}"),
        }
    }

    #[test]
    fn cyclic_wait_is_stuck_everywhere() {
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Recv { from: ProcId(1), tasks: vec![0] });
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Send { to: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "cycle".into() };
        assert_eq!(deadlock_check(&plan), DeadlockVerdict::Stuck(vec![(0, 0), (1, 0)]));
        assert_pinned(&plan, "cycle");
    }

    #[test]
    fn half_deadlock_strands_one_proc() {
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Compute(vec![8]));
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "half".into() };
        assert_eq!(deadlock_check(&plan), DeadlockVerdict::Stuck(vec![(1, 0)]));
        assert_pinned(&plan, "half");
    }

    #[test]
    fn out_of_order_sends_still_complete() {
        // p1 receives before it sends, but p0 sends first: no cycle.
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![0] });
        per_proc[0].phases.push(Phase::Recv { from: ProcId(1), tasks: vec![1] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Send { to: ProcId(0), tasks: vec![1] });
        let plan = ExecPlan { per_proc, label: "pingpong".into() };
        assert!(deadlock_check(&plan).is_free());
        assert_pinned(&plan, "pingpong");
    }

    #[test]
    fn pipeline_plans_are_free() {
        let g = heat1d_graph(24, 3, 3);
        for plan in [
            ExecPlan::naive(&g),
            ExecPlan::overlap(&g),
            ExecPlan::ca(&g, 3, TransformOptions::default()).unwrap(),
        ] {
            let verdict = deadlock_check(&plan);
            assert!(verdict.is_free(), "{}: {verdict:?}", plan.label);
            assert!(verdict.stuck().is_empty());
        }
    }
}
