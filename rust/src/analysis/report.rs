//! Diagnostic vocabulary and the aggregated analysis report.

/// How bad a [`Diagnostic`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// The plan runs to completion on the engine, but is value-unsafe or
    /// wasteful (orphaned sends, word mismatches, double-produces).
    Warning,
    /// The plan cannot run to completion, or consumes values that are
    /// never produced — it must not reach the engine or the coordinator.
    Fatal,
}

/// One static finding about a plan.
///
/// Channel diagnostics name the `(from, to)` channel and the 0-based
/// message sequence number on it; hazard diagnostics name the processor,
/// the phase index in its program, and the offending task id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Diagnostic {
    /// The `seq`-th `Recv` on the channel has no matching `Send`: the
    /// receiver blocks forever — the engine's "half-deadlock", caught
    /// statically.
    UnmatchedRecv {
        /// Sending processor of the channel.
        from: u32,
        /// Receiving processor of the channel.
        to: u32,
        /// 0-based message sequence number on the channel.
        seq: u32,
    },
    /// The `seq`-th `Send` on the channel has no matching `Recv`: the
    /// message is posted and never consumed (sends are non-blocking, so
    /// the plan still completes — but the slot leaks).
    OrphanSend {
        /// Sending processor of the channel.
        from: u32,
        /// Receiving processor of the channel.
        to: u32,
        /// 0-based message sequence number on the channel.
        seq: u32,
    },
    /// The `seq`-th `Send` and `Recv` on the channel disagree on the
    /// message's word count: the wire charges for `sent` words while the
    /// receiver unpacks `received` — values end up misrouted.
    WordMismatch {
        /// Sending processor of the channel.
        from: u32,
        /// Receiving processor of the channel.
        to: u32,
        /// 0-based message sequence number on the channel.
        seq: u32,
        /// Words in the `Send`'s payload.
        sent: usize,
        /// Words the `Recv` expects.
        received: usize,
    },
    /// A `Compute` phase consumes `task`'s value before any earlier
    /// phase on that processor produced it (RAW violation — the
    /// reordered consumer ran ahead of its producer/receive).
    UseWithoutProduce {
        /// Processor whose program is at fault.
        proc: u32,
        /// Phase index in that processor's program.
        phase: usize,
        /// The consumed-but-never-produced task id.
        task: u32,
    },
    /// A `Send` phase ships `task`'s value before any earlier phase on
    /// that processor produced it.
    SendWithoutProduce {
        /// Processor whose program is at fault.
        proc: u32,
        /// Phase index in that processor's program.
        phase: usize,
        /// The shipped-but-never-produced task id.
        task: u32,
    },
    /// A `Compute` phase produces `task`'s value a second time on the
    /// same processor (WAW hazard from overlap/CA reordering).
    DoubleProduce {
        /// Processor whose program is at fault.
        proc: u32,
        /// Phase index in that processor's program.
        phase: usize,
        /// The twice-produced task id.
        task: u32,
    },
    /// The wait-for structure has a stuck frontier: every listed
    /// processor is blocked at the listed phase index and nothing can
    /// unblock it — the same shape as
    /// [`crate::sim::SimError::Deadlock`], proven statically.
    Deadlock {
        /// `(proc, phase index)` of every stuck processor.
        stuck: Vec<(u32, usize)>,
    },
}

impl Diagnostic {
    /// The diagnostic's severity class.
    pub fn severity(&self) -> Severity {
        match self {
            Diagnostic::UnmatchedRecv { .. }
            | Diagnostic::UseWithoutProduce { .. }
            | Diagnostic::SendWithoutProduce { .. }
            | Diagnostic::Deadlock { .. } => Severity::Fatal,
            Diagnostic::OrphanSend { .. }
            | Diagnostic::WordMismatch { .. }
            | Diagnostic::DoubleProduce { .. } => Severity::Warning,
        }
    }

    /// Stable machine-readable tag ("unmatched-recv", "deadlock", ...).
    pub fn code(&self) -> &'static str {
        match self {
            Diagnostic::UnmatchedRecv { .. } => "unmatched-recv",
            Diagnostic::OrphanSend { .. } => "orphan-send",
            Diagnostic::WordMismatch { .. } => "word-mismatch",
            Diagnostic::UseWithoutProduce { .. } => "use-without-produce",
            Diagnostic::SendWithoutProduce { .. } => "send-without-produce",
            Diagnostic::DoubleProduce { .. } => "double-produce",
            Diagnostic::Deadlock { .. } => "deadlock",
        }
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Diagnostic::UnmatchedRecv { from, to, seq } => write!(
                f,
                "unmatched-recv: message #{seq} on p{from}→p{to} is received but never sent"
            ),
            Diagnostic::OrphanSend { from, to, seq } => {
                write!(f, "orphan-send: message #{seq} on p{from}→p{to} is sent but never received")
            }
            Diagnostic::WordMismatch { from, to, seq, sent, received } => write!(
                f,
                "word-mismatch: message #{seq} on p{from}→p{to} sends {sent} words but the receiver expects {received}"
            ),
            Diagnostic::UseWithoutProduce { proc, phase, task } => write!(
                f,
                "use-without-produce: p{proc} phase {phase} consumes t{task} before it is computed or received"
            ),
            Diagnostic::SendWithoutProduce { proc, phase, task } => write!(
                f,
                "send-without-produce: p{proc} phase {phase} ships t{task} before it is computed or received"
            ),
            Diagnostic::DoubleProduce { proc, phase, task } => {
                write!(f, "double-produce: p{proc} phase {phase} produces t{task} a second time")
            }
            Diagnostic::Deadlock { stuck } => {
                write!(f, "deadlock: ")?;
                for (i, (p, phase)) in stuck.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "p{p} blocked at phase {phase}")?;
                }
                Ok(())
            }
        }
    }
}

/// Everything [`super::analyze`] found out about one plan.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    /// The analyzed plan's label.
    pub plan_label: String,
    /// Processors in the plan.
    pub procs: usize,
    /// Total phases across all processor programs.
    pub phases: usize,
    /// Every finding, deterministic order (channels, hazards, deadlock).
    pub diagnostics: Vec<Diagnostic>,
    /// The static stuck frontier — empty iff the plan is deadlock-free.
    /// Matches [`crate::sim::SimError::Deadlock`]'s `stuck` list exactly
    /// when non-empty.
    pub stuck: Vec<(u32, usize)>,
}

impl AnalysisReport {
    /// True iff the static wait-for execution completes every program.
    pub fn deadlock_free(&self) -> bool {
        self.stuck.is_empty()
    }

    /// No diagnostics at all — the bar every pipeline-built plan meets.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// No *fatal* diagnostics — warnings alone don't stop the engine.
    pub fn is_safe(&self) -> bool {
        self.fatal_count() == 0
    }

    /// Number of fatal diagnostics.
    pub fn fatal_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity() == Severity::Fatal).count()
    }

    /// Number of warning diagnostics.
    pub fn warning_count(&self) -> usize {
        self.diagnostics.len() - self.fatal_count()
    }

    /// Convert an unsafe report into the error carrying its fatal
    /// diagnostics (warnings are dropped; call only when
    /// [`AnalysisReport::is_safe`] is false).
    pub fn into_error(self) -> AnalysisError {
        let fatal: Vec<Diagnostic> = self
            .diagnostics
            .into_iter()
            .filter(|d| d.severity() == Severity::Fatal)
            .collect();
        AnalysisError { plan_label: self.plan_label, fatal }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        if self.is_clean() {
            format!(
                "{}: clean ({} procs, {} phases, deadlock-free)",
                self.plan_label, self.procs, self.phases
            )
        } else {
            format!(
                "{}: {} fatal, {} warning ({} procs, {} phases){}",
                self.plan_label,
                self.fatal_count(),
                self.warning_count(),
                self.procs,
                self.phases,
                if self.deadlock_free() { "" } else { "; DEADLOCK" }
            )
        }
    }

    /// Single-line JSON object (the `serve` dialect: flat keys, one
    /// line) listing counts and rendered diagnostics.
    pub fn to_json(&self) -> String {
        let diags: Vec<String> =
            self.diagnostics.iter().map(|d| format!("{:?}", d.to_string())).collect();
        format!(
            "{{\"plan\": {:?}, \"procs\": {}, \"phases\": {}, \"deadlock_free\": {}, \
             \"fatal\": {}, \"warnings\": {}, \"diagnostics\": [{}]}}",
            self.plan_label,
            self.procs,
            self.phases,
            self.deadlock_free(),
            self.fatal_count(),
            self.warning_count(),
            diags.join(", ")
        )
    }
}

/// A plan failed static verification: the structured replacement for
/// the engine's dynamic deadlock panic, carrying every fatal
/// [`Diagnostic`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AnalysisError {
    /// The rejected plan's label.
    pub plan_label: String,
    /// The fatal diagnostics, in report order.
    pub fatal: Vec<Diagnostic>,
}

impl std::fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "plan {:?} failed static verification", self.plan_label)?;
        for d in &self.fatal {
            write!(f, "; {d}")?;
        }
        Ok(())
    }
}

impl std::error::Error for AnalysisError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> AnalysisReport {
        AnalysisReport {
            plan_label: "test".into(),
            procs: 2,
            phases: 7,
            diagnostics: vec![
                Diagnostic::OrphanSend { from: 0, to: 1, seq: 2 },
                Diagnostic::UnmatchedRecv { from: 1, to: 0, seq: 0 },
                Diagnostic::Deadlock { stuck: vec![(0, 3)] },
            ],
            stuck: vec![(0, 3)],
        }
    }

    #[test]
    fn severity_split_matches_engine_behavior() {
        // Fatal = the engine cannot complete (or values are consumed
        // unproduced); Warning = the engine completes anyway.
        assert_eq!(
            Diagnostic::UnmatchedRecv { from: 0, to: 1, seq: 0 }.severity(),
            Severity::Fatal
        );
        assert_eq!(Diagnostic::Deadlock { stuck: vec![] }.severity(), Severity::Fatal);
        assert_eq!(
            Diagnostic::UseWithoutProduce { proc: 0, phase: 1, task: 2 }.severity(),
            Severity::Fatal
        );
        assert_eq!(
            Diagnostic::SendWithoutProduce { proc: 0, phase: 1, task: 2 }.severity(),
            Severity::Fatal
        );
        assert_eq!(Diagnostic::OrphanSend { from: 0, to: 1, seq: 0 }.severity(), Severity::Warning);
        assert_eq!(
            Diagnostic::WordMismatch { from: 0, to: 1, seq: 0, sent: 2, received: 3 }.severity(),
            Severity::Warning
        );
        assert_eq!(
            Diagnostic::DoubleProduce { proc: 0, phase: 1, task: 2 }.severity(),
            Severity::Warning
        );
    }

    #[test]
    fn report_counts_and_summary() {
        let r = report();
        assert!(!r.is_clean());
        assert!(!r.is_safe());
        assert!(!r.deadlock_free());
        assert_eq!(r.fatal_count(), 2);
        assert_eq!(r.warning_count(), 1);
        let s = r.summary();
        assert!(s.contains("2 fatal") && s.contains("DEADLOCK"), "{s}");
    }

    #[test]
    fn error_keeps_only_fatal_diagnostics() {
        let err = report().into_error();
        assert_eq!(err.fatal.len(), 2);
        let text = err.to_string();
        assert!(text.contains("failed static verification"), "{text}");
        assert!(text.contains("unmatched-recv"), "{text}");
        assert!(!text.contains("orphan-send"), "{text}");
    }

    #[test]
    fn json_is_one_flat_line() {
        let json = report().to_json();
        assert_eq!(json.lines().count(), 1);
        assert!(json.contains("\"deadlock_free\": false"), "{json}");
        assert!(json.contains("\"fatal\": 2"), "{json}");
        assert!(json.contains("unmatched-recv"), "{json}");
    }

    #[test]
    fn every_code_is_stable_and_distinct() {
        let diags = [
            Diagnostic::UnmatchedRecv { from: 0, to: 1, seq: 0 },
            Diagnostic::OrphanSend { from: 0, to: 1, seq: 0 },
            Diagnostic::WordMismatch { from: 0, to: 1, seq: 0, sent: 1, received: 2 },
            Diagnostic::UseWithoutProduce { proc: 0, phase: 0, task: 0 },
            Diagnostic::SendWithoutProduce { proc: 0, phase: 0, task: 0 },
            Diagnostic::DoubleProduce { proc: 0, phase: 0, task: 0 },
            Diagnostic::Deadlock { stuck: vec![] },
        ];
        let codes: std::collections::BTreeSet<&str> = diags.iter().map(|d| d.code()).collect();
        assert_eq!(codes.len(), diags.len());
        for d in &diags {
            // The rendered message leads with the machine tag.
            assert!(d.to_string().starts_with(d.code()), "{d}");
        }
    }
}
