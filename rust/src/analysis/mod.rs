//! Static plan analysis: prove properties of an [`ExecPlan`] **without
//! running the engine**.
//!
//! The paper's latency-tolerance transforms reorder sends and receives
//! across supersteps, so a wrong transform used to surface only as a
//! *dynamic* [`crate::sim::SimError::Deadlock`] deep inside the engine.
//! This module makes the failure modes static, named diagnostics, and
//! turns the same machinery around to produce an *analytic* makespan
//! lower bound that the autotuner uses to prune candidates.
//!
//! Module map (verify → prune → report data flow):
//!
//! * [`channels`](channel_census) — per-channel send/recv census: the
//!   k-th `Send` on a `(from, to)` channel pairs with the k-th `Recv`;
//!   unmatched receives (the engine's "half-deadlock"), orphaned sends,
//!   and word-count disagreements become named diagnostics;
//! * [`deadlock`](deadlock_check) — a timing-free worklist execution of
//!   the plan's (proc, phase-cursor) wait-for structure; its stuck
//!   frontier is pinned to match [`crate::sim::try_simulate`]'s dynamic
//!   verdict exactly (message timing affects *when* a receive unblocks,
//!   never *whether* it does);
//! * [`hazards`](hazard_check) — whole-plan value-availability pass, the
//!   Theorem-1 predecessor-closure check generalized from per-superstep
//!   ([`crate::transform::check_schedule`]) to arbitrary phase programs:
//!   uses-without-produce, sends-without-produce, double-produces
//!   (WAW hazards from overlap/CA reordering);
//! * [`critpath`](critical_path) — the longest weighted path through the
//!   plan under a wire model's per-channel lower bounds
//!   ([`crate::sim::NetworkModel::message_lower_bound`]): an analytic
//!   makespan lower bound, *exact* on stateless wires (AlphaBeta,
//!   Hierarchical) and safely below stateful ones (LogGP, Contended);
//!   [`input_lower_bound`] is the tuner's branch-and-bound hook;
//! * [`report`](AnalysisReport) — aggregation: severities, summaries,
//!   JSON, and the structured [`AnalysisError`] that
//!   [`crate::pipeline::Pipeline::transform`] surfaces as a pre-flight
//!   failure instead of an engine panic.
#![deny(missing_docs)]

mod channels;
mod critpath;
mod deadlock;
mod hazards;
mod report;

pub use channels::channel_census;
pub use critpath::{critical_path, input_lower_bound, CritPath};
pub use deadlock::{deadlock_check, DeadlockVerdict};
pub use hazards::hazard_check;
pub use report::{AnalysisError, AnalysisReport, Diagnostic, Severity};

use crate::graph::TaskGraph;
use crate::sim::ExecPlan;

/// Run every structural check on `plan` and collect the findings.
///
/// Diagnostics come back in deterministic order: channel census first
/// (by channel), then hazards (by proc and phase), then the deadlock
/// verdict.  A plan built by [`crate::pipeline::Pipeline`] produces an
/// empty diagnostic list ([`AnalysisReport::is_clean`]); the mutation
/// matrix in `rust/tests/analysis_matrix.rs` pins that no corrupted
/// plan does.
pub fn analyze(g: &TaskGraph, plan: &ExecPlan) -> AnalysisReport {
    let mut diagnostics = channel_census(plan);
    diagnostics.extend(hazard_check(g, plan));
    let verdict = deadlock_check(plan);
    let stuck = verdict.stuck().to_vec();
    if !stuck.is_empty() {
        diagnostics.push(Diagnostic::Deadlock { stuck: stuck.clone() });
    }
    AnalysisReport {
        plan_label: plan.label.clone(),
        procs: plan.per_proc.len(),
        phases: plan.per_proc.iter().map(|p| p.phases.len()).sum(),
        diagnostics,
        stuck,
    }
}

/// The pre-flight gate: `Ok` iff [`analyze`] finds no fatal diagnostic
/// (warnings — orphaned sends, word-count mismatches, double-produces —
/// pass; the report carries them for inspection).
///
/// # Errors
///
/// Returns the structured [`AnalysisError`] listing every fatal
/// diagnostic when the plan can deadlock or consumes values it never
/// produced.
pub fn verify(g: &TaskGraph, plan: &ExecPlan) -> Result<AnalysisReport, AnalysisError> {
    let report = analyze(g, plan);
    if report.is_safe() {
        Ok(report)
    } else {
        Err(report.into_error())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ExecPlan;
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    #[test]
    fn pipeline_built_plans_are_clean() {
        let g = heat1d_graph(32, 4, 4);
        for plan in [
            ExecPlan::naive(&g),
            ExecPlan::overlap(&g),
            ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap(),
        ] {
            let report = analyze(&g, &plan);
            assert!(report.is_clean(), "{}: {}", plan.label, report.summary());
            assert!(report.deadlock_free());
            assert!(verify(&g, &plan).is_ok());
        }
    }

    #[test]
    fn verify_rejects_a_cyclic_wait() {
        use crate::graph::ProcId;
        use crate::sim::{Phase, ProcPlan};
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Recv { from: ProcId(1), tasks: vec![0] });
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Send { to: ProcId(0), tasks: vec![0] });
        let plan = ExecPlan { per_proc, label: "cycle".into() };
        let err = verify(&g, &plan).unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
        let report = analyze(&g, &plan);
        assert_eq!(report.stuck, vec![(0, 0), (1, 0)]);
    }
}
