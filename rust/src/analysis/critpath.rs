//! Analytic critical path: a makespan lower bound without the engine.
//!
//! The engine's timing algebra is monotone — every clock is a `max`/`+`
//! composition of non-negative durations — so replaying the plan with
//! each message arrival replaced by its state-independent lower bound
//! ([`crate::sim::NetworkModel::message_lower_bound`]) yields a lower
//! bound on every processor's finish time, hence on the makespan.  On
//! stateless wires (AlphaBeta, Hierarchical) the per-message bound *is*
//! the exact delivery cost, so the "bound" reproduces the engine
//! bit-for-bit ([`CritPath::exact_wire`]); on stateful wires (LogGP
//! injection gaps, contended NICs) only the queueing terms are dropped.
//!
//! Compute phases are timed with the engine's own list scheduler
//! (`run_compute`), so the compute side of the bound is exact
//! everywhere.  The pass doubles as a deadlock check: a plan that cannot
//! complete has no critical path.

use super::report::{AnalysisError, Diagnostic};
use crate::graph::TaskGraph;
use crate::sim::sweep::SweepInput;
use crate::sim::{run_compute, ExecPlan, Machine, NetworkKind, NetworkModel, Phase, TaskCostModel};
use std::collections::HashMap;

/// The timed result of the critical-path pass: lower bounds with the
/// same shape as the engine's [`crate::sim::SimResult`] (and equal to it
/// when [`CritPath::exact_wire`] holds).
#[derive(Debug, Clone, PartialEq)]
pub struct CritPath {
    /// Lower bound on the plan's makespan (max proc finish), γ units.
    pub makespan: f64,
    /// Per-processor finish-time lower bounds.
    pub proc_finish: Vec<f64>,
    /// Per-processor busy thread-time (exact, not a bound: work is
    /// timing-independent).
    pub proc_busy: Vec<f64>,
    /// Per-processor receive-wait lower bounds.
    pub proc_wait: Vec<f64>,
    /// Messages the plan posts (zero-word sends excluded, as in the
    /// engine).
    pub messages: usize,
    /// Words the plan moves.
    pub words: usize,
    /// True iff every posted message resolved stateless per-channel
    /// constants ([`crate::sim::NetworkModel::channel_cost`]): the
    /// lower bound then equals the simulated makespan exactly.
    pub exact_wire: bool,
}

/// Compute the critical path of `plan` on machine `m` under `network`'s
/// per-channel lower bounds and `cost`'s task weights.
///
/// # Errors
///
/// A plan that deadlocks has no critical path; the error carries the
/// static stuck frontier.
///
/// # Panics
///
/// Panics if `plan` and `m` disagree on the processor count — the same
/// contract as [`crate::sim::try_simulate`].
pub fn critical_path(
    g: &TaskGraph,
    plan: &ExecPlan,
    m: &Machine,
    network: &dyn NetworkModel,
    cost: &dyn TaskCostModel,
) -> Result<CritPath, AnalysisError> {
    assert_eq!(plan.per_proc.len(), m.nprocs as usize, "plan/machine proc count mismatch");
    let nprocs = plan.per_proc.len();
    let mut clock = vec![0.0f64; nprocs];
    let mut busy = vec![0.0f64; nprocs];
    let mut wait = vec![0.0f64; nprocs];
    let mut cursor = vec![0usize; nprocs];
    let mut messages = 0usize;
    let mut words = 0usize;
    let mut exact_wire = true;
    // Posted, unconsumed messages: (from, to, seq) → arrival lower bound.
    let mut posted: HashMap<(u32, u32, u32), f64> = HashMap::new();
    let mut send_seq: HashMap<(u32, u32), u32> = HashMap::new();
    let mut recv_seq: HashMap<(u32, u32), u32> = HashMap::new();

    loop {
        let mut progressed = false;
        for p in 0..nprocs {
            let phases = &plan.per_proc[p].phases;
            while cursor[p] < phases.len() {
                match &phases[cursor[p]] {
                    Phase::Compute(tasks) => {
                        let (end, b) = run_compute(g, tasks, m, clock[p], p as u32, cost, None);
                        busy[p] += b;
                        clock[p] = end;
                    }
                    Phase::Send { to, tasks } => {
                        let seq = send_seq.entry((p as u32, to.0)).or_insert(0);
                        let key = (p as u32, to.0, *seq);
                        *seq += 1;
                        // Zero-word sends arrive instantly at the
                        // sender's clock and are not counted — mirror of
                        // the engine's accounting.
                        let arrival = if tasks.is_empty() {
                            clock[p]
                        } else {
                            messages += 1;
                            words += tasks.len();
                            exact_wire &= network.channel_cost(p as u32, to.0).is_some();
                            clock[p] + network.message_lower_bound(p as u32, to.0, tasks.len())
                        };
                        posted.insert(key, arrival);
                    }
                    Phase::Recv { from, .. } => {
                        let seq = *recv_seq.entry((from.0, p as u32)).or_insert(0);
                        let key = (from.0, p as u32, seq);
                        let Some(arrival) = posted.remove(&key) else {
                            break; // blocked: re-examined next round
                        };
                        recv_seq.insert((from.0, p as u32), seq + 1);
                        if arrival > clock[p] {
                            wait[p] += arrival - clock[p];
                            clock[p] = arrival;
                        }
                    }
                }
                cursor[p] += 1;
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }

    let stuck: Vec<(u32, usize)> = (0..nprocs)
        .filter(|&p| cursor[p] < plan.per_proc[p].phases.len())
        .map(|p| (p as u32, cursor[p]))
        .collect();
    if !stuck.is_empty() {
        return Err(AnalysisError {
            plan_label: plan.label.clone(),
            fatal: vec![Diagnostic::Deadlock { stuck }],
        });
    }

    Ok(CritPath {
        makespan: clock.iter().copied().fold(0.0, f64::max),
        proc_finish: clock,
        proc_busy: busy,
        proc_wait: wait,
        messages,
        words,
        exact_wire,
    })
}

/// Makespan lower bound for one prepared sweep input on the *effective*
/// machine a sweep cell would use — the β of the base machine scaled by
/// the input's words-per-value, the wire built layout-aware — exactly
/// mirroring the sweep's cell evaluation.  `None` when the input cannot
/// be bounded (e.g. its plan deadlocks): callers must then evaluate it
/// for real rather than prune it.
///
/// This is the [`crate::tune`] branch-and-bound hook: a candidate whose
/// lower bound already exceeds the incumbent can never win.
pub fn input_lower_bound(input: &SweepInput, base: &Machine, kind: NetworkKind) -> Option<f64> {
    let procs = input.plan.per_proc.len() as u32;
    let mach = Machine::new(
        procs,
        base.threads,
        base.alpha,
        base.beta * input.words_per_value as f64,
        base.gamma,
    );
    let net = kind.build_for(&mach, input.layout.as_ref());
    critical_path(&input.graph, &input.plan, &mach, net.as_ref(), input.cost.as_ref())
        .ok()
        .map(|cp| cp.makespan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate, try_simulate, AlphaBeta, UniformCost};
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;
    use std::sync::Arc;

    fn plans(g: &TaskGraph) -> Vec<ExecPlan> {
        vec![
            ExecPlan::naive(g),
            ExecPlan::overlap(g),
            ExecPlan::ca(g, 2, TransformOptions::default()).unwrap(),
        ]
    }

    #[test]
    fn exact_on_the_alphabeta_wire() {
        let g = heat1d_graph(32, 4, 4);
        let mach = Machine::new(4, 2, 50.0, 0.5, 1.0);
        for plan in plans(&g) {
            let r = simulate(&g, &plan, &mach, false);
            let net = AlphaBeta::from_machine(&mach);
            let cp = critical_path(&g, &plan, &mach, &net, &UniformCost).unwrap();
            assert!(cp.exact_wire, "{}", plan.label);
            assert_eq!(cp.makespan, r.total_time, "{}", plan.label);
            assert_eq!(cp.proc_finish, r.proc_finish, "{}", plan.label);
            assert_eq!(cp.proc_busy, r.proc_busy, "{}", plan.label);
            assert_eq!(cp.proc_wait, r.proc_wait, "{}", plan.label);
            assert_eq!(cp.messages, r.messages, "{}", plan.label);
            assert_eq!(cp.words, r.words, "{}", plan.label);
        }
    }

    #[test]
    fn lower_bounds_every_wire() {
        let g = heat1d_graph(48, 4, 4);
        let mach = Machine::new(4, 2, 60.0, 0.5, 1.0);
        for plan in plans(&g) {
            for kind in NetworkKind::all_default() {
                let mut net = kind.build(&mach);
                let r = try_simulate(&g, &plan, &mach, net.as_mut(), &UniformCost, false)
                    .unwrap();
                let cp = critical_path(&g, &plan, &mach, net.as_ref(), &UniformCost).unwrap();
                assert!(
                    cp.makespan <= r.total_time + 1e-9,
                    "{}/{}: lb {} > sim {}",
                    plan.label,
                    kind.label(),
                    cp.makespan,
                    r.total_time
                );
                // Work is timing-independent: busy time is exact even on
                // stateful wires.
                for p in 0..4 {
                    assert!((cp.proc_busy[p] - r.proc_busy[p]).abs() < 1e-9);
                }
                assert_eq!(cp.messages, r.messages);
                assert_eq!(cp.words, r.words);
                if cp.exact_wire {
                    assert_eq!(cp.makespan, r.total_time, "{}", kind.label());
                }
            }
        }
    }

    #[test]
    fn deadlocked_plan_has_no_critical_path() {
        use crate::graph::ProcId;
        use crate::sim::ProcPlan;
        let g = heat1d_graph(8, 1, 2);
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Recv { from: ProcId(1), tasks: vec![0] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![1] });
        let plan = ExecPlan { per_proc, label: "stuck".into() };
        let mach = Machine::new(2, 1, 10.0, 0.1, 1.0);
        let net = AlphaBeta::from_machine(&mach);
        let err = critical_path(&g, &plan, &mach, &net, &UniformCost).unwrap_err();
        assert_eq!(err.fatal, vec![Diagnostic::Deadlock { stuck: vec![(0, 0), (1, 0)] }]);
    }

    #[test]
    fn input_lower_bound_scales_beta_by_words_per_value() {
        let g = Arc::new(heat1d_graph(32, 4, 2));
        let plan = Arc::new(ExecPlan::naive(&g));
        let base = Machine::new(2, 2, 40.0, 0.5, 1.0);
        let mk = |wpv: usize| {
            SweepInput::new(
                "heat1d",
                "naive",
                Arc::clone(&g),
                Arc::clone(&plan),
                Arc::new(UniformCost),
                wpv,
                None,
            )
        };
        let lb1 = input_lower_bound(&mk(1), &base, NetworkKind::AlphaBeta).unwrap();
        let lb4 = input_lower_bound(&mk(4), &base, NetworkKind::AlphaBeta).unwrap();
        assert!(lb4 > lb1, "wider values must cost more wire: {lb4} vs {lb1}");
        // And the exact-wire bound matches a direct simulation on the
        // effective machine.
        let eff = Machine::new(2, 2, 40.0, 0.5 * 4.0, 1.0);
        let direct = simulate(&g, &plan, &eff, false);
        assert_eq!(lb4, direct.total_time);
    }
}
