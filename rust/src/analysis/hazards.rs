//! Whole-plan value-hazard analysis.
//!
//! The Theorem-1 check ([`crate::transform::check_schedule`]) proves the
//! predecessor closure *per superstep* of a CA schedule; this pass
//! generalizes it to any phase program by replaying availability: a
//! value exists on processor `p` once `p` owns it as an `Input`,
//! computes it, or receives it.  A `Compute` whose predecessor is
//! neither available nor scheduled in the same phase (the engine's list
//! scheduler orders same-phase tasks by `(level, id)`, so intra-phase
//! producers always run first) is a RAW violation; a `Send` of an
//! unavailable value ships garbage; producing twice is the WAW hazard
//! overlap/CA reordering can introduce.

use super::report::Diagnostic;
use crate::graph::{ProcId, TaskGraph, TaskId, TaskKind};
use crate::sim::{ExecPlan, Phase};
use std::collections::{BTreeSet, HashSet};

/// Replay value availability on every processor and report RAW/WAW
/// hazards, ordered by proc, then phase, then task id.
pub fn hazard_check(g: &TaskGraph, plan: &ExecPlan) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (p, pp) in plan.per_proc.iter().enumerate() {
        // Values present on p before anything runs: its own inputs.
        let mut avail: HashSet<u32> = g
            .owned_by(ProcId(p as u32))
            .into_iter()
            .filter(|&t| g.kind(TaskId(t)) == TaskKind::Input)
            .collect();
        for (i, ph) in pp.phases.iter().enumerate() {
            match ph {
                Phase::Compute(tasks) => {
                    let in_phase: HashSet<u32> = tasks.iter().copied().collect();
                    // Dedup: one diagnostic per missing value per phase.
                    let mut missing: BTreeSet<u32> = BTreeSet::new();
                    for &t in tasks {
                        for &pr in g.preds(TaskId(t)) {
                            if !avail.contains(&pr) && !in_phase.contains(&pr) {
                                missing.insert(pr);
                            }
                        }
                    }
                    out.extend(missing.into_iter().map(|task| Diagnostic::UseWithoutProduce {
                        proc: p as u32,
                        phase: i,
                        task,
                    }));
                    let mut doubled: BTreeSet<u32> = BTreeSet::new();
                    for &t in tasks {
                        if !avail.insert(t) {
                            doubled.insert(t);
                        }
                    }
                    out.extend(doubled.into_iter().map(|task| Diagnostic::DoubleProduce {
                        proc: p as u32,
                        phase: i,
                        task,
                    }));
                }
                Phase::Send { tasks, .. } => {
                    let mut missing: BTreeSet<u32> = BTreeSet::new();
                    for &t in tasks {
                        if !avail.contains(&t) {
                            missing.insert(t);
                        }
                    }
                    out.extend(missing.into_iter().map(|task| Diagnostic::SendWithoutProduce {
                        proc: p as u32,
                        phase: i,
                        task,
                    }));
                }
                Phase::Recv { tasks, .. } => {
                    // Receiving a value twice is harmless redundancy in a
                    // matched channel (the census flags the mismatch side);
                    // availability just absorbs it.
                    avail.extend(tasks.iter().copied());
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ExecPlan, ProcPlan};
    use crate::stencil::heat1d_graph;
    use crate::transform::TransformOptions;

    #[test]
    fn pipeline_plans_have_no_hazards() {
        let g = heat1d_graph(32, 4, 4);
        for plan in [
            ExecPlan::naive(&g),
            ExecPlan::overlap(&g),
            ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap(),
            ExecPlan::ca(&g, 2, TransformOptions::level0()).unwrap(),
        ] {
            let diags = hazard_check(&g, &plan);
            assert!(diags.is_empty(), "{}: {diags:?}", plan.label);
        }
    }

    #[test]
    fn reordering_a_dependent_compute_is_a_raw_hazard() {
        // Take a valid naive plan and hoist the last compute phase of
        // proc 0 to the very front: its predecessors (previous level,
        // possibly received) are no longer available.
        let g = heat1d_graph(16, 3, 2);
        let plan = ExecPlan::naive(&g);
        let mut broken = plan.clone();
        let phases = &mut broken.per_proc[0].phases;
        let last_compute = phases
            .iter()
            .rposition(|ph| matches!(ph, Phase::Compute(_)))
            .expect("naive plans compute");
        let ph = phases.remove(last_compute);
        phases.insert(0, ph);
        let diags = hazard_check(&g, &broken);
        assert!(
            diags.iter().any(|d| matches!(d, Diagnostic::UseWithoutProduce { proc: 0, .. })),
            "{diags:?}"
        );
        assert!(hazard_check(&g, &plan).is_empty());
    }

    #[test]
    fn sending_an_unproduced_value_is_flagged() {
        use crate::graph::ProcId;
        let g = heat1d_graph(8, 2, 2);
        // Proc 0 ships a level-2 value it never computed.
        let top = (0..g.len() as u32)
            .find(|&t| g.level(TaskId(t)) == 2 && g.owner(TaskId(t)) == ProcId(1))
            .unwrap();
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases.push(Phase::Send { to: ProcId(1), tasks: vec![top] });
        per_proc[1].phases.push(Phase::Recv { from: ProcId(0), tasks: vec![top] });
        let plan = ExecPlan { per_proc, label: "garbage".into() };
        let diags = hazard_check(&g, &plan);
        assert_eq!(
            diags,
            vec![Diagnostic::SendWithoutProduce { proc: 0, phase: 0, task: top }]
        );
    }

    #[test]
    fn computing_twice_is_a_waw_hazard() {
        let g = heat1d_graph(8, 2, 1);
        let mut plan = ExecPlan::naive(&g);
        // Duplicate the first compute phase at the end of proc 0.
        let first = plan.per_proc[0]
            .phases
            .iter()
            .find(|ph| matches!(ph, Phase::Compute(_)))
            .cloned()
            .unwrap();
        plan.per_proc[0].phases.push(first);
        let diags = hazard_check(&g, &plan);
        assert!(
            diags.iter().all(|d| matches!(d, Diagnostic::DoubleProduce { proc: 0, .. })),
            "{diags:?}"
        );
        assert!(!diags.is_empty());
    }

    #[test]
    fn same_phase_producers_satisfy_consumers() {
        // One proc, every level in a single compute phase: the intra-
        // phase (level, id) ordering makes this legal, not a hazard.
        let g = heat1d_graph(8, 3, 1);
        let all: Vec<u32> =
            (0..g.len() as u32).filter(|&t| g.kind(TaskId(t)) == TaskKind::Compute).collect();
        let mut per_proc = vec![ProcPlan::default()];
        per_proc[0].phases.push(Phase::Compute(all));
        let plan = ExecPlan { per_proc, label: "fused".into() };
        assert!(hazard_check(&g, &plan).is_empty());
    }
}
