//! Channel safety: pair every `Send` with its `Recv` statically.
//!
//! The engine routes the k-th `Send` on a `(from, to)` channel to the
//! k-th `Recv` on it — per-channel FIFO sequence numbers, no tags.  A
//! processor executes its own phases in program order, so the k-th send
//! a channel *will* carry is fully determined by the plan text; this
//! census replays that pairing without timing and names every slot that
//! cannot line up.

use super::report::Diagnostic;
use crate::sim::{ExecPlan, Phase};
use std::collections::BTreeMap;

/// Census every channel of `plan`: unmatched receives (fatal — the
/// receiver blocks forever), orphaned sends and word-count mismatches
/// (warnings — the engine completes, but slots leak or values misroute).
///
/// Diagnostics come back ordered by channel `(from, to)`, then sequence
/// number, mismatches before unpaired slots.
pub fn channel_census(plan: &ExecPlan) -> Vec<Diagnostic> {
    // (from, to) → (send word counts, recv word counts), program order.
    let mut chans: BTreeMap<(u32, u32), (Vec<usize>, Vec<usize>)> = BTreeMap::new();
    for (p, pp) in plan.per_proc.iter().enumerate() {
        for ph in &pp.phases {
            match ph {
                Phase::Send { to, tasks } => {
                    chans.entry((p as u32, to.0)).or_default().0.push(tasks.len());
                }
                Phase::Recv { from, tasks } => {
                    chans.entry((from.0, p as u32)).or_default().1.push(tasks.len());
                }
                Phase::Compute(_) => {}
            }
        }
    }

    let mut out = Vec::new();
    for (&(from, to), (sends, recvs)) in &chans {
        for (k, (&sent, &received)) in sends.iter().zip(recvs.iter()).enumerate() {
            if sent != received {
                out.push(Diagnostic::WordMismatch { from, to, seq: k as u32, sent, received });
            }
        }
        for k in recvs.len()..sends.len() {
            out.push(Diagnostic::OrphanSend { from, to, seq: k as u32 });
        }
        for k in sends.len()..recvs.len() {
            out.push(Diagnostic::UnmatchedRecv { from, to, seq: k as u32 });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ProcId;
    use crate::sim::ProcPlan;
    use crate::transform::TransformOptions;

    fn two_proc(phases0: Vec<Phase>, phases1: Vec<Phase>) -> ExecPlan {
        let mut per_proc = vec![ProcPlan::default(); 2];
        per_proc[0].phases = phases0;
        per_proc[1].phases = phases1;
        ExecPlan { per_proc, label: "hand".into() }
    }

    #[test]
    fn balanced_channels_are_silent() {
        let g = crate::stencil::heat1d_graph(32, 4, 4);
        for plan in [
            ExecPlan::naive(&g),
            ExecPlan::overlap(&g),
            ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap(),
        ] {
            assert!(channel_census(&plan).is_empty(), "{}", plan.label);
        }
    }

    #[test]
    fn dropped_recv_orphans_the_send() {
        let plan = two_proc(vec![Phase::Send { to: ProcId(1), tasks: vec![3, 4] }], vec![]);
        let diags = channel_census(&plan);
        assert_eq!(diags, vec![Diagnostic::OrphanSend { from: 0, to: 1, seq: 0 }]);
    }

    #[test]
    fn extra_recv_is_the_half_deadlock() {
        let plan = two_proc(vec![], vec![Phase::Recv { from: ProcId(0), tasks: vec![3] }]);
        let diags = channel_census(&plan);
        assert_eq!(diags, vec![Diagnostic::UnmatchedRecv { from: 0, to: 1, seq: 0 }]);
    }

    #[test]
    fn inflated_word_count_mismatches() {
        let plan = two_proc(
            vec![Phase::Send { to: ProcId(1), tasks: vec![3, 4, 5] }],
            vec![Phase::Recv { from: ProcId(0), tasks: vec![3, 4] }],
        );
        let diags = channel_census(&plan);
        assert_eq!(
            diags,
            vec![Diagnostic::WordMismatch { from: 0, to: 1, seq: 0, sent: 3, received: 2 }]
        );
    }

    #[test]
    fn pairing_is_per_channel_fifo() {
        // Two sends 0→1 pair in program order with two recvs; a shifted
        // pairing (first recv dropped) surfaces as mismatch + orphan.
        let plan = two_proc(
            vec![
                Phase::Send { to: ProcId(1), tasks: vec![1] },
                Phase::Send { to: ProcId(1), tasks: vec![2, 3] },
            ],
            vec![Phase::Recv { from: ProcId(0), tasks: vec![2, 3] }],
        );
        let diags = channel_census(&plan);
        assert_eq!(
            diags,
            vec![
                Diagnostic::WordMismatch { from: 0, to: 1, seq: 0, sent: 1, received: 2 },
                Diagnostic::OrphanSend { from: 0, to: 1, seq: 1 },
            ]
        );
    }
}
