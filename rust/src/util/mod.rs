//! Small shared utilities: a deterministic PRNG, epoch-stamped membership
//! marks, sorted-set operations, and CSV emission.
//!
//! The vendored crate set contains neither `rand` nor `serde`, so these are
//! deliberately dependency-free.  Everything here is deterministic — the
//! whole reproduction is seeded so figures regenerate bit-identically.

/// xorshift64* PRNG — deterministic, seedable, good enough for workload
/// generation and property tests (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seed must be non-zero; zero is mapped to a fixed odd constant.
    pub fn new(seed: u64) -> Self {
        Rng(if seed == 0 { 0x9E3779B97F4A7C15 } else { seed })
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`; `n` must be positive.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine for our non-crypto uses.
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-1, 1)` — handy for synthetic field data.
    pub fn f32_pm1(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Epoch-stamped membership marks: O(1) set/test/clear-all over a fixed
/// universe, reused across many rounds without re-zeroing.
///
/// Used heavily by the transformation's per-processor closures, where the
/// same `|V|`-sized scratch is cycled through every processor.
#[derive(Debug)]
pub struct Stamp {
    marks: Vec<u32>,
    epoch: u32,
}

impl Stamp {
    pub fn new(universe: usize) -> Self {
        Stamp { marks: vec![0; universe], epoch: 1 }
    }

    /// Invalidate every mark in O(1) (amortized; re-zeroes on epoch wrap).
    pub fn clear(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.marks.iter_mut().for_each(|m| *m = 0);
            self.epoch = 1;
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        self.marks[i] = self.epoch;
    }

    #[inline]
    pub fn unset(&mut self, i: usize) {
        self.marks[i] = self.epoch.wrapping_sub(1);
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        self.marks[i] == self.epoch
    }

    pub fn len_universe(&self) -> usize {
        self.marks.len()
    }

    /// Grow the universe (new elements unmarked).
    pub fn grow(&mut self, universe: usize) {
        if universe > self.marks.len() {
            self.marks.resize(universe, 0);
        }
    }
}

/// Merge two sorted, deduplicated `u32` slices into a sorted, deduplicated
/// vector (set union).
pub fn union_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Set difference `a − b` over sorted, deduplicated slices.
pub fn difference_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len());
    let mut j = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            out.push(x);
        }
    }
    out
}

/// Set intersection over sorted, deduplicated slices.
pub fn intersect_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

/// True iff sorted slices `a` and `b` share no element.
pub fn disjoint_sorted(a: &[u32], b: &[u32]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => return false,
        }
    }
    true
}

/// True iff sorted slice `sub` ⊆ sorted slice `sup`.
pub fn subset_sorted(sub: &[u32], sup: &[u32]) -> bool {
    let mut j = 0;
    for &x in sub {
        while j < sup.len() && sup[j] < x {
            j += 1;
        }
        if j >= sup.len() || sup[j] != x {
            return false;
        }
        j += 1;
    }
    true
}

/// A tiny CSV writer: quotes nothing (callers emit plain numerics/idents),
/// used for the figure series the bench harness produces.
pub struct Csv {
    out: String,
    cols: usize,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { out: format!("{}\n", header.join(",")), cols: header.len() }
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.cols, "csv row width mismatch");
        self.out.push_str(&fields.join(","));
        self.out.push('\n');
    }

    pub fn rowf(&mut self, fields: &[f64]) {
        self.row(&fields.iter().map(|v| format!("{v}")).collect::<Vec<_>>());
    }

    pub fn finish(self) -> String {
        self.out
    }

    pub fn write_file(self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.out)
    }
}

/// Geometric mean of positive values (benchmark summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Simple monotonic wall-clock timer for the bench harness.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(std::time::Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn stamp_epochs() {
        let mut s = Stamp::new(10);
        s.set(3);
        assert!(s.contains(3));
        assert!(!s.contains(4));
        s.clear();
        assert!(!s.contains(3));
        s.set(4);
        assert!(s.contains(4));
    }

    #[test]
    fn stamp_epoch_wrap_rezeros() {
        let mut s = Stamp::new(4);
        s.epoch = u32::MAX; // force wrap on next clear
        s.set(1);
        s.clear();
        assert!(!s.contains(1));
        s.set(2);
        assert!(s.contains(2));
    }

    #[test]
    fn set_ops() {
        let a = vec![1, 3, 5, 7];
        let b = vec![3, 4, 7, 9];
        assert_eq!(union_sorted(&a, &b), vec![1, 3, 4, 5, 7, 9]);
        assert_eq!(difference_sorted(&a, &b), vec![1, 5]);
        assert_eq!(intersect_sorted(&a, &b), vec![3, 7]);
        assert!(!disjoint_sorted(&a, &b));
        assert!(disjoint_sorted(&[1, 2], &[3, 4]));
        assert!(subset_sorted(&[3, 7], &a));
        assert!(!subset_sorted(&[3, 8], &a));
        assert!(subset_sorted(&[], &a));
    }

    #[test]
    fn set_ops_empty() {
        assert_eq!(union_sorted(&[], &[1]), vec![1]);
        assert_eq!(difference_sorted(&[], &[1]), Vec::<u32>::new());
        assert_eq!(intersect_sorted(&[1], &[]), Vec::<u32>::new());
    }

    #[test]
    fn csv_roundtrip() {
        let mut c = Csv::new(&["a", "b"]);
        c.rowf(&[1.0, 2.5]);
        assert_eq!(c.finish(), "a,b\n1,2.5\n");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
