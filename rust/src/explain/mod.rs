//! Causal profiling: *why* is this plan this slow?
//!
//! The engine can say *that* a plan is faster ([`crate::sim`]) and the
//! analyzer can bound how fast it could ever be
//! ([`crate::analysis::critical_path`]); this subsystem explains the
//! gap.  Data flows through three stages:
//!
//! * [`provenance`] — run the compiled engine with observation on
//!   ([`crate::sim::simulate_observed`]) and type the recorded phase
//!   windows: [`Observation`] ties the [`crate::sim::CompiledPlan`] to
//!   when every lowered phase actually ran and which message each
//!   receive waited on.  Observed results are **bit-identical** to
//!   unobserved runs; with no buffer attached the hot loop pays one
//!   branch per phase.
//! * [`blame`] — walk back from the makespan-defining finish to extract
//!   the *observed critical path*, and decompose the makespan into
//!   compute / exposed latency / bandwidth / idle-imbalance terms that
//!   sum **bit-exactly** ([`fsum`] over [`two_diff`] pairs), per plan
//!   (along the path) and per proc; cross-check against the analytic
//!   bound ([`CrossCheck`]: observed ≥ bound always, bit-equal on exact
//!   wires).
//! * [`diff`] — compare two plans of the same workload ([`PlanDiff`]):
//!   which α terms the overlap/CA transforms moved off the critical
//!   path — the paper's §3 claim as a machine-checkable artifact.
//!
//! [`report`] renders one explanation in the repo's hand-rolled JSON
//! style for `BENCH_explain.json`, the `explain` CLI subcommand, and
//! the serve daemon's `explain` op.

#![deny(missing_docs)]

pub mod blame;
pub mod diff;
pub mod provenance;
pub mod report;

pub use blame::{fsum, two_diff, two_sum, Blame, BlameTerms, CrossCheck, PathSegment, SegmentKind};
pub use diff::{BlameSummary, PlanDiff};
pub use provenance::{Observation, PhaseWindow, WindowKind};
pub use report::ExplainCell;

use crate::analysis::critical_path;
use crate::sim::sweep::SweepInput;
use crate::sim::{EngineScratch, Machine, NetworkKind};
use std::sync::Arc;

/// One fully explained sweep cell: the observation, its blame
/// decomposition, and the analytic cross-check.
#[derive(Debug)]
pub struct Explanation {
    /// Workload tag of the input.
    pub workload: String,
    /// Strategy label of the input.
    pub strategy: String,
    /// Wire model label.
    pub network: &'static str,
    /// Processor count of the plan.
    pub procs: u32,
    /// The observed run.
    pub obs: Observation,
    /// Its blame decomposition.
    pub blame: Blame,
    /// Observed vs analytic critical path.
    pub cross: CrossCheck,
}

/// Observe, blame, and cross-check one sweep input on the *effective*
/// machine its sweep cell would use — the base machine's β scaled by the
/// input's words-per-value, the wire built layout-aware — exactly
/// mirroring the sweep's own cell evaluation (and
/// [`crate::analysis::input_lower_bound`]'s bound construction).
pub fn explain_input(
    input: &SweepInput,
    base: &Machine,
    kind: NetworkKind,
    scratch: &mut EngineScratch,
) -> Result<Explanation, String> {
    let procs = input.plan.per_proc.len() as u32;
    let mach = Machine::new(
        procs,
        base.threads,
        base.alpha,
        base.beta * input.words_per_value as f64,
        base.gamma,
    );
    let mut net = kind.build_for(&mach, input.layout.as_ref());
    let obs = Observation::observe(Arc::clone(&input.compiled), &mach, net.as_mut(), scratch)
        .map_err(|e| format!("{}/{}: {e:?}", input.workload, input.strategy))?;
    let blame = Blame::explain(&obs, net.as_ref());
    let analytic =
        critical_path(&input.graph, &input.plan, &mach, net.as_ref(), input.cost.as_ref())
            .map_err(|e| format!("{}/{}: {e}", input.workload, input.strategy))?;
    let cross = CrossCheck::check(&obs, &analytic);
    Ok(Explanation {
        workload: input.workload.to_string(),
        strategy: input.strategy.to_string(),
        network: kind.label(),
        procs,
        obs,
        blame,
        cross,
    })
}
