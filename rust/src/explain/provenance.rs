//! Observed runs: one compiled simulation plus the engine's own record
//! of when every lowered phase executed.
//!
//! [`Observation::observe`] runs [`crate::sim::simulate_observed`] — the
//! PR-5 compiled engine with the provenance gate *on* — and packages the
//! bit-identical [`SimResult`] together with the recorded
//! [`ProvenanceBuffer`] and the message-resolution maps (slot → sending
//! phase / channel / word count) the blame walk in
//! [`super::blame`] jumps through.  This is the *only* module that
//! interprets raw provenance indices; everything downstream sees typed
//! [`PhaseWindow`]s.

use crate::sim::{
    simulate_compiled, simulate_observed, CPhase, CompiledPlan, EngineScratch, Machine,
    NetworkModel, ProvenanceBuffer, SimError, SimResult,
};
use std::sync::Arc;

/// The observed role of one lowered phase, with everything the blame
/// walk needs resolved (channel endpoints, word counts, arrivals).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WindowKind {
    /// A compute phase of `tasks` list-scheduled tasks.
    Compute {
        /// Number of tasks in the phase.
        tasks: u32,
    },
    /// A send posting message slot `msg` of `words` words from proc
    /// `from` to proc `to` (zero-width: posting costs the sender
    /// nothing).
    Send {
        /// Message slot posted.
        msg: u32,
        /// Words on the wire (`0` = pure synchronization).
        words: u32,
        /// Sending processor.
        from: u32,
        /// Receiving processor.
        to: u32,
    },
    /// A receive of message slot `msg`; `arrival` is when the wire
    /// delivered it (the window's `end` is `max(start, arrival)`).
    Recv {
        /// Message slot received.
        msg: u32,
        /// Wire delivery time of that slot.
        arrival: f64,
    },
}

/// One lowered phase's observed execution window: `[start, end]` on
/// processor `proc`'s clock, with the phase's role resolved.  Windows of
/// one processor tile `[0, finish[proc]]` contiguously — the invariant
/// the blame walk's exact arithmetic rests on (pinned by the engine's
/// own provenance test and re-checked per proc by
/// [`super::blame::Blame::verify`]).
#[derive(Debug, Clone, Copy)]
pub struct PhaseWindow {
    /// Global phase index in the compiled stream.
    pub index: usize,
    /// Processor that executed the phase.
    pub proc: u32,
    /// Clock when the phase began (compute start / send post / the
    /// clock a receive found, i.e. when any exposed wait began).
    pub start: f64,
    /// Clock when the phase was satisfied.
    pub end: f64,
    /// What the phase was.
    pub kind: WindowKind,
}

/// One observed run: the compiled plan it replayed, the engine result
/// (bit-identical to an unobserved run), and the provenance recorded
/// along the way.
///
/// Unlike [`EngineScratch`], an `Observation` owns its
/// [`ProvenanceBuffer`]: explanation is an offline, per-plan activity,
/// not the sweep hot path, so the buffer is not recycled across plans.
#[derive(Debug)]
pub struct Observation {
    cp: Arc<CompiledPlan>,
    /// The engine result of the observed run.
    pub result: SimResult,
    prov: ProvenanceBuffer,
    /// Per message slot: global phase index of its `Send` (`u32::MAX` =
    /// the slot was never posted — only possible in malformed plans).
    msg_send: Vec<u32>,
    /// Per message slot: word count of its `Send`.
    msg_words: Vec<u32>,
    /// Per message slot: `(from, to)` endpoints of its channel.
    msg_ends: Vec<(u32, u32)>,
    /// Per global phase: the processor that owns it.
    phase_proc: Vec<u32>,
}

impl Observation {
    /// Run `cp` on `m` under `network` with provenance recording on and
    /// package the result.  The returned [`SimResult`] is bit-identical
    /// to what [`simulate_compiled`] produces for the same cell.
    pub fn observe(
        cp: Arc<CompiledPlan>,
        m: &Machine,
        network: &mut dyn NetworkModel,
        scratch: &mut EngineScratch,
    ) -> Result<Observation, SimError> {
        let mut prov = ProvenanceBuffer::new();
        let result = simulate_observed(&cp, m, network, scratch, false, &mut prov)?;
        let mut msg_send = vec![u32::MAX; cp.num_messages()];
        let mut msg_words = vec![0u32; cp.num_messages()];
        let mut msg_ends = vec![(0u32, 0u32); cp.num_messages()];
        let mut phase_proc = vec![0u32; cp.num_phases()];
        for p in 0..cp.num_procs() as usize {
            for k in cp.proc_phase_range(p) {
                phase_proc[k] = p as u32;
                if let CPhase::Send { msg, chan, words } = cp.phase(k) {
                    msg_send[msg as usize] = k as u32;
                    msg_words[msg as usize] = words;
                    msg_ends[msg as usize] = cp.channel(chan as usize);
                }
            }
        }
        Ok(Observation { cp, result, prov, msg_send, msg_words, msg_ends, phase_proc })
    }

    /// The compiled plan this observation replayed.
    pub fn compiled(&self) -> &CompiledPlan {
        &self.cp
    }

    /// The observed makespan (bit-equal to `result.total_time`).
    pub fn makespan(&self) -> f64 {
        self.result.total_time
    }

    /// The processor whose finish *is* the makespan (first such proc on
    /// bit-equal ties — the same `fold(0.0, f64::max)` the engine uses).
    pub fn critical_proc(&self) -> usize {
        let mut best = 0usize;
        for (p, &f) in self.result.proc_finish.iter().enumerate() {
            if f > self.result.proc_finish[best] {
                best = p;
            }
        }
        best
    }

    /// The typed window of global phase `k`.
    pub fn window(&self, k: usize) -> PhaseWindow {
        let kind = match self.cp.phase(k) {
            CPhase::Compute { len, .. } => WindowKind::Compute { tasks: len },
            CPhase::Send { msg, chan, words } => {
                let (from, to) = self.cp.channel(chan as usize);
                WindowKind::Send { msg, words, from, to }
            }
            CPhase::Recv { msg } => {
                WindowKind::Recv { msg, arrival: self.prov.msg_arrival(msg as usize) }
            }
        };
        PhaseWindow {
            index: k,
            proc: self.phase_proc[k],
            start: self.prov.phase_start(k),
            end: self.prov.phase_end(k),
            kind,
        }
    }

    /// Processor `p`'s windows in execution order; they tile
    /// `[0, finish[p]]` contiguously.
    pub fn windows(&self, p: usize) -> impl Iterator<Item = PhaseWindow> + '_ {
        self.cp.proc_phase_range(p).map(move |k| self.window(k))
    }

    /// Global phase index of the `Send` that posts message slot `msg`
    /// (`None` for a slot no send names — malformed plans only).
    pub fn send_phase(&self, msg: usize) -> Option<usize> {
        let k = self.msg_send[msg];
        (k != u32::MAX).then_some(k as usize)
    }

    /// Word count of message slot `msg`.
    pub fn msg_words(&self, msg: usize) -> u32 {
        self.msg_words[msg]
    }

    /// `(from, to)` processor endpoints of message slot `msg`.
    pub fn msg_endpoints(&self, msg: usize) -> (u32, u32) {
        self.msg_ends[msg]
    }

    /// Wire delivery time of message slot `msg` (`-1.0` = never posted).
    pub fn msg_arrival(&self, msg: usize) -> f64 {
        self.prov.msg_arrival(msg)
    }
}

/// Run the same cell unobserved and check the observed result is
/// bit-identical — the "observation is pure" invariant, callable from
/// smokes and tests without reaching into engine internals.  Returns the
/// unobserved result.
pub fn unobserved_twin(
    obs: &Observation,
    m: &Machine,
    network: &mut dyn NetworkModel,
    scratch: &mut EngineScratch,
) -> Result<SimResult, SimError> {
    let plain = simulate_compiled(obs.compiled(), m, network, scratch, false)?;
    debug_assert_eq!(plain.total_time.to_bits(), obs.result.total_time.to_bits());
    Ok(plain)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{AlphaBeta, ExecPlan, UniformCost};
    use crate::stencil::heat1d_graph;

    fn observe_heat1d() -> (Observation, Machine) {
        let g = heat1d_graph(48, 5, 4);
        let plan = ExecPlan::overlap(&g);
        let cp = Arc::new(CompiledPlan::compile(&g, &plan, &UniformCost));
        let mach = Machine::new(4, 2, 40.0, 0.5, 1.0);
        let mut net = AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let obs = Observation::observe(cp, &mach, &mut net, &mut scratch).unwrap();
        (obs, mach)
    }

    #[test]
    fn windows_tile_and_sends_resolve() {
        let (obs, _) = observe_heat1d();
        let cp = obs.compiled();
        for p in 0..cp.num_procs() as usize {
            let mut clock = 0.0f64;
            for w in obs.windows(p) {
                assert_eq!(w.proc, p as u32);
                assert_eq!(w.start.to_bits(), clock.to_bits(), "phase {} tiles", w.index);
                assert!(w.end >= w.start);
                clock = w.end;
                if let WindowKind::Recv { msg, arrival } = w.kind {
                    // Every received slot was posted by a known send on
                    // the channel's `from` proc, before it arrived.
                    let sp = obs.send_phase(msg as usize).expect("posted");
                    let sw = obs.window(sp);
                    assert_eq!(sw.proc, obs.msg_endpoints(msg as usize).0);
                    assert!(sw.start <= arrival);
                    assert_eq!(obs.msg_arrival(msg as usize).to_bits(), arrival.to_bits());
                }
            }
            assert_eq!(clock.to_bits(), obs.result.proc_finish[p].to_bits());
        }
    }

    #[test]
    fn critical_proc_matches_makespan() {
        let (obs, mach) = observe_heat1d();
        assert_eq!(
            obs.result.proc_finish[obs.critical_proc()].to_bits(),
            obs.makespan().to_bits()
        );
        // And the observed run is bit-identical to the unobserved twin.
        let mut net = AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let twin = unobserved_twin(&obs, &mach, &mut net, &mut scratch).unwrap();
        assert_eq!(twin.total_time.to_bits(), obs.makespan().to_bits());
        assert_eq!(twin.proc_finish, obs.result.proc_finish);
    }
}
