//! Differential explanations: how blame moved between two plans of the
//! same workload.
//!
//! The paper's whole §3 story is that the overlap and CA transforms
//! move α terms *off the critical path*; [`PlanDiff`] states that as a
//! machine-checkable artifact — the exposed-latency delta between a
//! baseline plan (typically naive) and a candidate (overlap, CA, or a
//! tuner winner), term by term.  The explain smoke gates on
//! `latency_moved_off_path() > 0` for CA vs naive in the high-α regime,
//! and [`crate::tune::TuneReport`] winners carry the one-line
//! [`PlanDiff::summary`] against their naive baseline.

use super::blame::Blame;

/// The scalar blame profile of one plan — [`Blame`] flattened to the
/// per-category totals a diff compares.
#[derive(Debug, Clone)]
pub struct BlameSummary {
    /// Strategy label ("naive", "overlap", "ca(b=4)").
    pub strategy: String,
    /// Observed makespan.
    pub makespan: f64,
    /// On-path compute.
    pub compute: f64,
    /// On-path exposed latency (the α terms).
    pub latency: f64,
    /// On-path exposed bandwidth (the β·words terms).
    pub bandwidth: f64,
    /// On-path queueing / idle.
    pub idle: f64,
    /// Messages whose flights are on the observed critical path.
    pub path_messages: usize,
}

impl BlameSummary {
    /// Flatten `blame`'s plan-level terms under a strategy label.
    pub fn from_blame(strategy: impl Into<String>, blame: &Blame) -> BlameSummary {
        BlameSummary {
            strategy: strategy.into(),
            makespan: blame.makespan,
            compute: blame.plan.compute(),
            latency: blame.plan.exposed_latency(),
            bandwidth: blame.plan.bandwidth(),
            idle: blame.plan.idle(),
            path_messages: blame.path_messages.len(),
        }
    }
}

/// A differential explanation of two plans of the same workload on the
/// same machine and wire.
#[derive(Debug, Clone)]
pub struct PlanDiff {
    /// The reference plan (typically naive).
    pub baseline: BlameSummary,
    /// The plan being explained against it.
    pub candidate: BlameSummary,
}

impl PlanDiff {
    /// Pair a baseline with a candidate profile.
    pub fn between(baseline: BlameSummary, candidate: BlameSummary) -> PlanDiff {
        PlanDiff { baseline, candidate }
    }

    /// Exposed latency the candidate removed from the critical path
    /// (positive = the candidate waits on fewer α terms — the paper's
    /// latency-hiding claim, quantified).
    pub fn latency_moved_off_path(&self) -> f64 {
        self.baseline.latency - self.candidate.latency
    }

    /// Critical-path messages the candidate removed.
    pub fn messages_moved_off_path(&self) -> isize {
        self.baseline.path_messages as isize - self.candidate.path_messages as isize
    }

    /// Makespan ratio baseline / candidate (> 1 = candidate faster).
    pub fn speedup(&self) -> f64 {
        if self.candidate.makespan > 0.0 {
            self.baseline.makespan / self.candidate.makespan
        } else {
            1.0
        }
    }

    /// One human-readable line, e.g. for a tune-report attachment:
    /// `"ca(b=4) vs naive: 1.83x; exposed latency 4200 -> 600 (-3600);
    /// path messages 84 -> 12"`.
    pub fn summary(&self) -> String {
        format!(
            "{} vs {}: {:.2}x; exposed latency {:.4} -> {:.4} ({:+.4}); path messages {} -> {}",
            self.candidate.strategy,
            self.baseline.strategy,
            self.speedup(),
            self.baseline.latency,
            self.candidate.latency,
            -self.latency_moved_off_path(),
            self.baseline.path_messages,
            self.candidate.path_messages,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(strategy: &str, makespan: f64, latency: f64, msgs: usize) -> BlameSummary {
        BlameSummary {
            strategy: strategy.into(),
            makespan,
            compute: makespan - latency,
            latency,
            bandwidth: 0.0,
            idle: 0.0,
            path_messages: msgs,
        }
    }

    #[test]
    fn diff_directions() {
        let d = PlanDiff::between(s("naive", 100.0, 40.0, 8), s("ca(b=4)", 70.0, 10.0, 2));
        assert_eq!(d.latency_moved_off_path(), 30.0);
        assert_eq!(d.messages_moved_off_path(), 6);
        assert!((d.speedup() - 100.0 / 70.0).abs() < 1e-12);
        let line = d.summary();
        assert!(line.contains("ca(b=4) vs naive"), "{line}");
        assert!(line.contains("path messages 8 -> 2"), "{line}");
    }
}
