//! Makespan blame attribution: where did every unit of time go?
//!
//! The decomposition must **sum bit-exactly** to the engine makespan —
//! a blame report that loses ulps cannot gate CI, because term drift
//! and rounding noise become indistinguishable.  Two tools make that
//! possible:
//!
//! * every attributed quantity is the width of an interval between
//!   *representable* cut points on the engine's own clock, kept as a
//!   Knuth [`two_diff`] pair whose real-valued sum is the width
//!   **exactly**; category boundaries (where does bandwidth end and
//!   latency begin inside one flight?) are rounded cut points, so
//!   rounding only ever moves an ulp *between* categories, never in or
//!   out of the total;
//! * the intervals tile the explained span by construction (processor
//!   windows tile `[0, finish]`, the observed critical path tiles
//!   `[0, makespan]`), so the exact real total telescopes to a
//!   *representable* number — and [`fsum`]'s correctly-rounded
//!   summation therefore returns it bit-for-bit.
//!
//! Two decompositions are produced from one [`Observation`]:
//!
//! * **plan-level** ([`Blame::plan`]): walk the *observed critical
//!   path* backward from the makespan-defining finish — compute windows
//!   on the critical proc, jumping through each binding message's
//!   flight (`[post, arrival]`, split into bandwidth / latency /
//!   queueing by [`NetworkModel::message_cost_split`]) to the sender's
//!   timeline.  Everything on the path is *exposed* by definition: this
//!   is the chain that determines the makespan.
//! * **per-proc** ([`Blame::per_proc`]): each processor's own windows —
//!   compute, the waited-on part of each receive (split the same way,
//!   anchored at the arrival), idle for late senders and queueing, plus
//!   the imbalance tail `makespan − finish[p]` — so every processor's
//!   terms also sum exactly to the makespan.

use super::provenance::{Observation, WindowKind};
use crate::analysis::CritPath;
use crate::sim::NetworkModel;

/// Knuth two-sum: `(s, e)` with `s = fl(a + b)` and `a + b = s + e`
/// exactly in real arithmetic, for any two finite doubles.
#[inline]
pub fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let bv = s - a;
    let av = s - bv;
    (s, (a - av) + (b - bv))
}

/// Exact difference: `(d, e)` with `d = fl(a − b)` and `a − b = d + e`
/// exactly in real arithmetic.
#[inline]
pub fn two_diff(a: f64, b: f64) -> (f64, f64) {
    two_sum(a, -b)
}

/// Correctly-rounded sum of `xs` — the `math.fsum` algorithm: a
/// Shewchuk non-overlapping partial expansion grown per input, summed
/// largest-down with the round-half correction.  The result is the
/// double nearest the exact real-valued sum regardless of ordering or
/// intermediate cancellation; in particular, when the exact sum is
/// representable (every total this module checks is), it is returned
/// **bit-for-bit**.
#[allow(clippy::needless_range_loop)] // the expansion is mutated in place
pub fn fsum<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let mut partials: Vec<f64> = Vec::new();
    for x in xs {
        let mut x = x;
        let mut i = 0usize;
        for j in 0..partials.len() {
            let mut y = partials[j];
            if x.abs() < y.abs() {
                std::mem::swap(&mut x, &mut y);
            }
            let hi = x + y;
            let lo = y - (hi - x);
            if lo != 0.0 {
                partials[i] = lo;
                i += 1;
            }
            x = hi;
        }
        partials.truncate(i);
        partials.push(x);
    }
    let Some(mut i) = partials.len().checked_sub(1) else {
        return 0.0;
    };
    let mut hi = partials[i];
    let mut lo = 0.0;
    while i > 0 {
        let x = hi;
        i -= 1;
        let y = partials[i];
        hi = x + y;
        lo = y - (hi - x);
        if lo != 0.0 {
            break;
        }
    }
    // Half-ulp boundary: if the discarded tail agrees in sign with the
    // next partial, the true sum is past the boundary — round once more.
    if i > 0 && ((lo < 0.0 && partials[i - 1] < 0.0) || (lo > 0.0 && partials[i - 1] > 0.0)) {
        let y = lo * 2.0;
        let x = hi + y;
        if (x - hi) == y {
            hi = x;
        }
    }
    hi
}

/// One span's blame, by category.  Components are kept as the raw
/// [`two_diff`] pairs so totals stay exact; the scalar accessors are
/// correctly-rounded [`fsum`]s over each category.
#[derive(Debug, Clone, Default)]
pub struct BlameTerms {
    compute: Vec<f64>,
    latency: Vec<f64>,
    bandwidth: Vec<f64>,
    idle: Vec<f64>,
}

impl BlameTerms {
    #[inline]
    fn push(v: &mut Vec<f64>, pair: (f64, f64)) {
        v.push(pair.0);
        if pair.1 != 0.0 {
            v.push(pair.1);
        }
    }

    /// Time spent computing (γ·cost of on-path / on-proc tasks).
    pub fn compute(&self) -> f64 {
        fsum(self.compute.iter().copied())
    }

    /// Exposed wire latency: the per-message fixed cost (α, LogGP
    /// `2o + L`) actually paid on the path / actually waited on.
    pub fn exposed_latency(&self) -> f64 {
        fsum(self.latency.iter().copied())
    }

    /// Exposed wire bandwidth: the β·words streaming term on the path /
    /// in the wait.
    pub fn bandwidth(&self) -> f64 {
        fsum(self.bandwidth.iter().copied())
    }

    /// Idle / imbalance: stateful-wire queueing (the part of a flight
    /// above its state-free cost), waits on senders that had not posted
    /// yet, and — per proc — the `makespan − finish` tail.
    pub fn idle(&self) -> f64 {
        fsum(self.idle.iter().copied())
    }

    /// The correctly-rounded total of **all** components: bit-equal to
    /// the span being explained (the makespan), because the components'
    /// exact real sum telescopes to it.
    pub fn total(&self) -> f64 {
        fsum(
            self.compute
                .iter()
                .chain(&self.latency)
                .chain(&self.bandwidth)
                .chain(&self.idle)
                .copied(),
        )
    }
}

/// The role of one observed-critical-path segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// On-proc compute.
    Compute,
    /// The β·words streaming tail of message `msg`'s flight.
    Bandwidth {
        /// Message slot on the wire.
        msg: u32,
    },
    /// The per-message fixed cost (α / `2o + L`) of message `msg`.
    Latency {
        /// Message slot on the wire.
        msg: u32,
    },
    /// Flight time above message `msg`'s state-free cost: stateful-wire
    /// queueing (LogGP injection gaps, NIC occupancy).
    Idle {
        /// Message slot on the wire.
        msg: u32,
    },
}

/// One segment of the observed critical path.  Segments are
/// time-ordered and tile `[0, makespan]` bit-contiguously.
#[derive(Debug, Clone, Copy)]
pub struct PathSegment {
    /// The processor whose timeline the segment lies on (for flight
    /// segments, the *receiving* processor — where the time manifests).
    pub proc: u32,
    /// Segment start on the global clock.
    pub start: f64,
    /// Segment end on the global clock.
    pub end: f64,
    /// What the time was spent on.
    pub kind: SegmentKind,
}

/// A message whose flight is on the observed critical path — the flow
/// arrows a trace renderer should draw.
#[derive(Debug, Clone, Copy)]
pub struct PathMessage {
    /// Message slot.
    pub msg: u32,
    /// Sending processor.
    pub from: u32,
    /// Receiving processor.
    pub to: u32,
    /// Post time on the sender.
    pub post: f64,
    /// Delivery time at the receiver.
    pub arrival: f64,
}

/// The full blame decomposition of one observed run.
#[derive(Debug, Clone)]
pub struct Blame {
    /// The makespan being explained (bit-equal to the engine's).
    pub makespan: f64,
    /// Plan-level terms along the observed critical path.
    pub plan: BlameTerms,
    /// Per-processor terms; each (with its imbalance tail) also sums to
    /// the makespan.
    pub per_proc: Vec<BlameTerms>,
    /// The observed critical path, time-ordered, tiling `[0, makespan]`.
    pub path: Vec<PathSegment>,
    /// The messages whose flights are on the path.
    pub path_messages: Vec<PathMessage>,
}

/// Split the interval `[lo, hi]` backward into (bandwidth, latency,
/// idle) sub-intervals via representable cut points, pushing the exact
/// widths into `terms` and any non-empty segments onto `path` (in
/// backward time order) when a path is being built.
#[allow(clippy::too_many_arguments)]
fn split_wait(
    terms: &mut BlameTerms,
    path: Option<&mut Vec<PathSegment>>,
    proc: u32,
    msg: u32,
    lo: f64,
    hi: f64,
    lat: f64,
    bw: f64,
) {
    let c1 = (hi - bw).clamp(lo, hi);
    let c2 = (c1 - lat).clamp(lo, c1);
    BlameTerms::push(&mut terms.bandwidth, two_diff(hi, c1));
    BlameTerms::push(&mut terms.latency, two_diff(c1, c2));
    BlameTerms::push(&mut terms.idle, two_diff(c2, lo));
    if let Some(path) = path {
        for (kind, s, e) in [
            (SegmentKind::Bandwidth { msg }, c1, hi),
            (SegmentKind::Latency { msg }, c2, c1),
            (SegmentKind::Idle { msg }, lo, c2),
        ] {
            if e > s {
                path.push(PathSegment { proc, start: s, end: e, kind });
            }
        }
    }
}

impl Blame {
    /// Decompose `obs` under the wire prices of `network` (the same
    /// model — or an identically parameterized one — the observed run
    /// used; only the stateless [`NetworkModel::message_cost_split`] is
    /// consulted).
    pub fn explain(obs: &Observation, network: &dyn NetworkModel) -> Blame {
        let makespan = obs.makespan();
        let cp = obs.compiled();
        let nprocs = cp.num_procs() as usize;

        // Per-proc view: every processor's own windows plus its
        // imbalance tail.
        let mut per_proc = Vec::with_capacity(nprocs);
        for p in 0..nprocs {
            let mut t = BlameTerms::default();
            for w in obs.windows(p) {
                match w.kind {
                    WindowKind::Compute { .. } => {
                        BlameTerms::push(&mut t.compute, two_diff(w.end, w.start));
                    }
                    WindowKind::Send { .. } => {}
                    WindowKind::Recv { msg, arrival } => {
                        if arrival > w.start {
                            let (from, _) = obs.msg_endpoints(msg as usize);
                            let words = obs.msg_words(msg as usize) as usize;
                            let (lat, bw) = network.message_cost_split(from, p as u32, words);
                            split_wait(&mut t, None, p as u32, msg, w.start, arrival, lat, bw);
                        }
                    }
                }
            }
            BlameTerms::push(&mut t.idle, two_diff(makespan, obs.result.proc_finish[p]));
            per_proc.push(t);
        }

        // Plan-level view: the observed critical path, walked backward
        // from the makespan-defining finish, jumping through binding
        // flights to their senders.
        let mut plan = BlameTerms::default();
        let mut path: Vec<PathSegment> = Vec::new();
        let mut path_messages: Vec<PathMessage> = Vec::new();
        if makespan > 0.0 {
            let mut p = obs.critical_proc();
            let mut k = cp.proc_phase_range(p).end;
            // Each step consumes a window or jumps through a flight, so
            // the walk is bounded; the guard makes that a hard invariant.
            let mut guard = cp.num_phases() + cp.num_messages() + 2;
            while k > cp.proc_phase_range(p).start && guard > 0 {
                guard -= 1;
                k -= 1;
                let w = obs.window(k);
                match w.kind {
                    WindowKind::Compute { .. } => {
                        BlameTerms::push(&mut plan.compute, two_diff(w.end, w.start));
                        if w.end > w.start {
                            path.push(PathSegment {
                                proc: p as u32,
                                start: w.start,
                                end: w.end,
                                kind: SegmentKind::Compute,
                            });
                        }
                    }
                    WindowKind::Send { .. } => {}
                    WindowKind::Recv { msg, arrival } => {
                        if arrival > w.start {
                            // Binding: the chain runs through this
                            // flight to the sender's timeline at post.
                            let sp = obs
                                .send_phase(msg as usize)
                                .expect("a delivered message has a send phase");
                            let post = obs.window(sp).start;
                            let (from, to) = obs.msg_endpoints(msg as usize);
                            let words = obs.msg_words(msg as usize) as usize;
                            let (lat, bw) = network.message_cost_split(from, to, words);
                            split_wait(
                                &mut plan,
                                Some(&mut path),
                                to,
                                msg,
                                post,
                                arrival,
                                lat,
                                bw,
                            );
                            path_messages.push(PathMessage { msg, from, to, post, arrival });
                            p = from as usize;
                            k = sp;
                        }
                    }
                }
            }
            debug_assert!(guard > 0, "critical-path walk did not terminate");
            path.reverse();
            path_messages.reverse();
        }

        Blame { makespan, plan, per_proc, path, path_messages }
    }

    /// Check every exactness invariant: the plan terms and each proc's
    /// terms total bit-equal to the makespan, and the path tiles
    /// `[0, makespan]` bit-contiguously.  `Err` carries the first
    /// violated invariant — this is what the explain smoke gates on.
    pub fn verify(&self) -> Result<(), String> {
        let t = self.plan.total();
        if t.to_bits() != self.makespan.to_bits() {
            return Err(format!("plan blame total {t} != makespan {}", self.makespan));
        }
        for (p, terms) in self.per_proc.iter().enumerate() {
            let t = terms.total();
            if t.to_bits() != self.makespan.to_bits() {
                return Err(format!("proc {p} blame total {t} != makespan {}", self.makespan));
            }
        }
        let mut clock = 0.0f64;
        for (i, seg) in self.path.iter().enumerate() {
            if seg.start.to_bits() != clock.to_bits() {
                return Err(format!("path segment {i} starts at {} != {clock}", seg.start));
            }
            if seg.end < seg.start {
                return Err(format!("path segment {i} runs backward"));
            }
            clock = seg.end;
        }
        if !self.path.is_empty() && clock.to_bits() != self.makespan.to_bits() {
            return Err(format!("path ends at {clock} != makespan {}", self.makespan));
        }
        Ok(())
    }
}

/// The observed-vs-analytic cross-check: the engine's observed makespan
/// can never undercut [`crate::analysis::critical_path`]'s lower bound,
/// and on exact wires (α-β, hierarchical) the two are bit-equal.
#[derive(Debug, Clone, Copy)]
pub struct CrossCheck {
    /// The engine's observed makespan.
    pub observed: f64,
    /// The analytic critical-path lower bound.
    pub bound: f64,
    /// Whether the wire's per-channel costs resolved exactly.
    pub exact_wire: bool,
}

impl CrossCheck {
    /// Compare an observation against the analytic critical path of the
    /// same `(graph, plan, machine, wire)` cell.
    pub fn check(obs: &Observation, analytic: &CritPath) -> CrossCheck {
        CrossCheck {
            observed: obs.makespan(),
            bound: analytic.makespan,
            exact_wire: analytic.exact_wire,
        }
    }

    /// Soundness: `observed ≥ bound`, and bit-equality on exact wires.
    pub fn ok(&self) -> bool {
        self.observed >= self.bound
            && (!self.exact_wire || self.observed.to_bits() == self.bound.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_sum_is_error_free() {
        for (a, b) in [(1.0, 1e-30), (1e16, 1.0), (0.1, 0.2), (-3.5, 3.5e-17)] {
            let (s, e) = two_sum(a, b);
            assert_eq!(s, a + b);
            // The error term recovers what naive addition lost:
            // reconstruct in higher precision via string-free checks.
            let (s2, e2) = two_sum(s, e);
            assert_eq!(s2, s);
            assert_eq!(e2, 0.0);
        }
    }

    #[test]
    fn fsum_is_correctly_rounded() {
        assert_eq!(fsum([1e100, 1.0, -1e100]), 1.0);
        assert_eq!(fsum([1e16, 1.0, -1e16, 1.0]), 2.0);
        assert_eq!(fsum(vec![0.1f64; 10]), 1.0);
        assert_eq!(fsum([]), 0.0);
        // A telescoping chain of two_diff pairs distills to the exact
        // total no matter how ragged the cut points are.
        let cuts = [0.0, 0.1, 0.30000000001, 1.7e-3 + 0.5, 40.0 / 7.0, 1234.5678];
        let mut parts = Vec::new();
        for w in cuts.windows(2) {
            let (d, e) = two_diff(w[1], w[0]);
            parts.push(d);
            parts.push(e);
        }
        assert_eq!(fsum(parts.iter().copied()), cuts[cuts.len() - 1]);
    }
}
