//! Rendering explanations in the repo's hand-rolled JSON style.
//!
//! One [`ExplainCell`] is the flat record of one explained sweep cell —
//! what `BENCH_explain.json`, the `explain` CLI table, and the serve
//! daemon's `explain` op all serialize.  Floats are formatted with
//! Rust's shortest round-trip `Display`, so bit-exact values survive
//! the JSON round trip.

use super::blame::SegmentKind;
use super::{Blame, CrossCheck, Explanation};
use crate::sim::BusySpan;
use crate::trace::MessageFlow;

/// The flat, serializable record of one explained cell.
#[derive(Debug, Clone)]
pub struct ExplainCell {
    /// Workload tag.
    pub workload: String,
    /// Strategy label.
    pub strategy: String,
    /// Wire model label.
    pub network: &'static str,
    /// Processor count.
    pub procs: u32,
    /// Observed makespan.
    pub makespan: f64,
    /// On-path compute total.
    pub compute: f64,
    /// On-path exposed latency total.
    pub latency: f64,
    /// On-path exposed bandwidth total.
    pub bandwidth: f64,
    /// On-path queueing / idle total.
    pub idle: f64,
    /// Every exactness invariant held ([`Blame::verify`]).
    pub exact: bool,
    /// Analytic critical-path lower bound of the same cell.
    pub bound: f64,
    /// The wire's costs resolved exactly (bound must be bit-equal).
    pub exact_wire: bool,
    /// Observed ≥ bound (bit-equal on exact wires).
    pub bound_ok: bool,
    /// Segments on the observed critical path.
    pub path_segments: usize,
    /// Messages whose flights are on the path.
    pub path_messages: usize,
}

impl ExplainCell {
    /// Flatten one [`Explanation`].
    pub fn from_explanation(e: &Explanation) -> ExplainCell {
        ExplainCell {
            workload: e.workload.clone(),
            strategy: e.strategy.clone(),
            network: e.network,
            procs: e.procs,
            makespan: e.blame.makespan,
            compute: e.blame.plan.compute(),
            latency: e.blame.plan.exposed_latency(),
            bandwidth: e.blame.plan.bandwidth(),
            idle: e.blame.plan.idle(),
            exact: e.blame.verify().is_ok(),
            bound: e.cross.bound,
            exact_wire: e.cross.exact_wire,
            bound_ok: e.cross.ok(),
            path_segments: e.blame.path.len(),
            path_messages: e.blame.path_messages.len(),
        }
    }

    /// One JSON object, every line prefixed with `indent`.
    pub fn to_json(&self, indent: &str) -> String {
        let mut s = String::new();
        s.push_str(&format!("{indent}{{\n"));
        s.push_str(&format!("{indent}  \"workload\": \"{}\",\n", self.workload));
        s.push_str(&format!("{indent}  \"strategy\": \"{}\",\n", self.strategy));
        s.push_str(&format!("{indent}  \"network\": \"{}\",\n", self.network));
        s.push_str(&format!("{indent}  \"procs\": {},\n", self.procs));
        s.push_str(&format!("{indent}  \"makespan\": {},\n", self.makespan));
        s.push_str(&format!("{indent}  \"compute\": {},\n", self.compute));
        s.push_str(&format!("{indent}  \"exposed_latency\": {},\n", self.latency));
        s.push_str(&format!("{indent}  \"bandwidth\": {},\n", self.bandwidth));
        s.push_str(&format!("{indent}  \"idle\": {},\n", self.idle));
        s.push_str(&format!("{indent}  \"exact\": {},\n", self.exact));
        s.push_str(&format!("{indent}  \"bound\": {},\n", self.bound));
        s.push_str(&format!("{indent}  \"exact_wire\": {},\n", self.exact_wire));
        s.push_str(&format!("{indent}  \"bound_ok\": {},\n", self.bound_ok));
        s.push_str(&format!("{indent}  \"path_segments\": {},\n", self.path_segments));
        s.push_str(&format!("{indent}  \"path_messages\": {}\n", self.path_messages));
        s.push_str(&format!("{indent}}}"));
        s
    }
}

/// A JSON array of cells, each rendered by [`ExplainCell::to_json`].
pub fn cells_to_json(cells: &[ExplainCell], indent: &str) -> String {
    let mut s = String::from("[\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str(&c.to_json(&format!("{indent}  ")));
        s.push_str(if i + 1 < cells.len() { ",\n" } else { "\n" });
    }
    s.push_str(&format!("{indent}]"));
    s
}

/// The observed critical path as renderable spans: one `crit:*` span
/// per path segment, on the owning processor's reserved lane (tid 99),
/// so a Perfetto load shows the path highlighted alongside the normal
/// compute/wait rows.
pub fn path_spans(blame: &Blame) -> Vec<BusySpan> {
    blame
        .path
        .iter()
        .map(|seg| BusySpan {
            proc: seg.proc,
            thread: 99,
            start: seg.start,
            end: seg.end,
            what: match seg.kind {
                SegmentKind::Compute => "crit:compute",
                SegmentKind::Bandwidth { .. } => "crit:bandwidth",
                SegmentKind::Latency { .. } => "crit:latency",
                SegmentKind::Idle { .. } => "crit:idle",
            },
        })
        .collect()
}

/// The on-path message flights as Perfetto flow arrows
/// ([`crate::trace::chrome_trace_with_flows`]).
pub fn path_flows(blame: &Blame) -> Vec<MessageFlow> {
    blame
        .path_messages
        .iter()
        .map(|m| MessageFlow {
            id: u64::from(m.msg),
            from_proc: m.from,
            post: m.post,
            to_proc: m.to,
            arrival: m.arrival,
        })
        .collect()
}

/// The blame share table of one decomposition: category → fraction of
/// the makespan, for human-readable summaries (`explain` CLI output).
pub fn share_line(blame: &Blame) -> String {
    let m = if blame.makespan > 0.0 { blame.makespan } else { 1.0 };
    format!(
        "compute {:.1}% | exposed latency {:.1}% | bandwidth {:.1}% | idle {:.1}%",
        100.0 * blame.plan.compute() / m,
        100.0 * blame.plan.exposed_latency() / m,
        100.0 * blame.plan.bandwidth() / m,
        100.0 * blame.plan.idle() / m,
    )
}

/// One line for the cross-check, e.g. `"observed 812.5 >= bound 812.5
/// (exact wire, bit-equal)"`.
pub fn crosscheck_line(c: &CrossCheck) -> String {
    if c.exact_wire {
        let eq = if c.observed.to_bits() == c.bound.to_bits() { "bit-equal" } else { "DRIFT" };
        format!("observed {} >= bound {} (exact wire, {eq})", c.observed, c.bound)
    } else {
        format!("observed {} >= bound {} (lower bound only)", c.observed, c.bound)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{AlphaBeta, CompiledPlan, EngineScratch, ExecPlan, Machine, UniformCost};
    use crate::stencil::heat1d_graph;
    use std::sync::Arc;

    #[test]
    fn cell_json_is_balanced_and_keyed() {
        let g = heat1d_graph(32, 3, 4);
        let plan = ExecPlan::naive(&g);
        let cp = Arc::new(CompiledPlan::compile(&g, &plan, &UniformCost));
        let mach = Machine::new(4, 1, 100.0, 0.5, 1.0);
        let mut net = AlphaBeta::from_machine(&mach);
        let mut scratch = EngineScratch::new();
        let obs =
            super::super::Observation::observe(cp, &mach, &mut net, &mut scratch).unwrap();
        let blame = Blame::explain(&obs, &net);
        blame.verify().unwrap();
        let e = Explanation {
            workload: "heat1d".into(),
            strategy: "naive".into(),
            network: "alphabeta",
            procs: 4,
            cross: CrossCheck { observed: obs.makespan(), bound: obs.makespan(), exact_wire: true },
            blame,
            obs,
        };
        let cell = ExplainCell::from_explanation(&e);
        assert!(cell.exact && cell.bound_ok);
        let json = cells_to_json(&[cell], "");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        for key in ["\"exposed_latency\"", "\"bound_ok\"", "\"path_messages\""] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }
}
