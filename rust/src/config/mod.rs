//! Experiment configuration: a small `key = value` config format plus the
//! presets used by the figures and examples.
//!
//! No `serde` is available in the vendored crate set, so the parser is
//! hand-rolled: one `key = value` pair per line, `#` comments, sections
//! ignored (`[section]` lines are allowed and flattened, so simple TOML
//! files parse too).  CLI `key=value` overrides merge on top.

use std::collections::BTreeMap;

/// A flat, ordered key-value config.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    map: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    /// Parse config text; later duplicates win (override semantics).
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut map = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with('[') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value, got {line:?}", ln + 1))?;
            map.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(Config { map })
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    /// Merge `key=value` CLI arguments over this config; unknown args are
    /// returned untouched.
    pub fn apply_overrides<'a>(&mut self, args: &[&'a str]) -> Vec<&'a str> {
        let mut rest = Vec::new();
        for a in args {
            match a.split_once('=') {
                Some((k, v)) if !k.is_empty() && !k.starts_with('-') => {
                    self.map.insert(k.to_string(), v.to_string());
                }
                _ => rest.push(*a),
            }
        }
        rest
    }

    pub fn set(&mut self, k: &str, v: impl ToString) {
        self.map.insert(k.to_string(), v.to_string());
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.map.get(k).map(String::as_str)
    }

    /// Typed getter with default.
    pub fn get_or<T: std::str::FromStr>(&self, k: &str, default: T) -> T {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Typed getter, error when missing/unparsable.
    pub fn require<T: std::str::FromStr>(&self, k: &str) -> Result<T, String> {
        self.get(k)
            .ok_or_else(|| format!("missing config key {k:?}"))?
            .parse()
            .map_err(|_| format!("config key {k:?} has unparsable value {:?}", self.get(k)))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    /// Render back to config text.
    pub fn to_text(&self) -> String {
        self.map.iter().map(|(k, v)| format!("{k} = {v}\n")).collect()
    }
}

/// The figure-7 preset (moderate latency strong-scaling sweep).
///
/// Calibration (see DESIGN.md §8): with block factors up to `b`, blocking
/// saves `α·(1 − 1/b)` per level but adds `≈ b²γ/t` of redundant work per
/// superstep, so the paper's figure-7 shape ("only for very high thread
/// count is there any gain") needs `α` of order `b·γ`; figure 8's shape
/// ("even for moderate thread counts blocking effects latency hiding")
/// needs `α ≫ b·γ`.
pub fn preset_fig7() -> Config {
    let mut c = Config::new();
    c.set("n", 65536);
    c.set("m", 64);
    c.set("p", 16);
    c.set("alpha", 8.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("threads", "1,2,4,8,16,32,64,128,256");
    c.set("blocks", "2,4,8");
    c
}

/// The figure-8 preset (high latency).
pub fn preset_fig8() -> Config {
    let mut c = preset_fig7();
    c.set("alpha", 500.0);
    c
}

/// The `sweep` CLI preset: the full (workload × strategy) × network ×
/// α × threads grid on the event-driven engine — four wire models and an
/// 8-point α axis over heat1d/heat2d/CG.
pub fn preset_sweep() -> Config {
    let mut c = Config::new();
    c.set("workloads", "heat1d,heat2d,cg");
    c.set("networks", "alphabeta,loggp,hier,contended");
    c.set("alphas", "1,2,4,8,16,64,256,500");
    c.set("threads", "1,4,16,64");
    c.set("blocks", "2,4,8");
    c.set("p", 4);
    c.set("n", 4096);
    c.set("m", 16);
    c.set("h", 32);
    c.set("w", 32);
    c.set("cg_n", 256);
    c.set("iters", 3);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("jobs", 0);
    c.set("out", "results/sweep.json");
    c
}

/// The `sweep --smoke` preset: the fig-7 (α=8) and fig-8 (α=500) regimes
/// shrunk to run on every CI push, emitting `BENCH_sim.json` so the
/// simulator's makespans and wall-times are tracked over time.
pub fn preset_sweep_smoke() -> Config {
    let mut c = preset_sweep();
    c.set("alphas", "8,500");
    c.set("threads", "1,8,64");
    c.set("blocks", "4");
    c.set("n", 2048);
    c.set("m", 16);
    c.set("h", 16);
    c.set("w", 16);
    c.set("cg_n", 64);
    c.set("iters", 2);
    c.set("out", "BENCH_sim.json");
    c
}

/// The `bench` CLI preset: the engine micro-benchmark — the sweep-smoke
/// grid (fig-7/8 regimes × all four wires) simulated `repeat` times per
/// cell on both the compiled and the interpreting engine, with every
/// cell cross-checked bit-for-bit between the two.
pub fn preset_bench() -> Config {
    let mut c = preset_sweep_smoke();
    c.set("repeat", 20);
    c.set("out", "results/bench.json");
    c
}

/// The `bench --smoke` preset: the CI engine-perf tracker, emitting
/// `BENCH_engine.json` (events/sec, sims/sec, compile-vs-simulate
/// split, compiled-vs-interpreted speedup) on every push.
pub fn preset_bench_smoke() -> Config {
    let mut c = preset_bench();
    c.set("repeat", 5);
    c.set("out", "BENCH_engine.json");
    c
}

/// The `tune` CLI preset: engine-in-the-loop autotuning of each
/// workload under every wire model, with a file-backed
/// [`crate::tune::TuningCache`] so repeat invocations skip the search.
pub fn preset_tune() -> Config {
    let mut c = Config::new();
    c.set("workloads", "heat1d,heat2d,spmv");
    c.set("networks", "alphabeta,loggp,hier,contended");
    c.set("search", "exhaustive");
    c.set("p", 4);
    c.set("n", 4096);
    c.set("m", 32);
    c.set("h", 32);
    c.set("w", 32);
    c.set("cg_n", 256);
    c.set("iters", 3);
    c.set("threads", 8);
    c.set("alpha", 500.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("repeat", 1);
    c.set("cache", "results/tune_cache.json");
    c.set("out", "results/tune.json");
    c
}

/// The `tune --smoke` preset: the CI perf tracker — two workloads ×
/// four wire models, each tuned twice so the second pass exercises the
/// cache (hit rate 0.5 in the emitted `BENCH_tune.json`).
pub fn preset_tune_smoke() -> Config {
    let mut c = preset_tune();
    c.set("workloads", "heat1d,heat2d");
    c.set("n", 1024);
    c.set("m", 16);
    c.set("h", 16);
    c.set("w", 16);
    c.set("repeat", 2);
    c.set("cache", "");
    c.set("out", "BENCH_tune.json");
    c
}

/// The `partition` CLI preset: processor-grid shapes on heat2d and
/// graph partitioners on a banded+random SpMV matrix, each simulated
/// under every wire model.  β is sized so the wire feels the words a
/// layout moves (the quality metric's edge-cut words).
pub fn preset_partition() -> Config {
    let mut c = Config::new();
    c.set("h", 30);
    c.set("w", 30);
    c.set("m", 8);
    c.set("p", 9);
    c.set("threads", 4);
    c.set("alpha", 40.0);
    c.set("beta", 1.0);
    c.set("gamma", 1.0);
    c.set("grids", "strip,1x9,3x3");
    c.set("partitioners", "rowblock,rcb,rcb+refine");
    c.set("networks", "alphabeta,loggp,hier,contended");
    c.set("spmv_h", 8);
    c.set("spmv_w", 32);
    c.set("chords", 16);
    c.set("out", "results/partition.json");
    c
}

/// The `partition --smoke` preset: the CI layout tracker — grid shapes ×
/// partitioners × wires shrunk to run on every push, emitting
/// `BENCH_partition.json` (per-cell makespan + edge cut).
pub fn preset_partition_smoke() -> Config {
    let mut c = preset_partition();
    c.set("h", 18);
    c.set("w", 18);
    c.set("m", 4);
    c.set("spmv_h", 6);
    c.set("spmv_w", 24);
    c.set("chords", 8);
    c.set("out", "BENCH_partition.json");
    c
}

/// The `serve` CLI preset: the long-running tuning/simulation daemon.
/// Request fields override the machine/problem defaults per request;
/// these keys size the daemon itself (worker pool, admission cap,
/// search-budget ceiling, cache shard directory) plus the smoke mix.
pub fn preset_serve() -> Config {
    let mut c = Config::new();
    c.set("workloads", "heat1d,heat2d");
    c.set("networks", "alphabeta,loggp");
    c.set("search", "exhaustive");
    c.set("p", 4);
    c.set("n", 1024);
    c.set("m", 16);
    c.set("h", 16);
    c.set("w", 16);
    c.set("cg_n", 64);
    c.set("iters", 2);
    c.set("threads", 8);
    c.set("alpha", 500.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("workers", 4);
    c.set("max_in_flight", 64);
    c.set("budget", 0);
    c.set("slots", 8);
    c.set("cache", "results/serve_cache");
    c.set("requests", "-");
    c.set("telemetry", 0);
    c.set("metrics", 0);
    c
}

/// The `serve --smoke` preset: the CI serving tracker — the scripted
/// cold → warm → duplicate-burst → batch mix on a throwaway cache,
/// emitting `BENCH_serve.json` (cold/warm req/s, dedupe and batch
/// counts, p50/p99 latency) on every push.
pub fn preset_serve_smoke() -> Config {
    let mut c = preset_serve();
    c.set("n", 512);
    c.set("m", 8);
    c.set("h", 12);
    c.set("w", 12);
    c.set("cache", "");
    c.set("out", "BENCH_serve.json");
    c
}

/// The `chaos` CLI preset: deterministic fault-injection ensembles.
/// Every (workload × strategy × wire × straggler-rate) group runs
/// `seeds` perturbed members against one clean baseline and reports
/// tail percentiles plus the p99 degradation ratio; `hetero`/`jitter`/
/// `straggler_factor`/`wire` shape the shared fault scenario and
/// `seed` roots every deterministic draw.  α is moderate so compute
/// stragglers (not wire latency) dominate the tail, which is the regime
/// the degradation gate reasons about.
pub fn preset_chaos() -> Config {
    let mut c = Config::new();
    c.set("workloads", "heat1d,heat2d");
    c.set("networks", "alphabeta,hier");
    c.set("blocks", "4,8");
    c.set("rates", "0.05,0.1,0.25");
    c.set("seeds", 64);
    c.set("p", 4);
    c.set("n", 2048);
    c.set("m", 16);
    c.set("h", 24);
    c.set("w", 24);
    c.set("cg_n", 64);
    c.set("iters", 2);
    c.set("threads", 4);
    c.set("alpha", 8.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("seed", 1);
    c.set("hetero", 0.1);
    c.set("jitter", 0.1);
    c.set("straggler_factor", 8.0);
    c.set("wire", "exp:2");
    c.set("gate_rate", 0.2);
    c.set("jobs", 0);
    c.set("out", "results/chaos.json");
    c
}

/// The `chaos --smoke` preset: the CI robustness tracker, emitting
/// `BENCH_chaos.json` on every push.  Gates: bit-exact determinism
/// (compiled ≡ interpreted per seed), bit-exact blame closure on
/// perturbed runs, the clean analytic lower bound never undercut, and
/// at straggler rates ≥ `gate_rate` the best transformed strategy's p99
/// degradation ratio must not exceed naive's on the heat workloads.
pub fn preset_chaos_smoke() -> Config {
    let mut c = preset_chaos();
    c.set("n", 256);
    c.set("m", 12);
    c.set("h", 12);
    c.set("w", 12);
    c.set("blocks", "4");
    c.set("rates", "0.05,0.25");
    c.set("seeds", 24);
    c.set("out", "BENCH_chaos.json");
    c
}

/// The `analyze` CLI preset: the static-analysis study — verify every
/// pipeline-built plan of the sweep grid without the engine, check the
/// analytic critical-path lower bound against the simulated makespan on
/// every grid cell (α=0 rows pin the exact-equality corner), and audit
/// lower-bound pruning ([`crate::tune::Tuner::with_pruning`]) against
/// un-pruned tuning on `tune_workloads` × `networks`.
pub fn preset_analyze() -> Config {
    let mut c = Config::new();
    c.set("workloads", "heat1d,heat2d,cg");
    c.set("tune_workloads", "heat1d,heat2d");
    c.set("networks", "alphabeta,loggp,hier,contended");
    c.set("alphas", "0,8,64,500");
    c.set("threads", "1,8,64");
    c.set("blocks", "2,4,8");
    c.set("p", 4);
    c.set("n", 2048);
    c.set("m", 16);
    c.set("h", 16);
    c.set("w", 16);
    c.set("cg_n", 64);
    c.set("iters", 2);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("jobs", 0);
    c.set("repeat", 50);
    c.set("tune_alpha", 500.0);
    c.set("tune_threads", 8);
    c.set("out", "results/analyze.json");
    c
}

/// The `analyze --smoke` preset: the CI static-analysis tracker — the
/// `BENCH_sim.json` regime grid (fig-7/8 α values plus the α=0
/// exactness corner), emitting `BENCH_analyze.json` (plans verified/sec,
/// bound tightness, prune rate) on every push; any violated soundness
/// gate fails the run.
pub fn preset_analyze_smoke() -> Config {
    let mut c = preset_analyze();
    c.set("alphas", "0,8,500");
    c.set("blocks", "4");
    c.set("repeat", 20);
    c.set("out", "BENCH_analyze.json");
    c
}

/// The `trace` CLI preset: the telemetry overhead/fidelity study — the
/// compiled engine timed with the [`crate::telemetry`] gate off, then a
/// fully instrumented sim + serve + tune pass merged into one Chrome
/// trace, then the gate switched off again and the engine re-timed to
/// bound the cost of the dormant instrumentation.
pub fn preset_trace() -> Config {
    let mut c = Config::new();
    c.set("n", 4096);
    c.set("m", 16);
    c.set("p", 4);
    c.set("threads", 8);
    c.set("alpha", 500.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("network", "alphabeta");
    c.set("repeat", 60);
    c.set("trials", 3);
    c.set("chrome", "results/trace_chrome.json");
    c.set("out", "results/trace.json");
    c
}

/// The `trace --smoke` preset: the CI observability tracker, emitting
/// `BENCH_trace.json` (disabled-gate overhead ratio, phase-sum fidelity,
/// span counts) plus the merged Perfetto-loadable Chrome trace on every
/// push; the 3% overhead gate and the phase-sum gate fail the run.
pub fn preset_trace_smoke() -> Config {
    let mut c = preset_trace();
    c.set("n", 2048);
    c.set("repeat", 30);
    c.set("out", "BENCH_trace.json");
    c
}

/// The `explain` CLI preset: the causal-profiling study — run the
/// provenance-recording engine over `workloads` × naive/overlap/CA ×
/// `networks`, decompose every observed makespan into bit-exact
/// compute / exposed-latency / bandwidth / idle blame terms, diff the
/// strategies (which α terms the transforms moved off the observed
/// critical path), attach the differential explanation to a tuned
/// winner, and bound the cost of the dormant provenance gate.
pub fn preset_explain() -> Config {
    let mut c = Config::new();
    c.set("workloads", "heat1d,heat2d,cg");
    c.set("networks", "alphabeta,loggp,hier,contended");
    c.set("n", 4096);
    c.set("m", 16);
    c.set("h", 16);
    c.set("w", 16);
    c.set("cg_n", 64);
    c.set("iters", 2);
    c.set("p", 4);
    c.set("threads", 8);
    c.set("alpha", 500.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c.set("b", 8);
    c.set("repeat", 60);
    c.set("trials", 3);
    c.set("chrome", "results/explain_chrome.json");
    c.set("out", "results/explain.json");
    c
}

/// The `explain --smoke` preset: the CI causal-profiling tracker,
/// emitting `BENCH_explain.json` (per-cell blame decompositions, the
/// naive→overlap→CA differential table, the tuned winner's explanation,
/// provenance-gate overhead) plus the critical-path-highlighted Chrome
/// trace on every push.  The exact-sum gate, the bound gate, the
/// CA-beats-naive exposed-latency gate (α = 500 is deep in the
/// latency-dominated regime), and the 3% overhead gate fail the run.
pub fn preset_explain_smoke() -> Config {
    let mut c = preset_explain();
    c.set("n", 1024);
    c.set("h", 12);
    c.set("w", 12);
    c.set("repeat", 30);
    c.set("out", "BENCH_explain.json");
    c
}

/// The figure-10 preset: SpMV partition quality vs. makespan per wire
/// model on the banded+random matrix.
pub fn preset_fig10() -> Config {
    let mut c = Config::new();
    c.set("h", 6);
    c.set("w", 24);
    c.set("chords", 8);
    c.set("m", 6);
    c.set("p", 4);
    c.set("threads", 4);
    c.set("alpha", 40.0);
    c.set("beta", 1.0);
    c.set("gamma", 1.0);
    c
}

/// The figure-9 preset: tuned vs fixed-b vs naive across the four wire
/// models.  α is sized so the §2.1 closed form picks a block factor
/// inside the default grid (sqrt(α·t/γ) ≈ 22.6 clamps to the depth).
pub fn preset_fig9() -> Config {
    let mut c = Config::new();
    c.set("n", 2048);
    c.set("m", 16);
    c.set("p", 4);
    c.set("threads", 8);
    c.set("alpha", 64.0);
    c.set("beta", 0.1);
    c.set("gamma", 1.0);
    c
}

/// The end-to-end driver preset (real PJRT run).
pub fn preset_end_to_end() -> Config {
    let mut c = Config::new();
    c.set("n_per_worker", 2048);
    c.set("workers", 8);
    c.set("steps", 256);
    c.set("nu", 0.2);
    c.set("blocks", "1,2,4,8");
    c
}

/// Parse a comma-separated numeric list config value.
pub fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>, String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| t.parse().map_err(|_| format!("bad list element {t:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let c = Config::parse("# comment\na = 1\nname = \"x y\"\n\n[sec]\nb=2.5\n").unwrap();
        assert_eq!(c.get_or("a", 0u32), 1);
        assert_eq!(c.get("name"), Some("x y"));
        assert_eq!(c.get_or("b", 0.0f64), 2.5);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Config::parse("no equals sign").is_err());
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("a = 1").unwrap();
        let rest = c.apply_overrides(&["a=2", "--flag", "b=3"]);
        assert_eq!(c.get_or("a", 0u32), 2);
        assert_eq!(c.get_or("b", 0u32), 3);
        assert_eq!(rest, vec!["--flag"]);
    }

    #[test]
    fn require_errors() {
        let c = Config::new();
        assert!(c.require::<u32>("missing").is_err());
    }

    #[test]
    fn list_parsing() {
        assert_eq!(parse_list::<u32>("1, 2,4").unwrap(), vec![1, 2, 4]);
        assert!(parse_list::<u32>("1,x").is_err());
    }

    #[test]
    fn presets_complete() {
        for c in [preset_fig7(), preset_fig8()] {
            for k in ["n", "m", "p", "alpha", "beta", "gamma", "threads", "blocks"] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        for c in [preset_sweep(), preset_sweep_smoke()] {
            for k in [
                "workloads", "networks", "alphas", "threads", "blocks", "p", "n", "m", "h",
                "w", "cg_n", "iters", "beta", "gamma", "jobs", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        // The smoke grid is exactly the two paper regimes.
        assert_eq!(preset_sweep_smoke().get("alphas"), Some("8,500"));
        for c in [preset_bench(), preset_bench_smoke()] {
            for k in [
                "workloads", "networks", "alphas", "threads", "blocks", "p", "repeat", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        assert_eq!(preset_bench_smoke().get("out"), Some("BENCH_engine.json"));
        for c in [preset_tune(), preset_tune_smoke()] {
            for k in [
                "workloads", "networks", "search", "p", "n", "m", "h", "w", "threads",
                "alpha", "beta", "gamma", "repeat", "cache", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        // The tune smoke pass runs everything twice to exercise the cache.
        assert_eq!(preset_tune_smoke().get("repeat"), Some("2"));
        for k in ["n", "m", "p", "threads", "alpha", "beta", "gamma"] {
            assert!(preset_fig9().get(k).is_some(), "{k}");
        }
        for c in [preset_partition(), preset_partition_smoke()] {
            for k in [
                "h", "w", "m", "p", "threads", "alpha", "beta", "gamma", "grids",
                "partitioners", "networks", "spmv_h", "spmv_w", "chords", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        assert_eq!(preset_partition_smoke().get("out"), Some("BENCH_partition.json"));
        for c in [preset_serve(), preset_serve_smoke()] {
            for k in [
                "workloads", "networks", "search", "p", "n", "m", "h", "w", "cg_n", "iters",
                "threads", "alpha", "beta", "gamma", "workers", "max_in_flight", "budget",
                "slots", "cache", "requests", "telemetry", "metrics",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        // The smoke benchmark must start cold: an empty cache key routes
        // it to a throwaway temp dir that is wiped before the run.
        assert_eq!(preset_serve_smoke().get("cache"), Some(""));
        assert_eq!(preset_serve_smoke().get("out"), Some("BENCH_serve.json"));
        for c in [preset_analyze(), preset_analyze_smoke()] {
            for k in [
                "workloads", "tune_workloads", "networks", "alphas", "threads", "blocks",
                "p", "n", "m", "h", "w", "cg_n", "iters", "beta", "gamma", "jobs", "repeat",
                "tune_alpha", "tune_threads", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        // The smoke grid covers the BENCH_sim regimes plus the α=0
        // corner where the bound must be bit-exact under uniform cost.
        assert_eq!(preset_analyze_smoke().get("alphas"), Some("0,8,500"));
        assert_eq!(preset_analyze_smoke().get("out"), Some("BENCH_analyze.json"));
        for c in [preset_trace(), preset_trace_smoke()] {
            for k in [
                "n", "m", "p", "threads", "alpha", "beta", "gamma", "network", "repeat",
                "trials", "chrome", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        assert_eq!(preset_trace_smoke().get("out"), Some("BENCH_trace.json"));
        for c in [preset_explain(), preset_explain_smoke()] {
            for k in [
                "workloads", "networks", "n", "m", "h", "w", "cg_n", "iters", "p", "threads",
                "alpha", "beta", "gamma", "b", "repeat", "trials", "chrome", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        // α = 500 keeps the smoke in the latency-dominated regime the
        // CA-beats-naive exposed-latency gate assumes.
        assert_eq!(preset_explain_smoke().get("alpha"), Some("500"));
        assert_eq!(preset_explain_smoke().get("out"), Some("BENCH_explain.json"));
        for c in [preset_chaos(), preset_chaos_smoke()] {
            for k in [
                "workloads", "networks", "blocks", "rates", "seeds", "p", "n", "m", "h", "w",
                "cg_n", "iters", "threads", "alpha", "beta", "gamma", "seed", "hetero",
                "jitter", "straggler_factor", "wire", "gate_rate", "jobs", "out",
            ] {
                assert!(c.get(k).is_some(), "{k}");
            }
        }
        // The chaos smoke must include a rate at/above the gate's
        // threshold, or the degradation gate would trivially pass.
        assert_eq!(preset_chaos_smoke().get("rates"), Some("0.05,0.25"));
        assert_eq!(preset_chaos_smoke().get("gate_rate"), Some("0.2"));
        assert_eq!(preset_chaos_smoke().get("out"), Some("BENCH_chaos.json"));
        for k in ["h", "w", "chords", "m", "p", "threads", "alpha", "beta", "gamma"] {
            assert!(preset_fig10().get(k).is_some(), "{k}");
        }
    }

    #[test]
    fn roundtrip_text() {
        let mut c = Config::new();
        c.set("z", 1);
        c.set("a", "hello");
        let c2 = Config::parse(&c.to_text()).unwrap();
        assert_eq!(c, c2);
    }
}
