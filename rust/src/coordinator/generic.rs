//! Generic distributed execution of an [`ExecPlan`] with deterministic
//! synthetic task semantics.
//!
//! This is the coordinator's routing/state-management core, exercised
//! independently of PJRT: every task's "value" is a u64 computed from its
//! item, level and predecessor values, so a distributed run can be checked
//! bit-exactly against a sequential evaluation of the graph.  The property
//! suite (`rust/tests/prop_coordinator.rs`) runs random DAGs through
//! random transforms here — if the subsets, message pairing, or phase
//! ordering were wrong in any way, values would diverge.
//!
//! The real PJRT-backed engines ([`super::heat1d`], [`super::heat2d`])
//! reuse the same fabric and phase loop shape.

use super::messages::{fabric, Payload};
use crate::graph::{TaskGraph, TaskId, TaskKind};
use crate::sim::{ExecPlan, Phase};
use std::collections::HashMap;
use std::sync::Arc;
use std::thread;

/// Deterministic task semantics: `Input` value from item; `Compute` value
/// mixes item, level and the (order-independent) sum of pred values.
#[inline]
pub fn input_value(item: u64) -> u64 {
    item.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xD6E8FEB86659FD93
}

#[inline]
pub fn compute_value(item: u64, level: u32, pred_sum: u64) -> u64 {
    pred_sum
        .wrapping_add(item.wrapping_mul(0xA24BAED4963EE407))
        .wrapping_add((level as u64).wrapping_mul(0x9FB21C651E98DF25))
        .rotate_left(17)
}

/// Pluggable task semantics for the generic engine: how an `Input` task's
/// value derives from its item, and how a `Compute` task's value derives
/// from (item, level, order-independent predecessor sum).  Plain function
/// pointers so a semantics is `Copy + Send` and crosses worker threads
/// for free; [`crate::pipeline::Workload`] implementations supply one and
/// the same semantics drives both the distributed run and its sequential
/// reference.
#[derive(Debug, Clone, Copy)]
pub struct ValueSemantics {
    pub input: fn(u64) -> u64,
    pub compute: fn(u64, u32, u64) -> u64,
}

impl Default for ValueSemantics {
    fn default() -> Self {
        ValueSemantics { input: input_value, compute: compute_value }
    }
}

/// Sequentially evaluate every task's value (the reference).
pub fn sequential_values(g: &TaskGraph) -> Vec<u64> {
    sequential_values_with(g, ValueSemantics::default())
}

/// [`sequential_values`] under caller-chosen semantics.
pub fn sequential_values_with(g: &TaskGraph, sem: ValueSemantics) -> Vec<u64> {
    // The topological order is cached on the graph at build time; no
    // per-evaluation Kahn pass.
    let mut val = vec![0u64; g.len()];
    for &t in g.topo() {
        let tid = TaskId(t);
        val[t as usize] = match g.kind(tid) {
            TaskKind::Input => (sem.input)(g.item(tid)),
            TaskKind::Compute => {
                let mut s = 0u64;
                for &p in g.preds(tid) {
                    s = s.wrapping_add(val[p as usize]);
                }
                (sem.compute)(g.item(tid), g.level(tid), s)
            }
        };
    }
    val
}

/// Outcome of a distributed run.
#[derive(Debug)]
pub struct GenericRunResult {
    /// Values of every task, as computed by its owner.
    pub owned_values: Vec<(u32, u64)>,
    /// Total messages sent.
    pub messages: u64,
    /// Total words sent.
    pub words: u64,
    /// Tasks executed across all workers (incl. redundant).
    pub executed: u64,
    /// Wall-clock seconds.
    pub wall_secs: f64,
}

/// Execute `plan` for `g` on real threads (one per processor) and real
/// channels.  Returns owner-computed values for verification.
///
/// Panics if the plan is not executable (a task's predecessor value is
/// unavailable when needed) — the property tests rely on that to catch
/// malformed schedules.
pub fn run_generic(g: &Arc<TaskGraph>, plan: &ExecPlan) -> GenericRunResult {
    run_generic_with(g, plan, ValueSemantics::default())
}

/// [`run_generic`] under caller-chosen value semantics.
pub fn run_generic_with(
    g: &Arc<TaskGraph>,
    plan: &ExecPlan,
    sem: ValueSemantics,
) -> GenericRunResult {
    let nprocs = plan.per_proc.len();
    let endpoints = fabric(nprocs as u32);
    let t0 = std::time::Instant::now();

    let mut handles = Vec::with_capacity(nprocs);
    for (p, (mut ep, proc_plan)) in
        endpoints.into_iter().zip(plan.per_proc.iter().cloned()).enumerate()
    {
        let g = Arc::clone(g);
        handles.push(thread::spawn(move || {
            // Worker-local value store: task id → value.
            let mut store: HashMap<u32, u64> = HashMap::new();
            // Inputs owned by this worker are available from the start.
            for t in g.tasks() {
                if g.kind(t) == TaskKind::Input && g.owner(t).0 == p as u32 {
                    store.insert(t.0, (sem.input)(g.item(t)));
                }
            }
            let mut executed = 0u64;
            for phase in &proc_plan.phases {
                match phase {
                    Phase::Compute(tasks) => {
                        let mut order = tasks.clone();
                        order.sort_unstable_by_key(|&t| (g.level(TaskId(t)), t));
                        for t in order {
                            let tid = TaskId(t);
                            let mut s = 0u64;
                            for &pr in g.preds(tid) {
                                let v = store.get(&pr).unwrap_or_else(|| {
                                    panic!(
                                        "p{p}: task t{t} needs t{pr} which is unavailable"
                                    )
                                });
                                s = s.wrapping_add(*v);
                            }
                            store.insert(t, (sem.compute)(g.item(tid), g.level(tid), s));
                            executed += 1;
                        }
                    }
                    Phase::Send { to, tasks } => {
                        let values: Vec<f32> = Vec::new(); // values travel in `raw`
                        let mut raw = Vec::with_capacity(tasks.len() * 2);
                        for &t in tasks {
                            let v = *store
                                .get(&t)
                                .unwrap_or_else(|| panic!("p{p}: sending unknown t{t}"));
                            // Pack u64 into two f32-slots losslessly via bits.
                            raw.push(f32::from_bits((v >> 32) as u32));
                            raw.push(f32::from_bits(v as u32));
                        }
                        let _ = values;
                        ep.send(to.0, Payload { tasks: tasks.clone(), values: raw });
                    }
                    Phase::Recv { from, tasks } => {
                        let payload = ep.recv_from(from.0);
                        assert_eq!(
                            payload.tasks, *tasks,
                            "p{p}: message task list mismatch from p{}",
                            from.0
                        );
                        for (i, &t) in payload.tasks.iter().enumerate() {
                            let hi = payload.values[2 * i].to_bits() as u64;
                            let lo = payload.values[2 * i + 1].to_bits() as u64;
                            store.insert(t, (hi << 32) | lo);
                        }
                    }
                }
            }
            // Report values of owned tasks.
            let owned: Vec<(u32, u64)> = g
                .tasks()
                .filter(|&t| g.owner(t).0 == p as u32)
                .map(|t| (t.0, *store.get(&t.0).unwrap_or(&u64::MAX)))
                .collect();
            (owned, ep.sent_messages, ep.sent_words, executed)
        }));
    }

    let mut owned_values = Vec::new();
    let (mut messages, mut words, mut executed) = (0u64, 0u64, 0u64);
    for h in handles {
        let (o, m, w, e) = h.join().expect("worker panicked");
        owned_values.extend(o);
        messages += m;
        words += w;
        executed += e;
    }
    GenericRunResult { owned_values, messages, words, executed, wall_secs: t0.elapsed().as_secs_f64() }
}

/// Run and verify against the sequential reference; returns the result or
/// a description of the first divergence.
pub fn run_and_verify(g: &Arc<TaskGraph>, plan: &ExecPlan) -> Result<GenericRunResult, String> {
    run_and_verify_with(g, plan, ValueSemantics::default())
}

/// [`run_and_verify`] under caller-chosen value semantics.
pub fn run_and_verify_with(
    g: &Arc<TaskGraph>,
    plan: &ExecPlan,
    sem: ValueSemantics,
) -> Result<GenericRunResult, String> {
    let reference = sequential_values_with(g, sem);
    let r = run_generic_with(g, plan, sem);
    for &(t, v) in &r.owned_values {
        if v == u64::MAX && reference[t as usize] != u64::MAX {
            return Err(format!("t{t}: owner never obtained a value"));
        }
        if v != reference[t as usize] {
            return Err(format!(
                "t{t}: distributed {v:#x} != sequential {:#x}",
                reference[t as usize]
            ));
        }
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{heat1d_graph, heat2d_graph};
    use crate::transform::TransformOptions;

    #[test]
    fn naive_plan_reproduces_reference() {
        let g = Arc::new(heat1d_graph(32, 4, 4));
        let plan = ExecPlan::naive(&g);
        let r = run_and_verify(&g, &plan).unwrap();
        assert_eq!(r.executed as usize, g.num_compute_tasks());
    }

    #[test]
    fn overlap_plan_reproduces_reference() {
        let g = Arc::new(heat1d_graph(32, 4, 4));
        run_and_verify(&g, &ExecPlan::overlap(&g)).unwrap();
    }

    #[test]
    fn ca_multilevel_reproduces_reference() {
        let g = Arc::new(heat1d_graph(48, 8, 3));
        let plan = ExecPlan::ca(&g, 4, TransformOptions::default()).unwrap();
        let r = run_and_verify(&g, &plan).unwrap();
        assert!(r.executed as usize >= g.num_compute_tasks());
    }

    #[test]
    fn ca_level0_reproduces_reference() {
        let g = Arc::new(heat1d_graph(48, 8, 3));
        let plan = ExecPlan::ca(&g, 4, TransformOptions::level0()).unwrap();
        let r = run_and_verify(&g, &plan).unwrap();
        assert!(r.executed as usize > g.num_compute_tasks(), "level0 must be redundant");
    }

    #[test]
    fn ca_on_2d_graph_reproduces_reference() {
        let g = Arc::new(heat2d_graph(8, 8, 4, 2, 2));
        let plan = ExecPlan::ca(&g, 2, TransformOptions::default()).unwrap();
        run_and_verify(&g, &plan).unwrap();
    }

    #[test]
    fn message_counts_match_plan() {
        let g = Arc::new(heat1d_graph(32, 6, 2));
        let plan = ExecPlan::ca(&g, 3, TransformOptions::default()).unwrap();
        let r = run_generic(&g, &plan);
        assert_eq!(r.messages as usize, plan.messages());
    }

    #[test]
    fn value_semantics_deterministic() {
        let g = heat1d_graph(16, 2, 2);
        assert_eq!(sequential_values(&g), sequential_values(&g));
    }
}
