//! The real (not simulated) distributed runtime: leader + worker threads
//! over channels, executing transformed schedules with PJRT compute.
//!
//! Two engines share the [`messages`] fabric:
//!
//! * [`generic`] — executes any [`crate::sim::ExecPlan`] with pluggable
//!   deterministic task values; the routing/state-management correctness
//!   core, verified bit-exactly against sequential evaluation (and
//!   hammered by the property suite).  This is what
//!   [`crate::pipeline::Pipeline::execute`] runs.
//! * [`tile`] — the single leader/worker loop behind every PJRT-backed
//!   run; problems plug in as [`tile::TiledWorkload`] geometries.
//!   [`heat1d`] and [`heat2d`] are thin geometry adapters over it.
//!
//! Python never runs here: every worker loads AOT artifacts through
//! [`crate::runtime::Runtime`].

pub mod generic;
pub mod heat1d;
pub mod heat2d;
pub mod messages;
pub mod tile;

pub use generic::{
    run_and_verify, run_and_verify_with, run_generic, run_generic_with, sequential_values,
    sequential_values_with, GenericRunResult, ValueSemantics,
};
pub use heat1d::Heat1dConfig;
pub use heat2d::Heat2dConfig;
pub use tile::{run_tiled, RunStats, TiledWorkload};
