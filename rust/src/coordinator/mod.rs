//! The real (not simulated) distributed runtime: leader + worker threads
//! over channels, executing transformed schedules with PJRT compute.
//!
//! Three engines share the [`messages`] fabric:
//!
//! * [`generic`] — executes any [`crate::sim::ExecPlan`] with synthetic
//!   deterministic task values; the routing/state-management correctness
//!   core, verified bit-exactly against sequential evaluation (and
//!   hammered by the property suite).
//! * [`heat1d`] — the paper's running example for real: tile-per-worker,
//!   `b`-deep ghost exchange once per superstep, blocked Pallas kernel
//!   via PJRT.  `b = 1` is the naive baseline.
//! * [`heat2d`] — the 2-D five-point version with 8-neighbour ghost-frame
//!   exchange on a periodic domain.
//!
//! Python never runs here: every worker loads AOT artifacts through
//! [`crate::runtime::Runtime`].

pub mod generic;
pub mod heat1d;
pub mod heat2d;
pub mod messages;

pub use generic::{run_and_verify, run_generic, sequential_values, GenericRunResult};
pub use heat1d::{Heat1dConfig, RunStats};
pub use heat2d::Heat2dConfig;
