//! 1-D heat equation geometry for the generic tiled engine
//! ([`super::tile`]): tile-per-worker, `b`-deep ghost exchange once per
//! superstep, blocked Pallas kernel `heat1d_n{n}_b{b}` via PJRT.
//!
//! This is the paper's scheme running for real: per superstep of `b`
//! steps, each worker exchanges a `b`-deep ghost region with its
//! neighbours (one message per neighbour per superstep — the `(M/b)·α`
//! term) and then executes the blocked kernel, which recomputes the
//! trapezoid of intermediate halo values inside VMEM — the redundant
//! computation of §2 traded for the factor-`b` message reduction.
//! `b = 1` is the naive baseline.
//!
//! Domain boundaries are odd-reflection ghosts (`ghost_j = 2·x_edge −
//! x_j`), which for the linear 3-point update reproduces zero-Dirichlet
//! semantics *exactly* for every block factor — so runs at different `b`
//! are comparable to each other and to the `heat1d_full_*` reference
//! artifact.
//!
//! All leader/worker plumbing lives in [`super::tile::run_tiled`]; this
//! module only describes the 1-D exchange geometry.

use super::messages::{Endpoint, Payload};
use super::tile::{run_tiled, TiledWorkload};
use crate::runtime::{Runtime, Value};
use anyhow::{bail, Result};

pub use super::tile::RunStats;

/// Configuration of one distributed 1-D heat run.
#[derive(Debug, Clone)]
pub struct Heat1dConfig {
    /// Points per worker (must match an AOT tile size: 256 or 2048).
    pub n_per_worker: usize,
    /// Worker (processor) count.
    pub workers: u32,
    /// Block factor (must match an AOT variant: 1, 2, 4, 8).
    pub b: u32,
    /// Total update steps (must be divisible by `b`).
    pub steps: u32,
    /// Diffusion coefficient.
    pub nu: f32,
    /// Artifact directory.
    pub artifacts_dir: std::path::PathBuf,
}

impl Heat1dConfig {
    pub fn artifact_name(&self) -> String {
        format!("heat1d_n{}_b{}", self.n_per_worker, self.b)
    }

    pub fn total_points(&self) -> usize {
        self.n_per_worker * self.workers as usize
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps % self.b != 0 {
            bail!("steps {} not divisible by b {}", self.steps, self.b);
        }
        if self.n_per_worker <= 2 * self.b as usize {
            bail!("tile {} too small for b {}", self.n_per_worker, self.b);
        }
        Ok(())
    }
}

impl TiledWorkload for Heat1dConfig {
    fn workers(&self) -> u32 {
        self.workers
    }

    fn supersteps(&self) -> u32 {
        self.steps / self.b
    }

    fn artifact(&self) -> String {
        self.artifact_name()
    }

    fn artifacts_dir(&self) -> &std::path::Path {
        &self.artifacts_dir
    }

    fn owned_len(&self) -> usize {
        self.n_per_worker
    }

    fn extract(&self, w: usize, global: &[f32]) -> Vec<f32> {
        let n = self.n_per_worker;
        global[w * n..(w + 1) * n].to_vec()
    }

    fn place(&self, w: usize, tile: &[f32], global: &mut [f32]) {
        let n = self.n_per_worker;
        global[w * n..(w + 1) * n].copy_from_slice(tile);
    }

    fn exchange(&self, w: usize, ep: &mut Endpoint, x: &[f32]) -> Vec<f32> {
        let n = self.n_per_worker;
        let b = self.b as usize;
        let last = self.workers as usize - 1;
        // Post edges to neighbours first (non-blocking sends)...
        if w > 0 {
            ep.send((w - 1) as u32, Payload { tasks: Vec::new(), values: x[..b].to_vec() });
        }
        if w < last {
            ep.send((w + 1) as u32, Payload { tasks: Vec::new(), values: x[n - b..].to_vec() });
        }
        // ...then fill the ghost regions.
        let mut tile = vec![0.0f32; n + 2 * b];
        if w > 0 {
            tile[..b].copy_from_slice(&ep.recv_from((w - 1) as u32).values);
        } else {
            // Odd reflection about x[0]: ghost[k] = 2 x0 − x[b−k].
            for k in 0..b {
                tile[k] = 2.0 * x[0] - x[b - k];
            }
        }
        if w < last {
            tile[n + b..].copy_from_slice(&ep.recv_from((w + 1) as u32).values);
        } else {
            // Odd reflection about x[n−1].
            for k in 0..b {
                tile[n + b + k] = 2.0 * x[n - 1] - x[n - 2 - k];
            }
        }
        tile[b..n + b].copy_from_slice(x);
        tile
    }

    fn kernel_args(&self) -> Vec<Value> {
        vec![Value::scalar(self.nu)]
    }
}

/// Run the distributed heat equation; returns the final field
/// (concatenated worker tiles) and statistics.
pub fn run(cfg: &Heat1dConfig, initial: &[f32]) -> Result<(Vec<f32>, RunStats)> {
    cfg.validate()?;
    run_tiled(cfg, initial)
}

/// Sequential reference via the `heat1d_full_n{N}` artifact (Dirichlet).
pub fn reference(
    artifacts_dir: &std::path::Path,
    initial: &[f32],
    nu: f32,
    steps: u32,
) -> Result<Vec<f32>> {
    let rt = Runtime::new(artifacts_dir)?;
    let name = format!("heat1d_full_n{}", initial.len());
    rt.execute_f32_1(
        &name,
        &[Value::F32(initial.to_vec()), Value::scalar(nu), Value::scalar_i32(steps as i32)],
    )
}

/// Relative L2 error between two fields.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        num += (x - y).powi(2) as f64;
        den += (*y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Registry;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = Registry::default_dir();
        dir.join("manifest.txt").exists().then_some(dir)
    }

    fn initial(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let t = i as f32 / n as f32;
                (t * 12.9898).sin() * 0.5 + (t * 4.0 * std::f32::consts::PI).cos() * 0.3
            })
            .collect()
    }

    #[test]
    fn distributed_matches_full_reference() {
        let Some(dir) = artifacts() else { return };
        let cfg = Heat1dConfig {
            n_per_worker: 256,
            workers: 8,
            b: 4,
            steps: 16,
            nu: 0.2,
            artifacts_dir: dir.clone(),
        };
        let init = initial(cfg.total_points());
        let (got, stats) = run(&cfg, &init).unwrap();
        let want = reference(&dir, &init, 0.2, 16).unwrap();
        let err = rel_l2(&got, &want);
        assert!(err < 1e-4, "rel l2 {err}");
        assert_eq!(stats.supersteps, 4);
        // 8 workers, 14 inner edges exchanged per superstep.
        assert_eq!(stats.messages, 4 * 14);
    }

    #[test]
    fn blocking_factor_does_not_change_answer() {
        let Some(dir) = artifacts() else { return };
        let init = initial(2048);
        let mut results = Vec::new();
        for b in [1u32, 2, 4, 8] {
            let cfg = Heat1dConfig {
                n_per_worker: 256,
                workers: 8,
                b,
                steps: 8,
                nu: 0.15,
                artifacts_dir: dir.clone(),
            };
            let (got, _) = run(&cfg, &init).unwrap();
            results.push(got);
        }
        for r in &results[1..] {
            let err = rel_l2(r, &results[0]);
            assert!(err < 1e-4, "b-variants disagree: {err}");
        }
    }

    #[test]
    fn message_count_scales_inversely_with_b() {
        let Some(dir) = artifacts() else { return };
        let init = initial(512);
        let count = |b: u32| {
            let cfg = Heat1dConfig {
                n_per_worker: 256,
                workers: 2,
                b,
                steps: 8,
                nu: 0.1,
                artifacts_dir: dir.clone(),
            };
            run(&cfg, &init).unwrap().1.messages
        };
        assert_eq!(count(1), 16); // 8 supersteps × 2 messages
        assert_eq!(count(8), 2); // 1 superstep × 2 messages
    }

    #[test]
    fn config_validation() {
        let cfg = Heat1dConfig {
            n_per_worker: 256,
            workers: 2,
            b: 3,
            steps: 8,
            nu: 0.1,
            artifacts_dir: "artifacts".into(),
        };
        assert!(cfg.validate().is_err()); // 8 % 3 != 0
    }

    #[test]
    fn exchange_geometry_without_pjrt() {
        // The trait geometry is testable with no artifacts: two workers
        // exchange b-deep edges over a real fabric.
        use crate::coordinator::messages::fabric;
        let cfg = Heat1dConfig {
            n_per_worker: 8,
            workers: 2,
            b: 2,
            steps: 2,
            nu: 0.1,
            artifacts_dir: "artifacts".into(),
        };
        let x0: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let x1: Vec<f32> = (0..8).map(|i| 10.0 + i as f32).collect();
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let c = cfg.clone();
        let x1c = x1.clone();
        let h = std::thread::spawn(move || c.exchange(1, &mut e1, &x1c));
        let t0 = cfg.exchange(0, &mut e0, &x0);
        let t1 = h.join().unwrap();
        // Worker 0: left ghost by odd reflection, right ghost = x1[..2].
        assert_eq!(&t0[2..10], &x0[..]);
        assert_eq!(&t0[10..], &x1[..2]);
        assert_eq!(t0[1], 2.0 * x0[0] - x0[1]);
        // Worker 1: left ghost = x0[6..], right ghost odd-reflected.
        assert_eq!(&t1[..2], &x0[6..]);
        assert_eq!(t1[10], 2.0 * x1[7] - x1[6]);
    }
}
