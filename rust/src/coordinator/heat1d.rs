//! Real distributed execution of the 1-D heat equation: worker threads,
//! channel halo exchange, PJRT blocked-stencil compute.
//!
//! This is the paper's scheme running for real: per superstep of `b`
//! steps, each worker exchanges a `b`-deep ghost region with its
//! neighbours (one message per neighbour per superstep — the `(M/b)·α`
//! term) and then executes the **blocked Pallas kernel**
//! `heat1d_n{n}_b{b}`, which recomputes the trapezoid of intermediate
//! halo values inside VMEM — the redundant computation of §2 traded for
//! the factor-`b` message reduction.  `b = 1` is the naive baseline.
//!
//! Domain boundaries are odd-reflection ghosts (`ghost_j = 2·x_edge −
//! x_j`), which for the linear 3-point update reproduces zero-Dirichlet
//! semantics *exactly* for every block factor — so runs at different `b`
//! are comparable to each other and to the `heat1d_full_*` reference
//! artifact.

use super::messages::{fabric, Payload};
use crate::runtime::{Runtime, Value};
use anyhow::{bail, Context, Result};
use std::thread;

/// Configuration of one distributed 1-D heat run.
#[derive(Debug, Clone)]
pub struct Heat1dConfig {
    /// Points per worker (must match an AOT tile size: 256 or 2048).
    pub n_per_worker: usize,
    /// Worker (processor) count.
    pub workers: u32,
    /// Block factor (must match an AOT variant: 1, 2, 4, 8).
    pub b: u32,
    /// Total update steps (must be divisible by `b`).
    pub steps: u32,
    /// Diffusion coefficient.
    pub nu: f32,
    /// Artifact directory.
    pub artifacts_dir: std::path::PathBuf,
}

impl Heat1dConfig {
    pub fn artifact_name(&self) -> String {
        format!("heat1d_n{}_b{}", self.n_per_worker, self.b)
    }

    pub fn total_points(&self) -> usize {
        self.n_per_worker * self.workers as usize
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps % self.b != 0 {
            bail!("steps {} not divisible by b {}", self.steps, self.b);
        }
        if self.n_per_worker <= 2 * self.b as usize {
            bail!("tile {} too small for b {}", self.n_per_worker, self.b);
        }
        Ok(())
    }
}

/// Timing/traffic statistics of a run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub wall_secs: f64,
    /// Max across workers of fixed setup time (PJRT client creation +
    /// artifact compile) — pay-once cost a long-running service amortizes.
    pub setup_secs: f64,
    /// Max across workers of time spent in halo exchange (blocked).
    pub exchange_secs: f64,
    /// Max across workers of time spent in PJRT execute.
    pub compute_secs: f64,
    pub messages: u64,
    pub words: u64,
    pub supersteps: u32,
    /// Per-worker PJRT executions.
    pub executions: u64,
}

impl RunStats {
    /// Wall-clock excluding the pay-once setup — the steady-state figure
    /// comparable across block factors.
    pub fn steady_secs(&self) -> f64 {
        (self.wall_secs - self.setup_secs).max(0.0)
    }
}

/// Run the distributed heat equation; returns the final field
/// (concatenated worker tiles) and statistics.
pub fn run(cfg: &Heat1dConfig, initial: &[f32]) -> Result<(Vec<f32>, RunStats)> {
    cfg.validate()?;
    let n = cfg.n_per_worker;
    let p = cfg.workers as usize;
    if initial.len() != n * p {
        bail!("initial field has {} points, expected {}", initial.len(), n * p);
    }
    let b = cfg.b as usize;
    let supersteps = cfg.steps / cfg.b;
    let endpoints = fabric(cfg.workers);
    let t0 = std::time::Instant::now();

    let mut handles = Vec::with_capacity(p);
    for (w, mut ep) in endpoints.into_iter().enumerate() {
        let mut x: Vec<f32> = initial[w * n..(w + 1) * n].to_vec();
        let cfg = cfg.clone();
        handles.push(thread::spawn(move || -> Result<_> {
            // Each worker owns its own PJRT client/executable (the xla
            // client is Rc-based and cannot be shared across threads).
            let t_setup = std::time::Instant::now();
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            let art = cfg.artifact_name();
            rt.warm(&art)?;
            let setup_s = t_setup.elapsed().as_secs_f64();
            let (mut exch_s, mut comp_s) = (0.0f64, 0.0f64);
            let last = cfg.workers as usize - 1;

            let mut tile = vec![0.0f32; n + 2 * b];
            for _ss in 0..supersteps {
                let te = std::time::Instant::now();
                // Post edges to neighbours first (non-blocking sends)...
                if w > 0 {
                    ep.send(
                        (w - 1) as u32,
                        Payload { tasks: Vec::new(), values: x[..b].to_vec() },
                    );
                }
                if w < last {
                    ep.send(
                        (w + 1) as u32,
                        Payload { tasks: Vec::new(), values: x[n - b..].to_vec() },
                    );
                }
                // ...then fill the ghost regions.
                if w > 0 {
                    let got = ep.recv_from((w - 1) as u32);
                    tile[..b].copy_from_slice(&got.values);
                } else {
                    // Odd reflection about x[0]: ghost[k] = 2 x0 − x[b−k].
                    for k in 0..b {
                        tile[k] = 2.0 * x[0] - x[b - k];
                    }
                }
                if w < last {
                    let got = ep.recv_from((w + 1) as u32);
                    tile[n + b..].copy_from_slice(&got.values);
                } else {
                    // Odd reflection about x[n−1].
                    for k in 0..b {
                        tile[n + b + k] = 2.0 * x[n - 1] - x[n - 2 - k];
                    }
                }
                tile[b..n + b].copy_from_slice(&x);
                exch_s += te.elapsed().as_secs_f64();

                let tc = std::time::Instant::now();
                x = rt
                    .execute_f32_1(
                        &art,
                        &[Value::F32(tile.clone()), Value::scalar(cfg.nu)],
                    )
                    .with_context(|| format!("worker {w} superstep"))?;
                comp_s += tc.elapsed().as_secs_f64();
            }
            Ok((x, setup_s, exch_s, comp_s, ep.sent_messages, ep.sent_words, rt.metrics().executions))
        }));
    }

    let mut field = vec![0.0f32; n * p];
    let mut stats = RunStats { supersteps, ..Default::default() };
    for (w, h) in handles.into_iter().enumerate() {
        let (tile, setup, exch, comp, msgs, words, execs) =
            h.join().expect("worker thread panicked")?;
        field[w * n..(w + 1) * n].copy_from_slice(&tile);
        stats.setup_secs = stats.setup_secs.max(setup);
        stats.exchange_secs = stats.exchange_secs.max(exch);
        stats.compute_secs = stats.compute_secs.max(comp);
        stats.messages += msgs;
        stats.words += words;
        stats.executions += execs;
    }
    stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok((field, stats))
}

/// Sequential reference via the `heat1d_full_n{N}` artifact (Dirichlet).
pub fn reference(
    artifacts_dir: &std::path::Path,
    initial: &[f32],
    nu: f32,
    steps: u32,
) -> Result<Vec<f32>> {
    let rt = Runtime::new(artifacts_dir)?;
    let name = format!("heat1d_full_n{}", initial.len());
    rt.execute_f32_1(
        &name,
        &[Value::F32(initial.to_vec()), Value::scalar(nu), Value::scalar_i32(steps as i32)],
    )
}

/// Relative L2 error between two fields.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        num += (x - y).powi(2) as f64;
        den += (*y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Registry;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = Registry::default_dir();
        dir.join("manifest.txt").exists().then_some(dir)
    }

    fn initial(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let t = i as f32 / n as f32;
                (t * 12.9898).sin() * 0.5 + (t * 4.0 * std::f32::consts::PI).cos() * 0.3
            })
            .collect()
    }

    #[test]
    fn distributed_matches_full_reference() {
        let Some(dir) = artifacts() else { return };
        let cfg = Heat1dConfig {
            n_per_worker: 256,
            workers: 8,
            b: 4,
            steps: 16,
            nu: 0.2,
            artifacts_dir: dir.clone(),
        };
        let init = initial(cfg.total_points());
        let (got, stats) = run(&cfg, &init).unwrap();
        let want = reference(&dir, &init, 0.2, 16).unwrap();
        let err = rel_l2(&got, &want);
        assert!(err < 1e-4, "rel l2 {err}");
        assert_eq!(stats.supersteps, 4);
        // 8 workers, 14 inner edges exchanged per superstep.
        assert_eq!(stats.messages, 4 * 14);
    }

    #[test]
    fn blocking_factor_does_not_change_answer() {
        let Some(dir) = artifacts() else { return };
        let init = initial(2048);
        let mut results = Vec::new();
        for b in [1u32, 2, 4, 8] {
            let cfg = Heat1dConfig {
                n_per_worker: 256,
                workers: 8,
                b,
                steps: 8,
                nu: 0.15,
                artifacts_dir: dir.clone(),
            };
            let (got, _) = run(&cfg, &init).unwrap();
            results.push(got);
        }
        for r in &results[1..] {
            let err = rel_l2(r, &results[0]);
            assert!(err < 1e-4, "b-variants disagree: {err}");
        }
    }

    #[test]
    fn message_count_scales_inversely_with_b() {
        let Some(dir) = artifacts() else { return };
        let init = initial(512);
        let count = |b: u32| {
            let cfg = Heat1dConfig {
                n_per_worker: 256,
                workers: 2,
                b,
                steps: 8,
                nu: 0.1,
                artifacts_dir: dir.clone(),
            };
            run(&cfg, &init).unwrap().1.messages
        };
        assert_eq!(count(1), 16); // 8 supersteps × 2 messages
        assert_eq!(count(8), 2); // 1 superstep × 2 messages
    }

    #[test]
    fn config_validation() {
        let cfg = Heat1dConfig {
            n_per_worker: 256,
            workers: 2,
            b: 3,
            steps: 8,
            nu: 0.1,
            artifacts_dir: "artifacts".into(),
        };
        assert!(cfg.validate().is_err()); // 8 % 3 != 0
    }
}
