//! The one tiled leader/worker engine behind every real PJRT run.
//!
//! [`run_tiled`] owns all of the distributed plumbing the per-problem
//! engines used to duplicate: fabric construction, one OS thread plus one
//! thread-local [`Runtime`] per worker, the superstep loop (timed ghost
//! exchange, then the blocked kernel dispatch), and the statistics
//! aggregation.  A problem plugs in through [`TiledWorkload`], which is
//! pure *geometry*: how to slice the global field into tiles, what to
//! exchange with which neighbour each superstep, and which AOT artifact
//! updates a tile.
//!
//! [`super::heat1d`] and [`super::heat2d`] are now thin geometry adapters
//! over this engine — adding a new tiled problem means implementing the
//! trait, not re-writing the leader/worker loop.

use super::messages::{fabric, Endpoint};
use crate::runtime::{Runtime, Value};
use anyhow::{bail, Context, Result};
use std::thread;

/// Timing/traffic statistics of one distributed tiled run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub wall_secs: f64,
    /// Max across workers of fixed setup time (PJRT client creation +
    /// artifact compile) — pay-once cost a long-running service amortizes.
    pub setup_secs: f64,
    /// Max across workers of time spent in halo exchange (blocked).
    pub exchange_secs: f64,
    /// Max across workers of time spent in PJRT execute.
    pub compute_secs: f64,
    pub messages: u64,
    pub words: u64,
    pub supersteps: u32,
    /// Per-worker PJRT executions.
    pub executions: u64,
}

impl RunStats {
    /// Wall-clock excluding the pay-once setup — the steady-state figure
    /// comparable across block factors.
    pub fn steady_secs(&self) -> f64 {
        (self.wall_secs - self.setup_secs).max(0.0)
    }
}

/// The geometry of one tiled distributed problem: everything the generic
/// engine cannot know.  Implementations are plain config structs; the
/// engine clones one into every worker thread.
pub trait TiledWorkload: Clone + Send + 'static {
    /// Worker (processor) count.
    fn workers(&self) -> u32;

    /// Supersteps to run (total steps / block factor).
    fn supersteps(&self) -> u32;

    /// Name of the AOT artifact that advances one tile by one superstep.
    fn artifact(&self) -> String;

    /// Directory holding the artifacts.
    fn artifacts_dir(&self) -> &std::path::Path;

    /// Owned values per worker tile (the global field has
    /// `workers() * owned_len()` values).
    fn owned_len(&self) -> usize;

    /// Extract worker `w`'s owned tile from the global field.
    fn extract(&self, w: usize, global: &[f32]) -> Vec<f32>;

    /// Place worker `w`'s owned tile back into the global field.
    fn place(&self, w: usize, tile: &[f32], global: &mut [f32]);

    /// One superstep's ghost exchange for worker `w`: post the sends,
    /// satisfy the receives on `ep`, and return the extended tile the
    /// kernel consumes.  Domain-boundary ghosts (reflection, periodicity)
    /// are the implementation's business.
    fn exchange(&self, w: usize, ep: &mut Endpoint, x: &[f32]) -> Vec<f32>;

    /// Kernel arguments following the extended tile (e.g. the diffusion
    /// coefficient).
    fn kernel_args(&self) -> Vec<Value>;
}

/// Run a tiled workload end to end: scatter `initial` into tiles, loop
/// `supersteps × (exchange; kernel)` on one thread per worker, gather the
/// final field.  Returns the field in the workload's global layout plus
/// aggregated statistics.
pub fn run_tiled<T: TiledWorkload>(t: &T, initial: &[f32]) -> Result<(Vec<f32>, RunStats)> {
    let p = t.workers() as usize;
    let n = t.owned_len();
    if initial.len() != n * p {
        bail!("initial field has {} values, expected {}", initial.len(), n * p);
    }
    let supersteps = t.supersteps();
    let endpoints = fabric(t.workers());
    let t0 = std::time::Instant::now();

    let mut handles = Vec::with_capacity(p);
    for (w, mut ep) in endpoints.into_iter().enumerate() {
        let mut x = t.extract(w, initial);
        let tw = t.clone();
        handles.push(thread::spawn(move || -> Result<_> {
            // Each worker owns its own PJRT client/executable (the xla
            // client is Rc-based and cannot be shared across threads).
            let t_setup = std::time::Instant::now();
            let rt = Runtime::new(tw.artifacts_dir())?;
            let art = tw.artifact();
            rt.warm(&art)?;
            let setup_s = t_setup.elapsed().as_secs_f64();
            let (mut exch_s, mut comp_s) = (0.0f64, 0.0f64);

            for _ss in 0..supersteps {
                let te = std::time::Instant::now();
                let ext = tw.exchange(w, &mut ep, &x);
                exch_s += te.elapsed().as_secs_f64();

                let tc = std::time::Instant::now();
                let mut inputs = vec![Value::F32(ext)];
                inputs.extend(tw.kernel_args());
                x = rt
                    .execute_f32_1(&art, &inputs)
                    .with_context(|| format!("worker {w} superstep"))?;
                comp_s += tc.elapsed().as_secs_f64();
            }
            Ok((x, setup_s, exch_s, comp_s, ep.sent_messages, ep.sent_words, rt.metrics().executions))
        }));
    }

    let mut field = vec![0.0f32; n * p];
    let mut stats = RunStats { supersteps, ..Default::default() };
    for (w, h) in handles.into_iter().enumerate() {
        let (tile, setup, exch, comp, msgs, words, execs) =
            h.join().expect("worker thread panicked")?;
        t.place(w, &tile, &mut field);
        stats.setup_secs = stats.setup_secs.max(setup);
        stats.exchange_secs = stats.exchange_secs.max(exch);
        stats.compute_secs = stats.compute_secs.max(comp);
        stats.messages += msgs;
        stats.words += words;
        stats.executions += execs;
    }
    stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok((field, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_secs_clamps_at_zero() {
        let s = RunStats { wall_secs: 1.0, setup_secs: 2.0, ..Default::default() };
        assert_eq!(s.steady_secs(), 0.0);
        let s = RunStats { wall_secs: 3.0, setup_secs: 1.0, ..Default::default() };
        assert!((s.steady_secs() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn run_rejects_wrong_field_size() {
        // A minimal geometry; never reaches PJRT because validation fires
        // first.
        #[derive(Clone)]
        struct Tiny;
        impl TiledWorkload for Tiny {
            fn workers(&self) -> u32 {
                2
            }
            fn supersteps(&self) -> u32 {
                1
            }
            fn artifact(&self) -> String {
                "nope".into()
            }
            fn artifacts_dir(&self) -> &std::path::Path {
                std::path::Path::new("artifacts")
            }
            fn owned_len(&self) -> usize {
                4
            }
            fn extract(&self, w: usize, global: &[f32]) -> Vec<f32> {
                global[w * 4..(w + 1) * 4].to_vec()
            }
            fn place(&self, w: usize, tile: &[f32], global: &mut [f32]) {
                global[w * 4..(w + 1) * 4].copy_from_slice(tile);
            }
            fn exchange(&self, _w: usize, _ep: &mut Endpoint, x: &[f32]) -> Vec<f32> {
                x.to_vec()
            }
            fn kernel_args(&self) -> Vec<Value> {
                Vec::new()
            }
        }
        assert!(run_tiled(&Tiny, &[0.0; 3]).is_err());
    }
}
