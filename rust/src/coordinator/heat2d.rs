//! 2-D five-point heat equation geometry for the generic tiled engine
//! ([`super::tile`]): a `px × py` worker grid with 8-neighbour
//! ghost-frame exchange and PJRT blocked compute on a periodic domain.
//!
//! The 2-D case is where the blocked exchange gets interesting: for
//! `b > 1` the dependence cone reaches *diagonally*, so a worker needs
//! `b × b` corner blocks from its diagonal neighbours in addition to the
//! `b`-deep edge strips — 8 messages per superstep instead of the naive
//! scheme's 4 per step (corners are subsumed per-step at `b = 1` only if
//! exchanges are split into two phases; we send all 8 for uniformity).
//! The domain is periodic, which makes the trajectory independent of the
//! block factor — runs at different `b` must agree to rounding, and the
//! tests assert it against a pure-Rust reference.
//!
//! All leader/worker plumbing lives in [`super::tile::run_tiled`]; this
//! module only describes the 2-D exchange geometry.

use super::messages::{Endpoint, Payload};
use super::tile::{run_tiled, TiledWorkload};
use crate::runtime::Value;
use anyhow::{bail, Result};

/// Statistics of a 2-D run (same shape as 1-D).
pub use super::tile::RunStats;

/// Configuration of one distributed 2-D heat run.
#[derive(Debug, Clone)]
pub struct Heat2dConfig {
    /// Tile height/width per worker (must match an AOT variant: 64×64).
    pub tile_h: usize,
    pub tile_w: usize,
    /// Worker grid extents.
    pub px: u32,
    pub py: u32,
    /// Block factor (AOT variants: 1, 2, 4).
    pub b: u32,
    /// Total steps (divisible by `b`).
    pub steps: u32,
    pub nu: f32,
    pub artifacts_dir: std::path::PathBuf,
}

impl Heat2dConfig {
    pub fn artifact_name(&self) -> String {
        format!("heat2d_h{}w{}_b{}", self.tile_h, self.tile_w, self.b)
    }

    pub fn grid_h(&self) -> usize {
        self.tile_h * self.px as usize
    }

    pub fn grid_w(&self) -> usize {
        self.tile_w * self.py as usize
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps % self.b != 0 {
            bail!("steps {} not divisible by b {}", self.steps, self.b);
        }
        let b = self.b as usize;
        if self.tile_h <= 2 * b || self.tile_w <= 2 * b {
            bail!("tile {}x{} too small for b {}", self.tile_h, self.tile_w, self.b);
        }
        Ok(())
    }

    fn rank(&self, qx: i64, qy: i64) -> u32 {
        let px = self.px as i64;
        let py = self.py as i64;
        let qx = qx.rem_euclid(px);
        let qy = qy.rem_euclid(py);
        (qx * py + qy) as u32
    }

    /// Worker grid coordinates of rank `w`.
    fn coords(&self, w: usize) -> (i64, i64) {
        ((w as u32 / self.py) as i64, (w as u32 % self.py) as i64)
    }
}

impl TiledWorkload for Heat2dConfig {
    fn workers(&self) -> u32 {
        self.px * self.py
    }

    fn supersteps(&self) -> u32 {
        self.steps / self.b
    }

    fn artifact(&self) -> String {
        self.artifact_name()
    }

    fn artifacts_dir(&self) -> &std::path::Path {
        &self.artifacts_dir
    }

    fn owned_len(&self) -> usize {
        self.tile_h * self.tile_w
    }

    fn extract(&self, w: usize, global: &[f32]) -> Vec<f32> {
        let (th, tw, gw) = (self.tile_h, self.tile_w, self.grid_w());
        let (qx, qy) = self.coords(w);
        let mut x = vec![0.0f32; th * tw];
        for r in 0..th {
            let gr = qx as usize * th + r;
            let gc0 = qy as usize * tw;
            x[r * tw..(r + 1) * tw].copy_from_slice(&global[gr * gw + gc0..gr * gw + gc0 + tw]);
        }
        x
    }

    fn place(&self, w: usize, tile: &[f32], global: &mut [f32]) {
        let (th, tw, gw) = (self.tile_h, self.tile_w, self.grid_w());
        let (qx, qy) = self.coords(w);
        for r in 0..th {
            let gr = qx as usize * th + r;
            let gc0 = qy as usize * tw;
            global[gr * gw + gc0..gr * gw + gc0 + tw].copy_from_slice(&tile[r * tw..(r + 1) * tw]);
        }
    }

    fn exchange(&self, w: usize, ep: &mut Endpoint, x: &[f32]) -> Vec<f32> {
        let (th, tw) = (self.tile_h, self.tile_w);
        let b = self.b as usize;
        let (eh, ew) = (th + 2 * b, tw + 2 * b);
        let (qx, qy) = self.coords(w);

        // Neighbour ranks (periodic): (dr, dc) offsets.
        let dirs: [(i64, i64); 8] =
            [(-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1)];
        let nbr: Vec<u32> = dirs.iter().map(|&(dr, dc)| self.rank(qx + dr, qy + dc)).collect();

        // Sub-rectangle extraction on the owned tile.
        let extract = |x: &[f32], r0: usize, c0: usize, h: usize, wd: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(h * wd);
            for r in r0..r0 + h {
                out.extend_from_slice(&x[r * tw + c0..r * tw + c0 + wd]);
            }
            out
        };

        // What each neighbour needs is the part of *our* tile adjacent to
        // it: e.g. the north neighbour needs our top b rows, the
        // north-west corner our top-left b×b block.
        let blocks: [Vec<f32>; 8] = [
            extract(x, 0, 0, b, b),           // to NW: our top-left corner
            extract(x, 0, 0, b, tw),          // to N:  top strip
            extract(x, 0, tw - b, b, b),      // to NE
            extract(x, 0, 0, th, b),          // to W:  left strip
            extract(x, 0, tw - b, th, b),     // to E
            extract(x, th - b, 0, b, b),      // to SW
            extract(x, th - b, 0, b, tw),     // to S
            extract(x, th - b, tw - b, b, b), // to SE
        ];
        for (i, blk) in blocks.iter().enumerate() {
            ep.send(nbr[i], Payload { tasks: Vec::new(), values: blk.clone() });
        }
        // Receive the mirror blocks.  Our ghost on side `i` is the
        // neighbour-at-`dirs[i]`'s block sent toward direction `7 − i`
        // (`dirs[i] + dirs[7−i] = 0`).  On small periodic grids one rank
        // serves several of our directions (px = 2 makes N and S the same
        // rank), and `recv_from` consumes that rank's messages in *its*
        // send order — ascending sender-direction `i' = 7 − i`, i.e. our
        // `i` descending.
        let mut incoming: Vec<Vec<f32>> = vec![Vec::new(); 8];
        let mut by_rank: std::collections::BTreeMap<u32, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, &r) in nbr.iter().enumerate() {
            by_rank.entry(r).or_default().push(i);
        }
        for (rank, mut sides) in by_rank {
            sides.sort_unstable_by(|a, b| b.cmp(a)); // our i desc
            for i in sides {
                incoming[i] = ep.recv_from(rank).values;
            }
        }
        // Assemble the extended tile.
        let mut tile = vec![0.0f32; eh * ew];
        let place = |t: &mut [f32], r0: usize, c0: usize, h: usize, wd: usize, v: &[f32]| {
            for r in 0..h {
                t[(r0 + r) * ew + c0..(r0 + r) * ew + c0 + wd]
                    .copy_from_slice(&v[r * wd..(r + 1) * wd]);
            }
        };
        place(&mut tile, 0, 0, b, b, &incoming[0]); // NW corner ghost
        place(&mut tile, 0, b, b, tw, &incoming[1]); // N strip
        place(&mut tile, 0, b + tw, b, b, &incoming[2]); // NE
        place(&mut tile, b, 0, th, b, &incoming[3]); // W
        place(&mut tile, b, b + tw, th, b, &incoming[4]); // E
        place(&mut tile, b + th, 0, b, b, &incoming[5]); // SW
        place(&mut tile, b + th, b, b, tw, &incoming[6]); // S
        place(&mut tile, b + th, b + tw, b, b, &incoming[7]); // SE
        place(&mut tile, b, b, th, tw, x); // centre
        tile
    }

    fn kernel_args(&self) -> Vec<Value> {
        vec![Value::scalar(self.nu)]
    }
}

/// Run the distributed 2-D heat equation.  `initial` is the global
/// row-major `grid_h × grid_w` field; the result is in the same layout.
pub fn run(cfg: &Heat2dConfig, initial: &[f32]) -> Result<(Vec<f32>, RunStats)> {
    cfg.validate()?;
    let (gh, gw) = (cfg.grid_h(), cfg.grid_w());
    if initial.len() != gh * gw {
        bail!("initial field {} != {}x{}", initial.len(), gh, gw);
    }
    run_tiled(cfg, initial)
}

/// Pure-Rust periodic reference (f32 arithmetic mirroring the kernel).
pub fn reference_periodic(initial: &[f32], h: usize, w: usize, nu: f32, steps: u32) -> Vec<f32> {
    assert_eq!(initial.len(), h * w);
    let mut cur = initial.to_vec();
    let mut nxt = vec![0.0f32; h * w];
    for _ in 0..steps {
        for r in 0..h {
            let rn = (r + h - 1) % h;
            let rs = (r + 1) % h;
            for c in 0..w {
                let cw = (c + w - 1) % w;
                let ce = (c + 1) % w;
                let x = cur[r * w + c];
                nxt[r * w + c] = x
                    + nu * (cur[rn * w + c] + cur[rs * w + c] + cur[r * w + cw] + cur[r * w + ce]
                        - 4.0 * x);
            }
        }
        std::mem::swap(&mut cur, &mut nxt);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::heat1d::rel_l2;
    use crate::runtime::Registry;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = Registry::default_dir();
        dir.join("manifest.txt").exists().then_some(dir)
    }

    fn initial(h: usize, w: usize) -> Vec<f32> {
        (0..h * w)
            .map(|k| {
                let (r, c) = (k / w, k % w);
                ((r as f32 * 0.37).sin() + (c as f32 * 0.23).cos()) * 0.4
            })
            .collect()
    }

    #[test]
    fn distributed_matches_periodic_reference() {
        let Some(dir) = artifacts() else { return };
        let cfg = Heat2dConfig {
            tile_h: 64,
            tile_w: 64,
            px: 2,
            py: 2,
            b: 2,
            steps: 8,
            nu: 0.15,
            artifacts_dir: dir,
        };
        let init = initial(128, 128);
        let (got, stats) = run(&cfg, &init).unwrap();
        let want = reference_periodic(&init, 128, 128, 0.15, 8);
        let err = rel_l2(&got, &want);
        assert!(err < 1e-4, "rel l2 {err}");
        // 4 supersteps × 4 workers × 8 messages.
        assert_eq!(stats.messages, 4 * 4 * 8);
    }

    #[test]
    fn block_factor_invariant_on_periodic_domain() {
        let Some(dir) = artifacts() else { return };
        let init = initial(128, 128);
        let mut results = Vec::new();
        for b in [1u32, 2, 4] {
            let cfg = Heat2dConfig {
                tile_h: 64,
                tile_w: 64,
                px: 2,
                py: 2,
                b,
                steps: 4,
                nu: 0.1,
                artifacts_dir: dir.clone(),
            };
            results.push(run(&cfg, &init).unwrap().0);
        }
        for r in &results[1..] {
            let err = rel_l2(r, &results[0]);
            assert!(err < 1e-4, "b-variants disagree: {err}");
        }
    }

    #[test]
    fn rank_wraps_periodically() {
        let cfg = Heat2dConfig {
            tile_h: 64,
            tile_w: 64,
            px: 2,
            py: 2,
            b: 1,
            steps: 1,
            nu: 0.1,
            artifacts_dir: "artifacts".into(),
        };
        assert_eq!(cfg.rank(-1, 0), cfg.rank(1, 0));
        assert_eq!(cfg.rank(0, -1), cfg.rank(0, 1));
        assert_eq!(cfg.rank(2, 2), cfg.rank(0, 0));
    }

    #[test]
    fn extract_place_roundtrip() {
        let cfg = Heat2dConfig {
            tile_h: 4,
            tile_w: 3,
            px: 2,
            py: 2,
            b: 1,
            steps: 1,
            nu: 0.1,
            artifacts_dir: "artifacts".into(),
        };
        let global: Vec<f32> = (0..cfg.grid_h() * cfg.grid_w()).map(|i| i as f32).collect();
        let mut rebuilt = vec![0.0f32; global.len()];
        for w in 0..4 {
            let tile = cfg.extract(w, &global);
            assert_eq!(tile.len(), cfg.owned_len());
            cfg.place(w, &tile, &mut rebuilt);
        }
        assert_eq!(global, rebuilt);
    }
}
