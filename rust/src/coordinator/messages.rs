//! The message fabric: typed point-to-point channels between workers.
//!
//! Implements the "MPI" of the real execution: every worker owns one
//! receiver; sends are addressed envelopes.  Delivery is reliable and
//! per-pair FIFO (std `mpsc` guarantees), and the receive side reorders
//! across sources by (source, sequence) so a worker can block on the
//! specific message its plan expects regardless of arrival interleaving.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Payload of one message: raw f32 values (the outputs of the tasks the
/// schedule assigned to this message) plus an optional id list.
#[derive(Debug, Clone)]
pub struct Payload {
    /// Task ids (empty for value-only protocols like halo exchange).
    pub tasks: Vec<u32>,
    pub values: Vec<f32>,
}

/// An addressed message.
#[derive(Debug)]
pub struct Envelope {
    pub from: u32,
    /// Per-(from → to) sequence number, assigned by the sender.
    pub seq: u32,
    pub payload: Payload,
}

/// A worker's endpoint: senders to every peer plus its own receiver.
pub struct Endpoint {
    pub me: u32,
    senders: Vec<Sender<Envelope>>,
    receiver: Receiver<Envelope>,
    /// Next sequence number per destination.
    next_send: Vec<u32>,
    /// Next expected sequence per source.
    next_recv: Vec<u32>,
    /// Out-of-order stash.
    stash: HashMap<(u32, u32), Payload>,
    /// Counters.
    pub sent_messages: u64,
    pub sent_words: u64,
    pub recv_messages: u64,
}

/// Build a fully-connected fabric of `n` endpoints.
pub fn fabric(n: u32) -> Vec<Endpoint> {
    let mut senders = Vec::with_capacity(n as usize);
    let mut receivers = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(me, receiver)| Endpoint {
            me: me as u32,
            senders: senders.clone(),
            receiver,
            next_send: vec![0; n as usize],
            next_recv: vec![0; n as usize],
            stash: HashMap::new(),
            sent_messages: 0,
            sent_words: 0,
            recv_messages: 0,
        })
        .collect()
}

impl Endpoint {
    /// Post a message to `to` (non-blocking; unbounded channel).
    pub fn send(&mut self, to: u32, payload: Payload) {
        let seq = self.next_send[to as usize];
        self.next_send[to as usize] = seq + 1;
        self.sent_messages += 1;
        self.sent_words += payload.values.len() as u64;
        self.senders[to as usize]
            .send(Envelope { from: self.me, seq, payload })
            .expect("peer receiver dropped");
    }

    /// Block until the next in-order message from `from` arrives.
    pub fn recv_from(&mut self, from: u32) -> Payload {
        let want = self.next_recv[from as usize];
        self.next_recv[from as usize] = want + 1;
        self.recv_messages += 1;
        if let Some(p) = self.stash.remove(&(from, want)) {
            return p;
        }
        loop {
            let env = self.receiver.recv().expect("fabric closed while waiting");
            if env.from == from && env.seq == want {
                return env.payload;
            }
            self.stash.insert((env.from, env.seq), env.payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn pairwise_fifo() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            e1.send(0, Payload { tasks: vec![1], values: vec![1.0] });
            e1.send(0, Payload { tasks: vec![2], values: vec![2.0] });
        });
        let a = e0.recv_from(1);
        let b = e0.recv_from(1);
        assert_eq!(a.values, vec![1.0]);
        assert_eq!(b.values, vec![2.0]);
        h.join().unwrap();
    }

    #[test]
    fn reorders_across_sources() {
        let mut eps = fabric(3);
        let mut e2 = eps.pop().unwrap();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h1 = thread::spawn(move || {
            e1.send(0, Payload { tasks: vec![], values: vec![1.0] });
        });
        let h2 = thread::spawn(move || {
            e2.send(0, Payload { tasks: vec![], values: vec![2.0] });
        });
        // Receive in the opposite order of whatever arrived first.
        let from2 = e0.recv_from(2);
        let from1 = e0.recv_from(1);
        assert_eq!(from2.values, vec![2.0]);
        assert_eq!(from1.values, vec![1.0]);
        h1.join().unwrap();
        h2.join().unwrap();
        assert_eq!(e0.recv_messages, 2);
    }

    #[test]
    fn self_send_allowed() {
        let mut eps = fabric(1);
        let mut e0 = eps.pop().unwrap();
        e0.send(0, Payload { tasks: vec![7], values: vec![7.0] });
        assert_eq!(e0.recv_from(0).tasks, vec![7]);
    }

    #[test]
    fn counters_track_traffic() {
        let mut eps = fabric(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, Payload { tasks: vec![], values: vec![0.0; 10] });
        assert_eq!(e0.sent_messages, 1);
        assert_eq!(e0.sent_words, 10);
        assert_eq!(e1.recv_from(0).values.len(), 10);
    }
}
