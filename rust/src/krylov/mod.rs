//! Krylov solvers — the paper's motivating application (§1).
//!
//! The paper motivates latency tolerance with iterative methods: repeated
//! sparse matvecs (the halo exchange the transformation blocks) plus
//! inner products (the collectives the s-step/pipelined reformulations
//! combine or overlap — refs [1, 2, 9, 13] in the paper).  This module
//! provides:
//!
//! * [`cg_reference`] — sequential CG in f64 (the numerical oracle);
//! * [`distributed`] — real leader/worker CG over the channel fabric with
//!   all vector compute in PJRT artifacts (classic and pipelined message
//!   schedules);
//! * [`cg_program`] — CG iterations as an IMP data-parallel program, so
//!   the §3 transformation can be applied to a graph *with collectives*;
//! * [`latency_model`] — the per-iteration α-cost model comparing classic
//!   vs. pipelined CG on `p` nodes.

pub mod distributed;
pub mod powers;

use crate::imp::{Distribution, Program, Signature};
use crate::stencil::CsrMatrix;

/// Sequential CG on a CSR matrix, f64 arithmetic; returns
/// `(x, iterations, final residual norm)`.
pub fn cg_reference(a: &CsrMatrix, rhs: &[f64], tol: f64, maxit: usize) -> (Vec<f64>, usize, f64) {
    let n = a.n;
    assert_eq!(rhs.len(), n);
    let spmv = |x: &[f64]| -> Vec<f64> {
        (0..n)
            .map(|i| {
                a.row_cols(i)
                    .iter()
                    .zip(a.row_vals(i))
                    .map(|(&c, &v)| v as f64 * x[c as usize])
                    .sum()
            })
            .collect()
    };
    let dot = |u: &[f64], v: &[f64]| u.iter().zip(v).map(|(a, b)| a * b).sum::<f64>();

    let mut x = vec![0.0; n];
    let mut r = rhs.to_vec();
    let mut p = r.clone();
    let mut rho = dot(&r, &r);
    let tol2 = tol * tol * rho.max(1e-300);
    for it in 0..maxit {
        if rho <= tol2 {
            return (x, it, rho.sqrt());
        }
        let ap = spmv(&p);
        let alpha = rho / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rho_new = dot(&r, &r);
        let beta = rho_new / rho;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rho = rho_new;
    }
    (x, maxit, rho.sqrt())
}

/// `iters` CG iterations as a data-parallel program over an `n`-point
/// domain on `p` processors.  Each iteration contributes three steps:
/// the matvec (the matrix's sparse signature), the inner-product
/// reduction (`AllToAll` — every output element depends on the whole
/// vector, the task-graph shape of an allreduce), and the vector update
/// (pointwise).  Running the §3 transformation on this graph shows what
/// the paper's framework does to collectives: `AllToAll` levels admit no
/// blocking across them, which is exactly why the s-step literature
/// reformulates CG — quantified in the `fig6_subset_sizes` bench.
pub fn cg_program(a: &CsrMatrix, p: u32, iters: u32) -> Program {
    cg_program_on(a, Distribution::block(a.n as u64, p), iters)
}

/// [`cg_program`] under an explicit row distribution — the entry point
/// the [`crate::partition`] layer's graph partitioners feed (the matvec
/// halo follows the partition; the `AllToAll` dot levels are
/// layout-indifferent by construction).
pub fn cg_program_on(a: &CsrMatrix, input: Distribution, iters: u32) -> Program {
    let mut prog = Program::new(input);
    for k in 0..iters {
        prog = prog
            .then(&format!("matvec[{k}]"), a.signature())
            .then(&format!("dot[{k}]"), Signature::AllToAll)
            .then(&format!("update[{k}]"), Signature::stencil_radius(0));
    }
    prog
}

/// Per-iteration latency model: how many α-latencies are *exposed* (not
/// overlapped) per CG iteration under each formulation, on `p` nodes with
/// tree allreduces of depth `⌈log₂ p⌉`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CgLatencyModel {
    pub p: u32,
    /// Message latency.
    pub alpha: f64,
    /// Local compute per iteration (matvec + vector ops), seconds.
    pub local_compute: f64,
}

impl CgLatencyModel {
    fn tree_depth(&self) -> f64 {
        (self.p as f64).log2().ceil().max(0.0)
    }

    /// Classic CG: halo exchange (1 α) + two separate allreduces, all on
    /// the critical path.
    pub fn classic_per_iter(&self) -> f64 {
        self.local_compute + self.alpha + 2.0 * self.tree_depth() * self.alpha
    }

    /// Pipelined CG (Gropp-style, paper ref [9]): the residual allreduce
    /// is launched with the fused update and overlaps the p-update and
    /// the next halo exchange; one allreduce remains exposed, and the
    /// halo exchange overlaps local interior compute.
    pub fn pipelined_per_iter(&self) -> f64 {
        let exposed_allreduce = self.tree_depth() * self.alpha;
        let halo = self.alpha.max(self.local_compute * 0.5);
        self.local_compute * 0.5 + halo + exposed_allreduce
    }

    /// s-step CG with block size `s` (paper refs [1, 4]): one combined
    /// allreduce per `s` iterations; the matrix-power halo grows to `s`
    /// points but stays one message.
    pub fn sstep_per_iter(&self, s: u32) -> f64 {
        assert!(s >= 1);
        let per_block = self.local_compute * s as f64
            + self.alpha                    // one (wider) halo exchange
            + self.tree_depth() * self.alpha; // one combined allreduce
        per_block / s as f64
    }

    /// Speedup of the pipelined variant over classic.
    pub fn pipelined_speedup(&self) -> f64 {
        self.classic_per_iter() / self.pipelined_per_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::{check_schedule, communication_avoiding_default, ScheduleStats};

    #[test]
    fn cg_reference_solves_laplace() {
        let n = 64;
        let a = CsrMatrix::laplace1d(n);
        let rhs: Vec<f64> = (0..n).map(|i| ((i * 7) % 13) as f64 / 13.0 - 0.5).collect();
        let (x, iters, res) = cg_reference(&a, &rhs, 1e-10, 10 * n);
        assert!(iters <= n + 5, "CG on SPD tridiagonal must converge in ≤ n iters: {iters}");
        assert!(res < 1e-8);
        // Verify A x = rhs.
        let ax: Vec<f64> = (0..n)
            .map(|i| {
                a.row_cols(i)
                    .iter()
                    .zip(a.row_vals(i))
                    .map(|(&c, &v)| v as f64 * x[c as usize])
                    .sum()
            })
            .collect();
        for (l, r) in ax.iter().zip(&rhs) {
            assert!((l - r).abs() < 1e-6);
        }
    }

    #[test]
    fn cg_reference_handles_exact_start() {
        let a = CsrMatrix::laplace1d(8);
        let (x, iters, _) = cg_reference(&a, &vec![0.0; 8], 1e-12, 100);
        assert_eq!(iters, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn cg_program_unrolls_and_transforms() {
        let a = CsrMatrix::laplace1d(24);
        let g = cg_program(&a, 3, 2).unroll();
        assert_eq!(g.num_levels(), 1 + 3 * 2);
        let s = communication_avoiding_default(&g);
        check_schedule(&g, &s).unwrap();
        // The AllToAll levels force communication: schedule must have
        // messages (no free lunch through collectives).
        assert!(s.total_messages() > 0);
    }

    #[test]
    fn alltoall_blocks_local_progress() {
        // After an AllToAll, nothing beyond it is locally computable:
        // L^(4) must not contain tasks above the first dot level.
        let a = CsrMatrix::laplace1d(16);
        let g = cg_program(&a, 2, 2).unroll();
        let s = communication_avoiding_default(&g);
        let stats = ScheduleStats::compute(&g, &s);
        for ps in &s.per_proc {
            for &t in &ps.l4 {
                assert!(
                    g.level(crate::graph::TaskId(t)) <= 2,
                    "t{t} beyond the first collective is in L4"
                );
            }
        }
        let _ = stats;
    }

    #[test]
    fn latency_model_orderings() {
        let m = CgLatencyModel { p: 64, alpha: 100.0, local_compute: 50.0 };
        assert!(m.pipelined_per_iter() < m.classic_per_iter());
        assert!(m.sstep_per_iter(8) < m.classic_per_iter());
        // Larger s amortizes more.
        assert!(m.sstep_per_iter(8) < m.sstep_per_iter(2));
        assert!(m.pipelined_speedup() > 1.0);
    }

    #[test]
    fn latency_model_single_node_no_gain() {
        let m = CgLatencyModel { p: 1, alpha: 100.0, local_compute: 50.0 };
        // No tree latency on one node; classic = compute + halo-α.
        assert!((m.classic_per_iter() - 150.0).abs() < 1e-9);
    }
}
