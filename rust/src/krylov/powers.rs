//! The communication-avoiding matrix-powers kernel (paper ref [4],
//! Demmel et al.): compute the Krylov block `[Ap, A²p, …, Aˢp]` with
//! **one** `s`-deep halo exchange instead of `s` single exchanges.
//!
//! This is the paper's transformation specialized to the SpMV chain —
//! the trapezoid shrinks by the matrix stencil radius per power, exactly
//! like the heat supersteps — and the building block of s-step Krylov
//! methods.  Implemented over the channel fabric for the distributed 1-D
//! Laplacian (tridiag(-1, 2, -1), zero Dirichlet), with a per-step
//! exchanging baseline for comparison and verification.

use crate::coordinator::messages::{fabric, Payload};
use anyhow::{bail, Result};
use std::thread;

/// One local tridiagonal application: y_i = 2x_i − x_{i−1} − x_{i+1} over
/// the interior of `x` (result two shorter).
fn local_matvec(x: &[f32]) -> Vec<f32> {
    x.windows(3).map(|w| 2.0 * w[1] - w[0] - w[2]).collect()
}

/// Result of one distributed matrix-powers run.
#[derive(Debug, Clone)]
pub struct PowersResult {
    /// `powers[k]` = global `A^{k+1} p`, concatenated across workers.
    pub powers: Vec<Vec<f32>>,
    pub messages: u64,
    pub words: u64,
    pub wall_secs: f64,
}

/// Compute `[A p, …, A^s p]` for the global `N = shard·workers` Laplacian.
///
/// `blocked = true`: one `s`-wide halo exchange, then all powers locally
/// on the shrinking extended shard (the CA kernel).  `blocked = false`:
/// the baseline — a 1-wide exchange before every power.
pub fn matrix_powers(
    p_vec: &[f32],
    workers: u32,
    s: u32,
    blocked: bool,
) -> Result<PowersResult> {
    let nw = workers as usize;
    if p_vec.len() % nw != 0 {
        bail!("vector length {} not divisible by {nw}", p_vec.len());
    }
    let shard = p_vec.len() / nw;
    if shard <= 2 * s as usize {
        bail!("shard {shard} too small for s={s}");
    }
    let endpoints = fabric(workers);
    let t0 = std::time::Instant::now();

    let mut handles = Vec::with_capacity(nw);
    for (w, mut ep) in endpoints.into_iter().enumerate() {
        let mine: Vec<f32> = p_vec[w * shard..(w + 1) * shard].to_vec();
        handles.push(thread::spawn(move || -> Result<_> {
            let last = nw - 1;
            let su = s as usize;
            let mut out: Vec<Vec<f32>> = Vec::with_capacity(su);

            // Halo exchange of width `width` around `v`.  Domain
            // boundaries use the **odd extension** (x₋₁ = 0, x₋₁₋ⱼ =
            // −xⱼ₋₁, and mirrored on the right): the infinite 3-point
            // operator preserves odd symmetry, so ghost position −1 stays
            // exactly 0 under every power — which is what makes the
            // blocked trapezoid reproduce the *Dirichlet matrix* powers
            // (a plain zero pad is only correct for the first power; the
            // evolved pad would contaminate power ≥ 2).
            let exchange = |ep: &mut crate::coordinator::messages::Endpoint,
                                v: &[f32],
                                width: usize|
             -> Vec<f32> {
                if w > 0 {
                    ep.send(
                        (w - 1) as u32,
                        Payload { tasks: Vec::new(), values: v[..width].to_vec() },
                    );
                }
                if w < last {
                    ep.send(
                        (w + 1) as u32,
                        Payload { tasks: Vec::new(), values: v[v.len() - width..].to_vec() },
                    );
                }
                let left = if w > 0 {
                    ep.recv_from((w - 1) as u32).values
                } else {
                    // positions −width..−1: [−x[width−2], …, −x[0], 0]
                    let mut pad = vec![0.0f32; width];
                    for j in 1..width {
                        pad[width - 1 - j] = -v[j - 1];
                    }
                    pad
                };
                let right = if w < last {
                    ep.recv_from((w + 1) as u32).values
                } else {
                    // positions n..n+width−1: [0, −x[n−1], …, −x[n−width+1]]
                    let n = v.len();
                    let mut pad = vec![0.0f32; width];
                    for k in 1..width {
                        pad[k] = -v[n - k];
                    }
                    pad
                };
                let mut ext = Vec::with_capacity(v.len() + 2 * width);
                ext.extend_from_slice(&left);
                ext.extend_from_slice(v);
                ext.extend_from_slice(&right);
                ext
            };

            if blocked {
                // One s-wide exchange, then all powers on the shrinking
                // extended vector (the CA trapezoid).
                let mut ext = exchange(&mut ep, &mine, su);
                for _ in 0..su {
                    ext = local_matvec(&ext);
                    let margin = (ext.len() - shard) / 2;
                    out.push(ext[margin..margin + shard].to_vec());
                }
            } else {
                // Baseline: exchange one halo point before every power.
                let mut cur = mine.clone();
                for _ in 0..su {
                    let ext = exchange(&mut ep, &cur, 1);
                    cur = local_matvec(&ext);
                    out.push(cur.clone());
                }
            }
            Ok((out, ep.sent_messages, ep.sent_words))
        }));
    }

    let mut powers = vec![vec![0.0f32; p_vec.len()]; s as usize];
    let (mut messages, mut words) = (0u64, 0u64);
    for (w, h) in handles.into_iter().enumerate() {
        let (shards, m, wd) = h.join().expect("worker panicked")?;
        for (k, sh) in shards.into_iter().enumerate() {
            powers[k][w * shard..(w + 1) * shard].copy_from_slice(&sh);
        }
        messages += m;
        words += wd;
    }
    Ok(PowersResult { powers, messages, words, wall_secs: t0.elapsed().as_secs_f64() })
}

/// Sequential reference: s applications of the global Laplacian.
pub fn reference_powers(p_vec: &[f32], s: u32) -> Vec<Vec<f32>> {
    let n = p_vec.len();
    let mut out = Vec::with_capacity(s as usize);
    let mut cur = p_vec.to_vec();
    for _ in 0..s {
        let mut ext = vec![0.0f32; n + 2];
        ext[1..=n].copy_from_slice(&cur);
        cur = local_matvec(&ext);
        out.push(cur.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecf(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 17 + 3) % 23) as f32 / 23.0 - 0.5).collect()
    }

    #[test]
    fn blocked_matches_reference() {
        let v = vecf(64);
        let r = matrix_powers(&v, 4, 4, true).unwrap();
        let want = reference_powers(&v, 4);
        for (k, (got, w)) in r.powers.iter().zip(&want).enumerate() {
            for (a, b) in got.iter().zip(w) {
                assert!((a - b).abs() < 1e-4, "power {k}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn baseline_matches_reference() {
        let v = vecf(64);
        let r = matrix_powers(&v, 4, 3, false).unwrap();
        let want = reference_powers(&v, 3);
        for (got, w) in r.powers.iter().zip(&want) {
            for (a, b) in got.iter().zip(w) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn blocked_sends_s_times_fewer_messages() {
        let v = vecf(128);
        let blocked = matrix_powers(&v, 4, 4, true).unwrap();
        let baseline = matrix_powers(&v, 4, 4, false).unwrap();
        assert_eq!(baseline.messages, 4 * blocked.messages);
        // Same words in this 1-D case: s × width-1 vs 1 × width-s.
        assert_eq!(baseline.words, blocked.words);
    }

    #[test]
    fn single_worker_no_messages() {
        let v = vecf(32);
        let r = matrix_powers(&v, 1, 3, true).unwrap();
        assert_eq!(r.messages, 0);
        let want = reference_powers(&v, 3);
        for (got, w) in r.powers.iter().zip(&want) {
            for (a, b) in got.iter().zip(w) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn shard_too_small_rejected() {
        let v = vecf(16);
        assert!(matrix_powers(&v, 4, 2, true).is_err()); // shard 4 ≤ 2s
    }
}
