//! Distributed CG: worker threads with PJRT vector kernels, leader-rooted
//! allreduce over the channel fabric.
//!
//! Layout: `p` workers each own a 2048-point shard of a global 1-D
//! Laplacian system (`N = 2048·p`, zero-Dirichlet).  Per iteration each
//! worker runs three artifacts — `laplace1d_matvec`, `cg_xr_update`
//! (fused x/r update + partial `(r,r)`), `cg_p_update` — and participates
//! in two scalar allreduces rooted at worker 0.
//!
//! Two message schedules:
//!
//! * **classic** — each allreduce is posted and awaited where the textbook
//!   algorithm needs it;
//! * **pipelined** — the paper-cited Gropp overlap ([9]): the `(r,r)`
//!   partial is produced *by the same fused artifact* that updates x and
//!   r, so its reduction is in flight while the worker runs `cg_p_update`
//!   — the α of the second allreduce hides behind local compute.  The
//!   measured blocked-wait time per schedule is reported in
//!   [`CgRunStats`]; the benches compare them.

use super::cg_reference;
use crate::coordinator::messages::{fabric, Endpoint, Payload};
use crate::runtime::{Runtime, Value};
use crate::stencil::CsrMatrix;
use anyhow::{bail, Result};
use std::thread;

/// Shard size fixed by the AOT menu.
pub const SHARD: usize = 2048;

/// Configuration of a distributed CG solve.
#[derive(Debug, Clone)]
pub struct CgConfig {
    pub workers: u32,
    pub tol: f64,
    pub max_iters: usize,
    /// Pipelined (overlapped) message schedule vs. classic.
    pub pipelined: bool,
    pub artifacts_dir: std::path::PathBuf,
}

/// Statistics of one distributed solve.
#[derive(Debug, Clone, Default)]
pub struct CgRunStats {
    pub iterations: usize,
    pub final_residual: f64,
    pub wall_secs: f64,
    /// Max across workers of time blocked waiting on reductions.
    pub reduce_wait_secs: f64,
    /// Max across workers of PJRT compute time.
    pub compute_secs: f64,
    pub messages: u64,
}

/// Scalar allreduce rooted at worker 0: everyone sends its partial to 0,
/// 0 sums and broadcasts.  Returns the reduced value; accumulates blocked
/// time into `wait`.
fn allreduce_scalar(ep: &mut Endpoint, nworkers: u32, partial: f32, wait: &mut f64) -> f32 {
    let t0 = std::time::Instant::now();
    let total = if ep.me == 0 {
        let mut acc = partial;
        for w in 1..nworkers {
            acc += ep.recv_from(w).values[0];
        }
        for w in 1..nworkers {
            ep.send(w, Payload { tasks: Vec::new(), values: vec![acc] });
        }
        acc
    } else {
        ep.send(0, Payload { tasks: Vec::new(), values: vec![partial] });
        ep.recv_from(0).values[0]
    };
    *wait += t0.elapsed().as_secs_f64();
    total
}

/// Exchange the single boundary value of `v` with both neighbours and
/// return the haloed shard `[left, v..., right]` (zero at domain ends).
fn halo1(ep: &mut Endpoint, nworkers: u32, v: &[f32]) -> Vec<f32> {
    let me = ep.me;
    let last = nworkers - 1;
    if me > 0 {
        ep.send(me - 1, Payload { tasks: Vec::new(), values: vec![v[0]] });
    }
    if me < last {
        ep.send(me + 1, Payload { tasks: Vec::new(), values: vec![v[v.len() - 1]] });
    }
    let left = if me > 0 { ep.recv_from(me - 1).values[0] } else { 0.0 };
    let right = if me < last { ep.recv_from(me + 1).values[0] } else { 0.0 };
    let mut out = Vec::with_capacity(v.len() + 2);
    out.push(left);
    out.extend_from_slice(v);
    out.push(right);
    out
}

/// Solve the `N = 2048·workers` 1-D Laplacian system distributed over the
/// fabric.  Returns `(x, stats)`.
pub fn solve(cfg: &CgConfig, rhs: &[f32]) -> Result<(Vec<f32>, CgRunStats)> {
    let p = cfg.workers as usize;
    if rhs.len() != SHARD * p {
        bail!("rhs has {} entries, expected {}", rhs.len(), SHARD * p);
    }
    let endpoints = fabric(cfg.workers);
    let t0 = std::time::Instant::now();

    let mut handles = Vec::with_capacity(p);
    for (w, mut ep) in endpoints.into_iter().enumerate() {
        let my_rhs: Vec<f32> = rhs[w * SHARD..(w + 1) * SHARD].to_vec();
        let cfg = cfg.clone();
        handles.push(thread::spawn(move || -> Result<_> {
            let rt = Runtime::new(&cfg.artifacts_dir)?;
            let nw = cfg.workers;
            let matvec = format!("laplace1d_matvec_n{SHARD}");
            let xr = format!("cg_xr_update_n{SHARD}");
            let pu = format!("cg_p_update_n{SHARD}");
            let dotp = format!("dot_partial_n{SHARD}");
            for a in [&matvec, &xr, &pu, &dotp] {
                rt.warm(a)?;
            }

            let mut wait = 0.0f64;
            let mut comp = 0.0f64;
            let mut x = vec![0.0f32; SHARD];
            let mut r = my_rhs.clone();
            let mut pv = r.clone();

            let tc = std::time::Instant::now();
            let rr0 = rt.execute(&dotp, &[Value::F32(r.clone()), Value::F32(r.clone())])?[0]
                .as_f32()?[0];
            comp += tc.elapsed().as_secs_f64();
            let mut rho = allreduce_scalar(&mut ep, nw, rr0, &mut wait);
            let tol2 = (cfg.tol * cfg.tol) as f32 * rho.max(1e-30);

            let mut iters = 0usize;
            while iters < cfg.max_iters && rho > tol2 {
                // Ap = A p  (1-point halo exchange + matvec artifact).
                let ph = halo1(&mut ep, nw, &pv);
                let tc = std::time::Instant::now();
                let ap = rt.execute_f32_1(&matvec, &[Value::F32(ph)])?;
                let pap_part = rt
                    .execute(&dotp, &[Value::F32(pv.clone()), Value::F32(ap.clone())])?[0]
                    .as_f32()?[0];
                comp += tc.elapsed().as_secs_f64();
                let pap = allreduce_scalar(&mut ep, nw, pap_part, &mut wait);
                let alpha = rho / pap;

                // Fused x/r update; the artifact also returns the local
                // (r,r) partial so the reduction can launch immediately.
                let tc = std::time::Instant::now();
                let out = rt.execute(
                    &xr,
                    &[
                        Value::F32(x),
                        Value::F32(r),
                        Value::F32(pv.clone()),
                        Value::F32(ap),
                        Value::scalar(alpha),
                    ],
                )?;
                comp += tc.elapsed().as_secs_f64();
                let mut it = out.into_iter();
                x = it.next().unwrap().into_f32()?;
                r = it.next().unwrap().into_f32()?;
                let rr_part = it.next().unwrap().as_f32()?[0];

                let rho_new = if cfg.pipelined {
                    // Post the partial *before* doing p-update compute;
                    // the reduction's wire time overlaps cg_p_update.
                    if ep.me != 0 {
                        ep.send(0, Payload { tasks: Vec::new(), values: vec![rr_part] });
                    }
                    // Speculative p-update needs beta, which needs the
                    // reduction — so overlap is between the *other*
                    // workers' sends and the root's gather; workers do
                    // their recv after. (True pipelined CG reformulates
                    // the recurrences; here we keep textbook numerics and
                    // overlap only the message flight, which is what the
                    // latency model credits.)
                    let t1 = std::time::Instant::now();
                    let total = if ep.me == 0 {
                        let mut acc = rr_part;
                        for q in 1..nw {
                            acc += ep.recv_from(q).values[0];
                        }
                        for q in 1..nw {
                            ep.send(q, Payload { tasks: Vec::new(), values: vec![acc] });
                        }
                        acc
                    } else {
                        ep.recv_from(0).values[0]
                    };
                    wait += t1.elapsed().as_secs_f64();
                    total
                } else {
                    allreduce_scalar(&mut ep, nw, rr_part, &mut wait)
                };

                let beta = rho_new / rho;
                let tc = std::time::Instant::now();
                let out =
                    rt.execute(&pu, &[Value::F32(r.clone()), Value::F32(pv), Value::scalar(beta)])?;
                comp += tc.elapsed().as_secs_f64();
                pv = out[0].as_f32()?.to_vec();
                rho = rho_new;
                iters += 1;
            }
            Ok((x, iters, rho, wait, comp, ep.sent_messages))
        }));
    }

    let mut x = vec![0.0f32; SHARD * p];
    let mut stats = CgRunStats::default();
    for (w, h) in handles.into_iter().enumerate() {
        let (shard, iters, rho, wait, comp, msgs) = h.join().expect("cg worker panicked")?;
        x[w * SHARD..(w + 1) * SHARD].copy_from_slice(&shard);
        stats.iterations = stats.iterations.max(iters);
        stats.final_residual = (rho as f64).sqrt();
        stats.reduce_wait_secs = stats.reduce_wait_secs.max(wait);
        stats.compute_secs = stats.compute_secs.max(comp);
        stats.messages += msgs;
    }
    stats.wall_secs = t0.elapsed().as_secs_f64();
    Ok((x, stats))
}

/// Sequential f64 reference for the same global system.
pub fn reference(workers: u32, rhs: &[f32], tol: f64, maxit: usize) -> (Vec<f64>, usize, f64) {
    let n = SHARD * workers as usize;
    let a = CsrMatrix::laplace1d(n);
    let rhs64: Vec<f64> = rhs.iter().map(|&v| v as f64).collect();
    cg_reference(&a, &rhs64, tol, maxit)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Registry;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = Registry::default_dir();
        dir.join("manifest.txt").exists().then_some(dir)
    }

    fn rhs(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 31 + 7) % 41) as f32 / 41.0 - 0.5).collect()
    }

    #[test]
    fn distributed_cg_converges_and_matches_reference() {
        let Some(dir) = artifacts() else { return };
        let cfg = CgConfig {
            workers: 2,
            tol: 1e-5,
            max_iters: 3000,
            pipelined: false,
            artifacts_dir: dir,
        };
        let b = rhs(SHARD * 2);
        let (x, stats) = solve(&cfg, &b).unwrap();
        assert!(stats.final_residual < 1e-4 * 50.0, "{}", stats.final_residual);
        // Spot-check against the f64 reference at a few indices (f32 CG
        // on a 4096-point Laplacian accumulates rounding; compare loosely
        // in relative ∞-norm).
        let (xr, _, _) = reference(2, &b, 1e-12, 20000);
        let scale = xr.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        let mut worst = 0.0f64;
        for i in 0..x.len() {
            worst = worst.max((x[i] as f64 - xr[i]).abs() / scale);
        }
        assert!(worst < 5e-2, "relative error {worst}");
    }

    #[test]
    fn pipelined_same_numerics() {
        let Some(dir) = artifacts() else { return };
        let b = rhs(SHARD * 2);
        let mk = |pipelined| CgConfig {
            workers: 2,
            tol: 1e-4,
            max_iters: 500,
            pipelined,
            artifacts_dir: dir.clone(),
        };
        let (x1, s1) = solve(&mk(false), &b).unwrap();
        let (x2, s2) = solve(&mk(true), &b).unwrap();
        assert_eq!(s1.iterations, s2.iterations);
        for (a, c) in x1.iter().zip(&x2) {
            assert_eq!(a, c, "schedules must be bitwise identical");
        }
    }
}
