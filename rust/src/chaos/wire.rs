//! [`JitterWire`] — fault injection as a network-model decorator.

use super::{FaultConfig, WireFault};
use crate::sim::NetworkModel;
use std::collections::HashMap;

/// A [`NetworkModel`] decorator adding a seeded, non-negative latency
/// draw to every delivered message.
///
/// Draws are addressed by `(seed, channel, message sequence number on
/// that channel)`, with the per-channel counters living in this
/// decorator — not by wall-clock or global call order.  The two engines
/// post each channel's messages in the identical program order (the
/// stateful-wire equivalence matrix pins exactly that), so the compiled
/// and interpreting engines observe the identical jitter stream and stay
/// bit-for-bit equivalent under perturbation.
///
/// Contract preservation:
/// * `channel_cost` returns `None` — the compiled engine must route
///   every message through `deliver` so the sequence counters advance
///   identically in both engines (the wire is stateful by nature now).
/// * `message_lower_bound` and `message_cost_split` delegate to the
///   inner wire: jitter is ≥ 0, so the inner bound stays sound, and
///   [`crate::explain::Blame`] keeps summing bit-exactly (the drawn
///   delay shows up as exposed latency).
pub struct JitterWire {
    inner: Box<dyn NetworkModel>,
    seed: u64,
    fault: WireFault,
    /// Messages delivered so far per `(from, to)` channel — the draw
    /// address, reset per run like any other wire state.
    seq: HashMap<(u32, u32), u64>,
}

impl JitterWire {
    /// Decorate `inner` with the scenario's wire fault.
    pub fn new(inner: Box<dyn NetworkModel>, fault: &FaultConfig) -> JitterWire {
        JitterWire { inner, seed: fault.seed, fault: fault.wire, seq: HashMap::new() }
    }

    /// Wrap only when the scenario actually perturbs the wire; the null
    /// scenario hands `inner` back untouched (keeping the compiled
    /// engine's static fast path available).
    pub fn wrap(inner: Box<dyn NetworkModel>, fault: &FaultConfig) -> Box<dyn NetworkModel> {
        if fault.wire.is_active() {
            Box::new(JitterWire::new(inner, fault))
        } else {
            inner
        }
    }
}

impl NetworkModel for JitterWire {
    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn deliver(&mut self, from: u32, to: u32, words: usize, post: f64) -> f64 {
        let base = self.inner.deliver(from, to, words, post);
        let n = self.seq.entry((from, to)).or_insert(0);
        let extra = self.fault.sample(self.seed, from, to, *n);
        *n += 1;
        base + extra
    }

    fn reset(&mut self) {
        self.seq.clear();
        self.inner.reset();
    }

    // Default `channel_cost` (None) is deliberate: see the type docs.

    fn message_lower_bound(&self, from: u32, to: u32, words: usize) -> f64 {
        self.inner.message_lower_bound(from, to, words)
    }

    fn message_cost_split(&self, from: u32, to: u32, words: usize) -> (f64, f64) {
        self.inner.message_cost_split(from, to, words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, NetworkKind};

    fn scenario(wire: WireFault) -> FaultConfig {
        FaultConfig { seed: 5, wire, ..FaultConfig::default() }
    }

    fn mach() -> Machine {
        Machine::new(4, 2, 10.0, 0.5, 1.0)
    }

    #[test]
    fn adds_nonnegative_jitter_and_replays_after_reset() {
        let fault = scenario(WireFault::Exponential { mean: 2.0 });
        let mut clean = NetworkKind::AlphaBeta.build(&mach());
        let mut jit = JitterWire::new(NetworkKind::AlphaBeta.build(&mach()), &fault);
        let mut first = Vec::new();
        let mut any_extra = false;
        for i in 0..16u32 {
            let (from, to, w) = (i % 4, (i + 1) % 4, 1 + i as usize % 3);
            let base = clean.deliver(from, to, w, 1.0);
            let got = jit.deliver(from, to, w, 1.0);
            assert!(got >= base, "jitter sped a message up: {got} < {base}");
            any_extra |= got > base;
            first.push(got);
        }
        assert!(any_extra, "exponential jitter never fired over 16 messages");
        // reset() must rewind the sequence counters: the second run is a
        // bit-identical replay (what EngineScratch reuse relies on).
        jit.reset();
        for (i, want) in first.iter().enumerate() {
            let i = i as u32;
            let (from, to, w) = (i % 4, (i + 1) % 4, 1 + i as usize % 3);
            assert_eq!(jit.deliver(from, to, w, 1.0), *want, "message {i} diverged after reset");
        }
    }

    #[test]
    fn channels_draw_independent_streams() {
        let fault = scenario(WireFault::Uniform { spread: 4.0 });
        let mut jit = JitterWire::new(NetworkKind::AlphaBeta.build(&mach()), &fault);
        let mut base = NetworkKind::AlphaBeta.build(&mach());
        // Same words, same post, same sequence position: the only thing
        // distinguishing the draws is the channel identity.
        let e01 = jit.deliver(0, 1, 2, 0.0) - base.deliver(0, 1, 2, 0.0);
        let e10 = jit.deliver(1, 0, 2, 0.0) - base.deliver(1, 0, 2, 0.0);
        let e23 = jit.deliver(2, 3, 2, 0.0) - base.deliver(2, 3, 2, 0.0);
        assert!(e01 != e10 && e01 != e23, "channels shared a jitter stream: {e01} {e10} {e23}");
    }

    #[test]
    fn wrap_is_identity_for_null_wire_and_forces_dyn_path_otherwise() {
        let fault = scenario(WireFault::None);
        let wrapped = JitterWire::wrap(NetworkKind::AlphaBeta.build(&mach()), &fault);
        // Null scenario keeps the static fast path resolvable.
        assert!(wrapped.channel_cost(0, 1).is_some());
        let fault = scenario(WireFault::Uniform { spread: 1.0 });
        let wrapped = JitterWire::wrap(NetworkKind::AlphaBeta.build(&mach()), &fault);
        assert!(wrapped.channel_cost(0, 1).is_none(), "jitter must disable the static path");
        assert_eq!(wrapped.label(), "alphabeta");
    }

    #[test]
    fn lower_bound_and_split_delegate_to_the_inner_wire() {
        let fault = scenario(WireFault::Pareto { scale: 2.0, shape: 1.5 });
        let m = mach();
        let jit = JitterWire::new(NetworkKind::AlphaBeta.build(&m), &fault);
        let inner = NetworkKind::AlphaBeta.build(&m);
        for w in [1usize, 7, 100] {
            assert_eq!(jit.message_lower_bound(0, 1, w), inner.message_lower_bound(0, 1, w));
            assert_eq!(jit.message_cost_split(0, 1, w), inner.message_cost_split(0, 1, w));
        }
        // And the bound stays sound under jitter (slowdown-only).
        let mut jit = jit;
        for i in 0..32u64 {
            let arr = jit.deliver(0, 1, 3, i as f64);
            assert!(arr >= i as f64 + jit.message_lower_bound(0, 1, 3));
        }
    }
}
