//! Deterministic fault injection — the robustness layer the paper never
//! tested.
//!
//! Every simulation in the repo runs on a perfectly uniform machine, yet
//! the paper's subject is latency *tolerance*.  This module perturbs the
//! machine instead of the plan: per-proc speed heterogeneity, seeded
//! compute jitter, probabilistic stragglers ([`PerturbedCost`], a
//! [`TaskCostModel`] decorator) and per-message latency drawn from seeded
//! distributions ([`JitterWire`], a
//! [`NetworkModel`](crate::sim::NetworkModel) decorator).  On top,
//! [`run_ensemble`] fans N-seed ensembles per (workload × strategy ×
//! wire × straggler intensity) through the sweep pool and reports tail
//! percentiles plus a *degradation ratio* (perturbed / clean makespan) —
//! the figure the paper never drew: do the §3 transforms degrade more
//! gracefully than naive execution when the machine misbehaves?
//!
//! Three invariants make the injection trustworthy rather than noisy:
//!
//! * **Determinism.**  Every draw is a pure function of
//!   `(seed, stream, entity)` through a splitmix64-style mixer — no RNG
//!   state threads through the engine, so the same seed reproduces the
//!   same perturbed makespan bit-for-bit on the compiled *and* the
//!   interpreting engine, across any worker-thread schedule.
//! * **Slowdown-only.**  Cost factors are ≥ 1 and wire jitter is ≥ 0, so
//!   the analytic critical-path lower bound computed on the *clean*
//!   input ([`crate::analysis::input_lower_bound`]) stays sound for
//!   every perturbed run — the ensemble checks it on every cell.
//! * **Blame still sums.**  [`JitterWire`] delegates
//!   `message_cost_split` to its inner wire, so
//!   [`crate::explain::Blame`] decompositions of perturbed runs still
//!   sum bit-exactly to the perturbed makespan (jitter surfaces as
//!   exposed latency, where it belongs).

use crate::sim::TaskCostModel;

mod cost;
mod ensemble;
mod wire;

pub use cost::PerturbedCost;
pub use ensemble::{
    degradation_gate, perturb_input, run_ensemble, to_json, ChaosCell, ChaosReport, EnsembleConfig,
};
pub use wire::JitterWire;

/// Domain-separation tags: each perturbation family draws from its own
/// stream so a proc's speed factor can never collide with a task's
/// jitter draw or a channel's latency draw (the "no accidental seed
/// reuse" the determinism matrix pins).
const STREAM_PROC: u64 = 0x9d39_247e_3377_6d41;
const STREAM_JITTER: u64 = 0x2af7_398005_aaa5c7 ^ 0x44db_5d57_6c8a_8df0;
const STREAM_STRAGGLER: u64 = 0x8f8f_47d1_56cf_5c4d;
const STREAM_WIRE: u64 = 0x61c8_8646_80b5_83eb;

/// SplitMix64 finalizer: a bijective avalanche mix, the entire RNG of
/// this module.  Statelessness is the point — every draw is addressable
/// by what it perturbs, never by when it is drawn.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Map a mixed hash to a uniform draw in `[0, 1)` (53 mantissa bits).
#[inline]
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// One addressable draw: `seed` × `stream` select the family, `a` and
/// `b` the entity (proc, task, channel, sequence number).
#[inline]
fn draw(seed: u64, stream: u64, a: u64, b: u64) -> u64 {
    mix64(mix64(mix64(seed ^ stream).wrapping_add(a)) ^ b)
}

/// The per-message latency distribution a [`JitterWire`] draws from.
/// Every variant is an *additive, non-negative* delay on top of the
/// inner wire's arrival, so `deliver ≥ post + inner lower bound` is
/// preserved by construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireFault {
    /// No wire perturbation (compute faults only).
    None,
    /// Uniform extra delay in `[0, spread)` γ-units.
    Uniform {
        /// Width of the uniform delay window (γ-units).
        spread: f64,
    },
    /// Exponential extra delay with the given mean (γ-units) — the
    /// classic memoryless OS-noise model.
    Exponential {
        /// Mean of the exponential delay (γ-units).
        mean: f64,
    },
    /// Pareto-ish heavy tail, shifted to start at 0:
    /// `scale · ((1-u)^(-1/shape) - 1)`.  Small `shape` ⇒ fatter tail;
    /// `shape > 1` keeps the mean finite.
    Pareto {
        /// Scale of the tail (γ-units).
        scale: f64,
        /// Tail exponent (must be > 0; > 1 for a finite mean).
        shape: f64,
    },
}

impl WireFault {
    /// Whether this fault actually perturbs anything.
    pub fn is_active(&self) -> bool {
        !matches!(self, WireFault::None)
    }

    /// Parse a CLI/config tag: `none`, `uniform:SPREAD`, `exp:MEAN`, or
    /// `pareto:SCALE,SHAPE`.
    pub fn parse(tag: &str) -> Result<WireFault, String> {
        let tag = tag.trim();
        if tag.is_empty() || tag == "none" {
            return Ok(WireFault::None);
        }
        let (kind, arg) = tag.split_once(':').unwrap_or((tag, ""));
        let num = |s: &str| -> Result<f64, String> {
            s.trim().parse::<f64>().map_err(|_| format!("bad wire-fault number {s:?} in {tag:?}"))
        };
        match kind {
            "uniform" => Ok(WireFault::Uniform { spread: num(arg)? }),
            "exp" | "exponential" => Ok(WireFault::Exponential { mean: num(arg)? }),
            "pareto" => {
                let (scale, shape) = arg
                    .split_once(',')
                    .ok_or_else(|| format!("pareto needs SCALE,SHAPE, got {tag:?}"))?;
                let shape = num(shape)?;
                if shape <= 0.0 {
                    return Err(format!("pareto shape must be > 0, got {shape}"));
                }
                Ok(WireFault::Pareto { scale: num(scale)?, shape })
            }
            _ => Err(format!(
                "unknown wire fault {tag:?} (expected none|uniform:S|exp:M|pareto:SC,SH)"
            )),
        }
    }

    /// Stable tag for cache keys and reports (round-trips via [`parse`](Self::parse)).
    pub fn key(&self) -> String {
        match self {
            WireFault::None => "none".to_string(),
            WireFault::Uniform { spread } => format!("uniform:{spread}"),
            WireFault::Exponential { mean } => format!("exp:{mean}"),
            WireFault::Pareto { scale, shape } => format!("pareto:{scale},{shape}"),
        }
    }

    /// The extra delay for message number `seq` on channel `(from, to)`
    /// under `seed`.  Pure in its arguments; always ≥ 0 and finite.
    pub fn sample(&self, seed: u64, from: u32, to: u32, seq: u64) -> f64 {
        if !self.is_active() {
            return 0.0;
        }
        let chan = ((from as u64) << 32) | to as u64;
        let u = unit(draw(seed, STREAM_WIRE, chan, seq));
        match *self {
            WireFault::None => 0.0,
            WireFault::Uniform { spread } => spread * u,
            // u ∈ [0,1) so 1-u ∈ (0,1]: ln ≤ 0, the draw is ≥ 0 and finite.
            WireFault::Exponential { mean } => -mean * (1.0 - u).ln(),
            WireFault::Pareto { scale, shape } => scale * ((1.0 - u).powf(-1.0 / shape) - 1.0),
        }
    }
}

/// A complete fault scenario: one seed plus the intensity of each
/// perturbation family.  `Default` is the null scenario (nothing
/// perturbed); every field is a pure intensity so configs compose by
/// struct update.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Root seed; every draw mixes it with a stream tag and an entity id.
    pub seed: u64,
    /// Per-proc speed spread: each proc slows by a fixed factor in
    /// `[1, 1 + hetero)` for the whole run (static heterogeneity).
    pub hetero: f64,
    /// Per-task compute jitter: each task slows by `[1, 1 + jitter)`
    /// (OS noise at task granularity).
    pub jitter: f64,
    /// Probability a task straggles.
    pub straggler_rate: f64,
    /// Multiplier a straggling task's cost is scaled by (≥ 1 enforced
    /// at draw time — stragglers only ever slow down).
    pub straggler_factor: f64,
    /// Per-message wire latency distribution.
    pub wire: WireFault,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            seed: 0,
            hetero: 0.0,
            jitter: 0.0,
            straggler_rate: 0.0,
            straggler_factor: 1.0,
            wire: WireFault::None,
        }
    }
}

impl FaultConfig {
    /// Same scenario under a different root seed (ensemble members).
    pub fn with_seed(&self, seed: u64) -> FaultConfig {
        FaultConfig { seed, ..self.clone() }
    }

    /// Whether any perturbation family is switched on.
    pub fn is_active(&self) -> bool {
        self.hetero > 0.0
            || self.jitter > 0.0
            || (self.straggler_rate > 0.0 && self.straggler_factor > 1.0)
            || self.wire.is_active()
    }

    /// Stable tag for tuning-cache keys: two pipelines tuned under
    /// different fault scenarios must never share a verdict
    /// ([`crate::tune::pipeline_tune_key`] appends this).
    pub fn key(&self) -> String {
        format!(
            "s{};het{};jit{};sr{};sf{};w{}",
            self.seed,
            self.hetero,
            self.jitter,
            self.straggler_rate,
            self.straggler_factor,
            self.wire.key()
        )
    }

    /// The compute slowdown factor for task `task` owned by `proc`:
    /// `hetero(proc) · jitter(task) · straggler(task)`, every term ≥ 1.
    /// Pure in `(self, proc, task)` — the compiled engine bakes it once
    /// per task, the interpreter re-evaluates it per run, and both see
    /// the identical number.
    pub fn compute_factor(&self, proc: u32, task: u32) -> f64 {
        let mut f = 1.0;
        if self.hetero > 0.0 {
            f *= 1.0 + self.hetero * unit(draw(self.seed, STREAM_PROC, proc as u64, 0));
        }
        if self.jitter > 0.0 {
            f *= 1.0 + self.jitter * unit(draw(self.seed, STREAM_JITTER, task as u64, 0));
        }
        if self.straggler_rate > 0.0
            && unit(draw(self.seed, STREAM_STRAGGLER, task as u64, 0)) < self.straggler_rate
        {
            f *= self.straggler_factor.max(1.0);
        }
        f
    }
}

/// Wrap `inner` in a [`PerturbedCost`] when the scenario perturbs
/// compute; hand back `inner` untouched otherwise (the null scenario
/// must not even change the cost model's `Debug` fingerprint).
pub fn perturb_cost(
    inner: std::sync::Arc<dyn TaskCostModel>,
    fault: &FaultConfig,
) -> std::sync::Arc<dyn TaskCostModel> {
    if fault.hetero > 0.0
        || fault.jitter > 0.0
        || (fault.straggler_rate > 0.0 && fault.straggler_factor > 1.0)
    {
        std::sync::Arc::new(PerturbedCost::new(inner, fault.clone()))
    } else {
        inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_deterministic_and_streams_are_separated() {
        assert_eq!(mix64(42), mix64(42));
        assert_ne!(mix64(42), mix64(43));
        // The same entity id in different streams draws different values.
        assert_ne!(draw(1, STREAM_PROC, 7, 0), draw(1, STREAM_JITTER, 7, 0));
        assert_ne!(draw(1, STREAM_JITTER, 7, 0), draw(1, STREAM_STRAGGLER, 7, 0));
        assert_ne!(draw(1, STREAM_STRAGGLER, 7, 0), draw(1, STREAM_WIRE, 7, 0));
    }

    #[test]
    fn unit_is_in_range() {
        for x in [0u64, 1, 42, u64::MAX, 0xdead_beef] {
            let u = unit(mix64(x));
            assert!((0.0..1.0).contains(&u), "{u}");
        }
    }

    #[test]
    fn wire_fault_parse_roundtrips() {
        for tag in ["none", "uniform:0.5", "exp:2", "pareto:1.5,2"] {
            let f = WireFault::parse(tag).unwrap();
            assert_eq!(WireFault::parse(&f.key()).unwrap(), f, "{tag}");
        }
        assert_eq!(WireFault::parse("").unwrap(), WireFault::None);
        assert!(WireFault::parse("gaussian:1").is_err());
        assert!(WireFault::parse("pareto:1").is_err());
        assert!(WireFault::parse("pareto:1,0").is_err());
        assert!(WireFault::parse("uniform:x").is_err());
    }

    #[test]
    fn samples_are_nonnegative_finite_and_seed_sensitive() {
        let faults = [
            WireFault::Uniform { spread: 3.0 },
            WireFault::Exponential { mean: 2.0 },
            WireFault::Pareto { scale: 1.0, shape: 1.5 },
        ];
        for f in faults {
            let mut distinct = false;
            for seq in 0..64u64 {
                let a = f.sample(1, 0, 1, seq);
                let b = f.sample(2, 0, 1, seq);
                assert!(a.is_finite() && a >= 0.0, "{f:?}: {a}");
                assert_eq!(a, f.sample(1, 0, 1, seq), "{f:?} must be pure");
                distinct |= a != b;
            }
            assert!(distinct, "{f:?}: two seeds drew identical streams");
        }
        assert_eq!(WireFault::None.sample(1, 0, 1, 0), 0.0);
    }

    #[test]
    fn compute_factor_is_pure_slowdown_only_and_entity_addressed() {
        let f = FaultConfig {
            seed: 7,
            hetero: 0.3,
            jitter: 0.2,
            straggler_rate: 0.5,
            straggler_factor: 4.0,
            ..FaultConfig::default()
        };
        for proc in 0..4u32 {
            for task in 0..32u32 {
                let x = f.compute_factor(proc, task);
                assert!(x >= 1.0, "slowdown-only violated: {x}");
                assert_eq!(x, f.compute_factor(proc, task), "must be pure");
            }
        }
        // Different procs draw different heterogeneity factors.
        let hetero_only =
            FaultConfig { seed: 7, hetero: 0.3, ..FaultConfig::default() };
        assert_ne!(hetero_only.compute_factor(0, 0), hetero_only.compute_factor(1, 0));
        // Hetero ignores the task id; jitter ignores the proc id.
        assert_eq!(hetero_only.compute_factor(0, 0), hetero_only.compute_factor(0, 9));
        let jitter_only = FaultConfig { seed: 7, jitter: 0.3, ..FaultConfig::default() };
        assert_eq!(jitter_only.compute_factor(0, 5), jitter_only.compute_factor(3, 5));
        assert_ne!(jitter_only.compute_factor(0, 5), jitter_only.compute_factor(0, 6));
    }

    #[test]
    fn null_config_is_inactive_and_identity() {
        let f = FaultConfig::default();
        assert!(!f.is_active());
        for (p, t) in [(0u32, 0u32), (3, 17)] {
            assert_eq!(f.compute_factor(p, t), 1.0);
        }
        // Rate without a factor > 1 perturbs nothing.
        let f = FaultConfig { straggler_rate: 0.9, ..FaultConfig::default() };
        assert!(!f.is_active());
    }

    #[test]
    fn key_distinguishes_scenarios() {
        let a = FaultConfig { seed: 1, straggler_rate: 0.2, ..FaultConfig::default() };
        assert_ne!(a.key(), a.with_seed(2).key());
        let b = FaultConfig { straggler_rate: 0.3, ..a.clone() };
        assert_ne!(a.key(), b.key());
        let c = FaultConfig { wire: WireFault::Exponential { mean: 2.0 }, ..a.clone() };
        assert_ne!(a.key(), c.key());
    }
}
