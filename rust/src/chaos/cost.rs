//! [`PerturbedCost`] — fault injection as a [`TaskCostModel`] decorator.

use super::FaultConfig;
use crate::graph::{TaskGraph, TaskId};
use crate::sim::TaskCostModel;
use std::sync::Arc;

/// A [`TaskCostModel`] decorator that scales the inner model's cost by
/// the scenario's compute factor for `(owner proc, task)` — static
/// per-proc heterogeneity × per-task jitter × probabilistic stragglers,
/// every term ≥ 1 (see [`FaultConfig::compute_factor`]).
///
/// The factor is a pure function of the config and the task's identity,
/// **not** of simulation time or evaluation order.  That purity is what
/// keeps the two engines equivalent: [`crate::sim::CompiledPlan`] bakes
/// the perturbed cost once per task at compile time while the
/// interpreting engine calls it during the run, and both observe the
/// identical bits.
#[derive(Debug, Clone)]
pub struct PerturbedCost {
    inner: Arc<dyn TaskCostModel>,
    fault: FaultConfig,
}

impl PerturbedCost {
    /// Decorate `inner` with the scenario's compute perturbations.
    pub fn new(inner: Arc<dyn TaskCostModel>, fault: FaultConfig) -> PerturbedCost {
        PerturbedCost { inner, fault }
    }

    /// The fault scenario this decorator applies.
    pub fn fault(&self) -> &FaultConfig {
        &self.fault
    }
}

impl TaskCostModel for PerturbedCost {
    fn task_cost(&self, g: &TaskGraph, t: TaskId) -> f64 {
        self.inner.task_cost(g, t) * self.fault.compute_factor(g.owner(t).0, t.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ScaledCost, UniformCost};
    use crate::stencil::heat1d_graph;

    fn scenario() -> FaultConfig {
        FaultConfig {
            seed: 11,
            hetero: 0.25,
            jitter: 0.1,
            straggler_rate: 0.3,
            straggler_factor: 5.0,
            ..FaultConfig::default()
        }
    }

    #[test]
    fn scales_the_inner_model_and_never_speeds_up() {
        let g = heat1d_graph(32, 4, 3);
        let clean: Arc<dyn TaskCostModel> = Arc::new(ScaledCost(2.0));
        let perturbed = PerturbedCost::new(Arc::clone(&clean), scenario());
        let mut straggled = 0;
        for t in g.tasks() {
            let base = clean.task_cost(&g, t);
            let x = perturbed.task_cost(&g, t);
            assert!(x >= base, "task {t:?} sped up: {x} < {base}");
            // hetero+jitter alone bound the factor below the straggler
            // multiplier, so anything past it must be a straggler.
            if x / base >= 5.0 {
                straggled += 1;
            }
        }
        assert!(straggled > 0, "rate 0.3 over {} tasks drew no straggler", g.len());
    }

    #[test]
    fn same_seed_same_costs_different_seed_different_costs() {
        let g = heat1d_graph(32, 4, 3);
        let a = PerturbedCost::new(Arc::new(UniformCost), scenario());
        let b = PerturbedCost::new(Arc::new(UniformCost), scenario());
        let c = PerturbedCost::new(Arc::new(UniformCost), scenario().with_seed(12));
        let mut diverged = false;
        for t in g.tasks() {
            assert_eq!(a.task_cost(&g, t), b.task_cost(&g, t));
            diverged |= a.task_cost(&g, t) != c.task_cost(&g, t);
        }
        assert!(diverged, "seed 12 reproduced seed 11's costs");
    }
}
