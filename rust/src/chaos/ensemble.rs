//! N-seed fault-injection ensembles over the sweep pool — the machinery
//! behind the `chaos` CLI subcommand and `BENCH_chaos.json`.
//!
//! For every (workload × strategy) input and wire model the ensemble
//! simulates one *clean* run, then `seeds` perturbed runs per straggler
//! intensity, and reports tail percentiles plus the degradation ratio
//! (perturbed / clean makespan).  Alongside the measurements it checks
//! the three invariants that make the numbers trustworthy:
//!
//! 1. **Determinism** — the seed-0 member of every group is re-simulated
//!    on the compiled engine *and* on the interpreting engine; all three
//!    makespans (and message/word counts) must agree bit-for-bit.
//! 2. **Blame closure** — the same member is run through the provenance
//!    engine and its [`Blame`] decomposition must sum bit-exactly to the
//!    perturbed makespan.
//! 3. **Bound soundness** — the analytic critical-path lower bound of
//!    the *clean* input ([`analysis::input_lower_bound`]) must stay ≤
//!    every perturbed makespan (faults only ever slow down).
//!
//! Failures are collected into [`ChaosReport::gate_failures`] rather
//! than thrown, so the caller can still emit the report JSON before
//! failing CI.

use super::{mix64, perturb_cost, FaultConfig, JitterWire};
use crate::analysis;
use crate::explain::{Blame, Observation};
use crate::sim::sweep::{self, SweepCell, SweepGrid, SweepInput};
use crate::sim::{try_simulate, EngineScratch, Machine, NetworkKind};
use std::sync::Arc;

/// Ensemble shape: which wires and straggler intensities to inject, how
/// many seeds per group, and the machine the runs share.
#[derive(Debug, Clone)]
pub struct EnsembleConfig {
    /// Wire models to run under.
    pub networks: Vec<NetworkKind>,
    /// Straggler rates (the intensity axis); each overrides
    /// `base.straggler_rate`.
    pub rates: Vec<f64>,
    /// Ensemble members per (input × rate); each gets its own root seed
    /// derived from `base.seed` by [`member_seed`].
    pub seeds: u32,
    /// Scenario template: heterogeneity / jitter / straggler magnitude /
    /// wire fault shared by every member.
    pub base: FaultConfig,
    /// Wire latency (γ-units).
    pub alpha: f64,
    /// Per-word wire cost (scaled by each input's words-per-value).
    pub beta: f64,
    /// Cost of one unit task.
    pub gamma: f64,
    /// Threads per simulated processor.
    pub threads: u32,
    /// Sweep worker threads (0 = one per core).
    pub jobs: usize,
    /// Straggler rate at/above which [`degradation_gate`] applies.
    pub gate_rate: f64,
}

/// One ensemble group: every seed of (workload × strategy × wire ×
/// rate), reduced to tail percentiles against the group's clean run.
#[derive(Debug, Clone)]
pub struct ChaosCell {
    /// Workload tag (shared with the input).
    pub workload: Arc<str>,
    /// Strategy label (shared with the input).
    pub strategy: Arc<str>,
    /// Wire-model tag.
    pub network: &'static str,
    /// Straggler rate injected into this group.
    pub rate: f64,
    /// Ensemble members.
    pub seeds: u32,
    /// Unperturbed makespan.
    pub clean: f64,
    /// Analytic critical-path bound of the clean input (`None` when the
    /// analyzer cannot price this plan).
    pub lower_bound: Option<f64>,
    /// Median perturbed makespan.
    pub p50: f64,
    /// 95th-percentile perturbed makespan.
    pub p95: f64,
    /// 99th-percentile perturbed makespan.
    pub p99: f64,
    /// Worst member.
    pub worst: f64,
    /// `p50 / clean`.
    pub ratio_p50: f64,
    /// `p99 / clean` — the tail degradation ratio the gate compares.
    pub ratio_p99: f64,
}

/// The ensemble's full outcome: measurement cells plus the invariant
/// bookkeeping (see the module docs).
#[derive(Debug, Clone, Default)]
pub struct ChaosReport {
    /// One entry per (input × wire × rate) group.
    pub cells: Vec<ChaosCell>,
    /// Simulations run (clean + perturbed + verification re-runs).
    pub sims: usize,
    /// Compiled-re-run + interpreted-engine equivalence checks passed.
    pub determinism_checks: usize,
    /// Blame decompositions verified bit-exact.
    pub blame_checks: usize,
    /// Perturbed cells that undercut the clean lower bound (must be 0).
    pub lb_violations: usize,
    /// Every violated invariant / gate, human-readable.  Empty = pass.
    pub gate_failures: Vec<String>,
    /// Wall-clock seconds for the whole ensemble.
    pub wall_secs: f64,
}

/// Root seed of ensemble member `s`: decorrelated from neighbouring
/// members by a golden-ratio stride through the mixer (member 0 is not
/// the template seed itself, so `seeds=1` still perturbs).
pub fn member_seed(base: u64, s: u32) -> u64 {
    mix64(base.wrapping_add(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(s as u64 + 1)))
}

/// Re-prepare `input` under `fault`: the cost model is wrapped in a
/// [`super::PerturbedCost`] (when compute is perturbed) and the plan is
/// recompiled so the compiled engine bakes the perturbed costs; graph,
/// plan, and labels stay shared.  The attached fault also makes every
/// sweep cell wrap its wire in a [`JitterWire`].
pub fn perturb_input(input: &SweepInput, fault: &FaultConfig) -> SweepInput {
    let cost = perturb_cost(Arc::clone(&input.cost), fault);
    let mut out = SweepInput::new(
        Arc::clone(&input.workload),
        Arc::clone(&input.strategy),
        Arc::clone(&input.graph),
        Arc::clone(&input.plan),
        cost,
        input.words_per_value,
        input.layout.clone(),
    );
    out.fault = Some(fault.clone());
    out
}

/// Nearest-rank percentile of an ascending-sorted slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The machine a sweep cell builds for `input` (β scaled by the input's
/// words-per-value) — verification re-runs must construct the identical
/// machine to reproduce the identical bits.
fn cell_machine(input: &SweepInput, cfg: &EnsembleConfig) -> Machine {
    let procs = input.plan.per_proc.len() as u32;
    Machine::new(
        procs,
        cfg.threads,
        cfg.alpha,
        cfg.beta * input.words_per_value as f64,
        cfg.gamma,
    )
}

/// Re-run one perturbed member on the compiled engine (fresh scratch),
/// on the interpreting engine, and through the provenance/blame stack;
/// append a failure string for anything that is not bit-identical.
fn verify_member(
    input: &SweepInput,
    cfg: &EnsembleConfig,
    kind: NetworkKind,
    rate: f64,
    want: &SweepCell,
    scratch: &mut EngineScratch,
    report: &mut ChaosReport,
) {
    let tag = format!("{}/{}/{}/rate={rate}", input.workload, input.strategy, kind.label());
    let fault = input.fault.clone().unwrap_or_default();
    let mach = cell_machine(input, cfg);

    // Compiled re-run with a fresh wire: same bits as the sweep cell.
    let mut net = JitterWire::wrap(kind.build_for(&mach, input.layout.as_ref()), &fault);
    match crate::sim::simulate_compiled(&input.compiled, &mach, net.as_mut(), scratch, false) {
        Ok(r) => {
            report.sims += 1;
            if r.total_time != want.makespan || r.messages != want.messages || r.words != want.words
            {
                report.gate_failures.push(format!(
                    "{tag}: compiled re-run diverged: {} vs {} ({} vs {} msgs)",
                    r.total_time, want.makespan, r.messages, want.messages
                ));
                return;
            }
        }
        Err(e) => {
            report.gate_failures.push(format!("{tag}: compiled re-run failed: {e}"));
            return;
        }
    }

    // Interpreting engine under the same perturbation: bit-for-bit.
    let mut net = JitterWire::wrap(kind.build_for(&mach, input.layout.as_ref()), &fault);
    match try_simulate(
        &input.graph,
        &input.plan,
        &mach,
        net.as_mut(),
        input.cost.as_ref(),
        false,
    ) {
        Ok(r) => {
            report.sims += 1;
            if r.total_time != want.makespan || r.messages != want.messages || r.words != want.words
            {
                report.gate_failures.push(format!(
                    "{tag}: interpreted engine diverged under perturbation: {} vs {}",
                    r.total_time, want.makespan
                ));
                return;
            }
            report.determinism_checks += 1;
        }
        Err(e) => {
            report.gate_failures.push(format!("{tag}: interpreted engine failed: {e}"));
            return;
        }
    }

    // Provenance + blame on the perturbed run: the decomposition must
    // still tile [0, makespan] bit-exactly (JitterWire keeps the inner
    // wire's message_cost_split, so jitter lands in exposed latency).
    let mut net = JitterWire::wrap(kind.build_for(&mach, input.layout.as_ref()), &fault);
    match Observation::observe(Arc::clone(&input.compiled), &mach, net.as_mut(), scratch) {
        Ok(obs) => {
            report.sims += 1;
            let blame = Blame::explain(&obs, net.as_ref());
            if blame.makespan != want.makespan {
                report.gate_failures.push(format!(
                    "{tag}: observed makespan diverged: {} vs {}",
                    blame.makespan, want.makespan
                ));
            } else if blame.plan.total() != blame.makespan {
                report.gate_failures.push(format!(
                    "{tag}: blame sum {} != perturbed makespan {}",
                    blame.plan.total(),
                    blame.makespan
                ));
            } else {
                report.blame_checks += 1;
            }
        }
        Err(e) => {
            report.gate_failures.push(format!("{tag}: observed run failed: {e}"));
        }
    }
}

/// The paper-extending claim `make chaos-smoke` gates on: at straggler
/// rates ≥ `gate_rate`, the best transformed strategy's p99 degradation
/// ratio must not exceed naive's on the heat workloads under the
/// `alphabeta` and `hier` wires.  (Naive re-synchronizes every level, so
/// each level pays its slowest proc — a sum of maxima; the transforms
/// synchronize every `b` levels, so stragglers average out within a
/// block — a max of sums, which is never larger.)  Returns one failure
/// string per violated group.
pub fn degradation_gate(cells: &[ChaosCell], gate_rate: f64) -> Vec<String> {
    let mut failures = Vec::new();
    let gated: Vec<&ChaosCell> = cells
        .iter()
        .filter(|c| {
            c.workload.starts_with("heat")
                && matches!(c.network, "alphabeta" | "hier")
                && c.rate >= gate_rate - 1e-12
        })
        .collect();
    let mut groups: Vec<(Arc<str>, &'static str, f64)> = Vec::new();
    for c in &gated {
        let key = (Arc::clone(&c.workload), c.network, c.rate);
        if !groups.contains(&key) {
            groups.push(key);
        }
    }
    for (workload, network, rate) in groups {
        let of = |pred: &dyn Fn(&str) -> bool| -> Option<f64> {
            gated
                .iter()
                .filter(|c| {
                    c.workload == workload && c.network == network && c.rate == rate && pred(&c.strategy)
                })
                .map(|c| c.ratio_p99)
                .fold(None, |m: Option<f64>, r| Some(m.map_or(r, |m| m.min(r))))
        };
        let (Some(naive), Some(transformed)) =
            (of(&|s| s == "naive"), of(&|s| s != "naive"))
        else {
            continue; // group lacks a naive baseline or a transform
        };
        if transformed > naive + 1e-9 {
            failures.push(format!(
                "{workload}/{network}/rate={rate}: best transformed p99 degradation {transformed:.4} \
                 exceeds naive's {naive:.4}"
            ));
        }
    }
    failures
}

/// Run the full ensemble over `inputs` (clean, unperturbed — typically
/// naive/overlap/CA per workload) and reduce it to a [`ChaosReport`].
pub fn run_ensemble(inputs: &[SweepInput], cfg: &EnsembleConfig) -> Result<ChaosReport, String> {
    if inputs.is_empty() || cfg.networks.is_empty() || cfg.rates.is_empty() || cfg.seeds == 0 {
        return Err("chaos ensemble needs ≥1 input, network, rate, and seed".to_string());
    }
    let t0 = std::time::Instant::now();
    let mut report = ChaosReport::default();
    let nn = cfg.networks.len();

    // Clean baselines: one grid of the unperturbed inputs × wires.
    let clean_grid = SweepGrid {
        inputs: inputs.to_vec(),
        networks: cfg.networks.clone(),
        alphas: vec![cfg.alpha],
        threads: vec![cfg.threads],
        beta: cfg.beta,
        gamma: cfg.gamma,
        jobs: cfg.jobs,
    };
    let clean_cells = sweep::run(&clean_grid)?;
    report.sims += clean_cells.len();

    // Analytic bounds on the clean inputs (the perturbed floor).
    let bounds: Vec<Vec<Option<f64>>> = inputs
        .iter()
        .map(|input| {
            let base = cell_machine(input, cfg);
            // input_lower_bound re-scales β by words_per_value itself.
            let raw = Machine::new(base.nprocs, cfg.threads, cfg.alpha, cfg.beta, cfg.gamma);
            cfg.networks.iter().map(|&k| analysis::input_lower_bound(input, &raw, k)).collect()
        })
        .collect();

    // Perturbed members: inputs-major, then rate, then seed, so the
    // sweep's grid order lets groups be sliced back out by index.
    let mut members = Vec::with_capacity(inputs.len() * cfg.rates.len() * cfg.seeds as usize);
    for input in inputs {
        for &rate in &cfg.rates {
            for s in 0..cfg.seeds {
                let fault = FaultConfig {
                    straggler_rate: rate,
                    ..cfg.base.with_seed(member_seed(cfg.base.seed, s))
                };
                members.push(perturb_input(input, &fault));
            }
        }
    }
    let perturbed_grid = SweepGrid {
        inputs: members,
        networks: cfg.networks.clone(),
        alphas: vec![cfg.alpha],
        threads: vec![cfg.threads],
        beta: cfg.beta,
        gamma: cfg.gamma,
        jobs: cfg.jobs,
    };
    let perturbed_cells = sweep::run(&perturbed_grid)?;
    report.sims += perturbed_cells.len();

    // Reduce each (input × rate × wire) group to a ChaosCell and verify
    // the seed-0 member's determinism + blame closure.
    let mut scratch = EngineScratch::new();
    for (o, input) in inputs.iter().enumerate() {
        for (ri, &rate) in cfg.rates.iter().enumerate() {
            for (ni, kind) in cfg.networks.iter().enumerate() {
                let clean = clean_cells[o * nn + ni].makespan;
                let lower_bound = bounds[o][ni];
                let mut makespans: Vec<f64> = (0..cfg.seeds as usize)
                    .map(|s| {
                        let member = (o * cfg.rates.len() + ri) * cfg.seeds as usize + s;
                        perturbed_cells[member * nn + ni].makespan
                    })
                    .collect();
                if let Some(lb) = lower_bound {
                    for (s, &m) in makespans.iter().enumerate() {
                        if m < lb - 1e-12 {
                            report.lb_violations += 1;
                            report.gate_failures.push(format!(
                                "{}/{}/{}/rate={rate}/seed#{s}: perturbed makespan {m} \
                                 undercuts the clean lower bound {lb}",
                                input.workload,
                                input.strategy,
                                kind.label()
                            ));
                        }
                    }
                }
                let member0 = (o * cfg.rates.len() + ri) * cfg.seeds as usize;
                verify_member(
                    &perturbed_grid.inputs[member0],
                    cfg,
                    *kind,
                    rate,
                    &perturbed_cells[member0 * nn + ni],
                    &mut scratch,
                    &mut report,
                );
                makespans.sort_by(f64::total_cmp);
                let (p50, p95, p99) = (
                    percentile(&makespans, 0.50),
                    percentile(&makespans, 0.95),
                    percentile(&makespans, 0.99),
                );
                report.cells.push(ChaosCell {
                    workload: Arc::clone(&input.workload),
                    strategy: Arc::clone(&input.strategy),
                    network: kind.label(),
                    rate,
                    seeds: cfg.seeds,
                    clean,
                    lower_bound,
                    p50,
                    p95,
                    p99,
                    worst: *makespans.last().unwrap(),
                    ratio_p50: p50 / clean,
                    ratio_p99: p99 / clean,
                });
            }
        }
    }

    report.gate_failures.extend(degradation_gate(&report.cells, cfg.gate_rate));
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Render the report as `BENCH_chaos.json`.
pub fn to_json(tag: &str, report: &ChaosReport) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"chaos\": {tag:?},\n"));
    s.push_str(&format!("  \"sims\": {},\n", report.sims));
    s.push_str(&format!("  \"determinism_checks\": {},\n", report.determinism_checks));
    s.push_str(&format!("  \"blame_checks\": {},\n", report.blame_checks));
    s.push_str(&format!("  \"lb_violations\": {},\n", report.lb_violations));
    s.push_str(&format!("  \"ensemble_wall_secs\": {},\n", report.wall_secs));
    s.push_str("  \"gate_failures\": [");
    for (i, f) in report.gate_failures.iter().enumerate() {
        s.push_str(&format!("{}{f:?}", if i == 0 { "" } else { ", " }));
    }
    s.push_str("],\n  \"cells\": [\n");
    for (i, c) in report.cells.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": {:?}, \"strategy\": {:?}, \"network\": {:?}, \
             \"rate\": {}, \"seeds\": {}, \"clean\": {}, \"lower_bound\": {}, \
             \"p50\": {}, \"p95\": {}, \"p99\": {}, \"worst\": {}, \
             \"ratio_p50\": {}, \"ratio_p99\": {}}}{}",
            c.workload,
            c.strategy,
            c.network,
            c.rate,
            c.seeds,
            c.clean,
            c.lower_bound.map_or("null".to_string(), |b| b.to_string()),
            c.p50,
            c.p95,
            c.p99,
            c.worst,
            c.ratio_p50,
            c.ratio_p99,
            if i + 1 == report.cells.len() { "" } else { "," }
        ));
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}
