//! Graph algorithms shared by the transformation, simulator and checker.

use super::{TaskGraph, TaskId};
use crate::util::Stamp;

/// A topological order of the graph's tasks.
#[derive(Debug, Clone)]
pub struct TopoOrder(pub Vec<u32>);

/// Per-task longest-path depths (already stored on the graph; this type
/// exists for algorithms that recompute depths over sub-graphs).
#[derive(Debug, Clone)]
pub struct Levels(pub Vec<u32>);

impl TaskGraph {
    /// The graph's topological order, computed **once** by the builder's
    /// Kahn validation pass and cached on the graph — transforms,
    /// simulators, and the sequential reference evaluator all share it
    /// instead of re-deriving it per call.
    #[inline]
    pub fn topo(&self) -> &[u32] {
        &self.topo
    }

    /// The cached topological order as an owned [`TopoOrder`] (clones;
    /// prefer [`TaskGraph::topo`] for borrowing consumers).
    pub fn topo_order(&self) -> TopoOrder {
        TopoOrder(self.topo.clone())
    }

    /// Backward transitive closure: every task reachable from `seeds`
    /// through predecessor edges, **including** the seeds.  Returns a
    /// sorted id vector.  `scratch` must span the graph's task universe.
    ///
    /// This is the building block for the paper's `L_p^(5) = L_p ∪ pred(L_p)`
    /// (the paper writes one application of `pred`, but its usage — "all
    /// tasks that are computed anywhere to construct the local result" —
    /// is the transitive closure, which is what we compute).
    pub fn backward_closure(&self, seeds: &[u32], scratch: &mut Stamp) -> Vec<u32> {
        scratch.grow(self.len());
        scratch.clear();
        let mut stack: Vec<u32> = Vec::with_capacity(seeds.len());
        for &s in seeds {
            if !scratch.contains(s as usize) {
                scratch.set(s as usize);
                stack.push(s);
            }
        }
        let mut out: Vec<u32> = Vec::with_capacity(seeds.len() * 2);
        while let Some(t) = stack.pop() {
            out.push(t);
            for &p in self.preds(TaskId(t)) {
                if !scratch.contains(p as usize) {
                    scratch.set(p as usize);
                    stack.push(p);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Fixpoint of "computable from `base` using only tasks in-universe":
    /// the set `F = {t ∈ universe, t ∉ base : pred(t) ⊆ base ∪ F}` — the
    /// paper's `L_p^(4)` when `base = L_p^(0)` and `universe = L_p^(5)`.
    ///
    /// Implemented as a forward worklist over the universe, O(V+E) on the
    /// sub-graph.  Returns a sorted id vector of the newly computable
    /// tasks (excluding `base` itself).
    ///
    /// Perf note: missing-predecessor counts live in a flat per-task
    /// array (`remaining`, grown to `len()` and reused across calls by
    /// the transformation) rather than a hash map — entries are
    /// initialized for every universe task before any read, so no
    /// clearing is needed, and the §Perf log records a ~2.4× transform
    /// speedup from this layout.
    pub fn local_fixpoint(
        &self,
        base: &[u32],
        universe: &[u32],
        scratch_in_universe: &mut Stamp,
        scratch_done: &mut Stamp,
    ) -> Vec<u32> {
        let mut remaining = vec![0u32; self.len()];
        self.local_fixpoint_with(base, universe, scratch_in_universe, scratch_done, &mut remaining)
    }

    /// [`Self::local_fixpoint`] with a caller-provided counter scratch
    /// (`remaining.len() >= self.len()`); the hot path for repeated
    /// per-processor calls.
    pub fn local_fixpoint_with(
        &self,
        base: &[u32],
        universe: &[u32],
        scratch_in_universe: &mut Stamp,
        scratch_done: &mut Stamp,
        remaining: &mut [u32],
    ) -> Vec<u32> {
        assert!(remaining.len() >= self.len());
        scratch_in_universe.grow(self.len());
        scratch_in_universe.clear();
        for &t in universe {
            scratch_in_universe.set(t as usize);
        }
        scratch_done.grow(self.len());
        scratch_done.clear();
        let mut stack: Vec<u32> = Vec::new();
        for &t in base {
            scratch_done.set(t as usize);
        }
        // Seed: universe tasks whose preds are all in base.  `Input`
        // tasks are data, not work — they are available iff in `base`,
        // never "computable" (they have no preds, so without this guard
        // every remote input would leak into the fixpoint).
        for &t in universe {
            if scratch_done.contains(t as usize)
                || self.kind(TaskId(t)) == crate::graph::TaskKind::Input
            {
                continue;
            }
            let preds = self.preds(TaskId(t));
            let missing =
                preds.iter().filter(|&&p| !scratch_done.contains(p as usize)).count() as u32;
            if missing == 0 {
                stack.push(t);
            }
            remaining[t as usize] = missing;
        }
        let mut out = Vec::new();
        while let Some(t) = stack.pop() {
            if scratch_done.contains(t as usize) {
                continue;
            }
            scratch_done.set(t as usize);
            out.push(t);
            for &s in self.succs(TaskId(t)) {
                if !scratch_in_universe.contains(s as usize) || scratch_done.contains(s as usize) {
                    continue;
                }
                let m = &mut remaining[s as usize];
                if *m > 0 {
                    *m -= 1;
                    if *m == 0 {
                        stack.push(s);
                    }
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Per-level histogram of task counts (diagnostics / figure 6 data).
    pub fn level_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.nlevels as usize];
        for &l in &self.level {
            h[l as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, ProcId};

    /// 1-D 3-point stencil, n points × m levels, one proc — small enough
    /// to check closures by hand.
    fn chain_graph(n: usize, m: usize) -> TaskGraph {
        let mut b = GraphBuilder::new(1);
        let mut prev: Vec<TaskId> = (0..n).map(|i| b.add_input(ProcId(0), i as u64)).collect();
        for lvl in 1..=m {
            let cur: Vec<TaskId> = (0..n)
                .map(|i| {
                    let lo = i.saturating_sub(1);
                    let hi = (i + 1).min(n - 1);
                    let preds: Vec<TaskId> = (lo..=hi).map(|j| prev[j]).collect();
                    b.add_task(ProcId(0), lvl as u32, i as u64, &preds)
                })
                .collect();
            prev = cur;
        }
        b.finish().unwrap()
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = chain_graph(5, 3);
        let order = g.topo_order().0;
        // The owned form clones the build-time cache.
        assert_eq!(order, g.topo());
        assert_eq!(order.len(), g.len());
        let mut pos = vec![0usize; g.len()];
        for (i, &t) in order.iter().enumerate() {
            pos[t as usize] = i;
        }
        for t in g.tasks() {
            for &p in g.preds(t) {
                assert!(pos[p as usize] < pos[t.idx()]);
            }
        }
    }

    #[test]
    fn backward_closure_cone() {
        let g = chain_graph(7, 2); // ids: inputs 0..7, lvl1 7..14, lvl2 14..21
        let mut st = Stamp::new(g.len());
        // Task at level 2, centre point 3 (id 14+3=17): cone is points
        // 2..4 at lvl1 and 1..5 at lvl0, plus itself — 3 + 5 + 1 = 9.
        let c = g.backward_closure(&[17], &mut st);
        assert_eq!(c.len(), 9);
        assert!(c.contains(&17) && c.contains(&10) && c.contains(&1) && c.contains(&5));
    }

    #[test]
    fn closure_of_input_is_itself() {
        let g = chain_graph(4, 1);
        let mut st = Stamp::new(g.len());
        assert_eq!(g.backward_closure(&[2], &mut st), vec![2]);
    }

    #[test]
    fn local_fixpoint_trapezoid() {
        // 6 points, 2 levels: from inputs {0..6} the computable set within
        // the full universe is everything (single proc).
        let g = chain_graph(6, 2);
        let base: Vec<u32> = (0..6).collect();
        let universe: Vec<u32> = (0..g.len() as u32).collect();
        let mut s1 = Stamp::new(g.len());
        let mut s2 = Stamp::new(g.len());
        let f = g.local_fixpoint(&base, &universe, &mut s1, &mut s2);
        assert_eq!(f.len(), 12); // both compute levels
    }

    #[test]
    fn local_fixpoint_partial_base() {
        // Only inputs 0..3 available: level-1 computable are points whose
        // 3-point stencil fits in [0,3): points 0 (preds 0,1), 1 (0,1,2),
        // 2 (1,2,3 — 3 missing!) => points 0 and 1 only.
        let g = chain_graph(6, 1);
        let base: Vec<u32> = (0..3).collect();
        let universe: Vec<u32> = (0..g.len() as u32).collect();
        let mut s1 = Stamp::new(g.len());
        let mut s2 = Stamp::new(g.len());
        let f = g.local_fixpoint(&base, &universe, &mut s1, &mut s2);
        assert_eq!(f, vec![6, 7]); // lvl-1 ids are 6+point
    }

    #[test]
    fn level_histogram_counts() {
        let g = chain_graph(5, 3);
        assert_eq!(g.level_histogram(), vec![5, 5, 5, 5]);
    }
}
