//! Mutable construction of [`TaskGraph`]s.
//!
//! Edges are accumulated as flat `(task, pred)` pairs and counting-sorted
//! into CSR at `finish()`; building a 4-million-task stencil graph takes
//! tens of milliseconds (see `benches/transform_scalability`).

use super::{ProcId, TaskGraph, TaskId, TaskKind};

/// Incremental builder; see [`TaskGraph`] for the field semantics.
#[derive(Debug)]
pub struct GraphBuilder {
    owner: Vec<u32>,
    level: Vec<u32>,
    kind: Vec<TaskKind>,
    item: Vec<u64>,
    edges: Vec<(u32, u32)>, // (task, pred)
    nprocs: u32,
}

/// Errors detected at `finish()` time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// An edge references a task id that was never added.
    DanglingEdge { task: u32, pred: u32 },
    /// A predecessor does not precede its task topologically.
    Cycle { involved: u32 },
    /// An owner id is out of the declared processor range.
    BadOwner { task: u32, owner: u32 },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::DanglingEdge { task, pred } => {
                write!(f, "edge t{task} <- t{pred} references unknown task")
            }
            GraphError::Cycle { involved } => write!(f, "cycle through t{involved}"),
            GraphError::BadOwner { task, owner } => {
                write!(f, "t{task} owned by out-of-range processor {owner}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

impl GraphBuilder {
    /// A graph distributed over `nprocs` processors.
    pub fn new(nprocs: u32) -> Self {
        assert!(nprocs > 0, "need at least one processor");
        GraphBuilder {
            owner: Vec::new(),
            level: Vec::new(),
            kind: Vec::new(),
            item: Vec::new(),
            edges: Vec::new(),
            nprocs,
        }
    }

    /// Pre-size for `ntasks` tasks and `nedges` edges.
    pub fn with_capacity(nprocs: u32, ntasks: usize, nedges: usize) -> Self {
        let mut b = Self::new(nprocs);
        b.owner.reserve(ntasks);
        b.level.reserve(ntasks);
        b.kind.reserve(ntasks);
        b.item.reserve(ntasks);
        b.edges.reserve(nedges);
        b
    }

    /// Add an `Input` task: initial data resident on `p` (level 0, no preds).
    pub fn add_input(&mut self, p: ProcId, item: u64) -> TaskId {
        self.push(p, 0, item, TaskKind::Input)
    }

    /// Add a `Compute` task with the given predecessors.
    pub fn add_task(&mut self, p: ProcId, level: u32, item: u64, preds: &[TaskId]) -> TaskId {
        let t = self.push(p, level, item, TaskKind::Compute);
        for &pr in preds {
            self.edges.push((t.0, pr.0));
        }
        t
    }

    /// Add a dependence edge `pred -> task` after the fact.
    pub fn add_pred(&mut self, task: TaskId, pred: TaskId) {
        self.edges.push((task.0, pred.0));
    }

    fn push(&mut self, p: ProcId, level: u32, item: u64, kind: TaskKind) -> TaskId {
        let id = self.owner.len() as u32;
        self.owner.push(p.0);
        self.level.push(level);
        self.kind.push(kind);
        self.item.push(item);
        TaskId(id)
    }

    /// Current number of tasks.
    pub fn len(&self) -> usize {
        self.owner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.owner.is_empty()
    }

    /// Validate, build CSR adjacency in both directions, recompute levels
    /// as longest-path depth (inputs stay at their declared level if it is
    /// already consistent), and freeze.
    pub fn finish(self) -> Result<TaskGraph, GraphError> {
        let n = self.owner.len();
        for (t, &o) in self.owner.iter().enumerate() {
            if o >= self.nprocs {
                return Err(GraphError::BadOwner { task: t as u32, owner: o });
            }
        }
        for &(t, p) in &self.edges {
            if t as usize >= n || p as usize >= n {
                return Err(GraphError::DanglingEdge { task: t, pred: p });
            }
        }

        // Counting sort edges into pred-CSR.
        let mut pred_off = vec![0u32; n + 1];
        for &(t, _) in &self.edges {
            pred_off[t as usize + 1] += 1;
        }
        for i in 0..n {
            pred_off[i + 1] += pred_off[i];
        }
        let mut cursor = pred_off.clone();
        let mut pred_adj = vec![0u32; self.edges.len()];
        for &(t, p) in &self.edges {
            pred_adj[cursor[t as usize] as usize] = p;
            cursor[t as usize] += 1;
        }

        // And succ-CSR.
        let mut succ_off = vec![0u32; n + 1];
        for &(_, p) in &self.edges {
            succ_off[p as usize + 1] += 1;
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut cursor = succ_off.clone();
        let mut succ_adj = vec![0u32; self.edges.len()];
        for &(t, p) in &self.edges {
            succ_adj[cursor[p as usize] as usize] = t;
            cursor[p as usize] += 1;
        }

        let mut g = TaskGraph {
            owner: self.owner,
            level: self.level,
            kind: self.kind,
            item: self.item,
            pred_off,
            pred_adj,
            succ_off,
            succ_adj,
            topo: Vec::new(),
            nprocs: self.nprocs,
            nlevels: 0,
        };

        // Kahn topological pass: detects cycles, recomputes levels as
        // longest-path depth from the sources, and records the visit
        // order — the cached topological order every later consumer
        // (transform, simulators, the sequential reference evaluator)
        // shares instead of re-deriving per call.
        let mut indeg: Vec<u32> = (0..n)
            .map(|i| g.pred_off[i + 1] - g.pred_off[i])
            .collect();
        let mut queue: std::collections::VecDeque<u32> = (0..n as u32)
            .filter(|&i| indeg[i as usize] == 0)
            .collect();
        let mut depth = vec![0u32; n];
        let mut order: Vec<u32> = Vec::with_capacity(n);
        while let Some(t) = queue.pop_front() {
            order.push(t);
            let (s0, s1) = (g.succ_off[t as usize], g.succ_off[t as usize + 1]);
            for k in s0..s1 {
                let s = g.succ_adj[k as usize];
                depth[s as usize] = depth[s as usize].max(depth[t as usize] + 1);
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    queue.push_back(s);
                }
            }
        }
        if order.len() != n {
            let involved = indeg.iter().position(|&d| d > 0).unwrap_or(0) as u32;
            return Err(GraphError::Cycle { involved });
        }
        g.topo = order;
        g.level = depth;
        g.nlevels = g.level.iter().copied().max().map_or(0, |m| m + 1);
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(1).finish().unwrap();
        assert!(g.is_empty());
        assert_eq!(g.num_levels(), 0);
    }

    #[test]
    fn levels_recomputed_as_longest_path() {
        let mut b = GraphBuilder::new(1);
        let i = b.add_input(ProcId(0), 0);
        let a = b.add_task(ProcId(0), 9, 0, &[i]); // declared level ignored
        let c = b.add_task(ProcId(0), 9, 0, &[a]);
        let _d = b.add_task(ProcId(0), 9, 0, &[i, c]); // longest path = 3
        let g = b.finish().unwrap();
        assert_eq!(g.level(TaskId(1)), 1);
        assert_eq!(g.level(TaskId(2)), 2);
        assert_eq!(g.level(TaskId(3)), 3);
        assert_eq!(g.num_levels(), 4);
    }

    #[test]
    fn cycle_detected() {
        let mut b = GraphBuilder::new(1);
        let a = b.add_task(ProcId(0), 0, 0, &[]);
        let c = b.add_task(ProcId(0), 1, 0, &[a]);
        b.add_pred(a, c);
        assert!(matches!(b.finish(), Err(GraphError::Cycle { .. })));
    }

    #[test]
    fn dangling_edge_detected() {
        let mut b = GraphBuilder::new(1);
        let a = b.add_task(ProcId(0), 0, 0, &[]);
        b.add_pred(a, TaskId(99));
        assert!(matches!(b.finish(), Err(GraphError::DanglingEdge { .. })));
    }

    #[test]
    fn bad_owner_detected() {
        let mut b = GraphBuilder::new(2);
        b.add_task(ProcId(5), 0, 0, &[]);
        assert!(matches!(b.finish(), Err(GraphError::BadOwner { .. })));
    }

    #[test]
    fn self_loop_is_cycle() {
        let mut b = GraphBuilder::new(1);
        let a = b.add_task(ProcId(0), 0, 0, &[]);
        b.add_pred(a, a);
        assert!(matches!(b.finish(), Err(GraphError::Cycle { .. })));
    }
}
