//! Graphviz DOT export for small graphs (debugging, paper figures 4/5).

use super::{TaskGraph, TaskId, TaskKind};

/// Palette cycled per processor in DOT output.
const COLORS: &[&str] = &[
    "lightblue", "lightsalmon", "palegreen", "plum", "khaki", "lightcyan", "mistyrose", "honeydew",
];

impl TaskGraph {
    /// Render the graph as Graphviz DOT, one cluster per level, nodes
    /// coloured by owner.  Intended for graphs of up to a few hundred
    /// tasks; callers should down-sample larger graphs first.
    pub fn to_dot(&self, title: &str) -> String {
        let mut s = String::new();
        s.push_str(&format!("digraph \"{title}\" {{\n  rankdir=BT;\n  node [style=filled];\n"));
        for lvl in 0..self.nlevels {
            s.push_str(&format!("  {{ rank=same;"));
            for t in self.tasks() {
                if self.level(t) == lvl {
                    s.push_str(&format!(" t{};", t.0));
                }
            }
            s.push_str(" }\n");
        }
        for t in self.tasks() {
            let color = COLORS[self.owner(t).idx() % COLORS.len()];
            let shape = match self.kind(t) {
                TaskKind::Input => "box",
                TaskKind::Compute => "ellipse",
            };
            s.push_str(&format!(
                "  t{} [label=\"{}@{}\\n{}\", fillcolor={}, shape={}];\n",
                t.0,
                self.item(t),
                self.level(t),
                self.owner(t),
                color,
                shape
            ));
        }
        for t in self.tasks() {
            for &p in self.preds(t) {
                s.push_str(&format!("  t{} -> t{};\n", p, t.0));
            }
        }
        s.push_str("}\n");
        s
    }

    /// DOT with an extra per-task annotation (e.g. the `L^(k)` subset a
    /// task landed in after the transformation).
    pub fn to_dot_annotated(&self, title: &str, note: impl Fn(TaskId) -> String) -> String {
        let mut s = String::new();
        s.push_str(&format!("digraph \"{title}\" {{\n  rankdir=BT;\n  node [style=filled];\n"));
        for t in self.tasks() {
            let color = COLORS[self.owner(t).idx() % COLORS.len()];
            s.push_str(&format!(
                "  t{} [label=\"{}@{} {}\", fillcolor={}];\n",
                t.0,
                self.item(t),
                self.level(t),
                note(t),
                color
            ));
        }
        for t in self.tasks() {
            for &p in self.preds(t) {
                s.push_str(&format!("  t{} -> t{};\n", p, t.0));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::{GraphBuilder, ProcId};

    #[test]
    fn dot_contains_nodes_and_edges() {
        let mut b = GraphBuilder::new(2);
        let i = b.add_input(ProcId(0), 0);
        let a = b.add_task(ProcId(1), 1, 1, &[i]);
        let _ = a;
        let g = b.finish().unwrap();
        let dot = g.to_dot("test");
        assert!(dot.contains("digraph"));
        assert!(dot.contains("t0 -> t1"));
        assert!(dot.contains("lightsalmon")); // p1 colour
    }

    #[test]
    fn dot_annotated_includes_notes() {
        let mut b = GraphBuilder::new(1);
        b.add_input(ProcId(0), 0);
        let g = b.finish().unwrap();
        let dot = g.to_dot_annotated("t", |_| "L1".to_string());
        assert!(dot.contains("L1"));
    }
}
