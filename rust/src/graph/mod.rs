//! The task-graph IR.
//!
//! A [`TaskGraph`] is the distributed task graph `{L_p}_p` of paper §3: a
//! DAG of tasks, each with an **owner** processor (the processor that the
//! original data distribution assigns the task's output to), a **level**
//! (topological depth — for stencil graphs, the time step), and a **kind**
//! (`Input` tasks are the `L^(0)` initial data; `Compute` tasks cost γ).
//!
//! Predecessors encode the paper's relation
//! `t' ∈ pred(t) ≡ t' computes direct input data for task t`.
//!
//! Storage is CSR-style (flat offset/adjacency arrays) so the
//! transformation's per-processor closures stream through memory; graphs
//! of several million tasks are routine (see `benches/transform_scalability`).

mod algo;
mod builder;
mod dot;

pub use algo::{Levels, TopoOrder};
pub use builder::GraphBuilder;

/// Identifies a task; indexes every per-task array in the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u32);

/// Identifies a processor (an "MPI node" in the paper's simulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ProcId(pub u32);

impl TaskId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl ProcId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

impl std::fmt::Display for ProcId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "p{}", self.0)
    }
}

/// Task kinds: `Input` tasks carry initial data (zero compute cost, they
/// are *data*, not work); `Compute` tasks perform one `f` evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Input,
    Compute,
}

/// Immutable distributed task graph (build with [`GraphBuilder`]).
#[derive(Debug, Clone)]
pub struct TaskGraph {
    pub(crate) owner: Vec<u32>,
    pub(crate) level: Vec<u32>,
    pub(crate) kind: Vec<TaskKind>,
    /// Payload: the domain item this task updates (grid point index,
    /// matrix row, ...).  Opaque to the transformation.
    pub(crate) item: Vec<u64>,
    pub(crate) pred_off: Vec<u32>,
    pub(crate) pred_adj: Vec<u32>,
    pub(crate) succ_off: Vec<u32>,
    pub(crate) succ_adj: Vec<u32>,
    /// A topological order, recorded by the builder's Kahn
    /// validation/levelling pass — computed once at build instead of
    /// per transform/execution ([`TaskGraph::topo`]).
    pub(crate) topo: Vec<u32>,
    pub(crate) nprocs: u32,
    pub(crate) nlevels: u32,
}

impl TaskGraph {
    /// Number of tasks (including `Input` data tasks).
    #[inline]
    pub fn len(&self) -> usize {
        self.owner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.owner.is_empty()
    }

    /// Number of dependence edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.pred_adj.len()
    }

    /// Number of processors the graph is distributed over.
    #[inline]
    pub fn num_procs(&self) -> u32 {
        self.nprocs
    }

    /// Number of distinct levels (max level + 1).
    #[inline]
    pub fn num_levels(&self) -> u32 {
        self.nlevels
    }

    #[inline]
    pub fn owner(&self, t: TaskId) -> ProcId {
        ProcId(self.owner[t.idx()])
    }

    #[inline]
    pub fn level(&self, t: TaskId) -> u32 {
        self.level[t.idx()]
    }

    #[inline]
    pub fn kind(&self, t: TaskId) -> TaskKind {
        self.kind[t.idx()]
    }

    #[inline]
    pub fn item(&self, t: TaskId) -> u64 {
        self.item[t.idx()]
    }

    /// Direct predecessors (the paper's `pred(t)`).
    #[inline]
    pub fn preds(&self, t: TaskId) -> &[u32] {
        let i = t.idx();
        &self.pred_adj[self.pred_off[i] as usize..self.pred_off[i + 1] as usize]
    }

    /// Direct successors (derived).
    #[inline]
    pub fn succs(&self, t: TaskId) -> &[u32] {
        let i = t.idx();
        &self.succ_adj[self.succ_off[i] as usize..self.succ_off[i + 1] as usize]
    }

    /// Iterator over all task ids.
    pub fn tasks(&self) -> impl Iterator<Item = TaskId> + '_ {
        (0..self.len() as u32).map(TaskId)
    }

    /// All tasks owned by `p` (the paper's `L_p`), including its inputs
    /// (`L_p^(0)`), in id order.
    pub fn owned_by(&self, p: ProcId) -> Vec<u32> {
        self.tasks().filter(|&t| self.owner(t) == p).map(|t| t.0).collect()
    }

    /// Count of `Compute` tasks (the real work; `Input`s are data).
    pub fn num_compute_tasks(&self) -> usize {
        self.kind.iter().filter(|k| **k == TaskKind::Compute).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> TaskGraph {
        // in0 -> a, b -> c   (a,b on p0/p1, c on p1)
        let mut g = GraphBuilder::new(2);
        let i0 = g.add_input(ProcId(0), 0);
        let a = g.add_task(ProcId(0), 1, 1, &[i0]);
        let b = g.add_task(ProcId(1), 1, 2, &[i0]);
        let _c = g.add_task(ProcId(1), 2, 3, &[a, b]);
        g.finish().unwrap()
    }

    #[test]
    fn basic_accessors() {
        let g = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.num_procs(), 2);
        assert_eq!(g.num_levels(), 3);
        assert_eq!(g.kind(TaskId(0)), TaskKind::Input);
        assert_eq!(g.num_compute_tasks(), 3);
    }

    #[test]
    fn preds_and_succs_inverse() {
        let g = diamond();
        for t in g.tasks() {
            for &p in g.preds(t) {
                assert!(g.succs(TaskId(p)).contains(&t.0));
            }
            for &s in g.succs(t) {
                assert!(g.preds(TaskId(s)).contains(&t.0));
            }
        }
    }

    #[test]
    fn owned_by_partitions_tasks() {
        let g = diamond();
        let total: usize = (0..2).map(|p| g.owned_by(ProcId(p)).len()).sum();
        assert_eq!(total, g.len());
        assert_eq!(g.owned_by(ProcId(0)), vec![0, 1]);
        assert_eq!(g.owned_by(ProcId(1)), vec![2, 3]);
    }
}
