//! The daemon's cache: N independent [`TuningCache`] slots, routed by
//! workload signature.
//!
//! Each slot is its own mutex, so tuning heat1d never contends with
//! tuning spmv; all slots share one on-disk shard directory (the
//! per-signature files plus file locks in [`crate::tune::cache`] keep
//! concurrent writers — threads here, or whole other processes — from
//! clobbering each other).  Routing uses the same signature hash as the
//! shard file names, so one slot owns each shard file end to end.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use crate::tune::cache::tag_hash;
use crate::tune::{signature_of, TuningCache};

/// Lock a mutex, recovering from poison.  A handler that panicked while
/// holding a slot must not wedge the daemon: the slot's `TuningCache`
/// is valid after any interrupted sequence of its methods (worst case a
/// fresh search re-runs), so the poison flag carries no information we
/// act on.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Aggregated counters over every slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheTotals {
    pub entries: usize,
    pub shards: usize,
    pub hits: usize,
    pub misses: usize,
}

#[derive(Debug)]
pub struct ShardedCache {
    slots: Vec<Mutex<TuningCache>>,
}

impl ShardedCache {
    /// `dir = None` keeps everything in memory (tests, throwaway runs);
    /// otherwise each slot lazily loads per-signature shard files from
    /// `dir` on first touch.  `slots` is clamped to ≥ 1.
    pub fn new(dir: Option<PathBuf>, slots: usize) -> Self {
        let slots = (0..slots.max(1))
            .map(|_| {
                Mutex::new(match &dir {
                    Some(d) => TuningCache::sharded_unloaded(d),
                    None => TuningCache::new(),
                })
            })
            .collect();
        ShardedCache { slots }
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// The slot responsible for `key` — deterministic per workload
    /// signature, so one signature's requests always serialize on the
    /// same mutex (and the same shard file).
    pub fn slot_for(&self, key: &str) -> &Mutex<TuningCache> {
        let i = tag_hash(signature_of(key)) as usize % self.slots.len();
        &self.slots[i]
    }

    pub fn totals(&self) -> CacheTotals {
        let mut t = CacheTotals { entries: 0, shards: 0, hits: 0, misses: 0 };
        for slot in &self.slots {
            let c = lock_recover(slot);
            t.entries += c.len();
            t.shards += c.shard_count();
            t.hits += c.hits();
            t.misses += c.misses();
        }
        t
    }

    /// Persist every slot (no-op for memory-backed slots).  Called on
    /// shutdown; individual saves during operation already happen under
    /// the per-shard file lock inside `tune_pipeline`.
    pub fn flush(&self) -> std::io::Result<()> {
        for slot in &self.slots {
            lock_recover(slot).save()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, NetworkKind};
    use crate::tune::space::Candidate;
    use crate::tune::{cache_key, CacheEntry};

    fn entry() -> CacheEntry {
        CacheEntry::from_candidate(&Candidate::naive(2), 10.0, 20.0, 3, "exhaustive", 0.1)
    }

    fn key(sig: &str, procs: u32) -> String {
        cache_key(sig, procs, &Machine::new(procs, 4, 5.0, 1.0, 1.0), &NetworkKind::AlphaBeta)
    }

    #[test]
    fn same_signature_routes_to_the_same_slot() {
        let cache = ShardedCache::new(None, 8);
        assert_eq!(cache.num_slots(), 8);
        let k1 = key("heat1d(v=1,e=1,l=4,w=1)", 2);
        let k2 = cache_key(
            "heat1d(v=1,e=1,l=4,w=1)",
            8,
            &Machine::new(8, 2, 9.0, 2.0, 1.0),
            &NetworkKind::LogGp { overhead: 1.0, gap: 2.0 },
        );
        assert!(std::ptr::eq(cache.slot_for(&k1), cache.slot_for(&k2)));
        // Zero slots is clamped, not a modulo-by-zero panic.
        assert_eq!(ShardedCache::new(None, 0).num_slots(), 1);
    }

    #[test]
    fn totals_aggregate_across_slots() {
        let cache = ShardedCache::new(None, 4);
        let keys = ["heat1d(v=1,e=1,l=4,w=1)", "heat2d(v=9,e=8,l=3,w=1)", "spmv(v=7,e=9,l=2,w=2)"]
            .map(|sig| key(sig, 2));
        for k in &keys {
            lock_recover(cache.slot_for(k)).insert(k.clone(), entry());
        }
        for k in &keys {
            assert!(lock_recover(cache.slot_for(k)).lookup_decoded(k).is_some());
        }
        assert!(lock_recover(cache.slot_for(&keys[0])).lookup_decoded("absent|key").is_none());
        let t = cache.totals();
        assert_eq!((t.entries, t.hits, t.misses), (3, 3, 1));
        cache.flush().unwrap(); // memory-backed: a no-op, not an error
    }
}
