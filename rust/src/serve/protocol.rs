//! The serve wire format: newline-delimited JSON requests and responses.
//!
//! One request per line, one response per line, in a deliberately small
//! dialect the repo can parse without a JSON dependency: a **flat object
//! of scalar fields** — string values are double-quoted *without escape
//! sequences*, everything else (numbers, booleans) is a bare token.
//! Nested objects, arrays, and `\"`-escapes are rejected; no tuning
//! request needs them.
//!
//! # Request schema
//!
//! ```json
//! {"id": "r1", "op": "tune", "workload": "heat1d", "n": 4096, "m": 16,
//!  "p": 4, "threads": 8, "alpha": 500.0, "beta": 0.1, "gamma": 1.0,
//!  "network": "alphabeta", "search": "exhaustive", "budget": 0}
//! ```
//!
//! - `id` (required): caller-chosen tag, echoed verbatim in the response.
//! - `op` (required): `"tune"`, `"simulate"`, `"analyze"`, `"explain"`,
//!   `"cache-stats"`, `"metrics"`, or `"drain"`.
//! - `deadline_ms` (optional): per-request latency budget.  Checked at
//!   admission and again between the server's search phases; once
//!   expired the request answers `"status": "deadline"` without
//!   (further) engine runs.  `0` expires immediately — the
//!   deterministic way to observe the deadline path.
//! - `priority` (optional): `"low"`, `"normal"` (default), or
//!   `"high"`.  Under load the admission gate sheds low-priority
//!   requests first (they cannot take the last `reserve` slots).
//! - every other field lands in a per-request [`Config`] and overrides
//!   the server's defaults: `workload` (`heat1d|heat2d|moore2d|spmv|cg`),
//!   problem size (`n`/`r`, `h`/`w`, `cg_n`/`iters`), steps `m`, procs
//!   `p`, machine `threads`/`alpha`/`beta`/`gamma`, wire `network`
//!   (`alphabeta|loggp|hier|contended`).  `tune` additionally honours
//!   `search` (`exhaustive|golden|coord`) and a per-request `budget`
//!   (max engine runs; `0` = unlimited, always clamped to the server's
//!   own ceiling).  `simulate`, `analyze`, and `explain` honour
//!   `strategy` (`naive|overlap|ca`) and block factor `b`.
//!
//! # Response schema
//!
//! One object per request, same order as the request wave:
//!
//! ```json
//! {"id": "r1", "status": "ok", "chosen": "ca(b=8)", "makespan": 1234.0,
//!  "naive_makespan": 2000.0, "engine_runs": 12, "evaluations": 18,
//!  "search": "exhaustive", "cache": "miss", "latency_ms": 3.2}
//! ```
//!
//! - `status`: `"ok"`, `"error"` (with `"error": "message"`),
//!   `"overloaded"` (admission control shed the request; retry later),
//!   or `"deadline"` (the request's `deadline_ms` budget expired before
//!   a result was ready; partial work is discarded).
//! - `tune` payload: `chosen`, `makespan`, `naive_makespan`,
//!   `engine_runs` (0 on a cache hit or deduped wait), `evaluations`,
//!   `search`, and `cache` — `"hit"` (served from the sharded cache,
//!   zero engine runs), `"miss"` (this request ran the search), or
//!   `"deduped"` (an identical request was already in flight; this one
//!   waited for that result instead of searching again).
//! - `simulate` payload: `strategy`, `makespan`, `messages`, `words`,
//!   and `batch` — how many compatible requests shared one sweep grid.
//! - `analyze` payload: `strategy`, `procs`, `phases`, `deadlock_free`,
//!   `fatal`/`warnings` diagnostic counts, and the analytic makespan
//!   `lower_bound` with its `exact` flag ([`crate::analysis`]); the op
//!   never runs the engine.
//! - `explain` payload ([`crate::explain`]): `strategy`, `procs`, the
//!   observed `makespan`, its bit-exact blame decomposition `compute` /
//!   `exposed_latency` / `bandwidth` / `idle` (the four sum back to the
//!   makespan to the last bit; `exact` reports that invariant), the
//!   analytic `bound` with `bound_ok` (observed ≥ bound, bit-equal on
//!   exact wires), and `path_messages` — how many message flights sit
//!   on the observed critical path.  Runs the provenance-recording
//!   engine once; never searches.
//! - `cache-stats` payload: `entries`, `shards`, `hits`, `misses`,
//!   `deduped`, `shed`, `in_flight`.
//! - `drain` payload: `in_flight_waited` (engine searches that were
//!   still running when the drain began), `shards_flushed` (dirty cache
//!   shards written out), `accepting` (always `false` afterwards — the
//!   daemon stops admitting new engine work and answers everything else
//!   `overloaded` until shutdown).  Graceful-shutdown op: stop
//!   admitting, finish in-flight, flush, report.
//! - `metrics` payload ([`crate::telemetry`]): `enabled`, `requests`,
//!   histogram-backed request-latency `p50_ms`/`p90_ms`/`p99_ms`,
//!   buffered `spans`, plus one `phase_<name>_ms` field per recorded
//!   serve phase (mean latency) — flat scalar fields, so the payload
//!   stays inside this dialect; the full Prometheus text exposition is
//!   available via the `metrics=` periodic dump on the CLI.
//! - `latency_ms`: wall time from wave start to this response.

use crate::config::Config;

/// Parse one line of the flat-object dialect into `(key, value)` pairs
/// in source order.  String values lose their quotes; bare tokens are
/// kept verbatim (the consumer parses them as needed).
pub fn parse_flat_object(line: &str) -> Result<Vec<(String, String)>, String> {
    let s = line.trim();
    let s = s
        .strip_prefix('{')
        .ok_or_else(|| format!("expected a JSON object, got {line:?}"))?;
    let s = s.strip_suffix('}').ok_or_else(|| format!("unterminated JSON object: {line:?}"))?;
    let mut out = Vec::new();
    let mut rest = s.trim();
    while !rest.is_empty() {
        rest = rest
            .strip_prefix('"')
            .ok_or_else(|| format!("expected a quoted key at {rest:?}"))?;
        let end = rest.find('"').ok_or_else(|| format!("unterminated key in {line:?}"))?;
        let key = rest[..end].to_string();
        rest = rest[end + 1..].trim_start();
        rest = rest
            .strip_prefix(':')
            .ok_or_else(|| format!("expected ':' after key {key:?}"))?
            .trim_start();
        let value = if let Some(v) = rest.strip_prefix('"') {
            let end =
                v.find('"').ok_or_else(|| format!("unterminated string value for {key:?}"))?;
            rest = v[end + 1..].trim_start();
            v[..end].to_string()
        } else {
            let end = rest.find(',').unwrap_or(rest.len());
            let token = rest[..end].trim();
            if token.is_empty() || token.contains(['{', '[', '"']) {
                return Err(format!("expected a scalar value for key {key:?} in {line:?}"));
            }
            rest = &rest[end..];
            token.to_string()
        };
        out.push((key, value));
        match rest.strip_prefix(',') {
            Some(r) => rest = r.trim_start(),
            None if rest.is_empty() => {}
            None => return Err(format!("expected ',' between fields in {line:?}")),
        }
    }
    Ok(out)
}

/// What a request asks the daemon to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Autotune one pipeline (cache-first, deduped in flight).
    Tune,
    /// Simulate one configuration (batched into shared sweep grids).
    Simulate,
    /// Statically verify one configuration and report its analytic
    /// makespan lower bound — never runs the engine.
    Analyze,
    /// Run one provenance-recording simulation and report the bit-exact
    /// makespan blame decomposition ([`crate::explain`]).
    Explain,
    /// Report cache/admission counters; never touches the engine.
    CacheStats,
    /// Report the telemetry recorder's aggregates (request counts,
    /// latency percentiles, per-phase means); never touches the engine.
    Metrics,
    /// Graceful shutdown of the engine side: stop admitting, wait for
    /// in-flight searches, flush dirty cache shards, report.
    Drain,
}

impl Op {
    pub fn parse(tag: &str) -> Result<Op, String> {
        match tag {
            "tune" => Ok(Op::Tune),
            "simulate" => Ok(Op::Simulate),
            "analyze" => Ok(Op::Analyze),
            "explain" => Ok(Op::Explain),
            "cache-stats" => Ok(Op::CacheStats),
            "metrics" => Ok(Op::Metrics),
            "drain" => Ok(Op::Drain),
            other => Err(format!(
                "unknown op {other:?} (tune|simulate|analyze|explain|cache-stats|metrics|drain)"
            )),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            Op::Tune => "tune",
            Op::Simulate => "simulate",
            Op::Analyze => "analyze",
            Op::Explain => "explain",
            Op::CacheStats => "cache-stats",
            Op::Metrics => "metrics",
            Op::Drain => "drain",
        }
    }
}

/// How urgently the caller wants an answer; the admission gate sheds
/// `Low` first under load (a low-priority request cannot take the last
/// reserved slots).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    Normal,
    High,
}

impl Priority {
    /// Parse the request's `priority` field (absent/empty = `Normal`).
    pub fn parse(tag: &str) -> Result<Priority, String> {
        match tag {
            "" | "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            "high" => Ok(Priority::High),
            other => Err(format!("unknown priority {other:?} (low|normal|high)")),
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// One parsed request line.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller's tag, echoed in the response.
    pub id: String,
    pub op: Op,
    /// Every non-`id`/`op` field, as overrides on the server defaults.
    pub params: Config,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let mut id = None;
        let mut op = None;
        let mut params = Config::new();
        for (k, v) in parse_flat_object(line)? {
            match k.as_str() {
                "id" => id = Some(v),
                "op" => op = Some(v),
                _ => params.set(&k, v),
            }
        }
        let id = id.ok_or_else(|| format!("request is missing \"id\": {line:?}"))?;
        let op = op.ok_or_else(|| format!("request {id:?} is missing \"op\""))?;
        Ok(Request { id, op: Op::parse(&op)?, params })
    }
}

/// Why a request produced no payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// Admission control shed the request; the caller should retry.
    Overloaded(String),
    /// The request itself failed (bad params, infeasible transform, …).
    Failed(String),
    /// The request's `deadline_ms` budget expired before a result was
    /// ready; whatever partial work existed was discarded.
    Deadline(String),
}

/// How a `tune` verdict was obtained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Served from the sharded cache — zero engine runs.
    Hit,
    /// Fresh search: this request ran the engine.
    Miss,
    /// Waited on an identical in-flight request — zero engine runs.
    Deduped,
}

impl CacheOutcome {
    pub fn tag(&self) -> &'static str {
        match self {
            CacheOutcome::Hit => "hit",
            CacheOutcome::Miss => "miss",
            CacheOutcome::Deduped => "deduped",
        }
    }
}

/// Successful response payload, per op.
#[derive(Debug, Clone)]
pub enum Payload {
    Tune {
        chosen: String,
        makespan: f64,
        naive_makespan: f64,
        engine_runs: usize,
        evaluations: usize,
        search: String,
        cache: CacheOutcome,
    },
    Simulate {
        strategy: String,
        makespan: f64,
        messages: usize,
        words: usize,
        /// Size of the coalesced sweep grid this cell ran in.
        batch: usize,
    },
    Analyze {
        strategy: String,
        procs: usize,
        phases: usize,
        deadlock_free: bool,
        fatal: usize,
        warnings: usize,
        /// Analytic critical-path makespan lower bound under the
        /// request's machine and wire.
        lower_bound: f64,
        /// True when the wire is stateless and the bound equals the
        /// engine's makespan exactly.
        exact: bool,
    },
    Explain {
        strategy: String,
        procs: usize,
        /// Observed makespan of the provenance-recording run.
        makespan: f64,
        /// On-path compute total.
        compute: f64,
        /// On-path exposed latency total.
        exposed_latency: f64,
        /// On-path exposed bandwidth total.
        bandwidth: f64,
        /// On-path queueing / idle total.
        idle: f64,
        /// The four blame terms sum back to the makespan bit-exactly
        /// and the path tiles `[0, makespan]` ([`crate::explain`]).
        exact: bool,
        /// Analytic critical-path lower bound of the same cell.
        bound: f64,
        /// Observed ≥ bound (bit-equal on exact wires).
        bound_ok: bool,
        /// Message flights on the observed critical path.
        path_messages: usize,
    },
    CacheStats {
        entries: usize,
        shards: usize,
        hits: usize,
        misses: usize,
        deduped: usize,
        shed: usize,
        in_flight: usize,
    },
    Drain {
        /// Engine searches still running when the drain began (all
        /// finished before this response was written).
        in_flight_waited: usize,
        /// Dirty cache shards flushed to disk.
        shards_flushed: usize,
        /// Always `false` afterwards: the gate admits nothing new.
        accepting: bool,
    },
    Metrics {
        /// Whether a telemetry recorder is attached to the server.
        enabled: bool,
        /// Requests observed by the recorder so far.
        requests: u64,
        /// Histogram-backed request-latency percentiles (ms).
        p50_ms: f64,
        p90_ms: f64,
        p99_ms: f64,
        /// Spans currently buffered in the recorder.
        spans: usize,
        /// Per-phase mean latencies (ms), rendered as flat
        /// `phase_<name>_ms` fields.
        phases: Vec<(String, f64)>,
    },
}

/// One response line.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: String,
    /// Wall time from wave start to this response.
    pub latency_ms: f64,
    pub result: Result<Payload, RequestError>,
}

impl Response {
    /// Render as one JSON line (no trailing newline).
    pub fn to_json(&self) -> String {
        let mut s = format!("{{\"id\": {:?}, ", self.id);
        match &self.result {
            Ok(Payload::Tune {
                chosen,
                makespan,
                naive_makespan,
                engine_runs,
                evaluations,
                search,
                cache,
            }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"chosen\": {chosen:?}, \"makespan\": {makespan}, \
                     \"naive_makespan\": {naive_makespan}, \"engine_runs\": {engine_runs}, \
                     \"evaluations\": {evaluations}, \"search\": {search:?}, \"cache\": \"{}\"",
                    cache.tag()
                ));
            }
            Ok(Payload::Simulate { strategy, makespan, messages, words, batch }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"strategy\": {strategy:?}, \"makespan\": {makespan}, \
                     \"messages\": {messages}, \"words\": {words}, \"batch\": {batch}"
                ));
            }
            Ok(Payload::Analyze {
                strategy,
                procs,
                phases,
                deadlock_free,
                fatal,
                warnings,
                lower_bound,
                exact,
            }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"strategy\": {strategy:?}, \"procs\": {procs}, \
                     \"phases\": {phases}, \"deadlock_free\": {deadlock_free}, \
                     \"fatal\": {fatal}, \"warnings\": {warnings}, \
                     \"lower_bound\": {lower_bound}, \"exact\": {exact}"
                ));
            }
            Ok(Payload::Explain {
                strategy,
                procs,
                makespan,
                compute,
                exposed_latency,
                bandwidth,
                idle,
                exact,
                bound,
                bound_ok,
                path_messages,
            }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"strategy\": {strategy:?}, \"procs\": {procs}, \
                     \"makespan\": {makespan}, \"compute\": {compute}, \
                     \"exposed_latency\": {exposed_latency}, \"bandwidth\": {bandwidth}, \
                     \"idle\": {idle}, \"exact\": {exact}, \"bound\": {bound}, \
                     \"bound_ok\": {bound_ok}, \"path_messages\": {path_messages}"
                ));
            }
            Ok(Payload::CacheStats { entries, shards, hits, misses, deduped, shed, in_flight }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"entries\": {entries}, \"shards\": {shards}, \
                     \"hits\": {hits}, \"misses\": {misses}, \"deduped\": {deduped}, \
                     \"shed\": {shed}, \"in_flight\": {in_flight}"
                ));
            }
            Ok(Payload::Metrics { enabled, requests, p50_ms, p90_ms, p99_ms, spans, phases }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"enabled\": {enabled}, \"requests\": {requests}, \
                     \"p50_ms\": {p50_ms}, \"p90_ms\": {p90_ms}, \"p99_ms\": {p99_ms}, \
                     \"spans\": {spans}"
                ));
                for (name, mean_ms) in phases {
                    // Phase names are static identifiers, so the field
                    // stays inside the no-escape flat dialect.
                    s.push_str(&format!(", \"phase_{name}_ms\": {mean_ms}"));
                }
            }
            Ok(Payload::Drain { in_flight_waited, shards_flushed, accepting }) => {
                s.push_str(&format!(
                    "\"status\": \"ok\", \"in_flight_waited\": {in_flight_waited}, \
                     \"shards_flushed\": {shards_flushed}, \"accepting\": {accepting}"
                ));
            }
            Err(RequestError::Overloaded(msg)) => {
                s.push_str(&format!("\"status\": \"overloaded\", \"error\": {msg:?}"));
            }
            Err(RequestError::Failed(msg)) => {
                s.push_str(&format!("\"status\": \"error\", \"error\": {msg:?}"));
            }
            Err(RequestError::Deadline(msg)) => {
                s.push_str(&format!("\"status\": \"deadline\", \"error\": {msg:?}"));
            }
        }
        s.push_str(&format!(", \"latency_ms\": {}}}", self.latency_ms));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_object_parses_strings_and_bare_tokens() {
        let fields =
            parse_flat_object(r#"{"id": "r1", "op": "tune", "n": 4096, "alpha": 500.5}"#).unwrap();
        assert_eq!(
            fields,
            vec![
                ("id".into(), "r1".into()),
                ("op".into(), "tune".into()),
                ("n".into(), "4096".into()),
                ("alpha".into(), "500.5".into()),
            ]
        );
        assert!(parse_flat_object("{}").unwrap().is_empty());
        // Whitespace-tolerant.
        let fields = parse_flat_object("  { \"a\" : \"x\" , \"b\" : 2 }  ").unwrap();
        assert_eq!(fields, vec![("a".into(), "x".into()), ("b".into(), "2".into())]);
    }

    #[test]
    fn flat_object_rejects_malformed_lines() {
        for bad in [
            "",
            "not json",
            "{\"k\": }",
            "{\"k\" 1}",
            "{\"k\": 1",
            "{k: 1}",
            "{\"k\": [1]}",
            "{\"k\": {\"nested\": 1}}",
            "{\"k\": \"unterminated}",
        ] {
            assert!(parse_flat_object(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn request_parse_splits_id_op_and_params() {
        let r = Request::parse(r#"{"id": "q7", "op": "tune", "workload": "heat2d", "p": 4}"#)
            .unwrap();
        assert_eq!(r.id, "q7");
        assert_eq!(r.op, Op::Tune);
        assert_eq!(r.params.get("workload"), Some("heat2d"));
        assert_eq!(r.params.get_or("p", 0u32), 4);
        assert!(r.params.get("id").is_none());

        assert!(Request::parse(r#"{"op": "tune"}"#).unwrap_err().contains("id"));
        assert!(Request::parse(r#"{"id": "x"}"#).unwrap_err().contains("op"));
        assert!(Request::parse(r#"{"id": "x", "op": "fry"}"#).unwrap_err().contains("unknown op"));
    }

    #[test]
    fn responses_render_one_json_line_per_status() {
        let ok = Response {
            id: "a".into(),
            latency_ms: 1.5,
            result: Ok(Payload::Tune {
                chosen: "ca(b=8)".into(),
                makespan: 10.0,
                naive_makespan: 20.0,
                engine_runs: 3,
                evaluations: 5,
                search: "exhaustive".into(),
                cache: CacheOutcome::Miss,
            }),
        };
        let line = ok.to_json();
        assert!(!line.contains('\n'));
        for needle in
            ["\"status\": \"ok\"", "\"chosen\": \"ca(b=8)\"", "\"cache\": \"miss\"", "1.5"]
        {
            assert!(line.contains(needle), "{line}");
        }
        // Round-trips through our own parser.
        let fields = parse_flat_object(&line).unwrap();
        assert!(fields.iter().any(|(k, v)| k == "engine_runs" && v == "3"));

        let analyzed = Response {
            id: "d".into(),
            latency_ms: 0.2,
            result: Ok(Payload::Analyze {
                strategy: "ca(b=4)".into(),
                procs: 4,
                phases: 28,
                deadlock_free: true,
                fatal: 0,
                warnings: 0,
                lower_bound: 123.5,
                exact: true,
            }),
        };
        let line = analyzed.to_json();
        for needle in
            ["\"deadlock_free\": true", "\"lower_bound\": 123.5", "\"exact\": true"]
        {
            assert!(line.contains(needle), "{line}");
        }
        assert!(parse_flat_object(&line).is_ok(), "{line}");

        let metrics = Response {
            id: "m".into(),
            latency_ms: 0.05,
            result: Ok(Payload::Metrics {
                enabled: true,
                requests: 12,
                p50_ms: 1.0,
                p90_ms: 2.0,
                p99_ms: 4.0,
                spans: 30,
                phases: vec![("search".into(), 3.25), ("respond".into(), 0.5)],
            }),
        };
        let line = metrics.to_json();
        for needle in [
            "\"enabled\": true",
            "\"requests\": 12",
            "\"p99_ms\": 4",
            "\"phase_search_ms\": 3.25",
            "\"phase_respond_ms\": 0.5",
        ] {
            assert!(line.contains(needle), "{line}");
        }
        // The metrics payload stays inside the flat dialect.
        assert!(parse_flat_object(&line).is_ok(), "{line}");

        let explained = Response {
            id: "e".into(),
            latency_ms: 0.3,
            result: Ok(Payload::Explain {
                strategy: "ca(b=8)".into(),
                procs: 4,
                makespan: 900.0,
                compute: 512.0,
                exposed_latency: 250.0,
                bandwidth: 100.0,
                idle: 38.0,
                exact: true,
                bound: 900.0,
                bound_ok: true,
                path_messages: 6,
            }),
        };
        let line = explained.to_json();
        for needle in [
            "\"exposed_latency\": 250",
            "\"exact\": true",
            "\"bound_ok\": true",
            "\"path_messages\": 6",
        ] {
            assert!(line.contains(needle), "{line}");
        }
        assert!(parse_flat_object(&line).is_ok(), "{line}");
        assert_eq!(Op::parse("explain").unwrap(), Op::Explain);
        assert_eq!(Op::Explain.tag(), "explain");

        let over = Response {
            id: "b".into(),
            latency_ms: 0.1,
            result: Err(RequestError::Overloaded("64 in flight".into())),
        };
        assert!(over.to_json().contains("\"status\": \"overloaded\""));
        let failed = Response {
            id: "c".into(),
            latency_ms: 0.1,
            result: Err(RequestError::Failed("bad workload".into())),
        };
        assert!(failed.to_json().contains("\"status\": \"error\""));
    }

    #[test]
    fn deadline_priority_and_drain_render_and_parse() {
        let expired = Response {
            id: "dl".into(),
            latency_ms: 0.1,
            result: Err(RequestError::Deadline("deadline of 5ms expired".into())),
        };
        let line = expired.to_json();
        assert!(line.contains("\"status\": \"deadline\""), "{line}");
        assert!(parse_flat_object(&line).is_ok(), "{line}");

        let drained = Response {
            id: "dr".into(),
            latency_ms: 2.0,
            result: Ok(Payload::Drain {
                in_flight_waited: 3,
                shards_flushed: 2,
                accepting: false,
            }),
        };
        let line = drained.to_json();
        for needle in
            ["\"status\": \"ok\"", "\"in_flight_waited\": 3", "\"accepting\": false"]
        {
            assert!(line.contains(needle), "{line}");
        }
        assert!(parse_flat_object(&line).is_ok(), "{line}");
        assert_eq!(Op::parse("drain").unwrap(), Op::Drain);
        assert_eq!(Op::Drain.tag(), "drain");

        assert_eq!(Priority::parse("").unwrap(), Priority::Normal);
        assert_eq!(Priority::parse("low").unwrap(), Priority::Low);
        assert_eq!(Priority::parse("high").unwrap(), Priority::High);
        assert!(Priority::parse("urgent").is_err());
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
    }
}
